"""Length-bucketed padded plans (the collapsed executable grid).

Parity contract: the masked padded-length forward must reproduce the
exact-length forward.  For plans whose pre-restoration schedule is
window-attention only (beta == 1) the two are BIT-identical — window
attention is window-local, pack/restore are pure data movement, and pad
windows are routed to the sentinel.  For beta >= 2 a pre-restoration
GLOBAL block reduces over the padded key count, so across the two
(different-shape) executables results agree to ULP only — the repo's
usual cross-executable contract (see test_serving_hotpath's docstring);
pad keys contribute exactly zero probability either way.  Within ONE
executable (wave vs solo at the same length/batch bucket) everything is
bit-exact, including waves that mix different n_low values.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import mixed_res as mr
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import LOW, REUSE, RegionPlan
from repro.models import registry
from repro.offload.simulator import ServerModel

SIZE = SIM.vit.img_size[0]


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    return params, vb.vit_partition(SIM)


def _frames(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 1, (n, SIZE, SIZE, 3)).astype(np.float32)


def _mask(part, lows):
    m = np.zeros(part.n_regions, np.int32)
    m[list(lows)] = 1
    return m


def _random_plan(part, rng, with_reuse=True):
    n_low = int(rng.integers(0, part.n_regions - 5))
    n_reuse = int(rng.integers(0, 5)) if with_reuse else 0
    ids = rng.permutation(part.n_regions)
    states = np.zeros((part.n_regions,), np.int8)
    states[ids[:n_low]] = LOW
    states[ids[n_low:n_low + n_reuse]] = REUSE
    if n_low == 0 and n_reuse == 0:
        states[ids[0]] = LOW
    return RegionPlan(states)


# ---------------------------------------------------------------------------
# length buckets + plan layouts (host-side)


def test_length_bucket_set_and_rounding(setup):
    _, part = setup
    edges = pt.length_bucket_set(part)
    assert edges == (24, 48, 64)        # SIM: 64 full-res windows
    assert edges[-1] == part.n_regions * part.windows_per_full_region
    assert all(e % part.windows_per_full_region == 0 for e in edges)
    assert pt.length_bucket(1, edges) == 24
    assert pt.length_bucket(24, edges) == 24
    assert pt.length_bucket(25, edges) == 48
    assert pt.length_bucket(64, edges) == 64
    with pytest.raises(ValueError):
        pt.length_bucket(65, edges)
    with pytest.raises(AssertionError):
        pt.length_bucket(0, edges)


def test_plan_layout_structure(setup):
    _, part = setup
    nR, dd = part.n_regions, part.windows_per_full_region
    states = np.zeros((nR,), np.int8)
    states[[3, 7]] = LOW
    states[[5]] = REUSE
    lay = pt.plan_layout(states, 64, part)
    assert (lay.nw, lay.n_low, lay.n_reuse) == ((nR - 3) * dd + 2, 2, 1)
    # full windows carry matching src/dst slots, in region order
    full = [r for r in range(nR) if states[r] == 0]
    want = [r * dd + k for r in full for k in range(dd)]
    assert lay.win_src[:len(want)].tolist() == want
    assert lay.win_dst[:len(want)].tolist() == want
    # the two LOW windows follow, gathered from the low half of the bank
    assert lay.win_src[len(want):lay.nw].tolist() == [nR * dd + 3,
                                                      nR * dd + 7]
    assert lay.low_src[:2].tolist() == [len(want), len(want) + 1]
    assert lay.low_ids[:2].tolist() == [3, 7]
    # pads: sentinel destinations, replicated window-0 sources
    assert np.all(lay.win_dst[lay.nw:] == nR * dd)
    assert np.all(lay.win_src[lay.nw:] == lay.win_src[0])
    assert np.all(lay.low_ids[2:] == nR)
    assert lay.reuse_ids[:1].tolist() == [5]
    assert np.all(lay.reuse_ids[1:] == nR)
    # the fingerprint is precomputed once (O(1) pos-cache keys)
    assert isinstance(lay.key, bytes) and len(lay.key) > 0
    other = states.copy()
    other[4] = LOW                            # different plan, same bucket
    assert lay.key != pt.plan_layout(other, 64, part).key
    with pytest.raises(ValueError):
        pt.plan_layout(states, 48, part)      # 54 windows don't fit 48


def test_pack_restore_padded_match_exact_bitwise(setup):
    """pack_padded / restore_padded are pure data movement: on the same
    values they are BIT-identical to the exact-shape pack/restore."""
    _, part = setup
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, part.grid_h, part.grid_w, 8))
                    .astype(np.float32))
    states = np.zeros((part.n_regions,), np.int8)
    states[[1, 6, 9]] = LOW
    states[[2, 12]] = REUSE
    plan = RegionPlan(states)
    fi, li, ri = pt.plan_to_region_ids(states, 3, 2)
    tiles = jnp.asarray(rng.normal(
        size=(2, 2, part.windows_per_full_region, part.tokens_low_region,
              8)).astype(np.float32))

    exact_tok, _ = mr.pack_mixed(x, part, jnp.asarray(fi), jnp.asarray(li))
    lay = pt.plan_layout(states, 64, part)
    pad_tok = mr.pack_padded(x, part, jnp.asarray(lay.win_src))
    n_tok = part.n_tokens(3, 2)
    np.testing.assert_array_equal(np.asarray(pad_tok[:, :n_tok]),
                                  np.asarray(exact_tok))

    exact_res = mr.restore_full(exact_tok, part, jnp.asarray(fi),
                                jnp.asarray(li), reuse_ids=jnp.asarray(ri),
                                reuse_tiles=tiles)
    tiles_pad = jnp.zeros((2, part.n_regions,
                           part.windows_per_full_region,
                           part.tokens_low_region, 8), jnp.float32)
    tiles_pad = tiles_pad.at[:, :2].set(tiles)
    pad_res = mr.restore_padded(pad_tok, part, jnp.asarray(lay.win_dst),
                                jnp.asarray(lay.low_src),
                                jnp.asarray(lay.low_ids),
                                reuse_ids=jnp.asarray(lay.reuse_ids),
                                reuse_tiles=tiles_pad)
    np.testing.assert_array_equal(np.asarray(pad_res),
                                  np.asarray(exact_res))


# ---------------------------------------------------------------------------
# forward parity: padded-length vs exact-length


def _forward_pair(params, part, img, plan, beta, rng):
    fi, li, ri = pt.plan_to_region_ids(plan.states, plan.n_low,
                                       plan.n_reuse)
    tiles = rng.normal(size=(1, plan.n_reuse,
                             part.windows_per_full_region,
                             part.tokens_low_region, SIM.d_model)) \
        .astype(np.float32)
    kw = {}
    if plan.n_reuse:
        kw = dict(reuse_ids=jnp.asarray(ri), reuse_tiles=jnp.asarray(tiles))
    exact = vb.forward_features(SIM, params, img, jnp.asarray(fi),
                                jnp.asarray(li), beta, **kw)

    lb = pt.length_bucket(pt.plan_n_windows(plan, part),
                          pt.length_bucket_set(part))
    lay = pt.plan_layout(plan.states, lb, part)
    layout = {k: jnp.asarray(getattr(lay, k))
              for k in ("win_src", "win_dst", "low_src", "low_ids",
                        "reuse_ids")}
    layout["nw"] = jnp.asarray([lay.nw], jnp.int32)
    tiles_pad = np.zeros((1, part.n_regions,
                          part.windows_per_full_region,
                          part.tokens_low_region, SIM.d_model), np.float32)
    if plan.n_reuse:
        tiles_pad[0, :plan.n_reuse] = tiles[0]
    padded = vb.forward_features(
        SIM, params, img, beta=beta, layout=layout,
        reuse_tiles=jnp.asarray(tiles_pad) if plan.n_reuse else None)
    return np.asarray(exact), np.asarray(padded)


def test_padded_forward_bit_identical_at_beta1(setup):
    """Randomized plans, beta=1 (window-only pre-restoration schedule):
    the padded-length forward is BIT-identical to the exact-length one."""
    params, part = setup
    img = jnp.asarray(_frames(1, seed=7))
    for trial in range(4):
        rng = np.random.default_rng(20 + trial)
        plan = _random_plan(part, rng)
        exact, padded = _forward_pair(params, part, img, plan, 1, rng)
        np.testing.assert_array_equal(exact, padded)


def test_padded_beta0_restores_at_input(setup):
    """beta=0 with a mask (the paper's "Subset 0" restore-at-input
    case, driven by examples/quickstart.py) serves through the padded
    grid and is BIT-identical to the exact-length forward — restoration
    happens before any block, so no cross-shape attention runs."""
    params, part = setup
    img = jnp.asarray(_frames(1, seed=11))
    states = np.zeros((part.n_regions,), np.int8)
    states[[0, 3, 11]] = LOW
    fi, li, _ = pt.plan_to_region_ids(states, 3, 0)
    exact = vb.forward_features(SIM, params, img, jnp.asarray(fi),
                                jnp.asarray(li), 0)
    lay = pt.plan_layout(states, 64, part)
    layout = {k: jnp.asarray(getattr(lay, k))
              for k in ("win_src", "win_dst", "low_src", "low_ids",
                        "reuse_ids")}
    layout["nw"] = jnp.asarray([lay.nw], jnp.int32)
    padded = vb.forward_features(SIM, params, img, beta=0, layout=layout)
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(padded))
    # and through the serving entry point
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    dets = server.infer(_frames(1, seed=11)[0], _mask(part, (0, 3, 11)),
                        beta=0)
    assert isinstance(dets, list)
    assert list(server._fns) == [(64, 0, 0, 1)]


def test_padded_forward_matches_exact_all_betas(setup):
    """Randomized (n_low, n_reuse, beta) plans: padded vs exact forward.
    beta >= 2 crosses two executables with different global-attention
    key counts, so the match is ULP-level (see module docstring)."""
    params, part = setup
    img = jnp.asarray(_frames(1, seed=8))
    for trial in range(6):
        rng = np.random.default_rng(40 + trial)
        plan = _random_plan(part, rng)
        beta = int(rng.integers(2, SIM.vit.n_subsets + 1))
        exact, padded = _forward_pair(params, part, img, plan, beta, rng)
        np.testing.assert_allclose(exact, padded, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# the satellite wave test: three different n_low values, ONE executable


def test_wave_mixing_three_n_low_values_matches_solo(setup):
    """A wave whose three samples have three DIFFERENT n_low values runs
    in one executable and matches each plan's solo run bit-identically
    (solo pinned to the same (length bucket, B bucket) executable)."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                         b_buckets=(4,))
    frames = _frames(3, seed=9)
    plans = [RegionPlan.from_mask(_mask(part, range(n)))
             for n in (2, 5, 9)]                  # 58/49/37 w -> lb 64
    wave = server.infer_wave(frames, plans, beta=2)
    assert server.stats.compiles == 1
    assert list(server._fns) == [(64, 2, 2, 4)]
    for i, plan in enumerate(plans):
        solo = server.infer_wave(frames[i][None], [plan], beta=2,
                                 lb_override=64)[0]
        assert wave[i] == solo            # dict floats compare bitwise
    assert server.stats.compiles == 1     # still the one executable


def test_wave_mixing_n_low_close_to_natural_solo(setup):
    """The same mixed wave vs each plan's NATURAL solo run (own length
    bucket, own B bucket): agreement to the usual tolerances."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    frames = _frames(3, seed=10)
    plans = [RegionPlan.from_mask(_mask(part, range(n)))
             for n in (2, 5, 9)]
    wave = server.infer_wave(frames, plans, beta=2)
    for i, plan in enumerate(plans):
        solo = server.infer_wave(frames[i][None], [plan], beta=2)[0]
        assert len(wave[i]) == len(solo)
        a = np.array([d["box"] for d in wave[i]], np.float64)
        b = np.array([d["box"] for d in solo], np.float64)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=0.1)


# ---------------------------------------------------------------------------
# packed-positions cache: O(1) keys from precomputed fingerprints


def test_packed_positions_padded_hits_with_ids_key(setup):
    _, part = setup
    pos = jnp.asarray(np.random.default_rng(0).normal(
        size=(part.grid_h, part.grid_w, 8)).astype(np.float32))
    states = np.zeros((part.n_regions,), np.int8)
    states[:2] = LOW
    lay = pt.plan_layout(states, 64, part)
    saved = dict(vb._POS_CACHE)
    vb._POS_CACHE.clear()
    try:
        a = vb.packed_positions(pos, part, None, None,
                                win_src=jnp.asarray(lay.win_src),
                                ids_key=lay.key)
        # a FRESH equal-content array hits through the precomputed key
        b = vb.packed_positions(pos, part, None, None,
                                win_src=jnp.asarray(lay.win_src.copy()),
                                ids_key=lay.key)
        assert a is b
        assert len(vb._POS_CACHE) == 1
        # the legacy no-key fallback is self-consistent (content hash)
        c = vb.packed_positions(pos, part, None, None,
                                win_src=jnp.asarray(lay.win_src))
        d = vb.packed_positions(pos, part, None, None,
                                win_src=jnp.asarray(lay.win_src.copy()))
        assert d is c
        np.testing.assert_array_equal(np.asarray(c), np.asarray(a))
        np.testing.assert_array_equal(
            np.asarray(a),
            np.asarray(mr.pack_positions_padded(pos, part,
                                                jnp.asarray(lay.win_src))))
    finally:
        vb._POS_CACHE.clear()
        vb._POS_CACHE.update(saved)


def test_backbone_flops_padded_length(setup):
    """backbone_flops with length_edges costs the padded bucket: a
    monotone step function of the plan, >= the exact-length cost."""
    _, part = setup
    edges = pt.length_bucket_set(part)
    exact = [vb.backbone_flops(SIM, n, 2) for n in range(17)]
    padded = [vb.backbone_flops(SIM, n, 2, length_edges=edges)
              for n in range(17)]
    assert all(p >= e for p, e in zip(padded, exact))
    # n_low 6..13 share the 48-window bucket -> identical padded cost
    assert len({padded[n] for n in range(6, 14)}) == 1
    # full res is never padded
    assert padded[0] == exact[0]
    assert vb.backbone_flops_windows(SIM, 64, 2) == \
        vb.backbone_flops(SIM, 0, 0)
