"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
ref.py pure-jnp oracle (kernels run in interpret=True mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mixed_res_pool.ops import avg_pool_2d, nn_upsample_2d
from repro.kernels.mixed_res_pool.ref import (avg_pool_2d_ref,
                                              nn_upsample_2d_ref)
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.window_attention.ops import window_attention
from repro.kernels.window_attention.ref import window_attention_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _check(out, ref, dtype):
    assert out.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(ref, jnp.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("shape", [
    (2, 256, 256, 4, 2, 64),      # GQA, block-aligned
    (1, 300, 300, 8, 8, 64),      # MHA, ragged T (padding path)
    (2, 128, 384, 4, 1, 32),      # MQA, cross lengths
    (1, 100, 260, 6, 2, 128),     # ragged both, Dh = 128
])
def test_flash_attention(shape, causal, dtype):
    B, T, S, H, KV, Dh = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 3)
    q = _rand(ks[0], (B, T, H, Dh), dtype)
    k = _rand(ks[1], (B, S, KV, Dh), dtype)
    v = _rand(ks[2], (B, S, KV, Dh), dtype)
    out = flash_attention(q, k, v, causal=causal)
    ref = flash_attention_ref(q, k, v, causal=causal)
    _check(out, ref, dtype)


def test_flash_attention_matches_model_sdpa():
    """The kernel and the model's XLA sdpa must agree (same semantics)."""
    from repro.models.attention import sdpa
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (2, 256, 8, 64), jnp.float32)
    k = _rand(ks[1], (2, 256, 2, 64), jnp.float32)
    v = _rand(ks[2], (2, 256, 2, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, causal=True)),
        np.asarray(sdpa(q, k, v, causal=True)), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# window attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (2, 4, 64, 4, 4, 64),     # w = 8 ViTDet window, MHA
    (1, 9, 81, 8, 8, 32),     # w = 9 (the paper's fine-tuned window), pads
    (2, 3, 49, 4, 2, 64),     # GQA + ragged window count
    (1, 16, 64, 16, 16, 64),  # ViTDet-L head count
])
def test_window_attention(shape, dtype):
    B, W, win, H, KV, Dh = shape
    T = W * win
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 3)
    q = _rand(ks[0], (B, T, H, Dh), dtype)
    k = _rand(ks[1], (B, T, KV, Dh), dtype)
    v = _rand(ks[2], (B, T, KV, Dh), dtype)
    out = window_attention(q, k, v, win)
    ref = window_attention_ref(q, k, v, win)
    _check(out, ref, dtype)


@pytest.mark.parametrize("valid", [0, 3, 8])
def test_window_attention_win_valid_boundaries(valid):
    """Pad-window zeroing at the boundaries: no valid windows, a count
    that ends mid-tile (wb does not divide it), and all windows valid —
    parity vs the masked XLA oracle."""
    B, W, win, H, Dh = 2, 8, 16, 4, 32
    T = W * win
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    q = _rand(ks[0], (B, T, H, Dh), jnp.float32)
    k = _rand(ks[1], (B, T, H, Dh), jnp.float32)
    v = _rand(ks[2], (B, T, H, Dh), jnp.float32)
    wv = jnp.asarray([valid, max(valid - 1, 0)], jnp.int32)
    out = window_attention(q, k, v, win, win_valid=wv, wb=4)
    ref = window_attention_ref(q, k, v, win)
    keep = (jnp.arange(W)[None, :] < wv[:, None]).astype(ref.dtype)
    ref = ref * jnp.repeat(keep, win, axis=1)[:, :, None, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # pad windows emit exact zeros
    np.testing.assert_array_equal(
        np.asarray(out.reshape(B, W, win, H, Dh)[0, valid:]), 0.0)


def test_window_attention_matches_model_window_sdpa():
    from repro.models.attention import window_sdpa
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (2, 256, 8, 64), jnp.float32)
    k = _rand(ks[1], (2, 256, 8, 64), jnp.float32)
    v = _rand(ks[2], (2, 256, 8, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(window_attention(q, k, v, 64)),
        np.asarray(window_sdpa(q, k, v, 64)), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (2, 1024, 8, 2, 64),
    (4, 777, 32, 8, 128),     # ragged cache length
    (1, 4096, 4, 4, 64),      # MHA (G = 1 -> pads group rows)
    (2, 300, 16, 1, 32),      # MQA
])
def test_decode_attention(shape, dtype):
    B, S, H, KV, Dh = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 4)
    q = _rand(ks[0], (B, 1, H, Dh), dtype)
    k = _rand(ks[1], (B, S, KV, Dh), dtype)
    v = _rand(ks[2], (B, S, KV, Dh), dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, k, v, kv_len)
    ref = decode_attention_ref(q, k, v, kv_len)
    _check(out, ref, dtype)


@pytest.mark.parametrize("kv_len_val", [1, 511, 512])
def test_decode_attention_kv_len_edges(kv_len_val):
    """Edge lengths: single valid key, one short of a block, full cache."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, S, H, KV, Dh = 2, 512, 8, 4, 64
    q = _rand(ks[0], (B, 1, H, Dh), jnp.float32)
    k = _rand(ks[1], (B, S, KV, Dh), jnp.float32)
    v = _rand(ks[2], (B, S, KV, Dh), jnp.float32)
    kv_len = jnp.full((B,), kv_len_val, jnp.int32)
    out = decode_attention(q, k, v, kv_len)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("G", [1, 2, 4])
def test_decode_attention_gqa_groups(G):
    """GQA group sizes 1/2/4 with a cache length that is NOT a multiple
    of the kv block (S = 300, bs = 128 -> ragged final block)."""
    B, S, KV, Dh = 2, 300, 4, 64
    H = KV * G
    ks = jax.random.split(jax.random.PRNGKey(31 + G), 4)
    q = _rand(ks[0], (B, 1, H, Dh), jnp.float32)
    k = _rand(ks[1], (B, S, KV, Dh), jnp.float32)
    v = _rand(ks[2], (B, S, KV, Dh), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, k, v, kv_len, bs=128)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_model_sdpa():
    from repro.models.attention import sdpa
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, S, H, KV, Dh = 3, 640, 16, 4, 64
    q = _rand(ks[0], (B, 1, H, Dh), jnp.float32)
    k = _rand(ks[1], (B, S, KV, Dh), jnp.float32)
    v = _rand(ks[2], (B, S, KV, Dh), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    np.testing.assert_allclose(
        np.asarray(decode_attention(q, k, v, kv_len)),
        np.asarray(sdpa(q, k, v, kv_len=kv_len)), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan


def _ssd_inputs(key, b, T, H, G, N, P, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = _rand(ks[0], (b, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    Bm = (_rand(ks[3], (b, T, G, N), dtype) * 0.3).astype(dtype)
    Cm = (_rand(ks[4], (b, T, G, N), dtype) * 0.3).astype(dtype)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("shape", [
    (2, 128, 8, 1, 32, 16, 32),
    (1, 200, 16, 2, 64, 32, 64),   # ragged T (chunk padding)
    (2, 64, 4, 4, 16, 64, 32),     # one head per group
    (1, 96, 8, 1, 128, 64, 96),    # full-size state dims, single chunk
])
def test_ssd_scan(shape):
    b, T, H, G, N, P, chunk = shape
    x, dt, A, Bm, Cm = _ssd_inputs(
        jax.random.PRNGKey(hash(shape) % 2**31), b, T, H, G, N, P)
    y, s_fin = ssd(x, dt, A, Bm, Cm, chunk, return_final_state=True)
    y_ref, s_ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_bf16_inputs():
    b, T, H, G, N, P, chunk = 2, 128, 8, 1, 32, 16, 64
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(0), b, T, H, G, N, P,
                                   jnp.bfloat16)
    y = ssd(x, dt, A, Bm, Cm, chunk)
    y_ref, _ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-2, atol=3e-2)


def test_ssd_state_handoff_chains():
    """Scanning two halves with state handoff == scanning the whole —
    the invariant the sequence-parallel sharding relies on."""
    b, T, H, G, N, P, chunk = 1, 128, 4, 1, 16, 16, 32
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(1), b, T, H, G, N, P)
    y_full, s_full = ssd(x, dt, A, Bm, Cm, chunk, return_final_state=True)
    h = T // 2
    y1, s1 = ssd(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], chunk,
                 return_final_state=True)
    y2, s2 = ssd(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:], chunk,
                 init_state=s1, return_final_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


def test_mamba2_forward_kernel_flag_matches():
    """mamba2_forward(use_kernel=True) must equal the jnp path."""
    from repro.configs import get_reduced
    from repro.models import mamba2
    cfg = get_reduced("mamba2-370m")
    params = mamba2.init_mamba2(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = _rand(jax.random.PRNGKey(1), (2, 64, cfg.d_model), jnp.float32)
    out_jnp = mamba2.mamba2_forward(cfg, params, x, use_kernel=False)
    out_krn = mamba2.mamba2_forward(cfg, params, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_krn), np.asarray(out_jnp),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mixed-res pool


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (2, 32, 32, 64, 2),
    (1, 48, 48, 100, 4),      # non-128 channels (padding path)
    (2, 16, 24, 128, 2),      # rectangular
    (1, 8, 8, 3, 2),          # RGB pixels
])
def test_avg_pool(shape, dtype):
    B, H, W, C, d = shape
    x = _rand(jax.random.PRNGKey(hash(shape) % 2**31), (B, H, W, C), dtype)
    _check(avg_pool_2d(x, d), avg_pool_2d_ref(x, d), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (2, 16, 16, 64, 2), (1, 12, 12, 100, 4), (1, 4, 6, 3, 2),
])
def test_nn_upsample(shape, dtype):
    B, H, W, C, d = shape
    x = _rand(jax.random.PRNGKey(hash(shape) % 2**31), (B, H, W, C), dtype)
    out = nn_upsample_2d(x, d)
    ref = nn_upsample_2d_ref(x, d)
    assert out.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_pool_matches_mixed_res_downsample():
    """The kernel is a drop-in for core.mixed_res.downsample_grid."""
    from repro.core.mixed_res import downsample_grid
    x = _rand(jax.random.PRNGKey(2), (2, 32, 32, 48), jnp.float32)
    np.testing.assert_allclose(np.asarray(avg_pool_2d(x, 2)),
                               np.asarray(downsample_grid(x, 2)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# custom VJPs: the Pallas entry points are differentiable, and their
# gradients match jax.grad through the pure-XLA oracles (the contract
# that lets dispatch route training graphs to the Pallas lane)

GRAD_TOL = dict(rtol=2e-4, atol=2e-4)


def _grad_check(f_pallas, f_ref, args):
    def loss(f):
        return lambda *a: jnp.sum(jnp.sin(f(*a)))
    g_pal = jax.grad(loss(f_pallas), argnums=tuple(range(len(args))))(*args)
    g_ref = jax.grad(loss(f_ref), argnums=tuple(range(len(args))))(*args)
    for a, b in zip(g_pal, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **GRAD_TOL)


@pytest.mark.parametrize("causal", [False, True])
def test_flash_attention_vjp_matches_xla(causal):
    ks = jax.random.split(jax.random.PRNGKey(41), 3)
    q = _rand(ks[0], (2, 48, 8, 32), jnp.float32)
    k = _rand(ks[1], (2, 48, 2, 32), jnp.float32)
    v = _rand(ks[2], (2, 48, 2, 32), jnp.float32)
    _grad_check(lambda q, k, v: flash_attention(q, k, v, causal=causal),
                lambda q, k, v: flash_attention_ref(q, k, v, causal=causal),
                (q, k, v))


def test_window_attention_vjp_matches_xla():
    ks = jax.random.split(jax.random.PRNGKey(43), 3)
    win, W = 16, 4
    q = _rand(ks[0], (2, W * win, 4, 32), jnp.float32)
    k = _rand(ks[1], (2, W * win, 2, 32), jnp.float32)
    v = _rand(ks[2], (2, W * win, 2, 32), jnp.float32)
    _grad_check(lambda q, k, v: window_attention(q, k, v, win),
                lambda q, k, v: window_attention_ref(q, k, v, win),
                (q, k, v))


def test_window_attention_vjp_with_win_valid():
    """Gradients respect the pad-window mask: pad windows contribute
    zero cotangent everywhere."""
    ks = jax.random.split(jax.random.PRNGKey(47), 3)
    win, W, B = 16, 4, 2
    wv = jnp.asarray([3, 2], jnp.int32)
    q = _rand(ks[0], (B, W * win, 4, 32), jnp.float32)
    k = _rand(ks[1], (B, W * win, 4, 32), jnp.float32)
    v = _rand(ks[2], (B, W * win, 4, 32), jnp.float32)

    def ref(q, k, v):
        o = window_attention_ref(q, k, v, win)
        keep = (jnp.arange(W)[None, :] < wv[:, None]).astype(o.dtype)
        return o * jnp.repeat(keep, win, axis=1)[:, :, None, None]

    _grad_check(lambda q, k, v: window_attention(q, k, v, win,
                                                 win_valid=wv),
                ref, (q, k, v))


def test_pool_vjps_match_xla():
    x = _rand(jax.random.PRNGKey(53), (2, 16, 16, 8), jnp.float32)
    _grad_check(lambda x: avg_pool_2d(x, 2),
                lambda x: avg_pool_2d_ref(x, 2), (x,))
    _grad_check(lambda x: nn_upsample_2d(x, 2),
                lambda x: nn_upsample_2d_ref(x, 2), (x,))


def test_vjp_survives_jit():
    """jax.jit around a custom-VJP entry keeps the analytic backward
    (the launch/train.py path: grads through a jitted training step)."""
    ks = jax.random.split(jax.random.PRNGKey(59), 3)
    q = _rand(ks[0], (1, 32, 4, 16), jnp.float32)
    k = _rand(ks[1], (1, 32, 4, 16), jnp.float32)
    v = _rand(ks[2], (1, 32, 4, 16), jnp.float32)

    @jax.jit
    def step(q, k, v):
        return jax.grad(
            lambda q: jnp.sum(flash_attention(q, k, v, causal=True)))(q)

    ref = jax.grad(
        lambda q: jnp.sum(flash_attention_ref(q, k, v, causal=True)))(q)
    np.testing.assert_allclose(np.asarray(step(q, k, v)), np.asarray(ref),
                               **GRAD_TOL)
