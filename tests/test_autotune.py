"""Block-size autotuner (kernels.autotune): cache machinery, disk
roundtrip, the REPRO_AUTOTUNE=0 escape hatch, and the ops.py default
fallback.  Sweeps run with ``force=True`` (interpret-mode timings are
meaningless but exercise the full machinery)."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels.window_attention import kernel as wk


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path))
    autotune.clear_memory_cache()
    yield tmp_path
    autotune.clear_memory_cache()


def test_bucket_key_rounds_to_pow2():
    assert autotune.bucket_key(b=3, h=8) == "b=4,h=8"
    assert autotune.bucket_key(t=100, dt="float32") == "dt=float32,t=128"
    # stable ordering regardless of kwarg order
    assert autotune.bucket_key(b=2, a=1) == autotune.bucket_key(a=1, b=2)


def test_block_falls_back_to_default(tmp_cache):
    out = autotune.block("window_attention", "b=1",
                         {"wb": wk.DEFAULT_WB})
    assert out == {"wb": wk.DEFAULT_WB}


def test_tune_records_and_persists(tmp_cache):
    calls = []

    def bench(params):
        calls.append(params["x"])
        # deterministic fake kernel: candidate 2 is "fastest" only in
        # the sense that all run; min-of-reps picks whichever, we just
        # assert a winner lands in the cache
        return lambda: jnp.zeros((1,))

    won = autotune.tune("fake_kernel", "b=1",
                        ({"x": 1}, {"x": 2}), bench, force=True, reps=1)
    assert won in ({"x": 1}, {"x": 2})
    assert sorted(calls) == [1, 2]
    # in-memory hit
    assert autotune.lookup("fake_kernel", "b=1") == won
    # disk roundtrip: a fresh process (cleared memory) reloads it
    autotune.clear_memory_cache()
    assert autotune.lookup("fake_kernel", "b=1") == won
    data = json.loads(autotune.cache_path().read_text())
    assert data["fake_kernel"]["b=1"]["params"] == won
    # a second tune call is a cache hit: bench never runs again
    calls.clear()
    assert autotune.tune("fake_kernel", "b=1", ({"x": 1}, {"x": 2}),
                         bench, force=True) == won
    assert calls == []


def test_tune_skips_invalid_and_failing_candidates(tmp_cache):
    def bench(params):
        if params["x"] == 1:
            return None                      # invalid for the shape
        if params["x"] == 2:
            def boom():
                raise RuntimeError("lowering failed")
            return boom
        return lambda: jnp.zeros((1,))

    won = autotune.tune("fake2", "b=1", ({"x": 1}, {"x": 2}, {"x": 3}),
                        bench, force=True, reps=1)
    assert won == {"x": 3}
    # all candidates invalid -> no winner, nothing cached
    assert autotune.tune("fake3", "b=1", ({"x": 1},), bench,
                         force=True) is None
    assert autotune.lookup("fake3", "b=1") is None


def test_autotune_disabled_env(tmp_cache, monkeypatch):
    """REPRO_AUTOTUNE is hoisted out of the hot path (read once at
    import, same convention as kernels.dispatch), so monkeypatching the
    env must be followed by refresh_from_env()."""
    autotune.record("fake4", "b=1", {"x": 9}, 1.0)
    monkeypatch.setenv(autotune.ENV_VAR, "0")
    assert autotune.enabled(), "cached: env flip alone must NOT apply"
    autotune.refresh_from_env()
    try:
        assert not autotune.enabled()
        # disabled: lookups miss (defaults win) and sweeps are no-ops
        assert autotune.lookup("fake4", "b=1") is None
        assert autotune.block("fake4", "b=1", {"x": 0}) == {"x": 0}
        assert autotune.tune("fake4", "b=2", ({"x": 1},),
                             lambda p: (lambda: jnp.zeros((1,))),
                             force=True) is None
    finally:
        monkeypatch.delenv(autotune.ENV_VAR)
        autotune.refresh_from_env()
    assert autotune.enabled()


def test_tune_window_end_to_end(tmp_cache):
    """The real window-attention sweep under force: records a winner the
    ops.py wb=None path then resolves (and the kernel still validates)."""
    won = autotune.tune_window(1, 64, 2, 16, 16, force=True)
    assert won is not None and "wb" in won
    bucket = autotune.window_bucket(1, 64, 2, 16, 16, jnp.float32)
    assert autotune.block("window_attention", bucket,
                          {"wb": wk.DEFAULT_WB})["wb"] == won["wb"]
    # shape-bucketed: a different shape misses and falls back
    other = autotune.window_bucket(4, 2048, 16, 64, 64, jnp.float32)
    assert autotune.block("window_attention", other,
                          {"wb": wk.DEFAULT_WB})["wb"] == wk.DEFAULT_WB
