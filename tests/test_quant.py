"""Quantized & head-pruned serving lane (repro.quant).

Contracts pinned here:

  * weight quantization roundtrips within the per-channel step size and
    the int8 Pallas kernel (interpret mode) is BIT-exact against the
    dot_general reference at padded and exact-tile shapes;
  * the quant-aware matmul's native int8 lane tracks the dequant float
    oracle, and mode precedence (env > arg > process > native) mirrors
    the backend machinery;
  * the int8 backbone forward stays within a stated atol of fp32 at
    every beta, through jit, on padded and exact layouts, with
    win_valid masking — and parameter bytes shrink >= 3.5x;
  * a head-pruned forward is EXACTLY the dense forward with the dropped
    heads' w_o rows zeroed (per-head additivity of attention);
  * autotune buckets separate by operand dtype, so int8/fp16 sweeps
    never reuse fp32 winners;
  * ServerModel(quant=...) compiles the SAME executable grid as fp32
    (no new keys) and serves with zero steady-state compiles.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import vit_backbone as vb
from repro.core.partition import RegionPlan
from repro.kernels import autotune, dispatch
from repro.kernels.int8_matmul import ops as mm_ops
from repro.kernels.int8_matmul import ref as mm_ref
from repro.models import registry
from repro.quant import (QuantSpec, QuantTensor, calibrate, prune, ptq,
                         qtensor as qt)

SIZE = SIM.vit.img_size[0]


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    return SIM, params, vb.vit_partition(SIM)


def _img(seed=0, n=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, (n, SIZE, SIZE, 3))
                       .astype(np.float32))


# ---------------------------------------------------------------------------
# QuantTensor + weight quantization


def test_quantize_weight_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((64, 48)).astype(np.float32))
    q = qt.quantize_weight(w)
    assert isinstance(q, QuantTensor)
    assert q.q.dtype == jnp.int8 and q.scale.shape == (48,)
    # error bounded by half a per-channel quantization step
    step = np.asarray(q.scale)
    err = np.abs(np.asarray(q.dequant()) - np.asarray(w))
    assert (err <= 0.5 * step[None, :] + 1e-7).all()
    # ~4x smaller than fp32
    assert q.nbytes < w.nbytes / 3.5


def test_quant_tensor_is_a_pytree():
    q = qt.quantize_weight(jnp.ones((8, 4)), out_dtype=jnp.float16)
    leaves, treedef = jax.tree_util.tree_flatten(q)
    assert len(leaves) == 2
    q2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert q2.out_dtype == "float16"
    # out_dtype is static aux: jit recompiles on it, not on the arrays
    out = jax.jit(lambda t: t.dequant())(q)
    assert out.dtype == jnp.float16


def test_concat_out_matches_separate_quantization():
    rng = np.random.default_rng(1)
    ws = [jnp.asarray(rng.standard_normal((32, n)).astype(np.float32))
          for n in (16, 8, 8)]
    fused = qt.concat_out([qt.quantize_weight(w) for w in ws])
    ref = jnp.concatenate([qt.quantize_weight(w).dequant() for w in ws],
                          axis=1)
    np.testing.assert_array_equal(np.asarray(fused.dequant()),
                                  np.asarray(ref))
    with pytest.raises(AssertionError):
        qt.concat_out([qt.quantize_weight(ws[0]), ws[1]])


def test_stacked_quantization_survives_scan_slicing():
    """Scan-stacked (L, K, N) weights carry per-layer scales shaped so
    lax.scan slices the QuantTensor children layer-by-layer."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((3, 16, 8)).astype(np.float32))
    q = qt.quantize_weight(w, stacked=True)
    assert q.scale.shape == (3, 1, 8)
    x = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))

    def body(c, wl):
        return c, qt.matmul(x, wl, mode="dequant")

    _, outs = jax.lax.scan(body, None, q)
    for l in range(3):
        per_layer = qt.quantize_weight(w[l])
        np.testing.assert_allclose(np.asarray(outs[l]),
                                   np.asarray(x @ per_layer.dequant()),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# int8 GEMM kernel: Pallas (interpret) vs dot_general reference


@pytest.mark.parametrize("M,N,K", [(8, 16, 32),       # tiny, padded
                                   (128, 128, 128),   # exact tiles
                                   (100, 65, 130)])   # ragged, padded
def test_int8_kernel_bit_exact_vs_ref(M, N, K):
    rng = np.random.default_rng(3)
    xq = jnp.asarray(rng.integers(-127, 128, (M, K), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (K, N), dtype=np.int8))
    sx = jnp.asarray(rng.uniform(0.01, 1, M).astype(np.float32))
    sw = jnp.asarray(rng.uniform(0.01, 1, N).astype(np.float32))
    out = mm_ops.int8_matmul(xq, wq, sx, sw, interpret=True)
    ref = mm_ref.int8_matmul_ref(xq, wq, sx, sw)
    # integer accumulation + identical f32 epilogue: bit-exact
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_int8_kernel_out_dtype():
    rng = np.random.default_rng(4)
    xq = jnp.asarray(rng.integers(-127, 128, (8, 16), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (16, 8), dtype=np.int8))
    s = jnp.ones((8,), jnp.float32)
    out = mm_ops.int8_matmul(xq, wq, s, s, out_dtype=jnp.float16,
                             interpret=True)
    assert out.dtype == jnp.float16


def test_qt_matmul_native_tracks_dequant_oracle():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((6, 10, 64)).astype(np.float32))
    w = qt.quantize_weight(
        jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32)))
    native = qt.matmul(x, w, mode="native")
    oracle = qt.matmul(x, w, mode="dequant")
    assert native.shape == oracle.shape == (6, 10, 32)
    # native adds dynamic activation quantization on top of the weight
    # quantization: per-row step ~ max|x|/127, accumulated over K=64
    np.testing.assert_allclose(np.asarray(native), np.asarray(oracle),
                               atol=0.3)
    # plain float weights: exact passthrough
    wf = w.dequant()
    np.testing.assert_array_equal(np.asarray(qt.matmul(x, wf)),
                                  np.asarray(x @ wf))


def test_quant_mode_precedence(monkeypatch):
    """env REPRO_QUANT (cached) > per-call arg > set_quant_mode >
    native — and quant_scope restores on exit."""
    assert dispatch.resolve_quant() == "native"
    assert dispatch.resolve_quant("dequant") == "dequant"
    with dispatch.quant_scope("dequant"):
        assert dispatch.resolve_quant() == "dequant"
        assert dispatch.resolve_quant("native") == "native"
    assert dispatch.resolve_quant() == "native"
    monkeypatch.setenv(dispatch.QUANT_ENV_VAR, "dequant")
    assert dispatch.resolve_quant() == "native", "env is cached"
    dispatch.refresh_from_env()
    try:
        assert dispatch.resolve_quant() == "dequant"
        assert dispatch.resolve_quant("native") == "dequant", \
            "cached env overrides the per-call arg"
    finally:
        monkeypatch.delenv(dispatch.QUANT_ENV_VAR)
        dispatch.refresh_from_env()
    with pytest.raises(ValueError):
        dispatch.set_quant_mode("bogus")


# ---------------------------------------------------------------------------
# autotune: per-dtype bucket separation


def test_matmul_bucket_separates_dtypes(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path))
    autotune.clear_memory_cache()
    try:
        b_int8 = autotune.matmul_bucket(64, 64, 64, jnp.int8, jnp.int8)
        b_fp32 = autotune.matmul_bucket(64, 64, 64, jnp.float32,
                                        jnp.float32)
        assert b_int8 != b_fp32
        autotune.record("int8_matmul", b_int8, {"bm": 256}, 1.0)
        # an int8 winner never answers an fp32 lookup
        assert autotune.lookup("int8_matmul", b_fp32) is None
        assert autotune.lookup("int8_matmul", b_int8) == {"bm": 256}
        # window/flash buckets carry the activation dtype too
        assert autotune.window_bucket(1, 64, 4, 16, 4, jnp.float16) != \
            autotune.window_bucket(1, 64, 4, 16, 4, jnp.float32)
        assert autotune.flash_bucket(1, 64, 64, 4, 4, 16, False,
                                     jnp.float16) != \
            autotune.flash_bucket(1, 64, 64, 4, 4, 16, False, jnp.float32)
    finally:
        autotune.clear_memory_cache()


def test_tune_matmul_records_per_dtype(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path))
    autotune.clear_memory_cache()
    try:
        won = autotune.tune_matmul(32, 32, 64, force=True)
        assert won is not None and {"bm", "bn", "bk"} <= set(won)
        bucket = autotune.matmul_bucket(32, 32, 64, jnp.int8, jnp.int8)
        assert autotune.lookup("int8_matmul", bucket) == won
    finally:
        autotune.clear_memory_cache()


# ---------------------------------------------------------------------------
# compression: bytes, forward parity


def test_compress_ratio_and_bytes(setup):
    cfg, params, _ = setup
    _, cp, rep = ptq.compress(cfg, params, QuantSpec("int8", "fp32", 0))
    assert rep["ratio"] >= 3.5, rep
    assert qt.tree_bytes(cp) == rep["bytes"]
    _, cph, reph = ptq.compress(cfg, params, QuantSpec("int8", "fp16", 0))
    assert reph["bytes"] < rep["bytes"]     # half biases/norms/scales...
    _, _, rep16 = ptq.compress(cfg, params, QuantSpec("fp16", "fp16", 0))
    assert 1.9 <= rep16["ratio"] <= 2.1


@pytest.mark.parametrize("spec,atol", [
    (QuantSpec("int8", "fp32", 0), 0.25),
    (QuantSpec("int8", "fp16", 0), 0.25),
    (QuantSpec("fp16", "fp16", 0), 0.05),
])
def test_quantized_forward_parity_full_res(setup, spec, atol):
    cfg, params, _ = setup
    img = _img()
    ref = vb.forward_features(cfg, params, img)
    ccfg, cp, _ = ptq.compress(cfg, params, spec)
    out = vb.forward_features(ccfg, cp, img)
    assert out.dtype == spec.act_jnp
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref)))
    assert err <= atol, (spec.name, err)
    # through jit: same executable-facing contract
    jout = jax.jit(lambda p, i: vb.forward_features(ccfg, p, i))(cp, img)
    jerr = float(jnp.max(jnp.abs(jout.astype(jnp.float32) - ref)))
    assert jerr <= atol, (spec.name, jerr)


@pytest.mark.parametrize("beta", [0, 1, 2])
def test_quantized_forward_parity_every_beta(setup, beta):
    """Mixed-resolution (legacy ids layout) forward: the int8 lane
    tracks fp32 at every restoration point."""
    cfg, params, part = setup
    img = _img(1)
    n_low = 4
    low_ids = np.arange(n_low, dtype=np.int32)
    full_ids = np.arange(n_low, part.n_regions, dtype=np.int32)
    kw = dict(beta=beta, full_ids=jnp.asarray(full_ids),
              low_ids=jnp.asarray(low_ids))
    ref = vb.forward_features(cfg, params, img, **kw)
    ccfg, cp, _ = ptq.compress(cfg, params, QuantSpec("int8", "fp32", 0))
    out = vb.forward_features(ccfg, cp, img, **kw)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err <= 0.25, (beta, err)


def test_quantized_forward_parity_padded_layout(setup):
    """Padded PlanLayout path (the serving executables' shape) with
    win_valid masking: pad windows stay deterministic on the int8 lane
    and valid windows track fp32."""
    from repro.core import partition as pt
    cfg, params, part = setup
    img = _img(2)
    plan = RegionPlan.from_mask(
        np.r_[np.ones(4, np.int32), np.zeros(part.n_regions - 4,
                                             np.int32)])
    lb = pt.length_bucket(pt.plan_n_windows(plan, part),
                          pt.length_bucket_set(part, pt.N_LENGTH_BUCKETS))
    arrays, _ = pt.stack_plan_layouts([pt.plan_layout(plan.states, lb,
                                                      part)])
    layout = {k: jnp.asarray(v) for k, v in arrays.items()}
    ref = vb.forward_features(cfg, params, img, beta=2, layout=layout)
    ccfg, cp, _ = ptq.compress(cfg, params, QuantSpec("int8", "fp32", 0))
    out = vb.forward_features(ccfg, cp, img, beta=2, layout=layout)
    assert out.shape == ref.shape
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err <= 0.25, err


# ---------------------------------------------------------------------------
# head pruning


def test_pruned_forward_equals_dense_with_heads_zeroed(setup):
    cfg, params, _ = setup
    img = _img(3)
    scores = prune.score_heads(cfg, params, [np.asarray(img[0])])
    assert scores.shape == (cfg.n_layers, cfg.n_heads)
    assert (scores > 0).all()
    cfg2, p2, kept = prune.prune_heads(cfg, params, 1, scores)
    assert cfg2.n_heads == cfg.n_heads - 1
    assert all(len(k) == cfg.n_heads - 1 for k in kept)
    dropped = [sorted(set(range(cfg.n_heads)) - set(k)) for k in kept]
    pz = prune.zero_heads(cfg, params, dropped)
    a = vb.forward_features(cfg2, p2, img)
    b = vb.forward_features(cfg, pz, img)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prune_rejects_bad_k(setup):
    cfg, params, _ = setup
    with pytest.raises(AssertionError):
        prune.prune_heads(cfg, params, cfg.n_heads)
    gqa = cfg.replace(n_kv_heads=2)
    with pytest.raises(AssertionError):
        prune.prune_heads(gqa, params, 1)


def test_prune_uses_w_o_norm_proxy_without_frames(setup):
    cfg, params, _ = setup
    cfg2, p2, kept = prune.prune_heads(cfg, params, 1)
    proxy = prune.w_o_head_norms(cfg, params)
    for l, k in enumerate(kept):
        assert int(np.argmin(proxy[l])) not in k


# ---------------------------------------------------------------------------
# ServerModel integration: grid invariance, zero steady compiles


@pytest.mark.slow
def test_server_model_quantized_grid(setup):
    cfg, params, part = setup
    kw = dict(top_k=8, score_thresh=0.0, b_buckets=(1, 2))
    from repro.offload.simulator import ServerModel
    ref = ServerModel(cfg, params, **kw)
    space = ref.default_plan_space(betas=(2,), reuse_edges=(0,),
                                  captures=(0,))
    ref.warmup(space)

    spec = QuantSpec("int8", "fp16", 1)
    s = ServerModel(cfg, params, quant=spec,
                    calib_frames=np.asarray(_img(4, 2)), **kw)
    assert s.act_dtype == jnp.float16
    assert s.quant_report["ratio"] >= 3.5
    s.warmup(space)
    # the per-dtype lane adds NO executable keys beyond the fp32 grid
    assert set(s._fns) == set(ref._fns)

    frames = np.asarray(_img(5, 2))
    mask = np.r_[np.ones(4, np.int32),
                 np.zeros(part.n_regions - 4, np.int32)]
    s.infer(frames[0])
    s.infer(frames[0], mask, beta=2)
    s.infer_wave(frames, [RegionPlan.from_mask(mask)] * 2, beta=2)
    assert s.stats.steady_compiles == 0


@pytest.mark.slow
def test_calibration_gate_ships_within_bound(setup):
    """The accuracy gate on SIM synthetic scenarios: the shipped point
    holds the F1 delta bound on every scenario; candidates that ship
    must really be the most compressed passing point."""
    cfg, params, _ = setup
    report = calibrate.calibrate(
        cfg, params, scenarios=("parkS",), n_frames=2,
        candidates=(QuantSpec("int8", "fp32", 0),),
        server_kw=dict(top_k=8, score_thresh=0.0, b_buckets=(1,)))
    assert len(report.points) == 1
    pt_ = report.points[0]
    assert set(pt_.deltas) == {"parkS"}
    if report.shipped is not None:
        assert pt_.passed
        assert max(pt_.deltas.values()) <= report.bound
