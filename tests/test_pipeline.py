"""Pipeline parallelism: GPipe schedule must equal the sequential stack."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.pipeline import (bubble_fraction, pipeline_forward,
                                        stage_layers)


def _layer_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


def _stacked_params(key, n_layers, d):
    ks = jax.random.split(key, 2)
    return {
        "w": jax.random.normal(ks[0], (n_layers, d, d), jnp.float32) / d**0.5,
        "b": jax.random.normal(ks[1], (n_layers, d), jnp.float32) * 0.01,
    }


def _sequential(params, x):
    L = params["w"].shape[0]
    for i in range(L):
        x = _layer_fn(jax.tree_util.tree_map(lambda a: a[i], params), x)
    return x


def test_stage_layers_partition():
    assert stage_layers(8, 4, 0) == (0, 2)
    assert stage_layers(8, 4, 3) == (6, 8)
    # uneven: 10 layers on 4 stages -> 3,3,2,2
    spans = [stage_layers(10, 4, s) for s in range(4)]
    assert [hi - lo for lo, hi in spans] == [3, 3, 2, 2]
    assert spans[0][0] == 0 and spans[-1][1] == 10


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(32, 4) == pytest.approx(3 / 35)


@pytest.mark.parametrize("n_micro", [1, 2, 4])
def test_pipeline_matches_sequential(n_micro):
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 host devices (XLA_FLAGS set too late)")
    mesh = jax.make_mesh((4,), ("stage",))
    L, d, B, T = 8, 16, 8, 4
    params = _stacked_params(jax.random.PRNGKey(0), L, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d), jnp.float32)

    with mesh:
        y = pipeline_forward(mesh, _layer_fn, params, x, n_micro)
    ref = _sequential(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_pipeline_single_stage_degenerates():
    mesh = jax.make_mesh((1,), ("stage",))
    L, d, B, T = 4, 8, 4, 2
    params = _stacked_params(jax.random.PRNGKey(2), L, d)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, d), jnp.float32)
    with mesh:
        y = pipeline_forward(mesh, _layer_fn, params, x, n_micro=2)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_sequential(params, x)),
                               rtol=2e-5, atol=2e-5)
