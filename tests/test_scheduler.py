"""The unified scheduling plane (serve/scheduler.py).

Fast tests drive the schedulers directly with fake jobs/servers (pure
host-side timing — no model, no compile); the slow ones record a real
closed-loop arrival pattern and replay it through both policies to pin
scheduler equivalence:

  * BarrierScheduler is bit-identical to the closed-loop (pre-refactor)
    waves for a recorded arrival pattern.
  * ContinuousScheduler produces the same per-frame output on that
    pattern — only timestamps (queue/e2e) may differ, and its p50
    queue delay is no worse.
"""
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import vit_backbone as vb
from repro.core.partition import LOW, REUSE, Partition, RegionPlan
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.faults import FaultInjector, FaultSpec
from repro.offload.simulator import Policy, Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)
from repro.serve.request import FeatureCache
from repro.serve.scheduler import (BarrierScheduler, ContinuousScheduler,
                                   edge_restart_tick, form_wave,
                                   make_scheduler)

PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]


# ---------------------------------------------------------------------------
# fakes: pure host-side scheduling, no model


class _FakeStats:
    stale_epoch_rejects = 0


class _FakeServer:
    b_buckets = (1, 2, 4, 8)
    epoch = 0

    def __init__(self):
        self.stats = _FakeStats()
        self.restarts = []

    def plan_length_bucket(self, plan):
        return 48

    def batch_bucket(self, b):
        return next(e for e in self.b_buckets if e >= b)

    def infer_wave(self, frames, plans, beta, **kw):
        return [[] for _ in plans]

    def stage_frames(self, frames):
        return np.asarray(frames)

    def restart(self, preserve_executables=False):
        self.epoch += 1
        self.restarts.append(preserve_executables)
        return self.epoch


class _FakeClient:
    feature_cache = None

    def __init__(self):
        self.finished = []

    def _finish_offload(self, job, dets, queue_delay=0.0, t_dec=None,
                        t_inf=None):
        t_dec = job["t_dec"] if t_dec is None else t_dec
        t_inf = job["t_inf"] if t_inf is None else t_inf
        job["e2e"] = queue_delay + t_dec + t_inf
        job["done_at"] = job["arrival"] + job["e2e"]
        job["parts"] = {"queue": queue_delay, "dec": t_dec, "inf": t_inf}
        job["dets"] = dets
        self.finished.append(job)


def _fake_job(arrival, frame=0, ci=0, t_dec=0.1, t_inf=0.5):
    plan = RegionPlan(np.array([1] * 4 + [0] * 12, np.int8))
    return {"arrival": arrival, "frame": frame, "_client": ci,
            "t_dec": t_dec, "t_inf": t_inf, "beta": 2, "plan": plan,
            "rtt": 0.0, "decoded": np.zeros((2, 2, 3), np.float32),
            "submit": arrival, "t_enc": 0.0, "t_up": 0.0}


def _sched(cls, n_clients=2, **ec_kw):
    clients = [_FakeClient() for _ in range(n_clients)]
    ec = EdgeConfig(**ec_kw)
    return cls(_FakeServer(), clients, ec), clients


# ---------------------------------------------------------------------------
# form_wave: the one shared grouping pass


def test_form_wave_groups_by_head_key_and_caps():
    items = [("a", 1), ("b", 1), ("c", 2), ("d", 1), ("e", 1)]
    wave, rest, hk = form_wave(items, key_fn=lambda it: it[1], cap=3)
    assert hk == 1
    assert [n for n, _ in wave] == ["a", "b", "d"]      # queue order
    assert [n for n, _ in rest] == ["c", "e"]           # order preserved

    wave, rest, _ = form_wave(items, key_fn=lambda it: it[1], cap=1)
    assert len(wave) == 1 and len(rest) == 4


def test_form_wave_admit_and_promote_hooks():
    items = [("a", 1), ("b", 2), ("c", 1)]
    promoted = []
    wave, rest, _ = form_wave(
        items, key_fn=lambda it: it[1], cap=8,
        admit=lambda it: it[0] != "c",
        promote=lambda it, k, hk, w: promoted.append(it[0]) or True)
    assert [n for n, _ in wave] == ["a", "b"]           # b promoted in
    assert promoted == ["b"]
    assert [n for n, _ in rest] == ["c"]                # admit cut


def test_unknown_scheduler_name_raises():
    with pytest.raises(ValueError, match="unknown EdgeConfig.scheduler"):
        make_scheduler(_FakeServer(), [_FakeClient()],
                       EdgeConfig(scheduler="warp"))


# ---------------------------------------------------------------------------
# modelled timelines: barrier vs continuous


def test_barrier_queue_is_all_admission_wait():
    sched, clients = _sched(BarrierScheduler)
    sched.enqueue(0, _fake_job(0.0, frame=0, ci=0))
    sched.enqueue(1, _fake_job(0.2, frame=0, ci=1))
    sched.drain(float("inf"))
    # barrier: wave 1 = [A] (B arrived after its start); B waits out the
    # whole service (decode + infer) and then decodes again, serially
    assert sched.stats.wave_sizes == [1, 1]
    assert sched.free_at == pytest.approx(1.2)          # 0.6 + 0.6
    np.testing.assert_allclose(sched.stats.queue_delays, [0.0, 0.4])
    np.testing.assert_allclose(sched.stats.queue_admit,
                               sched.stats.queue_delays)
    assert all(s == 0.0 for s in sched.stats.queue_slot)
    # the replica idles through wave 2's decode: busy 1.0s of [0.1, 1.2]
    assert sched.stats.device_idle_frac == pytest.approx(1 - 1.0 / 1.1)


def test_continuous_overlaps_decode_with_compute():
    sched, clients = _sched(ContinuousScheduler)
    sched.enqueue(0, _fake_job(0.0, frame=0, ci=0))
    sched.enqueue(1, _fake_job(0.2, frame=0, ci=1))
    sched.drain(float("inf"))
    # wave 2's decode (0.2 -> 0.3) hides under wave 1's compute
    # (0.1 -> 0.6): compute restarts immediately at 0.6, not 0.7
    assert sched.stats.wave_sizes == [1, 1]
    assert sched.free_at == pytest.approx(1.1)
    np.testing.assert_allclose(sched.stats.queue_delays, [0.0, 0.3])
    assert sched.stats.decode_hidden_s == pytest.approx(0.1)
    # back-to-back compute: zero idle between first and last wave
    assert sched.stats.device_idle_frac == pytest.approx(0.0)
    # Eq. (2) still decomposes with the job's OWN t_dec
    b = clients[1].finished[0]
    assert b["e2e"] == pytest.approx(0.3 + 0.1 + 0.5)
    assert b["parts"]["queue_admit"] + b["parts"]["queue_slot"] \
        == pytest.approx(b["parts"]["queue"])


def test_continuous_matches_barrier_when_uncontended():
    """With no replica contention the two policies are the same
    timeline: overlap only removes waiting, never adds service."""
    for cls in (BarrierScheduler, ContinuousScheduler):
        sched, clients = _sched(cls)
        sched.enqueue(0, _fake_job(0.0, ci=0))
        sched.enqueue(1, _fake_job(5.0, ci=1))          # replica long idle
        sched.drain(float("inf"))
        assert sched.free_at == pytest.approx(5.6)
        assert all(q == 0.0 for q in sched.stats.queue_delays)


def test_continuous_admits_late_job_into_pad_slot():
    """A job whose decode outlasts the wave's compute start may still
    claim a padded B-bucket slot (3 -> 4 pads anyway) when the cost
    model prices the wave's wait below the job's queueing."""
    sched, clients = _sched(ContinuousScheduler, n_clients=4)
    for ci in range(3):
        sched.enqueue(ci, _fake_job(0.0, frame=0, ci=ci, t_dec=0.05))
    late = _fake_job(0.03, frame=0, ci=3, t_dec=0.05)   # staged at 0.08
    sched.enqueue(3, late)
    sched.drain(float("inf"))
    assert sched.stats.wave_sizes == [4]
    # the wave waited for the late job's staging: compute at 0.08
    assert late["parts"]["queue"] == pytest.approx(0.0)
    assert sched.free_at == pytest.approx(
        0.08 + 0.5 * (1 + 0.35 * 3))
    assert sched.stats.queue_delays[0] == pytest.approx(0.03)


def test_continuous_never_grows_the_padded_bucket_for_late_jobs():
    """A late-staging job may only fill a PAD row: at B=2 (its own
    bucket edge) admission would re-shape the executable, so the job
    waits for the next wave instead."""
    sched, clients = _sched(ContinuousScheduler, n_clients=3)
    for ci in range(2):
        sched.enqueue(ci, _fake_job(0.0, frame=0, ci=ci, t_dec=0.05))
    sched.enqueue(2, _fake_job(0.03, frame=0, ci=2, t_dec=0.05))
    sched.drain(float("inf"))
    assert sched.stats.wave_sizes == [2, 1]


# ---------------------------------------------------------------------------
# the shared crash-restart plane (satellite: _edge_fault_tick dedup)


def test_edge_restart_tick_applies_each_event_once():
    server = _FakeServer()
    inj = FaultInjector(FaultSpec(edge_restarts=((0.5, 0.2), (1.5, 0.1))))
    events = edge_restart_tick(server, inj, -1.0, 1.0)
    assert events == [(0.5, 0.2)] and server.epoch == 1
    events = edge_restart_tick(server, inj, 1.0, 2.0,
                               preserve_executables=True)
    assert events == [(1.5, 0.1)] and server.epoch == 2
    assert server.restarts == [False, True]
    assert edge_restart_tick(server, None, -1.0, 99.0) == []


def test_wave_scheduler_restart_loses_queue_and_holds_replica():
    inj = FaultInjector(FaultSpec(edge_restarts=((0.5, 0.4),)))
    clients = [_FakeClient(), _FakeClient()]
    sched = BarrierScheduler(_FakeServer(), clients, EdgeConfig(),
                             faults=inj)
    j0, j1 = _fake_job(0.3, ci=0), _fake_job(0.4, ci=1)
    sched.enqueue(0, j0)
    sched.enqueue(1, j1)
    sched.fault_tick(0.2, 0.6)
    assert sched.pending == [] and sched.stats.lost_jobs == 2
    assert j0["lost"] and j1["lost"]
    assert sched.free_at == pytest.approx(0.9)          # r + outage
    assert sched.stats.restarts == 1 and sched.server.epoch == 1


def test_solo_and_mc_restart_recovery_match(monkeypatch):
    """Satellite pin: both planes now apply restarts through ONE helper
    (edge_restart_tick) and recover identically — same epoch bumps,
    same restart counts, and the N=1 multi-client run keeps matching
    the solo run under the same fault schedule (PR 6 behaviour)."""
    from repro.offload.faults import RobustConfig

    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    server_a = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    server_b = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    spec = FaultSpec(edge_restarts=((0.55, 0.2),))

    def client(server, faults=None):
        frames, _ = sv.make_clip("walkS", 40, size=SIZE, seed=11)
        gt = [server.infer(f) for f in frames]
        return Simulation(frames, gt, make_trace("4g", 11, duration_s=60),
                          _FixedPolicy([0, 1, 2, 3]), server,
                          vb.vit_partition(SIM), PATCH, fps=10,
                          faults=faults, robust=RobustConfig(slo_s=1.0))

    solo = client(server_a, faults=FaultInjector(spec))
    r_solo = solo.run("v")
    mc = MultiClientSimulation([client(server_b)], server_b,
                               EdgeConfig(),
                               faults=FaultInjector(spec))
    r_mc = mc.run(["v"])[0]
    assert solo.rstats["edge_restarts"] == 1
    assert mc.stats.restarts == 1
    assert server_a.epoch == server_b.epoch == 1
    # both replicas recovered: offloads completed after the restart
    # (frame 6 = 0.6 s > the 0.55 s crash)
    assert len(r_solo.e2e_latency) >= 2 and solo.cache_frame > 6
    assert len(r_mc.e2e_latency) >= 2 and mc.clients[0].cache_frame > 6


# ---------------------------------------------------------------------------
# speculative REUSE execution (fakes: modelled timeline, no model)


# tiny geometry: 2x2 decision regions of 4x4 px (patch_px=1), so a
# frame is 8x8x3 and region j covers rows/cols (j//2, j%2) * 4
_SPART = Partition(grid_h=8, grid_w=8, window=2, downsample=2)


class _SpecServer(_FakeServer):
    part = _SPART
    cfg = SIM

    def plan_length_bucket(self, plan):
        return _SPART.n_windows(plan.n_low, plan.n_reuse)

    def infer_speculative(self, pred, plan, beta, cache, frame_idx):
        clone = cache.speculative_clone()
        return [{"box": (0.0, 0.0, 1.0, 1.0), "score": 1.0,
                 "label": 0}], clone


class _SpecClient(_FakeClient):
    analyzer = SimpleNamespace(patch_px=1)

    def __init__(self):
        super().__init__()
        self.feature_cache = FeatureCache(n_regions=4, max_age=4,
                                          beta=2, warm=True, epoch=0)


def _spec_job(decoded, t_up=1.0, **kw):
    """A REUSE-heavy job: header lands at submit + t_enc = 0.05, the
    payload at arrival = 0.05 + t_up."""
    plan = RegionPlan(np.array([LOW, LOW, REUSE, REUSE], np.int8))
    job = {"frame": 0, "_client": 0, "submit": 0.0, "t_enc": 0.05,
           "t_up": t_up, "arrival": 0.05 + t_up, "t_dec": 0.1,
           "t_inf": 0.5, "beta": 2, "plan": plan, "rtt": 0.0,
           "decoded": decoded, "spec_frac": 0.75, "spec_conf": 1.0}
    job.update(kw)
    return job


def _spec_sched(**ec_kw):
    clients = [_SpecClient()]
    ec_kw.setdefault("speculate", True)
    sched = ContinuousScheduler(_SpecServer(), clients,
                                EdgeConfig(**ec_kw))
    pred = np.full((8, 8, 3), 0.25, np.float32)
    clients[0].feature_cache.note_pred(pred, -1, 0)
    return sched, clients, pred


def test_speculation_hides_uplink_when_prediction_converges():
    """Launch at header time, compute under the uplink, serve at
    payload arrival with ZERO residual inference: e2e collapses to the
    decode check, and the hidden seconds equal the spec compute."""
    sched, clients, pred = _spec_sched()
    job = _spec_job(pred.copy())
    sched.enqueue(0, job)
    sched.drain(0.5)                    # payload still in flight
    assert sched.stats.spec_launched == 1
    assert sched.pending == [] and len(sched._spec) == 1
    assert sched.free_at == pytest.approx(0.55)     # 0.05 + t_inf
    sched.drain(2.0)                    # payload landed at 1.05
    assert sched.stats.spec_patched == 1
    assert sched.stats.spec_discarded == 0
    assert job["speculation"] == "patched"
    done = clients[0].finished[0]
    # all in-flight regions converged: no patch compute at all
    assert done["parts"]["inf"] == 0.0
    assert done["e2e"] == pytest.approx(0.1)        # t_dec only
    assert done["done_at"] == pytest.approx(1.15)
    # hidden transmission = spec compute overlapped with the uplink
    assert sched.stats.spec_hidden_s == pytest.approx(0.5)
    assert sched.stats.spec_hidden_percentile(50) == pytest.approx(0.5)
    # committed: every region's content derives from reuse/prediction,
    # so the whole frame burns one offload of the staleness budget K
    cache = clients[0].feature_cache
    np.testing.assert_array_equal(cache.age, [1, 1, 1, 1])
    assert cache.pred_age == 0          # note_pred reset after commit


def test_speculation_patches_only_diverged_regions():
    sched, clients, pred = _spec_sched()
    decoded = pred.copy()
    decoded[:4, :4] += 0.5              # region 0 diverges (1 of 2 tx)
    job = _spec_job(decoded)
    sched.enqueue(0, job)
    sched.drain(0.5)
    sched.drain(2.0)
    assert sched.stats.spec_patched == 1
    assert job["speculation"] == "patched"
    done = clients[0].finished[0]
    # patch reruns ONE window of the original two: flops-scaled cost
    # strictly inside (0, t_inf)
    assert 0.0 < done["parts"]["inf"] < 0.5
    # only the diverged region was recomputed from real pixels
    np.testing.assert_array_equal(clients[0].feature_cache.age,
                                  [0, 1, 1, 1])


def test_speculation_discards_on_gross_mispredict():
    sched, clients, pred = _spec_sched()
    decoded = pred.copy()
    decoded[:4, :] += 0.5               # regions 0 AND 1: 2/2 diverged
    job = _spec_job(decoded)
    sched.enqueue(0, job)
    sched.drain(0.5)
    sched.drain(2.0)
    assert sched.stats.spec_discarded == 1
    assert sched.stats.spec_patched == 0
    assert job["speculation"] == "discarded"
    done = clients[0].finished[0]
    assert done["parts"]["inf"] == pytest.approx(0.5)   # full rerun
    # the discarded clone never touched the session cache
    np.testing.assert_array_equal(clients[0].feature_cache.age,
                                  [0, 0, 0, 0])
    # the REAL decoded frame becomes the next prediction source
    assert clients[0].feature_cache.pred_frame is decoded


def test_speculation_abandoned_mid_payload_never_renders():
    """Blackout mid-payload: the client deadline reaps the offload and
    climbs the degradation ladder; the speculation must die with it —
    a prediction-only frame is NEVER rendered."""
    sched, clients, pred = _spec_sched()
    job = _spec_job(pred.copy())
    sched.enqueue(0, job)
    sched.drain(0.5)
    assert sched.stats.spec_launched == 1
    job["abandoned"] = True             # client reaped it at its SLO
    sched.drain(float("inf"))
    assert sched.stats.spec_discarded == 1
    assert sched._spec == []
    assert clients[0].finished == []    # nothing rendered
    assert "speculation" not in job


def test_speculation_stale_epoch_refusal():
    """Edge restart between launch and patch: the clone's tiles died
    with the old generation, so the resolution is a stale-epoch NACK —
    exactly the refusal real splices get — not a render."""
    sched, clients, pred = _spec_sched()
    job = _spec_job(pred.copy())
    sched.enqueue(0, job)
    sched.drain(0.5)
    sched.server.restart()              # epoch 0 -> 1 mid-flight
    sched.drain(0.6)                    # payload not yet landed
    assert len(sched._spec) == 1        # NACK waits for the payload
    sched.drain(2.0)
    assert job["stale_epoch"] and job["dets"] == []
    assert job["done_at"] == pytest.approx(job["arrival"])
    assert sched.stats.stale_nacks == 1
    assert sched.stats.spec_discarded == 1
    assert sched.server.stats.stale_epoch_rejects == 1
    assert clients[0].finished == []    # completion path handles NACKs


def test_speculation_admission_gates():
    """No launch when confidence is low, when the prediction source is
    stale (bound K) or from a dead epoch, or when the lane is off —
    the job serves through the normal wave path instead."""
    cases = [
        dict(job_kw={"spec_conf": 0.2}),                # low confidence
        dict(pred_age=4),                               # source too old
        dict(ec_kw={"speculate": False}),
        dict(job_kw={"spec_frac": 0.1}),                # not REUSE-heavy
    ]
    for case in cases:
        sched, clients, pred = _spec_sched(**case.get("ec_kw", {}))
        if "pred_age" in case:
            clients[0].feature_cache.pred_age = case["pred_age"]
        job = _spec_job(pred.copy(), **case.get("job_kw", {}))
        sched.enqueue(0, job)
        sched.drain(float("inf"))
        assert sched.stats.spec_launched == 0, case
        assert len(clients[0].finished) == 1, case      # normal path
        assert "speculation" not in job


def test_speculation_waits_for_replica_and_payload_window():
    """s_start = max(free_at, header) must fall strictly before the
    payload arrival: with the replica busy past the arrival there is
    no uplink left to hide in, so the job stays in the normal lane."""
    sched, clients, pred = _spec_sched()
    sched.free_at = 2.0                 # busy replica past arrival=1.05
    job = _spec_job(pred.copy())
    sched.enqueue(0, job)
    sched.drain(float("inf"))
    assert sched.stats.spec_launched == 0
    assert len(clients[0].finished) == 1


# ---------------------------------------------------------------------------
# deferred-dispatch error path (satellite: staged buffers must release)


class _BoomServer(_FakeServer):
    def __init__(self, boom_on=2):
        super().__init__()
        self.calls = 0
        self.boom_on = boom_on

    def infer_wave(self, frames, plans, beta, **kw):
        self.calls += 1
        if self.calls == self.boom_on:
            raise RuntimeError("device OOM mid-dispatch")
        return [[] for _ in plans]


def test_deferred_dispatch_failure_releases_pipeline():
    """infer_wave raising AFTER stage_frames must not wedge the
    one-deep executor pipeline: the previous wave's deferred decode
    still lands, the failed wave's jobs are marked lost (deadlines can
    reap them), and the PendingWave slot is empty afterwards."""
    clients = [_FakeClient(), _FakeClient()]
    sched = ContinuousScheduler(_BoomServer(), clients,
                                EdgeConfig(stage_ahead=True))
    j0, j1 = _fake_job(0.0, ci=0), _fake_job(0.2, ci=1)
    sched.enqueue(0, j0)
    sched.enqueue(1, j1)
    with pytest.raises(RuntimeError, match="mid-dispatch"):
        sched.drain(0.7)                # wave A deferred, wave B raises
    assert sched._exec_q == []          # pipeline flushed, not wedged
    assert len(clients[0].finished) == 1            # wave A landed
    assert j1["lost"] and j1["done_at"] == float("inf")
    assert sched.stats.lost_jobs == 1
    # the scheduler survives: a fresh job still serves normally
    j2 = _fake_job(1.0, ci=0)
    sched.enqueue(0, j2)
    sched.drain(float("inf"))
    assert len(clients[0].finished) == 2


# ---------------------------------------------------------------------------
# closed-loop replay equivalence (slow: real model inference)


class _FixedPolicy(Policy):
    name = "fixed"
    use_tracker = True

    def __init__(self, lows, beta=2, n_regions=16):
        self.lows = lows
        self.beta = beta
        self.n_regions = n_regions

    def decide(self, sim, frame_idx):
        mask = np.zeros(self.n_regions, np.int32)
        mask[self.lows] = 1
        return {"mask": mask, "quality": 85, "beta": self.beta}


@pytest.fixture(scope="module")
def loop():
    """One closed-loop barrier run + its recorded arrival pattern."""
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    part = vb.vit_partition(SIM)
    slow = lambda beta, n_d: 0.5          # force queueing -> real waves

    def clients():
        return [Simulation(*_clip(server, i), make_trace("4g", i,
                                                         duration_s=60),
                           _FixedPolicy(list(range(4 * i, 4 * i + 4))),
                           server, part, PATCH, fps=10, inf_delay=slow)
                for i in range(3)]

    def _clip(server, seed):
        frames, _ = sv.make_clip("walkS", 12, size=SIZE, seed=seed)
        return frames, [server.infer(f) for f in frames]

    mc = MultiClientSimulation(clients(), server,
                               EdgeConfig(batched=True, keep_dets=True))
    recorded = []
    orig = mc.scheduler.enqueue

    def tap(ci, job):
        recorded.append((ci, dict(job)))   # pre-execution snapshot
        orig(ci, job)

    mc.scheduler.enqueue = tap
    mc.run()
    return server, mc, recorded, clients


def _replay(sched_cls, server, clients, recorded, **ec_kw):
    """Feed a recorded arrival pattern through a fresh scheduler.

    Formation only depends on jobs already arrived by each wave's
    start, and every recorded job was enqueued before its wave could
    run, so enqueue-all + one final drain reproduces the closed-loop
    schedule exactly.
    """
    sched = sched_cls(server, clients, EdgeConfig(keep_dets=True, **ec_kw))
    for ci, job in recorded:
        sched.enqueue(ci, dict(job))
    sched.drain(float("inf"))
    return sched


def _boxes(dets):
    return np.array([d["box"] for d in dets], np.float64).reshape(-1, 4)


@pytest.mark.slow
def test_barrier_replay_is_bit_identical_to_closed_loop(loop):
    """BarrierScheduler over the recorded pattern reproduces the
    closed-loop (pre-refactor behaviour) waves bit-exactly: same wave
    structure, same queue delays, same detections."""
    server, mc, recorded, clients = loop
    sched = _replay(BarrierScheduler, server, clients(), recorded)
    assert sched.stats.wave_sizes == mc.stats.wave_sizes
    np.testing.assert_array_equal(sched.stats.queue_delays,
                                  mc.stats.queue_delays)
    ref = {(j["client"], j["frame"]): j["dets"] for j in mc.stats.jobs}
    got = {(j["client"], j["frame"]): j["dets"] for j in sched.stats.jobs}
    assert set(ref) == set(got) and len(ref) > 3
    for k in ref:
        np.testing.assert_array_equal(_boxes(got[k]), _boxes(ref[k]))


@pytest.mark.slow
def test_continuous_replay_matches_barrier_output(loop):
    """ContinuousScheduler == barrier output per frame on the same
    recorded pattern — only timestamps may differ — with no worse p50
    queue delay and zero new executables (the warmed grid serves it)."""
    server, mc, recorded, clients = loop
    keys_before = set(server._fns)
    compiles_before = server.stats.compiles
    sched = _replay(ContinuousScheduler, server, clients(), recorded,
                    scheduler="continuous")
    assert set(server._fns) == keys_before          # zero new keys
    assert server.stats.compiles == compiles_before

    ref = {(j["client"], j["frame"]): j["dets"] for j in mc.stats.jobs}
    got = {(j["client"], j["frame"]): j["dets"] for j in sched.stats.jobs}
    assert set(ref) == set(got)
    for k in ref:
        assert len(got[k]) == len(ref[k])
        np.testing.assert_allclose(_boxes(got[k]), _boxes(ref[k]),
                                   rtol=1e-5, atol=1e-5)
    # timestamps: continuous can only shorten queueing on this pattern
    assert (np.median(sched.stats.queue_delays)
            <= np.median(mc.stats.queue_delays) + 1e-12)
    assert sched.stats.decode_hidden_s > 0.0
