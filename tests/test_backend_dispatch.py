"""Kernel backend dispatch layer (kernels.dispatch) — parity between the
``"pallas"`` (interpret mode on CPU) and ``"xla"`` backbone paths, the
fused-QKV bit-compatibility guarantee, and the packed-position cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.kernels import dispatch
from repro.models import attention as attn
from repro.models import registry
from repro.models.config import ModelConfig

TOL = dict(rtol=5e-5, atol=5e-5)


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    img = jax.random.uniform(jax.random.PRNGKey(1), (1, *SIM.vit.img_size, 3))
    part = vb.vit_partition(SIM)
    return params, img, part


def _ids(part, n_low):
    mask = np.zeros(part.n_regions, np.int32)
    mask[:n_low] = 1
    fi, li = pt.mask_to_region_ids(mask, n_low)
    return jnp.asarray(fi), jnp.asarray(li)


# ---------------------------------------------------------------------------
# backend resolution


def _no_env(monkeypatch):
    """Neutralise any ambient REPRO_BACKEND (the CI parity lane runs
    this file WITH it set) for the duration of a resolution test."""
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    dispatch.refresh_from_env()


def _restore_env(monkeypatch):
    monkeypatch.undo()
    dispatch.refresh_from_env()
    dispatch.set_backend(None)


def test_resolve_backends(monkeypatch):
    try:
        _no_env(monkeypatch)
        assert dispatch.resolve("xla") == "xla"
        assert dispatch.resolve("pallas") == "pallas"
        # auto never picks interpret-mode pallas for the hot path off-TPU
        expect = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert dispatch.resolve("auto") == expect
        # bare default == "auto" (the kernels are grad-capable via custom
        # VJPs now, so None no longer has to force XLA for grad safety)
        assert dispatch.resolve(None) == expect
        with pytest.raises(ValueError):
            dispatch.resolve("cuda")
    finally:
        _restore_env(monkeypatch)


def test_resolve_env_override(monkeypatch):
    """REPRO_BACKEND is read ONCE per process (refresh_from_env for
    tests) and outranks every per-call and process-default choice."""
    try:
        monkeypatch.setenv(dispatch.ENV_VAR, "pallas")
        dispatch.refresh_from_env()
        assert dispatch.resolve("xla") == "pallas"
        dispatch.set_backend("xla")
        assert dispatch.resolve(None) == "pallas"   # env > set_backend
        monkeypatch.setenv(dispatch.ENV_VAR, "xla")
        assert dispatch.resolve("pallas") == "pallas"  # stale until refresh
        dispatch.refresh_from_env()
        assert dispatch.resolve("pallas") == "xla"
    finally:
        _restore_env(monkeypatch)


def test_set_backend_process_default(monkeypatch):
    """set_backend() replaces the "auto" fallback for backend=None calls
    only; explicit per-call choices still win."""
    try:
        _no_env(monkeypatch)
        dispatch.set_backend("pallas")
        assert dispatch.resolve(None) == "pallas"
        assert dispatch.resolve("xla") == "xla"
        dispatch.set_backend(None)
        expect = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert dispatch.resolve(None) == expect
        with pytest.raises(ValueError):
            dispatch.set_backend("cuda")
    finally:
        _restore_env(monkeypatch)


def test_backend_scope_pins_trace(monkeypatch):
    """backend_scope() pins None-backend dispatch sites for its dynamic
    extent — the ServeEngine wraps jit traces with it."""
    try:
        _no_env(monkeypatch)
        with dispatch.backend_scope("pallas"):
            assert dispatch.resolve(None) == "pallas"
            with dispatch.backend_scope("xla"):
                assert dispatch.resolve(None) == "xla"
            assert dispatch.resolve(None) == "pallas"
        expect = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert dispatch.resolve(None) == expect
        # a None scope is a no-op: the current default stays in force
        with dispatch.backend_scope(None):
            assert dispatch.resolve(None) == expect
    finally:
        _restore_env(monkeypatch)


# ---------------------------------------------------------------------------
# forward_features parity: pallas (interpret) vs xla, full-res and mixed


def test_forward_features_full_res_parity(setup):
    params, img, _ = setup
    ref = vb.forward_features(SIM, params, img, backend="xla")
    out = vb.forward_features(SIM, params, img, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("n_low", [8, 16])
@pytest.mark.parametrize("beta", [0, 1, SIM.vit.n_subsets])
def test_forward_features_mixed_parity(setup, beta, n_low):
    params, img, part = setup
    fi, li = _ids(part, n_low)
    ref = vb.forward_features(SIM, params, img, fi, li, beta, backend="xla")
    out = vb.forward_features(SIM, params, img, fi, li, beta,
                              backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_forward_features_jit_parity(setup):
    """The dispatch choice survives jit (the ServerModel path)."""
    params, img, part = setup
    fi, li = _ids(part, 8)

    def f(backend):
        fn = jax.jit(lambda p, i, a, b: vb.forward_features(
            SIM, p, i, a, b, 2, backend=backend))
        return fn(params, img, fi, li)

    np.testing.assert_allclose(np.asarray(f("pallas")),
                               np.asarray(f("xla")), **TOL)


# ---------------------------------------------------------------------------
# fused QKV: one concatenated GEMM must be BIT-compatible with the old
# three-GEMM path (each output column touches only its own weight column)


def _three_gemm_qkv(cfg, p, x, positions, rope):
    """The pre-fusion reference implementation of _project_qkv."""
    from repro.models.layers import apply_rope, rms_norm
    B, T, _ = x.shape
    q = x @ p["w_q"]
    k = x @ p["w_k"]
    v = x @ p["w_v"]
    if cfg.attention_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta,
                       cfg.partial_rotary_factor)
        k = apply_rope(k, positions, cfg.rope_theta,
                       cfg.partial_rotary_factor)
    return q, k, v


@pytest.mark.parametrize("cfg", [
    SIM,                                            # bias, MHA (ViT)
    ModelConfig(n_heads=8, n_kv_heads=2, head_dim=16, d_model=64),  # GQA
], ids=["vit-sim", "gqa"])
def test_fused_qkv_bit_compatible(cfg):
    p = attn.init_attention(cfg, jax.random.PRNGKey(3), jnp.float32)
    for T in (16, 1):         # prefill (fused) and decode (three-GEMM)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, T, cfg.d_model))
        positions = jnp.arange(T)[None].repeat(2, 0)
        for rope in (False, True):
            fused = attn._project_qkv(cfg, p, x, positions, rope)
            ref = _three_gemm_qkv(cfg, p, x, positions, rope)
            for a, b, name in zip(fused, ref, "qkv"):
                assert jnp.array_equal(a, b), \
                    f"{name} not bit-identical (T={T})"


# ---------------------------------------------------------------------------
# sdpa / window_sdpa routing guards


def test_sdpa_decode_routes_to_decode_kernel():
    """The one-token kv_len shape (q_len 1, no offset, non-causal) now
    routes to the Pallas GQA decode kernel; parity with XLA holds."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16))
    k = jax.random.normal(ks[1], (2, 32, 4, 16))
    v = jax.random.normal(ks[2], (2, 32, 4, 16))
    kv_len = jnp.array([7, 32])
    ref = attn.sdpa(q, k, v, kv_len=kv_len, backend="xla")
    out = attn.sdpa(q, k, v, kv_len=kv_len, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_sdpa_unsupported_shapes_stay_on_xla():
    """Shapes the decode kernel does NOT support — multi-token queries
    with kv_len (padded ViT global blocks) and nonzero q_offset — must
    fall back to XLA, not mis-route (parity pins the routing)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    k = jax.random.normal(ks[1], (2, 32, 4, 16))
    v = jax.random.normal(ks[2], (2, 32, 4, 16))
    kv_len = jnp.array([7, 32])
    # multi-token query + kv_len mask
    qm = jax.random.normal(ks[0], (2, 8, 4, 16))
    np.testing.assert_allclose(
        np.asarray(attn.sdpa(qm, k, v, kv_len=kv_len, backend="pallas")),
        np.asarray(attn.sdpa(qm, k, v, kv_len=kv_len, backend="xla")),
        **TOL)
    # one-token causal decode with an explicit query offset
    q1 = jax.random.normal(ks[0], (2, 1, 4, 16))
    np.testing.assert_allclose(
        np.asarray(attn.sdpa(q1, k, v, kv_len=kv_len, causal=True,
                             q_offset=16, backend="pallas")),
        np.asarray(attn.sdpa(q1, k, v, kv_len=kv_len, causal=True,
                             q_offset=16, backend="xla")), **TOL)


def test_window_sdpa_backend_parity():
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 4, 16))
    v = jax.random.normal(ks[2], (2, 64, 4, 16))
    np.testing.assert_allclose(
        np.asarray(attn.window_sdpa(q, k, v, 4, backend="pallas")),
        np.asarray(attn.window_sdpa(q, k, v, 4, backend="xla")), **TOL)


# ---------------------------------------------------------------------------
# packed positional-embedding cache


def test_packed_positions_cache_hit(setup):
    params, _, part = setup
    pos = params["pos_emb"]
    fi, li = _ids(part, 8)
    vb._POS_CACHE.clear()
    a = vb.packed_positions(pos, part, fi, li)
    b = vb.packed_positions(pos, part, fi, li)
    assert b is a                       # second eager call is a cache hit
    np.testing.assert_array_equal(
        np.asarray(a),
        np.asarray(__import__("repro.core.mixed_res", fromlist=["x"])
                   .pack_positions(pos, part, fi, li)))
    # different region choice with the same n_low -> different entry
    mask = np.zeros(part.n_regions, np.int32)
    mask[8:] = 1
    fi2, li2 = (jnp.asarray(x) for x in pt.mask_to_region_ids(mask, 8))
    c = vb.packed_positions(pos, part, fi2, li2)
    assert c is not a
    assert not np.array_equal(np.asarray(c), np.asarray(a))


def test_packed_positions_tracer_bypass(setup):
    params, _, part = setup
    pos = params["pos_emb"]
    fi, li = _ids(part, 8)
    vb._POS_CACHE.clear()
    out = jax.jit(lambda p, a, b: vb.packed_positions(p, part, a, b))(
        pos, fi, li)
    assert not vb._POS_CACHE            # traced call must not populate
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(vb.packed_positions(pos, part, fi, li)),
        **TOL)
