"""Zero-stall serving hot path: AOT-warmed executables on the COLLAPSED
(length bucket, beta, capture, B bucket) grid, padded waves,
device-resident feature caches, cross-bucket coalescing.

Bit-identity contract pinned here: within ONE executable (same length
bucket AND batch bucket), XLA results are invariant to pad content and
row order — so a padded wave matches a solo run EXACTLY whenever both
land on the same (lb, B) pair.  Tests that need bit-identity therefore
configure a single batch bucket (and pin lb via ``lb_override`` where
the natural buckets differ); cross-bucket comparisons are ULP-level
only and use the repo's usual tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import REUSE, RegionPlan
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.simulator import Policy, ServerModel, Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)
from repro.serve.request import FeatureCache

PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    return params, vb.vit_partition(SIM)


def _frames(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0, 1, (n, SIZE, SIZE, 3)).astype(np.float32)


def _mask(part, lows):
    m = np.zeros(part.n_regions, np.int32)
    m[list(lows)] = 1
    return m


# ---------------------------------------------------------------------------
# batch buckets


def test_batch_bucket_rounds_up():
    assert pt.batch_bucket(1) == 1
    assert pt.batch_bucket(2) == 2
    assert pt.batch_bucket(3) == 4
    assert pt.batch_bucket(5) == 8
    assert pt.batch_bucket(3, (2, 6)) == 6
    with pytest.raises(ValueError):
        pt.batch_bucket(9)
    with pytest.raises(AssertionError):
        pt.batch_bucket(0)


# ---------------------------------------------------------------------------
# AOT warmup + steady-state compile telemetry


def test_warmup_then_steady_state_has_zero_compiles(setup):
    """After warmup over the plan space, serving never compiles: no new
    ``_fns`` entries and ``stats.steady_compiles == 0`` — a steady-state
    compile is a test failure, not a silent p95 spike."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                         b_buckets=(1, 2, 4))
    space = server.default_plan_space(betas=(2,), reuse_edges=(0, 4),
                                      captures=(0, 2))
    n = server.warmup(space)
    assert n == len(server._fns) == server.stats.compiles
    n_fns = len(server._fns)

    frames = _frames(4)
    cache = FeatureCache(part.n_regions, max_age=4)
    plan_low = RegionPlan.from_mask(_mask(part, range(4)))
    # solo full-res, solo mixed, mixed wave, capture + reuse session
    server.infer(frames[0])
    server.infer(frames[0], _mask(part, range(4)), beta=2)
    server.infer_wave(frames[:3], [plan_low] * 3, beta=2)
    server.infer_plan(frames[0], plan_low, beta=2, cache=cache,
                      frame_idx=0)
    states = plan_low.states.copy()
    states[8:12] = REUSE
    server.infer_plan(frames[1], RegionPlan(states), beta=2, cache=cache,
                      frame_idx=1)
    assert len(server._fns) == n_fns
    assert server.stats.steady_compiles == 0
    assert server.stats.warmed and server.stats.warmup_wall_s > 0


def test_unwarmed_shape_is_counted_as_steady_compile(setup):
    params, _ = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    server.warmup([(0, 0, 0, 0)], batch_buckets=(1,))
    assert server.stats.steady_compiles == 0
    server.infer(_frames(1)[0], _mask(vb.vit_partition(SIM), range(4)),
                 beta=2)
    # 4 LOW regions -> 52 transmitted windows -> the 64 length bucket
    assert server.stats.steady_compiles == 1
    assert server.stats.steady_compile_keys == [(64, 2, 2, 1)]


def test_collapsed_grid_key_is_length_bucket(setup):
    """Plans with different (n_low, n_reuse) but one padded length share
    ONE executable — the tentpole collapse."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                         b_buckets=(1,))
    assert server.length_edges == (24, 48, 64)
    frame = _frames(1)[0]
    # n_low 6..13 all land in the 48 bucket (64 - 3*n_low windows)
    for lows in (range(6), range(9), range(13)):
        server.infer(frame, _mask(part, lows), beta=2)
    assert server.stats.compiles == 1
    assert list(server._fns) == [(48, 2, 2, 1)]


def test_warmup_covers_every_distinct_full_res_capture(setup):
    """A deployment configuring SEVERAL full-res capture points warms
    each of them (no-capture requests fold into the canonical
    full_capture) — no steady-state compile when the smaller capture
    point is served."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    server.warmup([(0, 0, 0, 0), (0, 0, 0, 2), (0, 0, 0, 4)],
                  batch_buckets=(1,))
    assert server.full_capture == 4
    assert set(server._fns) == {(0, 0, 4, 1), (0, 0, 2, 1)}
    cache = FeatureCache(part.n_regions, max_age=4)
    full = RegionPlan(np.zeros((part.n_regions,), np.int8))
    server.infer_wave(_frames(1), [full], caches=[cache],
                      frame_ids=[0], capture_beta=2)
    assert server.stats.steady_compiles == 0
    assert cache.warm and cache.beta == 2


def test_full_res_wave_respects_per_job_capture_intent(setup):
    """infer_wave with a caches list but capture_beta=0 (a sessionful
    client that did NOT request capture) must leave the cache cold even
    though the canonical full-res executable captures tiles."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    server.warmup(server.default_plan_space(betas=(2,), captures=(0, 2)),
                  batch_buckets=(1,))
    assert server.full_capture == 2
    cache = FeatureCache(part.n_regions, max_age=4)
    full = RegionPlan(np.zeros((part.n_regions,), np.int8))
    server.infer_wave(_frames(1), [full], caches=[cache], frame_ids=[0],
                      capture_beta=0)
    assert not cache.warm and cache.tiles is None
    assert server.stats.steady_compiles == 0


# ---------------------------------------------------------------------------
# padded waves: bit-identical to solo through the shared executable


def test_padded_wave_bit_identical_to_solo(setup):
    """A B=3 wave padded to the B=4 executable produces detections
    BIT-identical to solo B=1 runs (which pad to the same executable:
    single batch bucket)."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                         b_buckets=(4,))
    frames = _frames(3, seed=1)
    plans = [RegionPlan.from_mask(_mask(part, range(s, s + 4)))
             for s in (0, 4, 8)]
    wave = server.infer_wave(frames, plans, beta=2)
    for i in range(3):
        solo = server.infer_wave(frames[i][None], [plans[i]], beta=2)[0]
        assert wave[i] == solo            # dict floats compare bitwise
    # padding compiled exactly one executable for all four calls
    assert server.stats.compiles == 1


def test_padded_wave_close_to_other_bucket_solo(setup):
    """Across DIFFERENT batch buckets results agree to tolerance (the
    repo's usual batched-vs-solo contract)."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    frames = _frames(3, seed=2)
    plans = [RegionPlan.from_mask(_mask(part, range(4)))] * 3
    wave = server.infer_wave(frames, plans, beta=2)       # B bucket 4
    for i in range(3):
        solo = server.infer_wave(frames[i][None], [plans[i]],
                                 beta=2)[0]               # B bucket 1
        assert len(wave[i]) == len(solo)
        a = np.array([d["box"] for d in wave[i]], np.float64)
        b = np.array([d["box"] for d in solo], np.float64)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=0.1)


def test_padded_reuse_wave_never_touches_pad_caches(setup):
    """A padded sessionful wave updates exactly the B real caches, and
    each sample matches its solo run bit-identically."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                         b_buckets=(4,))
    frames = _frames(3, seed=3)
    warm_plan = RegionPlan.from_mask(_mask(part, range(4)))

    def warm():
        caches = [FeatureCache(part.n_regions, max_age=4)
                  for _ in range(3)]
        for i, c in enumerate(caches):
            server.infer_plan(frames[i], warm_plan, beta=2, cache=c,
                              frame_idx=0)
        return caches

    plans = []
    for sel in ((8, 9), (10, 11), (12, 13)):
        states = warm_plan.states.copy()
        states[list(sel)] = REUSE
        plans.append(RegionPlan(states))
    # bucket-exact reuse needs n_reuse on a bucket edge (step = 4 for 16
    # regions): use 4 reused regions per plan
    plans = []
    for sel in ((8, 9, 10, 11), (9, 10, 11, 12), (10, 11, 12, 13)):
        states = warm_plan.states.copy()
        states[list(sel)] = REUSE
        plans.append(RegionPlan(states))

    caches_w = warm()
    wave = server.infer_wave(frames, plans, beta=2, caches=caches_w,
                             frame_ids=[1, 1, 1])
    caches_s = warm()
    for i in range(3):
        solo = server.infer_plan(frames[i], plans[i], beta=2,
                                 cache=caches_s[i], frame_idx=1)
        assert wave[i] == solo
        np.testing.assert_array_equal(np.asarray(caches_w[i].tiles),
                                      np.asarray(caches_s[i].tiles))
        assert caches_w[i].age.tolist() == caches_s[i].age.tolist()


# ---------------------------------------------------------------------------
# cross-bucket coalescing


def test_coalesced_job_bit_identical_to_solo_at_promoted_bucket(setup):
    """A wave mixing length buckets runs at the LARGEST one (shorter
    plans pad further — zero resolution changes) and each sample matches
    the solo run of the same padded configuration bit-identically."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                         b_buckets=(2,))
    frames = _frames(2, seed=4)
    plan_a = RegionPlan.from_mask(_mask(part, range(4)))   # 52 w -> lb 64
    plan_b = RegionPlan.from_mask(_mask(part, range(8)))   # 40 w -> lb 48
    wave = server.infer_wave(frames, [plan_a, plan_b], beta=2)
    assert list(server._fns) == [(64, 2, 2, 2)]            # one executable
    solo_b = server.infer_wave(frames[1][None], [plan_b], beta=2,
                               lb_override=64)[0]
    assert wave[1] == solo_b
    solo_a = server.infer_wave(frames[0][None], [plan_a], beta=2)[0]
    assert wave[0] == solo_a


def test_override_may_only_pad(setup):
    """lb_override may only pad FURTHER (and must be a bucket edge):
    shrinking below the plan's window count would drop transmitted
    windows."""
    params, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    plan = RegionPlan.from_mask(_mask(part, range(4)))     # 52 windows
    with pytest.raises(AssertionError):
        server.infer_wave(_frames(1), [plan], beta=2, lb_override=48)
    with pytest.raises(AssertionError):
        server.infer_wave(_frames(1), [plan], beta=2, lb_override=60)


class TwoBucketPolicy(Policy):
    """Fixed-layout policy whose n_low differs per client — the
    mixed-bucket workload coalescing exists for."""
    name = "twobucket"
    use_tracker = True

    def __init__(self, lows, beta=2, n_regions=16):
        self.lows = list(lows)
        self.beta = beta
        self.n_regions = n_regions

    def decide(self, sim, frame_idx):
        m = np.zeros(self.n_regions, np.int32)
        m[self.lows] = 1
        return {"mask": m, "quality": 85, "beta": self.beta}


def _mixed_bucket_clients(server, part, n_frames=12):
    slow = lambda beta, n_d, n_r=0: 0.5      # force queueing
    clients = []
    for i, lows in enumerate((range(4), range(8), range(4, 8),
                              range(8, 16))):
        frames, _ = sv.make_clip("walkS", n_frames, size=SIZE,
                                 seed=10 + i)
        gt = [server.infer(f) for f in frames]
        pol = TwoBucketPolicy(lows, n_regions=part.n_regions)
        clients.append(Simulation(frames, gt,
                                  make_trace("4g", i, duration_s=60),
                                  pol, server, part, PATCH, fps=10,
                                  inf_delay=slow))
    return clients


@pytest.mark.slow
def test_coalescing_grows_waves_and_matches_solo(setup):
    """Mixed-bucket multi-client workload: coalescing promotes jobs,
    grows the mean wave, and every promoted job's detections equal the
    solo run of its promoted configuration bit-exactly (single batch
    bucket -> shared executables)."""
    params, part = setup
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0,
                                b_buckets=(4,))
    completed = {}

    def keep(ci, job):
        completed[(ci, job["frame"])] = job

    mc_on = MultiClientSimulation(
        _mixed_bucket_clients(server, part), server,
        EdgeConfig(batched=True, coalesce=True), on_complete=keep)
    mc_on.run()
    mc_off = MultiClientSimulation(
        _mixed_bucket_clients(server, part), server,
        EdgeConfig(batched=True, coalesce=False))
    mc_off.run()

    assert mc_on.stats.promoted > 0
    assert mc_on.stats.mean_wave_size > mc_off.stats.mean_wave_size

    promoted = [j for j in completed.values() if "promoted_lb" in j]
    assert promoted
    for job in promoted:
        # a promoted job ran padded to the wave's length bucket; the
        # solo run of the same padded configuration (same executable:
        # single batch bucket) must match bit-exactly
        solo = server.infer_wave(
            job["decoded"][None], [job["plan"]], job["beta"],
            lb_override=job["promoted_lb"])[0]
        assert job["dets"] == solo


# ---------------------------------------------------------------------------
# device-resident feature caches


def test_device_cache_ships_zero_tile_bytes(setup):
    params, part = setup
    frames = _frames(3, seed=5)

    def reuse_run(device_cache):
        server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                             device_cache=device_cache)
        cache = FeatureCache(part.n_regions, max_age=4)
        plan = RegionPlan.from_mask(_mask(part, range(4)))
        server.infer_plan(frames[0], plan, beta=2, cache=cache,
                          frame_idx=0)
        states = plan.states.copy()
        states[8:12] = REUSE
        for fi in (1, 2):
            server.infer_plan(frames[fi], RegionPlan(states), beta=2,
                              cache=cache, frame_idx=fi)
        return server, cache

    dev_server, dev_cache = reuse_run(True)
    assert dev_cache.tiles_on_device
    assert dev_server.stats.tile_bytes == 0
    assert dev_server.stats.tile_bytes_per_offload() == 0.0

    host_server, host_cache = reuse_run(False)
    assert not host_cache.tiles_on_device
    assert host_server.stats.tile_bytes_d2h > 0    # capture copies out
    assert host_server.stats.tile_bytes_h2d > 0    # reuse re-uploads


def test_device_and_host_caches_agree(setup):
    """Residence is a pure transport choice: detections and cache
    contents agree across modes."""
    params, part = setup
    frames = _frames(2, seed=6)
    plan = RegionPlan.from_mask(_mask(part, range(4)))
    states = plan.states.copy()
    states[8:12] = REUSE
    outs = {}
    for mode in (True, False):
        server = ServerModel(SIM, params, top_k=8, score_thresh=0.0,
                             device_cache=mode)
        cache = FeatureCache(part.n_regions, max_age=4)
        server.infer_plan(frames[0], plan, beta=2, cache=cache,
                          frame_idx=0)
        dets = server.infer_plan(frames[1], RegionPlan(states), beta=2,
                                 cache=cache, frame_idx=1)
        outs[mode] = (dets, np.asarray(cache.tiles))
    assert len(outs[True][0]) == len(outs[False][0])
    a = np.array([d["box"] for d in outs[True][0]], np.float64)
    b = np.array([d["box"] for d in outs[False][0]], np.float64)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=0.1)
    np.testing.assert_allclose(outs[True][1], outs[False][1],
                               rtol=1e-5, atol=1e-5)


def test_feature_cache_update_donates_stale_device_buffer():
    cache = FeatureCache(n_regions=4, max_age=2)
    t0 = jnp.ones((4, 1, 4, 8), jnp.float32)
    cache.update(t0, np.zeros((0,), np.int32), beta=2, frame=0)
    assert cache.tiles_on_device
    stale = cache.tiles
    cache.update(stale * 2.0, np.zeros((0,), np.int32), beta=2, frame=1)
    # the stale buffer was donated into the refresh
    with pytest.raises(Exception):
        np.asarray(stale)
    assert float(np.asarray(cache.tiles).mean()) == 2.0


# ---------------------------------------------------------------------------
# scheduler satellites: sorted-on-insert queue, bounded EdgeStats.jobs


def _fake_job(arrival, n_d=4, frame=0):
    states = np.zeros((16,), np.int8)
    states[:n_d] = 1                              # LOW
    return {"arrival": arrival, "n_d": n_d, "n_r": 0, "beta": 2,
            "plan": RegionPlan(states),
            "frame": frame, "_client": 0, "t_dec": 0.0, "t_inf": 0.1}


def test_pending_queue_sorted_on_insert(setup):
    params, part = setup
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    frames, _ = sv.make_clip("walkS", 2, size=SIZE, seed=0)
    sim = Simulation(frames, [[], []], make_trace("4g", 0, duration_s=10),
                     TwoBucketPolicy(range(4), n_regions=part.n_regions),
                     server, part, PATCH, fps=10)
    mc = MultiClientSimulation([sim], server)
    order = []
    mc._run_wave = lambda wave, t_start, key: (
        order.extend(j["frame"] for _, j in wave) or (t_start + 0.01))
    for i, arr in enumerate([0.5, 0.1, 0.9, 0.3]):
        mc._enqueue(0, _fake_job(arr, frame=i))
    assert [j["arrival"] for _, j in mc.pending] == [0.1, 0.3, 0.5, 0.9]
    mc._drain(float("inf"))
    # waves form in ARRIVAL order, not insertion order
    assert order[0] == 1 and set(order) == {0, 1, 2, 3}


def test_wave_never_exceeds_largest_batch_bucket(setup):
    """max_batch larger than the biggest batch bucket must not form an
    unservable wave (padding only rounds UP)."""
    params, part = setup
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0,
                                b_buckets=(2,))
    frames, _ = sv.make_clip("walkS", 2, size=SIZE, seed=2)
    sim = Simulation(frames, [[], []], make_trace("4g", 0, duration_s=10),
                     TwoBucketPolicy(range(4), n_regions=part.n_regions),
                     server, part, PATCH, fps=10)
    mc = MultiClientSimulation([sim], server, EdgeConfig(max_batch=8))
    assert mc.max_wave == 2
    sizes = []
    mc._run_wave = lambda wave, t_start, key: (
        sizes.append(len(wave)) or (t_start + 0.01))
    for i in range(5):
        mc._enqueue(0, _fake_job(0.1 * i, frame=i))
    mc._drain(float("inf"))
    assert sizes and max(sizes) <= 2 and sum(sizes) == 5


def test_edge_stats_dets_opt_in(setup):
    params, part = setup
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)

    def one_client():
        frames, _ = sv.make_clip("walkS", 6, size=SIZE, seed=1)
        gt = [server.infer(f) for f in frames]
        return [Simulation(frames, gt, make_trace("4g", 0, duration_s=30),
                           TwoBucketPolicy(range(4),
                                           n_regions=part.n_regions),
                           server, part, PATCH, fps=10)]

    mc = MultiClientSimulation(one_client(), server, EdgeConfig())
    mc.run()
    assert mc.stats.jobs and all("dets" not in j for j in mc.stats.jobs)
    mc2 = MultiClientSimulation(one_client(), server,
                                EdgeConfig(keep_dets=True))
    mc2.run()
    assert mc2.stats.jobs and all("dets" in j for j in mc2.stats.jobs)


# ---------------------------------------------------------------------------
# positional-embedding cache LRU


def test_pos_cache_evicts_lru_not_everything():
    part = pt.make_partition(16, 16, window=2, downsample=2)  # 16 regions
    pos = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, 16, 8)).astype(np.float32))
    saved = dict(vb._POS_CACHE)
    vb._POS_CACHE.clear()
    try:
        layouts = []
        for i in range(16):
            for j in range(i + 1, 16):
                layouts.append((i, j))
        layouts = layouts[:vb._POS_CACHE_MAX + 8]
        for (i, j) in layouts:
            states = np.zeros(16, np.int8)
            states[[i, j]] = 1            # LOW
            f, l, _ = pt.plan_to_region_ids(states, 2, 0)
            vb.packed_positions(pos, part, jnp.asarray(f), jnp.asarray(l))
        assert len(vb._POS_CACHE) == vb._POS_CACHE_MAX
        # the most recent layout survives; the oldest was evicted alone
        keys = list(vb._POS_CACHE)
        assert len(keys) == vb._POS_CACHE_MAX
        first_victims = layouts[:len(layouts) - vb._POS_CACHE_MAX]
        assert len(first_victims) == 8
        # re-touching the newest entry is a hit (no growth, stays last)
        i, j = layouts[-1]
        states = np.zeros(16, np.int8)
        states[[i, j]] = 1
        f, l, _ = pt.plan_to_region_ids(states, 2, 0)
        vb.packed_positions(pos, part, jnp.asarray(f), jnp.asarray(l))
        assert len(vb._POS_CACHE) == vb._POS_CACHE_MAX
        assert list(vb._POS_CACHE)[-1] == keys[-1]
    finally:
        vb._POS_CACHE.clear()
        vb._POS_CACHE.update(saved)
