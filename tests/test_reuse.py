"""Temporal region reuse: three-state RegionPlan through the stack
(core.partition -> mixed_res -> vit_backbone -> simulator -> edge)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.vitdet_l import SIM
from repro.core import mixed_res as mr
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import FULL, LOW, REUSE, RegionPlan
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.optimizer import build_reuse_plan
from repro.offload.simulator import Policy, Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)
from repro.serve.request import FeatureCache

PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]


# ---------------------------------------------------------------------------
# RegionPlan + id mapping


def test_region_plan_counts_and_masks():
    states = np.array([FULL, LOW, REUSE, LOW, FULL, REUSE], np.int8)
    plan = RegionPlan(states)
    assert plan.n_low == 2 and plan.n_reuse == 2 and plan.n_transmit == 4
    assert plan.low_mask().tolist() == [0, 1, 0, 1, 0, 0]
    assert plan.reuse_mask().tolist() == [0, 0, 1, 0, 0, 1]
    p2 = RegionPlan.from_mask(np.array([0, 1, 1, 0]))
    assert p2.n_low == 2 and p2.n_reuse == 0


def test_plan_to_region_ids_three_state():
    states = np.array([REUSE, LOW, FULL, FULL, LOW, REUSE, FULL, FULL],
                      np.int8)
    full, low, reuse = pt.plan_to_region_ids(states, 2, 2)
    assert sorted(low.tolist()) == [1, 4]
    assert sorted(reuse.tolist()) == [0, 5]
    assert sorted(full.tolist()) == [2, 3, 6, 7]
    # buckets smaller than the selection: extras revert to FULL
    full, low, reuse = pt.plan_to_region_ids(states, 1, 1)
    assert low.tolist() == [1] and reuse.tolist() == [0]
    assert len(full) == 6 and 4 in full and 5 in full


def test_mask_to_region_ids_matches_plan_special_case():
    mask = np.zeros(16, np.int32)
    mask[[1, 5, 7]] = 1
    f2, l2 = pt.mask_to_region_ids(mask, 3)
    states = np.where(mask != 0, LOW, FULL).astype(np.int8)
    f3, l3, r3 = pt.plan_to_region_ids(states, 3, 0)
    np.testing.assert_array_equal(f2, f3)
    np.testing.assert_array_equal(l2, l3)
    assert r3.shape == (0,)


def test_partition_token_counts_with_reuse():
    p = pt.make_partition(16, 16, window=2, downsample=2)
    assert p.n_tokens(2, 4) == p.n_tokens(2) - 4 * p.tokens_full_region
    assert p.n_windows(2, 4) == p.n_windows(2) - 4 * p.windows_per_full_region
    assert p.n_tokens(0, p.n_regions) == 0


# ---------------------------------------------------------------------------
# restore_full with reuse tiles + sentinel scatter


def small_partition():
    return pt.make_partition(16, 16, window=2, downsample=2)


def test_restore_full_splices_reuse_tiles():
    p = small_partition()
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 8))
    states = np.full(p.n_regions, FULL, np.int8)
    states[[2, 9]] = LOW
    states[[4, 11]] = REUSE
    full_ids, low_ids, reuse_ids = pt.plan_to_region_ids(states, 2, 2)
    tokens, _ = mr.pack_mixed(x, p, jnp.asarray(full_ids),
                              jnp.asarray(low_ids))
    assert tokens.shape == (2, p.n_tokens(2, 2), 8)
    tiles = jax.random.normal(jax.random.PRNGKey(1),
                              (2, 2, p.windows_per_full_region,
                               p.tokens_low_region, 8))
    restored = mr.restore_full(tokens, p, jnp.asarray(full_ids),
                               jnp.asarray(low_ids),
                               reuse_ids=jnp.asarray(reuse_ids),
                               reuse_tiles=tiles)
    out = np.asarray(restored).reshape(2, p.n_regions,
                                       p.windows_per_full_region,
                                       p.tokens_low_region, 8)
    # reused regions hold the cached tiles verbatim
    for k, rid in enumerate(reuse_ids.tolist()):
        np.testing.assert_array_equal(out[:, rid], np.asarray(tiles[:, k]))
    # full regions hold the original grid content
    expect = np.asarray(mr.grid_to_region_windows(x, p))
    for rid in full_ids.tolist():
        np.testing.assert_array_equal(out[:, rid], expect[:, rid])


def test_restore_full_empty_reuse_bit_identical():
    p = small_partition()
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 16, 4))
    mask = np.zeros(p.n_regions, np.int32)
    mask[[0, 9]] = 1
    full_ids, low_ids = (jnp.asarray(a)
                         for a in pt.mask_to_region_ids(mask, 2))
    tokens, _ = mr.pack_mixed(x, p, full_ids, low_ids)
    a = mr.restore_full(tokens, p, full_ids, low_ids)
    b = mr.restore_full(tokens, p, full_ids, low_ids,
                        reuse_ids=jnp.zeros((0,), jnp.int32),
                        reuse_tiles=jnp.zeros(
                            (1, 0, p.windows_per_full_region,
                             p.tokens_low_region, 4)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_full_duplicate_pad_ids_first_write_wins():
    """Satellite fix: padded duplicate low ids scatter through the
    sentinel row, so the FIRST occurrence deterministically wins even
    when the duplicated window slots hold different (garbage) data."""
    p = small_partition()
    w2 = p.tokens_low_region
    nF = p.n_regions - 2
    full_ids = jnp.asarray([i for i in range(p.n_regions) if i != 3][:nF],
                           jnp.int32)
    low_ids = jnp.asarray([3, 3], jnp.int32)        # padded duplicate
    D = 4
    rng = np.random.default_rng(0)
    tokens = rng.normal(size=(1, nF * p.tokens_full_region + 2 * w2, D)
                        ).astype(np.float32)
    first_window = tokens[0, nF * p.tokens_full_region:
                          nF * p.tokens_full_region + w2].reshape(
                              p.window, p.window, D)
    restored = np.asarray(mr.restore_full(jnp.asarray(tokens), p,
                                          full_ids, low_ids))
    out = restored.reshape(1, p.n_regions, p.windows_per_full_region,
                           w2, D)
    # region 3 holds the upsampled FIRST duplicate window
    up = np.repeat(np.repeat(first_window, p.downsample, 0),
                   p.downsample, 1)
    d, w = p.downsample, p.window
    up = up.reshape(d, w, d, w, D).transpose(0, 2, 1, 3, 4).reshape(
        d * d, w2, D)
    np.testing.assert_allclose(out[0, 3], up, rtol=1e-6)
    # and the scatter is deterministic across runs
    again = np.asarray(mr.restore_full(jnp.asarray(tokens), p,
                                       full_ids, low_ids))
    np.testing.assert_array_equal(restored, again)


def test_restore_full_per_sample_duplicate_pad_ids():
    p = small_partition()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16, 4))
    # per-sample layouts where sample 1 has a padded duplicate
    ids = [pt.plan_to_region_ids(
        np.where(np.isin(np.arange(16), sel), LOW, FULL).astype(np.int8),
        2, 0)[:2] for sel in ([0, 9], [5])]
    assert ids[1][1].tolist() == [5, 5]             # dup pad happened
    fb = jnp.asarray(np.stack([f for f, _ in ids]))
    lb = jnp.asarray(np.stack([l for _, l in ids]))
    tok, _ = mr.pack_mixed(x, p, fb, lb)
    res = np.asarray(mr.restore_full(tok, p, fb, lb))
    # sample 0 must match its solo restore exactly
    f0, l0 = (jnp.asarray(a) for a in ids[0])
    tok0, _ = mr.pack_mixed(x[:1], p, f0, l0)
    solo = np.asarray(mr.restore_full(tok0, p, f0, l0))
    np.testing.assert_array_equal(res[:1], solo)
    assert np.isfinite(res).all()


# ---------------------------------------------------------------------------
# backbone: reuse forward + tile capture


def test_forward_det_empty_reuse_bit_identical():
    """Acceptance pin: with an empty reuse set, forward_det is
    bit-identical to the reuse-free call."""
    cfg = get_reduced("vitdet-l")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    part = vb.vit_partition(cfg)
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (1, *cfg.vit.img_size, 3))
    mask = np.zeros(part.n_regions, np.int32)
    mask[0] = 1
    fi, li = (jnp.asarray(a) for a in pt.mask_to_region_ids(mask, 1))
    base = vb.forward_det(cfg, params, img, fi, li, beta=2)
    empty_tiles = jnp.zeros((1, 0, part.windows_per_full_region,
                             part.tokens_low_region, cfg.d_model))
    (outs, tiles) = vb.forward_det(cfg, params, img, fi, li, beta=2,
                                   reuse_ids=jnp.zeros((0,), jnp.int32),
                                   reuse_tiles=empty_tiles, capture_beta=2)
    for a, b in zip(base, outs):
        for xa, xb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    assert tiles.shape == (1, part.n_regions,
                           part.windows_per_full_region,
                           part.tokens_low_region, cfg.d_model)


def test_reuse_of_same_frame_tiles_is_exact_at_beta1():
    """Structural invariant: before the first global block, full-region
    windows only ever attended within themselves, so tiles captured from
    a full-res forward at beta=1 and spliced back for the SAME frame
    reproduce the full-res features exactly."""
    cfg = get_reduced("vitdet-l")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    part = vb.vit_partition(cfg)
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (1, *cfg.vit.img_size, 3))
    f_full, tiles = vb.forward_features(cfg, params, img, capture_beta=1)
    states = np.full(part.n_regions, REUSE, np.int8)
    states[0] = FULL
    fi, li, ri = pt.plan_to_region_ids(states, 0, part.n_regions - 1)
    f_reuse = vb.forward_features(
        cfg, params, img, jnp.asarray(fi), jnp.asarray(li), beta=1,
        reuse_ids=jnp.asarray(ri), reuse_tiles=tiles[:, np.asarray(ri)])
    np.testing.assert_allclose(np.asarray(f_reuse), np.asarray(f_full),
                               rtol=1e-5, atol=1e-5)


def test_backbone_flops_decrease_with_reuse():
    cfg = get_reduced("vitdet-l")
    part = vb.vit_partition(cfg)
    f = [vb.backbone_flops(cfg, 0, 2, r) for r in range(part.n_regions)]
    assert all(f[i] > f[i + 1] for i in range(len(f) - 1))
    # reuse only matters before the restoration point
    assert vb.backbone_flops(cfg, 0, 0, 0) == vb.backbone_flops(cfg, 0, 0)


# ---------------------------------------------------------------------------
# FeatureCache + reuse planning


def test_feature_cache_staleness_state_machine():
    c = FeatureCache(n_regions=4, max_age=2)
    assert not c.eligible(2).any()                  # cold
    tiles = np.zeros((4, 1, 1, 8), np.float32)
    c.update(tiles, np.zeros((0,), np.int32), beta=2, frame=0)
    assert c.eligible(2).all()
    assert not c.eligible(3).any()                  # wrong RP
    c.update(tiles, np.array([1, 2]), beta=2, frame=1)
    assert c.age.tolist() == [0, 1, 1, 0]
    c.update(tiles, np.array([1, 2]), beta=2, frame=2)
    assert c.age.tolist() == [0, 2, 2, 0]
    assert c.eligible(2).tolist() == [True, False, False, True]  # K hit
    c.update(tiles, np.array([3]), beta=2, frame=3)
    assert c.age.tolist() == [0, 0, 0, 1]           # transmitted -> reset


def test_build_reuse_plan_bucket_exact_and_min_transmit():
    part = pt.make_partition(32, 32, window=4, downsample=2)   # 16 regions
    mask = np.zeros(16, np.int32)
    mask[:8] = 1
    m = np.zeros(16, np.float32)
    m[0] = 0.5                                     # region 0 moves
    eligible = np.ones(16, bool)
    plan = build_reuse_plan(part, mask, m, eligible)
    # 15 candidates, min_transmit=1 -> limit 15 -> bucket edge 12
    assert plan.n_reuse == 12
    assert plan.states[0] != REUSE
    assert plan.n_transmit >= 1
    # no eligibility -> plain two-state plan
    plan0 = build_reuse_plan(part, mask, m, np.zeros(16, bool))
    assert plan0.n_reuse == 0 and plan0.n_low == 8
    # all eligible + empty mask still keeps min_transmit regions
    plan_all = build_reuse_plan(part, np.zeros(16, np.int32),
                                np.zeros(16, np.float32),
                                np.ones(16, bool))
    assert plan_all.n_reuse == 12 and plan_all.n_transmit == 4


# ---------------------------------------------------------------------------
# server + sessions (SIM scale)


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    return params, server, vb.vit_partition(SIM)


class FixedReusePolicy(Policy):
    """Static low mask + motion-gated reuse at a fixed restoration point
    (deterministic; exercises the full plan/cache plumbing)."""
    name = "fixed-reuse"
    use_tracker = True
    reuse_k = 3

    def __init__(self, n_regions, lows=(0, 1, 2, 3), beta=2):
        self.n_regions = n_regions
        self.lows = list(lows)
        self.beta = beta

    def decide(self, sim, frame_idx):
        mask = np.zeros(self.n_regions, np.int32)
        mask[self.lows] = 1
        cache = sim.feature_cache
        elig = (cache.eligible(self.beta) if cache is not None
                else np.zeros(self.n_regions, bool))
        plan = build_reuse_plan(sim.part, mask, sim.m, elig)
        return {"mask": mask, "quality": 85, "beta": self.beta,
                "plan": plan, "capture_beta": self.beta}


def _client(server, part, seed, video="parkS", n_frames=10,
            inf_delay=None, delay_model=None):
    frames, _ = sv.make_clip(video, n_frames, size=SIZE, seed=seed)
    gt = [server.infer(f) for f in frames]
    trace = make_trace("4g", seed, duration_s=60)
    pol = FixedReusePolicy(part.n_regions)
    return Simulation(frames, gt, trace, pol, server, part, PATCH,
                      fps=10, inf_delay=inf_delay, delay_model=delay_model)


def _boxes(dets):
    return np.array([d["box"] for d in dets], np.float64).reshape(-1, 4)


def test_infer_plan_warms_and_reuses_cache(setup):
    _, server, part = setup
    rng = np.random.default_rng(0)
    frame = rng.uniform(0, 1, (SIZE, SIZE, 3)).astype(np.float32)
    cache = FeatureCache(part.n_regions, max_age=3)
    mask = np.zeros(part.n_regions, np.int32)
    mask[:4] = 1
    plan0 = RegionPlan.from_mask(mask)
    server.infer_plan(frame, plan0, beta=2, cache=cache, frame_idx=0)
    assert cache.warm and cache.beta == 2
    assert cache.tiles.shape == (part.n_regions,
                                 part.windows_per_full_region,
                                 part.tokens_low_region, SIM.d_model)
    # second offload reuses 4 regions
    states = plan0.states.copy()
    states[8:12] = REUSE
    dets = server.infer_plan(frame, RegionPlan(states), beta=2,
                             cache=cache, frame_idx=1)
    assert cache.age[8:12].tolist() == [1, 1, 1, 1]
    assert cache.age[:8].sum() == 0
    assert isinstance(dets, list)


def test_reuse_of_cached_static_frame_matches_transmit(setup):
    """Reusing tiles captured from the SAME frame must reproduce the
    plain mixed forward's detections (the static-scene limit)."""
    _, server, part = setup
    rng = np.random.default_rng(1)
    frame = rng.uniform(0, 1, (SIZE, SIZE, 3)).astype(np.float32)
    mask = np.zeros(part.n_regions, np.int32)
    cache = FeatureCache(part.n_regions, max_age=4)
    # warm at beta=1 on a full-res offload
    server.infer_plan(frame, RegionPlan.from_mask(mask), beta=0,
                      cache=cache, frame_idx=0, capture_beta=1)
    assert cache.beta == 1
    states = np.full(part.n_regions, FULL, np.int8)
    states[4:8] = REUSE
    d_reuse = server.infer_plan(frame, RegionPlan(states), beta=1,
                                cache=cache, frame_idx=1)
    d_full = server.infer(frame)
    assert len(d_reuse) == len(d_full)
    np.testing.assert_allclose(_boxes(d_reuse), _boxes(d_full),
                               rtol=1e-4, atol=0.1)


def test_infer_plans_batched_matches_solo(setup):
    """Co-batched reuse waves: each sample spliced from its OWN cache."""
    _, server, part = setup
    rng = np.random.default_rng(2)
    frames = rng.uniform(0, 1, (2, SIZE, SIZE, 3)).astype(np.float32)
    mask = np.zeros(part.n_regions, np.int32)
    mask[:4] = 1
    plan_warm = RegionPlan.from_mask(mask)

    def warm_caches():
        caches = [FeatureCache(part.n_regions, max_age=4)
                  for _ in range(2)]
        for i, c in enumerate(caches):
            server.infer_plan(frames[i], plan_warm, beta=2, cache=c,
                              frame_idx=0)
        return caches

    plans = []
    for sel in ((8, 9, 10, 11), (12, 13, 14, 15)):
        states = plan_warm.states.copy()
        states[list(sel)] = REUSE
        plans.append(RegionPlan(states))

    caches_b = warm_caches()
    batched = server.infer_plans(frames, plans, 2, caches_b, [1, 1])
    caches_s = warm_caches()
    for i in range(2):
        solo = server.infer_plan(frames[i], plans[i], beta=2,
                                 cache=caches_s[i], frame_idx=1)
        assert len(batched[i]) == len(solo)
        np.testing.assert_allclose(_boxes(batched[i]), _boxes(solo),
                                   rtol=1e-4, atol=0.1)
        np.testing.assert_allclose(caches_b[i].tiles, caches_s[i].tiles,
                                   rtol=1e-4, atol=1e-4)
        assert caches_b[i].age.tolist() == caches_s[i].age.tolist()


def test_simulation_reuse_cuts_bytes_on_static_scene(setup):
    _, server, part = setup
    c = _client(server, part, seed=3)
    res = c.run("parkS")
    assert c.feature_cache.warm
    sizes = np.asarray(res.sizes)
    assert len(sizes) >= 3
    # offloads after the warm-up one ship far fewer bytes
    assert np.median(sizes[1:]) < 0.7 * sizes[0]


@pytest.mark.slow
def test_two_client_cache_isolation(setup):
    """Two clients, DIFFERENT content, shared replica: each client's
    detections must equal its own solo run — a cross-client cache leak
    would corrupt the reused features."""
    _, server, part = setup
    # zero service time -> the replica is never busy -> no queueing, so
    # the multi-client run is tick-for-tick identical to the solo runs
    from repro.offload.codec import CodecDelayModel
    fast = lambda beta, n_d, n_r=0: 0.0
    zero_dm = CodecDelayModel(enc_base=0.0, dec_base=0.0,
                              quality_slope=0.0, mixed_overhead=0.0)
    solo = {}
    for seed, vid in ((5, "parkS"), (6, "walkB")):
        r = _client(server, part, seed, vid, inf_delay=fast,
                    delay_model=zero_dm).run(vid)
        solo[vid] = r
    mc = MultiClientSimulation(
        [_client(server, part, 5, "parkS", inf_delay=fast,
                 delay_model=zero_dm),
         _client(server, part, 6, "walkB", inf_delay=fast,
                 delay_model=zero_dm)],
        server, EdgeConfig(batched=True))
    rs = mc.run(["parkS", "walkB"])
    for r_multi, vid in zip(rs, ("parkS", "walkB")):
        r_solo = solo[vid]
        np.testing.assert_allclose(r_solo.rendering_f1,
                                   r_multi.rendering_f1, atol=1e-6)
        np.testing.assert_allclose(r_solo.sizes, r_multi.sizes, rtol=1e-9)
        np.testing.assert_allclose(r_solo.e2e_latency, r_multi.e2e_latency,
                                   rtol=1e-9)
    # caches hold different content (different videos)
    ca, cb = (c.feature_cache for c in mc.clients)
    assert not np.allclose(ca.tiles, cb.tiles)


def test_server_fn_cache_stays_bounded_with_reuse(setup):
    """Plans over many frames must key a bounded set of compiled fns:
    (length buckets) x betas (+ the full-res executable) — the
    (n_low, n_reuse) mix is runtime data on the collapsed grid."""
    _, server, part = setup
    n_before = len(server._fns)
    c = _client(server, part, seed=7, n_frames=8)
    c.run("parkS")
    grown = len(server._fns) - n_before
    assert grown <= len(server.length_edges) + 1   # +1 full-res
