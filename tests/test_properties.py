"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an OPTIONAL dev dependency: when it is not installed
the whole module is skipped (importorskip) instead of aborting the
``pytest -x`` collection run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import mixed_res as mr
from repro.core import seq_mixed_res as smr
from repro.core.partition import (bucket_n_low, bucket_set, make_partition,
                                  mask_to_region_ids, region_ids_to_mask)
from repro.offload.optimizer import knee_point, pareto_frontier

SET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# partitioning (paper §III-A)


@SET
@given(w=st.sampled_from([2, 4, 8]), d=st.sampled_from([2, 4]),
       nh=st.integers(1, 4), nw=st.integers(1, 4))
def test_partition_token_counts(w, d, nh, nw):
    part = make_partition(nh * w * d, nw * w * d, w, d)
    assert part.n_regions == nh * nw
    # n_tokens interpolates between full and all-low monotonically
    counts = [part.n_tokens(n) for n in range(part.n_regions + 1)]
    assert counts[0] == part.grid_h * part.grid_w
    assert all(a > b for a, b in zip(counts, counts[1:]))
    # every mixed sequence tiles exactly into w*w windows (the paper's
    # structural invariant)
    for n in range(part.n_regions + 1):
        assert part.n_tokens(n) == part.n_windows(n) * w * w


@SET
@given(n_regions=st.sampled_from([4, 16, 64]),
       n_buckets=st.sampled_from([2, 4, 8]),
       n=st.integers(0, 64))
def test_bucket_rounds_down_to_valid_edge(n_regions, n_buckets, n):
    n = min(n, n_regions)
    b = bucket_n_low(n, n_regions, n_buckets)
    assert 0 <= b <= n                    # never rounds UP (accuracy-safe)
    assert b in bucket_set(n_regions, n_buckets)


@SET
@given(data=st.data(), nr=st.sampled_from([4, 16]))
def test_mask_roundtrip(data, nr):
    bits = data.draw(st.lists(st.booleans(), min_size=nr, max_size=nr))
    mask = np.array(bits, np.int32)
    n_low = int(mask.sum())
    full_ids, low_ids = mask_to_region_ids(mask, n_low)
    assert len(full_ids) == nr - n_low and len(low_ids) == n_low
    assert not set(full_ids) & set(low_ids)
    np.testing.assert_array_equal(region_ids_to_mask(low_ids, nr), mask)


# ---------------------------------------------------------------------------
# 2-D pack / restore (paper §III-B)


@SET
@given(data=st.data(), w=st.sampled_from([2, 4]), d=st.just(2),
       nh=st.integers(1, 2), nw=st.integers(1, 2))
def test_pack_restore_full_regions_identity(data, w, d, nh, nw):
    """Regions kept at full resolution survive pack->restore exactly."""
    part = make_partition(nh * w * d, nw * w * d, w, d)
    nr = part.n_regions
    n_low = data.draw(st.integers(0, nr - 1))
    bits = np.zeros(nr, np.int32)
    bits[data.draw(st.permutations(range(nr)))[:n_low]] = 1
    full_ids, low_ids = mask_to_region_ids(bits, n_low)

    key = jax.random.PRNGKey(data.draw(st.integers(0, 2 ** 16)))
    x = jax.random.normal(key, (1, part.grid_h, part.grid_w, 3))
    tokens, _ = mr.pack_mixed(x, part, jnp.asarray(full_ids),
                              jnp.asarray(low_ids))
    restored = mr.restore_full(tokens, part, jnp.asarray(full_ids),
                               jnp.asarray(low_ids))
    grid = mr.full_seq_to_grid(restored, part)

    # full regions byte-identical; low regions constant within d x d cells
    rpx = part.region
    for j in range(nr):
        ry, rx = divmod(j, part.regions_w)
        a = np.asarray(x[0, ry*rpx:(ry+1)*rpx, rx*rpx:(rx+1)*rpx])
        b = np.asarray(grid[0, ry*rpx:(ry+1)*rpx, rx*rpx:(rx+1)*rpx])
        if bits[j] == 0:
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
        else:
            cells = b.reshape(rpx // d, d, rpx // d, d, 3)
            np.testing.assert_allclose(
                cells, np.broadcast_to(cells[:, :1, :, :1], cells.shape),
                rtol=1e-6, atol=1e-6)


def test_grid_seq_roundtrip():
    part = make_partition(16, 16, 4, 2)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 8))
    seq = mr.grid_to_full_seq(x, part)
    back = mr.full_seq_to_grid(seq, part)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# ---------------------------------------------------------------------------
# 1-D pack / restore (sequence adaptation)


@SET
@given(data=st.data(), w=st.sampled_from([2, 4]), d=st.just(2),
       n_spans=st.integers(2, 6))
def test_seq_pack_restore_structure(data, w, d, n_spans):
    part = smr.SeqPartition(n_spans * w * d, w, d)
    n_low = data.draw(st.integers(0, n_spans))
    bits = np.zeros(n_spans, np.int32)
    bits[data.draw(st.permutations(range(n_spans)))[:n_low]] = 1
    pack = smr.build_seq_pack(bits, n_low, part)
    assert len(pack["mix_idx"]) == part.n_tokens(n_low)
    # positions strictly increase -> index-causality == position-causality
    pos = pack["pos_mix"]
    assert (np.diff(pos) > 0).all()
    # restore covers every full position with a valid mixed slot
    assert pack["restore_idx"].min() >= 0
    assert pack["restore_idx"].max() < len(pack["mix_idx"])

    x = jax.random.normal(jax.random.PRNGKey(0), (1, part.seq_len, 8))
    xm = smr.pack_sequence(x, jnp.asarray(pack["mix_idx"]), d)
    xr = smr.restore_sequence(xm, jnp.asarray(pack["restore_idx"]))
    assert xr.shape == x.shape
    # unpooled spans restore exactly
    for s in range(n_spans):
        t0 = s * part.span
        if bits[s] == 0:
            np.testing.assert_allclose(
                np.asarray(xr[0, t0:t0 + part.span]),
                np.asarray(x[0, t0:t0 + part.span]), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Pareto / knee (Algorithm 1)


@SET
@given(pts=st.lists(st.tuples(st.floats(0.01, 10), st.floats(0, 1)),
                    min_size=1, max_size=30))
def test_pareto_frontier_properties(pts):
    Z = [{"config": i, "T": t, "A": a} for i, (t, a) in enumerate(pts)]
    front = pareto_frontier(Z)
    assert front, "frontier never empty"
    ts = [z["T"] for z in front]
    as_ = [z["A"] for z in front]
    assert ts == sorted(ts)                       # sorted by latency
    assert all(b > a for a, b in zip(as_, as_[1:]))   # accuracy increases
    # no frontier point is dominated by any candidate
    for f in front:
        for z in Z:
            assert not (z["T"] < f["T"] - 1e-12 and z["A"] > f["A"] + 1e-12)
    k = knee_point(front)
    assert k in front


# ---------------------------------------------------------------------------
# codec / estimator / checkpoint invariants


@SET
@given(seed=st.integers(0, 2 ** 16), q1=st.integers(70, 90))
def test_codec_quality_monotone(seed, q1):
    from repro.core.partition import make_partition
    from repro.offload.codec import MixedResCodec
    rng = np.random.default_rng(seed)
    part = make_partition(8, 8, 2, 2)
    codec = MixedResCodec(part, 16, 2)
    frame = rng.uniform(0, 1, (128, 128, 3)).astype(np.float32)
    mask = np.zeros(part.n_regions, np.int32)
    s_lo = codec.encode_size_only(frame, mask, q1)
    s_hi = codec.encode_size_only(frame, mask, 100)
    assert s_lo <= s_hi                     # higher quality never smaller


def test_checkpoint_roundtrip(tmp_path):
    from repro.train import checkpoint as ckpt
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": [{"w": jnp.ones((4,), jnp.bfloat16)},
                       {"w": jnp.zeros((2, 2), jnp.int32)}]}
    ckpt.save(tree, str(tmp_path), step=7)
    back = ckpt.restore(jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree),
        str(tmp_path))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float64), np.asarray(b, np.float64))


def test_grad_compression_error_feedback_converges():
    """int8 compression with error feedback: accumulated compressed sums
    approach the true sum (the residual stays bounded)."""
    from repro.optim.grad_compression import quantize_roundtrip
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1e-3, (256,)).astype(np.float32))
    err = jnp.zeros_like(g)
    total_true = np.zeros((256,), np.float64)
    total_comp = np.zeros((256,), np.float64)
    for _ in range(50):
        dec, err = quantize_roundtrip(g, err)
        total_true += np.asarray(g, np.float64)
        total_comp += np.asarray(dec, np.float64)
    # relative drift of the accumulated signal stays small
    denom = np.abs(total_true).mean()
    assert np.abs(total_comp - total_true).mean() < 0.05 * denom + 1e-6
