"""Offload-system component tests (paper C2)."""
import numpy as np
import pytest

from repro.core.partition import make_partition
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace, trace_set
from repro.offload import motion as mo
from repro.offload.codec import CodecDelayModel, MixedResCodec
from repro.offload.detection import frame_f1, iou, match_detections
from repro.offload.estimator import (InferenceDelayModel, LinearEstimator,
                                     MLPEstimator, OfflineMean,
                                     ThroughputEstimator,
                                     regression_metrics)
from repro.offload.optimizer import (OffloadConfig, OffloadOptimizer,
                                     SystemState, candidate_configs,
                                     knee_point, pareto_frontier)
from repro.offload.tracker import LKTracker

PART = make_partition(32, 32, window=4, downsample=2)   # 4x4 regions
PATCH = 16


def test_synthetic_video_deterministic():
    f1, g1 = sv.make_clip("walkS", 5, size=128, seed=3)
    f2, g2 = sv.make_clip("walkS", 5, size=128, seed=3)
    np.testing.assert_array_equal(f1, f2)
    assert all(len(a) == len(b) for a, b in zip(g1, g2))
    assert f1.shape == (5, 128, 128, 3)
    assert f1.min() >= 0 and f1.max() <= 1


def test_motion_analyzer_finds_moving_objects():
    frames, gts = sv.make_clip("walkB", 12, size=512, seed=0)
    an = mo.RegionMotionAnalyzer(PART, PATCH)
    for f in frames[:-1]:
        m, m_f = an.update(f)
    m, m_f = an.update(frames[-1])
    assert m.shape == (16,)
    assert abs(m.sum() - 1.0) < 1e-4 or m.sum() == 0
    assert 0 <= m_f <= 1


def test_region_classification_rules():
    m = np.array([0.0, 0.1, 0.2, 0.0005])
    rho = np.array([0.0, 0.0, 0.5, 0.9])
    phi = mo.classify_regions(m, rho, delta_m=0.001, delta_rho=0.0)
    assert phi.tolist() == [0, 1, 2, 0]       # SBR, CMR, DOR, SBR
    assert mo.downsample_mask(phi, 0).sum() == 0
    assert mo.downsample_mask(phi, 1).tolist() == [0, 1, 0, 0]
    assert mo.downsample_mask(phi, 2).tolist() == [1, 1, 0, 1]


def test_codec_size_monotonic_in_quality_and_mask():
    frames, _ = sv.make_clip("cycleS", 2, size=512, seed=1)
    codec = MixedResCodec(PART, PATCH, 2)
    mask0 = np.zeros(16, np.int32)
    mask8 = np.zeros(16, np.int32)
    mask8[:8] = 1
    s_q95 = codec.encode_size_only(frames[0], mask0, 95)
    s_q70 = codec.encode_size_only(frames[0], mask0, 70)
    s_down = codec.encode_size_only(frames[0], mask8, 95)
    assert s_q70 < s_q95
    assert s_down < s_q95
    # decode returns a full-canvas frame
    enc, dec = codec.encode(frames[0], mask8, 85)
    assert dec.shape == frames[0].shape
    assert np.isfinite(dec).all()


def test_codec_delay_model_monotonic():
    dm = CodecDelayModel()
    d0 = dm.encode_delay(PART, 0, 95)
    d8 = dm.encode_delay(PART, 8, 95)
    assert d8 < d0 + dm.mixed_overhead + 1e-9
    assert dm.decode_delay(PART, 8) < dm.decode_delay(PART, 0)


def test_encode_size_only_matches_encode():
    """Satellite: the decode-free fast path must report byte-identical
    payload sizes to the full encode, with and without reuse."""
    frames, _ = sv.make_clip("walkS", 2, size=512, seed=2)
    codec = MixedResCodec(PART, PATCH, 2)
    reuse = np.zeros(16, np.int32)
    reuse[10:14] = 1
    for quality in (70, 85, 95):
        for n in (0, 4, 8):
            mask = np.zeros(16, np.int32)
            mask[:n] = 1
            enc, _ = codec.encode(frames[0], mask, quality)
            assert codec.encode_size_only(frames[0], mask, quality) == \
                enc.payload_bytes
        mask = np.zeros(16, np.int32)
        mask[:4] = 1
        enc, _ = codec.encode(frames[1], mask, quality, reuse_mask=reuse)
        assert codec.encode_size_only(frames[1], mask, quality,
                                      reuse_mask=reuse) == enc.payload_bytes


def test_payload_monotonic_in_n_low_and_quality():
    """Satellite: payload bytes fall as n_low grows (fixed quality) and
    rise with quality (fixed mask)."""
    frames, _ = sv.make_clip("walkS", 1, size=512, seed=5)
    codec = MixedResCodec(PART, PATCH, 2)
    sizes = []
    for n in range(0, 17, 4):
        mask = np.zeros(16, np.int32)
        mask[:n] = 1
        sizes.append(codec.encode_size_only(frames[0], mask, 90))
    assert all(sizes[i] > sizes[i + 1] for i in range(len(sizes) - 1)), sizes
    mask = np.zeros(16, np.int32)
    mask[:6] = 1
    qsizes = [codec.encode_size_only(frames[0], mask, q)
              for q in (70, 80, 90, 100)]
    assert all(qsizes[i] < qsizes[i + 1]
               for i in range(len(qsizes) - 1)), qsizes


def test_codec_reuse_regions_ship_zero_bytes():
    frames, _ = sv.make_clip("walkS", 1, size=512, seed=6)
    codec = MixedResCodec(PART, PATCH, 2)
    mask = np.zeros(16, np.int32)
    reuse = np.zeros(16, np.int32)
    reuse[:8] = 1
    enc_all, dec = codec.encode(frames[0], mask, 90)
    enc_r, dec_r = codec.encode(frames[0], mask, 90, reuse_mask=reuse)
    # reused regions: empty streams, gray canvas, far fewer bytes
    assert all(len(s) == 0 for s in enc_r.streams[:8])
    assert all(len(s) > 0 for s in enc_r.streams[8:])
    assert enc_r.payload_bytes < 0.75 * enc_all.payload_bytes
    rpx = codec.region_px()
    assert (dec_r[:rpx, :rpx] == 0.5).all()
    # untouched regions decode identically
    np.testing.assert_array_equal(dec_r[-rpx:, -rpx:], dec[-rpx:, -rpx:])


def test_codec_delay_model_reuse_scales_with_transmitted_regions():
    dm = CodecDelayModel()
    # a reused region is cheaper than a downsampled one, which is
    # cheaper than a full one
    assert dm.encode_delay(PART, 0, 90, n_reuse=8) < \
        dm.encode_delay(PART, 8, 90) < dm.encode_delay(PART, 0, 90)
    assert dm.decode_delay(PART, 0, n_reuse=8) < dm.decode_delay(PART, 8)
    # everything-reused degenerates to the (clamped) overhead floor
    assert dm.decode_delay(PART, 0, n_reuse=16) == 0.0


def test_tracker_follows_translation():
    rng = np.random.default_rng(0)
    base = rng.uniform(0, 1, (96, 96, 3)).astype(np.float32)
    obj = rng.uniform(0, 1, (20, 20, 3)).astype(np.float32)

    def frame(dx):
        f = base.copy()
        f[30:50, 20 + dx:40 + dx] = obj
        return f

    tr = LKTracker()
    tr.reinit(frame(0), [{"box": (20, 30, 40, 50), "cls": 0}])
    for dx in (2, 4, 6):
        boxes = tr.step(frame(dx))
    assert boxes, "track lost"
    x1 = boxes[0]["box"][0]
    assert 23 <= x1 <= 29, x1          # should have moved ~+6 px
    assert tr.retention == 1.0


@pytest.mark.slow
def test_estimators_and_metrics():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (400, 8)).astype(np.float32)
    y = (3 * X[:, 0] ** 2 + np.sin(3 * X[:, 1]) + 0.5 * X[:, 2]
         ).astype(np.float32)
    mlp, lin, om = MLPEstimator(), LinearEstimator(), OfflineMean()
    mlp.fit(X[:300], y[:300], steps=800)
    lin.fit(X[:300], y[:300])
    om.fit(X[:300], y[:300])
    m_mlp = regression_metrics(y[300:], mlp.predict(X[300:]))
    m_lin = regression_metrics(y[300:], lin.predict(X[300:]))
    m_om = regression_metrics(y[300:], om.predict(X[300:]))
    # paper Table II ordering: MLP beats Linear beats/approx OfflineMean
    assert m_mlp["RMSE"] < m_lin["RMSE"]
    assert m_mlp["R2"] > m_lin["R2"] > m_om["R2"] - 1e-9


def test_inference_delay_model_from_flops():
    from repro.configs import get_config
    from repro.core import vit_backbone as vb
    cfg = get_config("vitdet-l")
    part = vb.vit_partition(cfg)
    lm = InferenceDelayModel.fit_from_flops(
        lambda n, b: vb.backbone_flops(cfg, n, b), part.n_regions,
        betas=(0, 1, 2, 3, 4), full_res_delay_s=0.281)
    # full res must be ~the paper's 281 ms measurement
    assert abs(lm(0, 0) - 0.281) < 0.02
    # later RPs and more regions are faster
    assert lm(4, 8) < lm(1, 8) < lm(0, 0) + 1e-9
    assert lm(4, 16) < lm(4, 4)
    # a 2-arg flops_fn yields a legacy model: the reuse term is free
    assert lm(4, 8, 4) == lm(4, 8)

    # 3-arg flops_fn fits the reuse plane: reused regions cut delay
    lm3 = InferenceDelayModel.fit_from_flops(
        lambda n, b, r=0: vb.backbone_flops(cfg, n, b, r), part.n_regions,
        betas=(0, 1, 2, 3, 4), full_res_delay_s=0.281)
    assert abs(lm3(0, 0) - 0.281) < 0.02
    assert lm3(2, 0, 8) < lm3(2, 0, 0)
    assert lm3(2, 4, 8) < lm3(2, 4, 0)
    # a reused region saves more than a downsampled one (zero tokens)
    assert lm3(2, 0, 8) < lm3(2, 8, 0)


def test_pareto_and_knee():
    Z = [{"config": i, "T": t, "A": a}
         for i, (t, a) in enumerate([(1.0, 0.5), (2.0, 0.8), (3.0, 0.9),
                                     (2.5, 0.7), (4.0, 0.91)])]
    front = pareto_frontier(Z)
    ts = [z["T"] for z in front]
    assert ts == sorted(ts)
    assert all(z["T"] != 2.5 for z in front)      # dominated point dropped
    k = knee_point(front)
    assert k in front


def test_candidate_config_space():
    cs = candidate_configs()
    # 7 qualities * (1 + 2*4 betas) = 63 configs
    assert len(cs) == 7 + 2 * 7 * 4
    assert all(c.beta == 0 for c in cs if c.tau_d == 0)


def test_network_traces_in_paper_ranges():
    traces = trace_set(n_per_kind=5, duration_s=60)
    for t in traces:
        assert t.tput_bps.shape == (60,)
        if t.kind == "4g":
            assert 5 < t.mean_mbps < 50
        else:
            assert 8 < t.mean_mbps < 200
        assert 0.01 < t.rtt_s.mean() < 0.2
    t0 = make_trace("4g", 0)
    t1 = make_trace("4g", 0)
    np.testing.assert_array_equal(t0.tput_bps, t1.tput_bps)


def test_detection_metrics():
    a = {"box": (0, 0, 10, 10), "cls": 1, "score": 0.9}
    b = {"box": (1, 1, 11, 11), "cls": 1, "score": 0.8}
    assert iou(a["box"], b["box"]) > 0.6
    tp, fp, fn = match_detections([a], [b])
    assert (tp, fp, fn) == (1, 0, 0)
    assert frame_f1([a], [b]) == 1.0
    c = {"box": (50, 50, 60, 60), "cls": 2, "score": 0.9}
    assert frame_f1([a, c], [b]) == pytest.approx(2 / 3)
