"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting output shapes and no NaNs.
(The FULL configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_MODULES, ASSIGNED, get_config, get_reduced
from repro.models import registry
from repro.models.config import ModelConfig

BATCH, SEQ = 2, 64


def _batch_for(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (BATCH, SEQ), 0,
                                          cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[1], (BATCH, cfg.encdec.encoder_seq_len, cfg.d_model),
            jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (BATCH, cfg.vlm.n_image_tokens, cfg.vlm.vision_hidden),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_loss(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    hidden, aux = registry.forward_hidden(cfg, params, batch)
    exp_t = SEQ + (cfg.vlm.n_image_tokens if cfg.family == "vlm" else 0)
    assert hidden.shape == (BATCH, exp_t, cfg.d_model)
    assert np.isfinite(np.asarray(hidden)).all()

    loss, metrics = registry.lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_grads_finite(arch):
    cfg = get_reduced(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        return registry.lm_loss(cfg, p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.isfinite(np.asarray(g)).all()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_forward(arch):
    """prefill + KV-cache decode must agree with the full forward pass."""
    cfg = get_reduced(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    n_img = cfg.vlm.n_image_tokens if cfg.family == "vlm" else 0
    max_len = SEQ + n_img + 8

    state = registry.init_decode_state(cfg, BATCH, max_len, jnp.float32)
    hidden_pf, state, _ = registry.prefill(cfg, params, batch, state)
    hidden_fw, _ = registry.forward_hidden(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(hidden_pf), np.asarray(hidden_fw),
                               rtol=2e-4, atol=2e-4)

    # one decode step must be finite and match teacher-forced logits
    tok = batch["tokens"][:, -1:]
    logits, state = registry.decode_step(cfg, params, tok, SEQ, state)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", list(ARCH_MODULES))
def test_full_config_matches_assignment(arch):
    """The full (published) configs carry the assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "mamba2-370m": dict(n_layers=48, d_model=1024, vocab_size=50280),
        "qwen3-4b": dict(n_layers=36, d_model=2560, n_heads=32,
                         n_kv_heads=8, d_ff=9728, vocab_size=151936),
        "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                                 n_kv_heads=8, d_ff=14336,
                                 vocab_size=131072),
        "phi4-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=24,
                               n_kv_heads=8, d_ff=8192, vocab_size=200064),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab_size=102400),
        "llava-next-mistral-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                                      n_kv_heads=8, d_ff=14336,
                                      vocab_size=32000),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48,
                          n_kv_heads=8, vocab_size=100352),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 vocab_size=102400),
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32,
                            d_ff=8192, vocab_size=32000),
        "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16,
                               d_ff=4096, vocab_size=51865),
        "vitdet-l": dict(n_layers=24, d_model=1024, n_heads=16, d_ff=4096),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_match_families():
    """Analytic param counts should land near the advertised sizes."""
    approx = {
        "mamba2-370m": (0.30e9, 0.5e9),
        "qwen3-4b": (3.5e9, 4.5e9),
        "mistral-nemo-12b": (11e9, 13.5e9),
        "phi4-mini-3.8b": (3.3e9, 4.4e9),
        "deepseek-7b": (6.2e9, 7.5e9),
        "llava-next-mistral-7b": (6.5e9, 7.8e9),
        "dbrx-132b": (125e9, 140e9),
        "deepseek-v2-236b": (220e9, 250e9),
        "zamba2-1.2b": (1.0e9, 1.5e9),
        # whisper-medium is 769M parameters (enc+dec; tiny/base/small/medium
        # = 39/74/244/769M) — our analytic count adds the learned pos-emb.
        "whisper-medium": (0.70e9, 0.85e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
