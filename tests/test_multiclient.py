"""Multi-client batched edge serving (serve/edge.py) + the simulator
satellite fixes that ride along with it."""
import jax
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import vit_backbone as vb
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.estimator import ThroughputEstimator
from repro.offload.simulator import Policy, ServerModel, Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation, stack_region_ids)

PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    # score_thresh 0: random-init scores are tiny, keep top_k slots live
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    return params, server, vb.vit_partition(SIM)


class FixedPolicy(Policy):
    """Deterministic per-client mask — exercises per-sample layouts."""
    name = "fixed"
    use_tracker = True

    def __init__(self, lows, beta=2, n_regions=16):
        self.lows = lows
        self.beta = beta
        self.n_regions = n_regions

    def decide(self, sim, frame_idx):
        mask = np.zeros(self.n_regions, np.int32)
        mask[self.lows] = 1
        return {"mask": mask, "quality": 85, "beta": self.beta}


def _client(server, part, seed, lows, n_frames=12, inf_delay=None):
    frames, _ = sv.make_clip("walkS", n_frames, size=SIZE, seed=seed)
    gt = [server.infer(f) for f in frames]
    trace = make_trace("4g", seed, duration_s=60)
    pol = FixedPolicy(lows, beta=2, n_regions=part.n_regions)
    return Simulation(frames, gt, trace, pol, server, part, PATCH,
                      fps=10, inf_delay=inf_delay)


def _boxes(dets):
    return np.array([d["box"] for d in dets], np.float64).reshape(-1, 4)


# ---------------------------------------------------------------------------
# batched server


def test_infer_batch_matches_solo_different_masks(setup):
    """Same n_low bucket, DIFFERENT masks, one batched call == solo runs.

    This is the 2-D regression for the wave-mask bug: pre-fix-style
    shared-layout batching would downsample client 1's regions with
    client 0's layout."""
    params, server, part = setup
    rng = np.random.default_rng(0)
    frames = rng.uniform(0, 1, (2, SIZE, SIZE, 3)).astype(np.float32)
    m0 = np.zeros(part.n_regions, np.int32)
    m0[:4] = 1
    m1 = np.zeros(part.n_regions, np.int32)
    m1[-4:] = 1
    batched = server.infer_batch(frames, [m0, m1], beta=2)
    for i, m in enumerate((m0, m1)):
        solo = server.infer(frames[i], m, beta=2)
        assert len(batched[i]) == len(solo)
        np.testing.assert_allclose(_boxes(batched[i]), _boxes(solo),
                                   rtol=1e-4, atol=0.1)


def test_infer_batch_serves_mixed_buckets(setup):
    """Masks in DIFFERENT n_low buckets co-batch on the collapsed grid:
    the wave runs at the longer plan's length bucket and each frame
    matches its solo run (old behaviour: AssertionError)."""
    params, server, part = setup
    rng = np.random.default_rng(7)
    frames = rng.uniform(0, 1, (2, SIZE, SIZE, 3)).astype(np.float32)
    m0 = np.zeros(part.n_regions, np.int32)
    m0[:4] = 1
    m1 = np.zeros(part.n_regions, np.int32)
    m1[:8] = 1
    batched = server.infer_batch(frames, [m0, m1], beta=2)
    for i, m in enumerate((m0, m1)):
        solo = server.infer(frames[i], m, beta=2)
        assert len(batched[i]) == len(solo)
        np.testing.assert_allclose(_boxes(batched[i]), _boxes(solo),
                                   rtol=1e-4, atol=0.1)


def test_server_cache_stays_bucketed(setup):
    """Varied masks must not grow _fns beyond length buckets x betas:
    EVERY (n_low, n_reuse) mix collapses onto the few length-bucket
    executables."""
    params, _, part = setup
    server = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    rng = np.random.default_rng(1)
    frame = rng.uniform(0, 1, (SIZE, SIZE, 3)).astype(np.float32)
    betas = (1, 2)
    for n in range(1, part.n_regions + 1):
        mask = np.zeros(part.n_regions, np.int32)
        mask[:n] = 1
        server.infer(frame, mask, beta=betas[n % len(betas)])
    assert len(server._fns) <= len(server.length_edges) * len(betas)


def test_stack_region_ids_shapes(setup):
    _, _, part = setup
    masks = []
    for s in (0, 4):
        m = np.zeros(part.n_regions, np.int32)
        m[s:s + 4] = 1
        masks.append(m)
    full, low = stack_region_ids(masks, 4)
    assert full.shape == (2, part.n_regions - 4) and low.shape == (2, 4)
    assert sorted(low[1].tolist()) == [4, 5, 6, 7]


# ---------------------------------------------------------------------------
# single-client satellite fixes


def test_final_inflight_offload_is_flushed(setup):
    """An offload still in flight at video end must reach SimResult."""
    _, server, part = setup
    # inference slower than the remaining clip: completion only via flush
    c = _client(server, part, seed=3, lows=[0, 1, 2, 3], n_frames=6,
                inf_delay=lambda beta, n_d: 60.0)
    res = c.run("v")
    assert c.inflight is None
    assert len(res.e2e_latency) == 1          # dropped entirely pre-fix
    assert len(res.inference_f1) == 1
    assert len(res.delay_parts) == 1
    assert res.e2e_latency[0] > 60.0


def test_first_offload_interval_not_recorded(setup):
    """The warm-up gap before the first offload is not an interval."""
    _, server, part = setup
    c = _client(server, part, seed=4, lows=[0, 1, 2, 3], n_frames=12)
    res = c.run("v")
    n_completed = len(res.e2e_latency)
    # every offload completes (end-of-clip flush), and every offload
    # EXCEPT the first records an inter-offload interval: pre-fix the
    # warm-up gap made these counts equal
    assert n_completed >= 2
    assert len(res.offload_interval) == n_completed - 1
    assert all(i >= 1 for i in res.offload_interval)


def test_throughput_estimator_window_bounded():
    est = ThroughputEstimator(window=2)
    for i in range(50):
        est.observe(10e6 + i, 0.04)
    assert len(est.obs_tput) == 2 and len(est.obs_rtt) == 2
    assert est.throughput == pytest.approx(10e6 + 48.5)


# ---------------------------------------------------------------------------
# multi-client engine


@pytest.mark.slow
def test_clients_batched_matches_sequential(setup):
    """Clients with different masks: batched detections == sequential,
    waves actually form, and queueing delay is accounted for.  (Three
    clients: with two, back-to-back offloads interleave and never
    overlap at the replica.)"""
    _, server, part = setup
    slow = lambda beta, n_d: 0.5          # force queueing -> real waves

    def clients():
        return [_client(server, part, seed=i, lows=list(range(4 * i,
                                                              4 * i + 4)),
                        n_frames=12, inf_delay=slow)
                for i in range(3)]

    done = []
    mc_b = MultiClientSimulation(clients(), server,
                                 EdgeConfig(batched=True, keep_dets=True),
                                 on_complete=lambda ci, job:
                                 done.append((ci, job["frame"])))
    res_b = mc_b.run()
    mc_s = MultiClientSimulation(clients(), server,
                                 EdgeConfig(batched=False, keep_dets=True))
    res_s = mc_s.run()

    assert max(mc_b.stats.wave_sizes) >= 2       # co-batching happened
    assert all(s == 1 for s in mc_s.stats.wave_sizes)
    assert len(done) == sum(len(r.e2e_latency) for r in res_b)
    assert any(q > 0 for q in mc_s.stats.queue_delays)

    jb = {(j["client"], j["frame"]): j["dets"] for j in mc_b.stats.jobs}
    js = {(j["client"], j["frame"]): j["dets"] for j in mc_s.stats.jobs}
    shared = set(jb) & set(js)
    assert shared
    for k in shared:
        assert len(jb[k]) == len(js[k])
        np.testing.assert_allclose(_boxes(jb[k]), _boxes(js[k]),
                                   rtol=1e-4, atol=0.5)
    # queueing delay is part of Eq. (2)'s e2e accounting
    for r in res_b + res_s:
        for e2e, parts in zip(r.e2e_latency, r.delay_parts):
            assert e2e == pytest.approx(parts["enc"] + parts["net"]
                                        + parts["dec"] + parts["inf"]
                                        + parts["queue"])


@pytest.mark.slow
def test_single_client_is_n1_case(setup):
    """MultiClientSimulation with N=1 reproduces Simulation.run."""
    _, server, part = setup
    r_solo = _client(server, part, seed=5, lows=[0, 1, 2, 3]).run("v")
    mc = MultiClientSimulation(
        [_client(server, part, seed=5, lows=[0, 1, 2, 3])], server,
        EdgeConfig(batched=True))
    r_multi = mc.run(["v"])[0]
    np.testing.assert_allclose(r_solo.e2e_latency, r_multi.e2e_latency,
                               rtol=1e-9)
    np.testing.assert_allclose(r_solo.rendering_f1, r_multi.rendering_f1,
                               atol=1e-6)
    assert r_solo.offload_interval == r_multi.offload_interval
    assert all(d["queue"] == 0.0 for d in r_multi.delay_parts)
