"""Serving engine: wave batching must reproduce the reference decode.

Wave-formation tests are pure python (fast lane); tests that run a
model are marked slow.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import registry
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request

ARCH = "qwen3-4b"
T, NEW = 32, 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced(ARCH)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# wave formation (no model needed: _wave_key/_form_wave are host-side)


def _queue_engine(masked_requests, max_batch=8):
    """Engine with params=None — only queue mechanics are exercised."""
    eng = ServeEngine(None, None, ServeConfig(max_batch=max_batch,
                                              buckets=(T,)))
    rng = np.random.default_rng(0)
    for rid, (mask, beta) in enumerate(masked_requests):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, 100, (T,)).astype(np.int32),
                           low_span_mask=mask, beta=beta))
    return eng


def test_wave_key_distinguishes_mask_content():
    """Same n_low, different span layout -> different waves (the
    cross-request wave-mask corruption fix)."""
    m_head = np.array([1, 0, 0, 0], np.int32)
    m_tail = np.array([0, 0, 0, 1], np.int32)
    eng = _queue_engine([(m_head, 2), (m_tail, 2), (m_head.copy(), 2)])
    k0, k1, k2 = (eng._wave_key(r) for r in eng.queue)
    assert k0 != k1                      # same popcount, different spans
    assert k0 == k2                      # identical masks may share
    wave = eng._form_wave()
    assert [r.rid for r in wave] == [0, 2]
    assert [r.rid for r in eng.queue] == [1]


def test_wave_key_ignores_mask_without_beta():
    m = np.array([1, 1, 0, 0], np.int32)
    eng = _queue_engine([(m, 0), (None, 0)])
    assert eng._wave_key(eng.queue[0]) == eng._wave_key(eng.queue[1])


def test_form_wave_respects_max_batch_and_order():
    m = np.array([1, 0, 0, 0], np.int32)
    eng = _queue_engine([(m, 2)] * 5, max_batch=2)
    wave = eng._form_wave()
    assert [r.rid for r in wave] == [0, 1]
    assert [r.rid for r in eng.queue] == [2, 3, 4]


def test_prefill_cache_key_uses_bucketed_n_low():
    """Varied span counts collapse onto bucket edges (bounded jit cache)."""
    reqs = []
    for n in range(1, 9):
        mask = np.zeros(8, np.int32)
        mask[:n] = 1
        reqs.append((mask, 2))
    eng = _queue_engine(reqs)
    n_lows = {eng._wave_key(r)[1] for r in eng.queue}
    assert n_lows <= {0, 2, 4, 6, 8}     # bucket edges for 8 spans


def test_reuse_sessions_gate_and_bucket_waves():
    """Temporal-reuse plumbing: anonymous / cold sessions get no reuse;
    a warm session's reuse spans enter the wave key; after K consecutive
    reuses the staleness bound forces the spans back out."""
    eng = ServeEngine(None, None, ServeConfig(max_batch=8, buckets=(T,),
                                              reuse_max_age=2))
    rng = np.random.default_rng(0)
    reuse = np.zeros(8, np.int32)
    reuse[:4] = 1

    def req(rid, client_id):
        return Request(rid=rid,
                       prompt=rng.integers(0, 100, (T,)).astype(np.int32),
                       reuse_span_mask=reuse, beta=2, client_id=client_id)

    # anonymous request: no session, no reuse
    assert eng._wave_key(req(0, -1)) == (T, 0, 0, 0, b"")
    # cold session: no reuse yet, but the session warms up when noted
    r1 = req(1, 7)
    assert eng._wave_key(r1) == (T, 0, 0, 0, b"")
    eng.session(7, 8).note(np.zeros((0,), np.int32), beta=2, frame=0)
    k = eng._wave_key(r1)
    assert k[2] == 4 and k[3] == 2          # n_reuse bucket, beta
    # per-client isolation: client 8's cold session still gets nothing
    assert eng._wave_key(req(2, 8)) == (T, 0, 0, 0, b"")
    # staleness: after K=2 consecutive reuses the spans are forced back
    eng.session(7, 8).note(np.arange(4), beta=2, frame=1)
    assert eng._wave_key(r1)[2] == 4
    eng.session(7, 8).note(np.arange(4), beta=2, frame=2)
    assert eng._wave_key(r1)[2] == 0        # age hit K -> transmit again
    # a restoration-point switch invalidates the session
    eng.session(7, 8).note(np.zeros((0,), np.int32), beta=3, frame=3)
    assert eng._wave_key(r1)[2] == 0


def test_reuse_spans_never_ride_low_spans():
    """A span claimed both low and reusable stays LOW (transmitted
    pooled) — the reuse discount must not double-count it."""
    eng = ServeEngine(None, None, ServeConfig(max_batch=8, buckets=(T,)))
    rng = np.random.default_rng(1)
    low = np.zeros(8, np.int32)
    low[:4] = 1
    eng.session(3, 8).note(np.zeros((0,), np.int32), beta=2, frame=0)
    r = Request(rid=0, prompt=rng.integers(0, 100, (T,)).astype(np.int32),
                low_span_mask=low, reuse_span_mask=low.copy(), beta=2,
                client_id=3)
    key = eng._wave_key(r)
    assert key[1] == 4 and key[2] == 0      # all low, nothing reused


def _reference_greedy(cfg, params, prompt, n_new):
    """Single-request prefill + greedy decode, straight off the registry."""
    from repro.models import transformer as tfm
    state = registry.init_decode_state(cfg, 1, T + NEW + 8, jnp.float32)
    batch = {"tokens": jnp.asarray(prompt)[None]}
    hidden, state, _ = registry.prefill(cfg, params, batch, state)
    logits = tfm.logits_from_hidden(cfg, params, hidden[:, -1:, :])
    out = [int(jnp.argmax(logits[0, -1]))]
    for step in range(1, n_new):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, state = registry.decode_step(cfg, params, tok,
                                             T + step - 1, state)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


@pytest.mark.slow
def test_wave_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (T,)).astype(np.int32)
               for _ in range(3)]
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=4, max_len=T + NEW + 8, buckets=(T,)))
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=p, max_new_tokens=NEW))
    responses = {r.rid: r for r in engine.run()}
    assert len(responses) == 3
    for rid, p in enumerate(prompts):
        ref = _reference_greedy(cfg, params, p, NEW)
        assert responses[rid].tokens == ref, (rid, responses[rid].tokens,
                                              ref)


@pytest.mark.slow
def test_mixed_prefill_wave_runs(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    n_spans = T // span
    mask = np.zeros(n_spans, np.int32)
    mask[0] = 1
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=4, max_len=T + NEW + 8, buckets=(T,)))
    for rid in range(2):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, (T,))
            .astype(np.int32), max_new_tokens=NEW,
            low_span_mask=mask, beta=2))
    responses = engine.run()
    assert len(responses) == 2
    assert all(r.n_tokens == NEW for r in responses)


@pytest.mark.slow
def test_waves_group_by_config(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    mask = np.zeros(T // span, np.int32)
    mask[0] = 1
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=8, max_len=T + NEW + 8, buckets=(T,)))
    for rid in range(4):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, (T,))
            .astype(np.int32), max_new_tokens=NEW,
            low_span_mask=mask if rid % 2 else None,
            beta=2 if rid % 2 else 0))
    responses = engine.run()
    assert len(responses) == 4
    # plain and mixed requests cannot share a wave
    assert len(engine.wave_latencies) == 2


@pytest.mark.slow
def test_reuse_wave_runs_through_model(setup):
    """Sessionful reuse request end-to-end: the engine pools the
    effective reuse spans (conservative seq fallback) and refreshes the
    per-client session after the wave."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    n_spans = T // span
    reuse = np.zeros(n_spans, np.int32)
    reuse[0] = 1
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=4, max_len=T + NEW + 8, buckets=(T,)))

    def submit(rid):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, (T,))
            .astype(np.int32), max_new_tokens=NEW,
            reuse_span_mask=reuse, beta=2, client_id=1))

    submit(0)                    # cold session: plain prefill, warms it
    r0 = engine.run()[0]
    assert r0.n_tokens == NEW
    assert engine.sessions[1].warm and engine.sessions[1].beta == 2
    submit(1)                    # warm session: reuse spans engage
    r1 = engine.run()[0]
    assert r1.n_tokens == NEW
    assert engine.sessions[1].age[0] == 1


def test_batch_bucket_covers_max_batch():
    eng = ServeEngine(None, None, ServeConfig(max_batch=3, buckets=(T,)))
    assert eng.batch_bucket(1) == 1
    assert eng.batch_bucket(3) == 4      # B=3 waves pad to the 4 bucket


@pytest.mark.slow
def test_engine_warmup_steady_state_zero_compiles(setup):
    """After warmup over (prompt bucket x plan space x B buckets),
    serving identical-shaped waves never compiles: the prefill/decode
    executable set is exactly the warmup grid."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    n_spans = T // span
    mask = np.zeros(n_spans, np.int32)
    mask[:2] = 1
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=3, max_len=T + NEW + 8, buckets=(T,)))
    n_low = engine._wave_key(Request(
        rid=-1, prompt=np.zeros(T, np.int32), low_span_mask=mask,
        beta=2))[1]
    n = engine.warmup(plan_space=[(n_low, 0, 2)])
    assert n == engine.stats.compiles > 0
    assert engine.stats.warmed

    for rid in range(3):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, (T,))
            .astype(np.int32), max_new_tokens=NEW,
            low_span_mask=mask if rid else None, beta=2 if rid else 0))
    responses = engine.run()
    assert len(responses) == 3
    assert engine.stats.steady_compiles == 0, \
        engine.stats.steady_compile_keys


@pytest.mark.slow
def test_prefill_key_collapses_low_reuse_splits(setup):
    """(n_low, n_reuse) splits of one POOLED length share one prefill
    executable: warming the (1, 1) split covers a (2, 0) wave — the
    sequence-side analogue of the vision edge's length-bucket grid."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    n_spans = T // span
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=2, max_len=T + NEW + 8, buckets=(T,)))
    n = engine.warmup(plan_space=[(1, 1, 2)])       # pooled length 2
    assert n == engine.stats.compiles > 0
    mask = np.zeros(n_spans, np.int32)
    mask[:2] = 1                                    # n_low 2, n_reuse 0
    engine.submit(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, (T,))
        .astype(np.int32), max_new_tokens=NEW, low_span_mask=mask,
        beta=2))
    assert len(engine.run()) == 1
    assert engine.stats.steady_compiles == 0, \
        engine.stats.steady_compile_keys


@pytest.mark.slow
def test_engine_padded_wave_tokens_bit_identical_to_solo(setup):
    """B=3 wave padded to the B=4 executable decodes token-identically
    to solo runs through the same executable (single batch bucket)."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, (T,)).astype(np.int32)
               for _ in range(3)]
    sc = ServeConfig(max_batch=4, max_len=T + NEW + 8, buckets=(T,),
                     b_buckets=(4,))

    def solo(prompt):
        eng = ServeEngine(cfg, params, sc)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=NEW))
        return eng.run()[0].tokens

    expected = [solo(p) for p in prompts]
    engine = ServeEngine(cfg, params, sc)
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=p, max_new_tokens=NEW))
    responses = {r.rid: r for r in engine.run()}
    assert len(engine.wave_latencies) == 1          # one padded wave
    for rid in range(3):
        assert responses[rid].tokens == expected[rid]


@pytest.mark.slow
def test_decode_executable_is_position_independent(setup):
    """The decode step compiles ONCE per batch bucket — the old static
    pos argument recompiled at every token position (a steady-state
    stall the warmup could never cover)."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=2, max_len=T + NEW + 8, buckets=(T,)))
    engine.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, (T,)).astype(np.int32), max_new_tokens=NEW))
    engine.run()
    assert len(engine._decode_fns) == 1
    before = engine.stats.compiles
    engine.submit(Request(rid=1, prompt=rng.integers(
        0, cfg.vocab_size, (T,)).astype(np.int32),
        max_new_tokens=NEW + 2))       # more positions, same executable
    engine.run()
    assert engine.stats.compiles == before


@pytest.mark.slow
def test_same_nlow_different_masks_match_solo(setup):
    """Regression for the cross-request wave-mask corruption: two
    requests with the SAME popcount but DIFFERENT low-span layouts,
    submitted together, must decode exactly like solo runs.  On the old
    n_low-only wave key they shared one wave and the second request was
    prefilled with the first request's pack."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    n_spans = T // span
    mask_a = np.zeros(n_spans, np.int32)
    mask_a[0] = 1
    mask_b = np.zeros(n_spans, np.int32)
    mask_b[-1] = 1
    prompts = [rng.integers(0, cfg.vocab_size, (T,)).astype(np.int32)
               for _ in range(2)]
    sc = ServeConfig(max_batch=4, max_len=T + NEW + 8, buckets=(T,))

    def solo(prompt, mask):
        eng = ServeEngine(cfg, params, sc)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=NEW,
                           low_span_mask=mask, beta=2))
        return eng.run()[0].tokens

    expected = [solo(prompts[0], mask_a), solo(prompts[1], mask_b)]

    engine = ServeEngine(cfg, params, sc)
    for rid, (p, m) in enumerate(zip(prompts, (mask_a, mask_b))):
        engine.submit(Request(rid=rid, prompt=p, max_new_tokens=NEW,
                              low_span_mask=m, beta=2))
    responses = {r.rid: r for r in engine.run()}
    assert len(responses) == 2
    for rid in (0, 1):
        assert responses[rid].tokens == expected[rid], rid


# ---------------------------------------------------------------------------
# kernel-backend lane (ServeConfig.backend -> dispatch.backend_scope)


def test_decode_trace_routes_through_decode_kernel(setup, monkeypatch):
    """ServeConfig(backend="pallas") pins the decode jit trace to the
    Pallas lane: the traced graph calls kernels/decode_attention."""
    from repro.kernels import dispatch

    cfg, params = setup
    calls = []
    real = dispatch.decode_attention

    def spy(q, k, v, kv_len):
        calls.append(q.shape)
        return real(q, k, v, kv_len)

    monkeypatch.setattr(dispatch, "decode_attention", spy)
    eng = ServeEngine(cfg, params, ServeConfig(
        max_batch=2, max_len=T + NEW + 8, buckets=(T,), backend="pallas"))
    state = registry.init_decode_state(cfg, 1, eng.sc.max_len,
                                       eng.sc.cache_dtype)
    eng._get_decode(1)(params, jnp.zeros((1, 1), jnp.int32),
                       jnp.asarray(T, jnp.int32), state)
    assert calls and calls[0][:2] == (1, 1)
    # the xla lane never touches the kernel
    calls.clear()
    eng2 = ServeEngine(cfg, params, ServeConfig(
        max_batch=2, max_len=T + NEW + 8, buckets=(T,), backend="xla"))
    state = registry.init_decode_state(cfg, 1, eng2.sc.max_len,
                                       eng2.sc.cache_dtype)
    eng2._get_decode(1)(params, jnp.zeros((1, 1), jnp.int32),
                        jnp.asarray(T, jnp.int32), state)
    assert not calls


@pytest.mark.slow
def test_decode_backend_token_equivalence(setup):
    """End to end: the Pallas decode lane emits the same greedy tokens
    as the XLA lane on identical prompts."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, (T,)).astype(np.int32)
               for _ in range(3)]

    def run(backend):
        eng = ServeEngine(cfg, params, ServeConfig(
            max_batch=4, max_len=T + NEW + 8, buckets=(T,),
            backend=backend))
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=NEW))
        return {r.rid: r.tokens for r in eng.run()}

    assert run("pallas") == run("xla")
