"""Serving engine: wave batching must reproduce the reference decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import registry
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request

# end-to-end serving waves: excluded from the default fast lane
pytestmark = pytest.mark.slow

ARCH = "qwen3-4b"
T, NEW = 32, 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced(ARCH)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reference_greedy(cfg, params, prompt, n_new):
    """Single-request prefill + greedy decode, straight off the registry."""
    from repro.models import transformer as tfm
    state = registry.init_decode_state(cfg, 1, T + NEW + 8, jnp.float32)
    batch = {"tokens": jnp.asarray(prompt)[None]}
    hidden, state, _ = registry.prefill(cfg, params, batch, state)
    logits = tfm.logits_from_hidden(cfg, params, hidden[:, -1:, :])
    out = [int(jnp.argmax(logits[0, -1]))]
    for step in range(1, n_new):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        logits, state = registry.decode_step(cfg, params, tok,
                                             T + step - 1, state)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_wave_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (T,)).astype(np.int32)
               for _ in range(3)]
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=4, max_len=T + NEW + 8, buckets=(T,)))
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=p, max_new_tokens=NEW))
    responses = {r.rid: r for r in engine.run()}
    assert len(responses) == 3
    for rid, p in enumerate(prompts):
        ref = _reference_greedy(cfg, params, p, NEW)
        assert responses[rid].tokens == ref, (rid, responses[rid].tokens,
                                              ref)


def test_mixed_prefill_wave_runs(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    n_spans = T // span
    mask = np.zeros(n_spans, np.int32)
    mask[0] = 1
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=4, max_len=T + NEW + 8, buckets=(T,)))
    for rid in range(2):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, (T,))
            .astype(np.int32), max_new_tokens=NEW,
            low_span_mask=mask, beta=2))
    responses = engine.run()
    assert len(responses) == 2
    assert all(r.n_tokens == NEW for r in responses)


def test_waves_group_by_config(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    span = cfg.mixed_res.window * cfg.mixed_res.downsample
    mask = np.zeros(T // span, np.int32)
    mask[0] = 1
    engine = ServeEngine(cfg, params, ServeConfig(
        max_batch=8, max_len=T + NEW + 8, buckets=(T,)))
    for rid in range(4):
        engine.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, (T,))
            .astype(np.int32), max_new_tokens=NEW,
            low_span_mask=mask if rid % 2 else None,
            beta=2 if rid % 2 else 0))
    responses = engine.run()
    assert len(responses) == 4
    # plain and mixed requests cannot share a wave
    assert len(engine.wave_latencies) == 2
