"""Fused serving prologue/epilogue kernels (kernels/fused_serving).

Contract: both kernels are pure data movement plus one in-dtype add, so
they are BIT-identical to the unfused pack/pos-add/restore pipeline —
at every beta, not just the window-only schedule.  The fused prologue
zeroes pad windows where the unfused path carries window-0 replicas;
that divergence is unobservable (window attention is window-local and
zeroes pads via ``win_valid``, global blocks mask pad keys to exact-zero
probability via ``kv_len``, and restoration never reads pads), which the
whole-forward tests pin.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import mixed_res as mr
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import LOW, REUSE
from repro.kernels.fused_serving import ops as fops
from repro.kernels.fused_serving.ref import (fused_pack_pos_ref,
                                             fused_restore_ref)
from repro.models import registry

SIZE = SIM.vit.img_size[0]


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    return params, vb.vit_partition(SIM)


# ---------------------------------------------------------------------------
# kernel vs pure-jnp oracle (bitwise)


def test_pack_pos_kernel_matches_ref():
    rng = np.random.default_rng(0)
    B, nbank, w2, C = 2, 12, 16, 8
    bank = jnp.asarray(rng.standard_normal((B, nbank, w2, C)), jnp.float32)
    pos = jnp.asarray(rng.standard_normal((nbank, w2, C)), jnp.float32)
    src = jnp.asarray(rng.integers(0, nbank, (B, 7)), jnp.int32)
    nw = jnp.asarray([5, 7], jnp.int32)
    out = fops.fused_pack_pos(bank, pos, src, nw)
    ref = fused_pack_pos_ref(bank, pos, src, nw)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref).reshape(B, -1, C))
    # pad windows are exact zeros
    np.testing.assert_array_equal(
        np.asarray(out.reshape(B, 7, w2, C)[0, 5:]), 0.0)


def test_restore_kernel_matches_ref():
    rng = np.random.default_rng(1)
    window, d = 4, 2
    w2, dd = window * window, 4
    B, nw_pad, nout, D = 2, 9, 12, 8
    win = jnp.asarray(rng.standard_normal((B, nw_pad, w2, D)), jnp.float32)
    out_src = jnp.asarray(rng.integers(0, nw_pad, (nout,)), jnp.int32)
    out_map = jnp.asarray(rng.integers(0, dd + 1, (nout,)), jnp.int32)
    got = fops.fused_restore(win, out_src, out_map, window, d)
    maps = jnp.asarray(fops.upsample_token_maps(window, d))
    ref = fused_restore_ref(
        win, maps, jnp.broadcast_to(out_src[None], (B, nout)),
        jnp.broadcast_to(out_map[None], (B, nout)))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref).reshape(B, -1, D))


def test_upsample_token_maps_match_mixed_res():
    """maps[k+1] reproduces mixed_res._upsample_low_windows: upsampling
    one low window and re-blocking equals gathering by the token map."""
    rng = np.random.default_rng(2)
    window, d = 8, 2
    w2, dd = window * window, d * d
    part = pt.make_partition(16, 16, window, d)
    low = jnp.asarray(rng.standard_normal((1, 1, window, window, 5)),
                      jnp.float32)
    up = mr._upsample_low_windows(low, part)      # (1, 1, dd, w2, 5)
    flat = low.reshape(1, 1, w2, 5)
    maps = fops.upsample_token_maps(window, d)
    for k in range(dd):
        np.testing.assert_array_equal(np.asarray(up[0, 0, k]),
                                      np.asarray(flat[0, 0, maps[k + 1]]))
    np.testing.assert_array_equal(maps[0], np.arange(w2))


# ---------------------------------------------------------------------------
# PlanLayout inverse maps (out_src / out_map)


def test_plan_layout_out_maps(setup):
    _, part = setup
    nR, dd = part.n_regions, part.windows_per_full_region
    states = np.zeros((nR,), np.int8)
    states[[3, 7]] = LOW
    states[[5]] = REUSE
    lay = pt.plan_layout(states, 64, part)
    nw_pad = 64
    # FULL region r, sub-window k reads packed slot (in packing order)
    full = [r for r in range(nR) if states[r] == 0]
    for j, r in enumerate(full):
        for kk in range(dd):
            assert lay.out_src[r * dd + kk] == j * dd + kk
            assert lay.out_map[r * dd + kk] == 0
    # LOW region j reads its single packed window through map k+1
    n_full_w = len(full) * dd
    for j, r in enumerate([3, 7]):
        for kk in range(dd):
            assert lay.out_src[r * dd + kk] == n_full_w + j
            assert lay.out_map[r * dd + kk] == kk + 1
    # REUSE region j reads the appended tile bank (offset nw_pad)
    for kk in range(dd):
        assert lay.out_src[5 * dd + kk] == nw_pad + 0 * dd + kk
        assert lay.out_map[5 * dd + kk] == 0


def test_fused_restore_matches_restore_padded(setup):
    """On the same packed values the fused gather is bit-identical to
    mixed_res.restore_padded (the sentinel-scatter epilogue it fuses)."""
    _, part = setup
    rng = np.random.default_rng(3)
    nR, dd, w2 = (part.n_regions, part.windows_per_full_region,
                  part.tokens_low_region)
    states = np.zeros((nR,), np.int8)
    states[[1, 6, 9]] = LOW
    states[[2, 12]] = REUSE
    lay = pt.plan_layout(states, 64, part)
    D = 8
    tok = jnp.asarray(rng.standard_normal((2, 64 * w2, D)), jnp.float32)
    tiles = jnp.zeros((2, nR, dd, w2, D), jnp.float32)
    tiles = tiles.at[:, :2].set(jnp.asarray(
        rng.standard_normal((2, 2, dd, w2, D)), jnp.float32))
    ref = mr.restore_padded(tok, part, jnp.asarray(lay.win_dst),
                            jnp.asarray(lay.low_src),
                            jnp.asarray(lay.low_ids),
                            reuse_ids=jnp.asarray(lay.reuse_ids),
                            reuse_tiles=tiles)
    got = fops.fused_restore(tok.reshape(2, 64, w2, D),
                             jnp.asarray(lay.out_src),
                             jnp.asarray(lay.out_map), part.window,
                             part.downsample, reuse_tiles=tiles)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# whole-forward parity: fused vs unfused, pallas vs xla


def _layout_pair(part, states, lb):
    lay = pt.plan_layout(states, lb, part)
    full = {k: jnp.asarray(getattr(lay, k))
            for k in ("win_src", "win_dst", "low_src", "low_ids",
                      "reuse_ids", "out_src", "out_map")}
    full["nw"] = jnp.asarray([lay.nw], jnp.int32)
    legacy = {k: v for k, v in full.items()
              if k not in ("out_src", "out_map")}
    return full, legacy


@pytest.mark.parametrize("beta", [1, 3])
def test_fused_forward_bit_identical_to_unfused(setup, beta):
    """The fused prologue+epilogue forward is BIT-identical to the
    unfused padded Pallas forward at every beta (module docstring)."""
    params, part = setup
    rng = np.random.default_rng(7)
    img = jnp.asarray(rng.uniform(0, 1, (1, SIZE, SIZE, 3))
                      .astype(np.float32))
    states = np.zeros((part.n_regions,), np.int8)
    states[[1, 6, 9]] = LOW
    states[[2, 12]] = REUSE
    full, legacy = _layout_pair(part, states, 64)
    tiles = np.zeros((1, part.n_regions, part.windows_per_full_region,
                      part.tokens_low_region, SIM.d_model), np.float32)
    tiles[0, :2] = rng.standard_normal(tiles.shape[1:])[:2]
    tiles = jnp.asarray(tiles)
    fused = vb.forward_features(SIM, params, img, beta=beta, layout=full,
                                reuse_tiles=tiles, backend="pallas")
    unfused = vb.forward_features(SIM, params, img, beta=beta,
                                  layout=legacy, reuse_tiles=tiles,
                                  backend="pallas")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


def test_fused_forward_matches_exact_xla(setup):
    """Fused padded Pallas forward vs the exact-length XLA forward —
    the full cross-backend cross-shape contract (ULP-level)."""
    params, part = setup
    rng = np.random.default_rng(8)
    img = jnp.asarray(rng.uniform(0, 1, (1, SIZE, SIZE, 3))
                      .astype(np.float32))
    states = np.zeros((part.n_regions,), np.int8)
    states[[0, 4, 10]] = LOW
    fi, li, _ = pt.plan_to_region_ids(states, 3, 0)
    exact = vb.forward_features(SIM, params, img, jnp.asarray(fi),
                                jnp.asarray(li), 2, backend="xla")
    full, _ = _layout_pair(part, states, 64)
    fused = vb.forward_features(SIM, params, img, beta=2, layout=full,
                                backend="pallas")
    np.testing.assert_allclose(np.asarray(fused), np.asarray(exact),
                               rtol=5e-5, atol=5e-5)
