"""Paper C1 (2-D ViT-native) correctness tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import mixed_res as mr
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.models import registry


def small_partition():
    # 16x16 patch grid, window 2, downsample 2 -> region r=4, 4x4 regions
    return pt.make_partition(16, 16, window=2, downsample=2)


def test_partition_geometry():
    p = small_partition()
    assert p.region == 4 and p.n_regions == 16
    assert p.tokens_full_region == 16 and p.tokens_low_region == 4
    assert p.n_tokens(0) == 256
    assert p.n_tokens(16) == 16 * 4           # all low: one window each
    assert p.n_windows(0) == 64 and p.n_windows(16) == 16


def test_partition_requires_divisibility():
    with pytest.raises(ValueError):
        pt.make_partition(10, 16, window=2, downsample=2)


def test_bucketing():
    assert pt.bucket_n_low(0, 16) == 0
    assert pt.bucket_n_low(5, 16, 4) == 4      # rounds DOWN (safe direction)
    assert pt.bucket_n_low(16, 16, 4) == 16
    assert pt.bucket_set(16, 4) == (0, 4, 8, 12, 16)


def test_mask_roundtrip():
    mask = np.zeros(16, np.int32)
    mask[[1, 5, 7]] = 1
    full, low = pt.mask_to_region_ids(mask, 3)
    assert sorted(low.tolist()) == [1, 5, 7]
    assert len(full) == 13 and not set(full) & {1, 5, 7}
    back = pt.region_ids_to_mask(low, 16)
    np.testing.assert_array_equal(back, mask)


def test_grid_window_roundtrip():
    p = small_partition()
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 8))
    rw = mr.grid_to_region_windows(x, p)
    assert rw.shape == (2, 16, 4, 4, 8)
    back = mr.region_windows_to_grid(rw, p)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_pack_restore_all_full_is_identity():
    p = small_partition()
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 8))
    full_ids = jnp.arange(16, dtype=jnp.int32)
    low_ids = jnp.zeros((0,), jnp.int32)
    tokens, _ = mr.pack_mixed(x, p, full_ids, low_ids)
    assert tokens.shape == (2, 256, 8)
    restored = mr.restore_full(tokens, p, full_ids, low_ids)
    expect = mr.grid_to_full_seq(x, p)
    np.testing.assert_array_equal(np.asarray(restored), np.asarray(expect))
    grid = mr.full_seq_to_grid(restored, p)
    np.testing.assert_array_equal(np.asarray(grid), np.asarray(x))


def test_pack_restore_low_regions_are_pooled_broadcast():
    p = small_partition()
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 16, 4))
    mask = np.zeros(16, np.int32)
    mask[[0, 9]] = 1
    full_ids, low_ids = (jnp.asarray(a) for a in pt.mask_to_region_ids(mask, 2))
    tokens, _ = mr.pack_mixed(x, p, full_ids, low_ids)
    assert tokens.shape == (1, p.n_tokens(2), 4)
    restored = mr.full_seq_to_grid(mr.restore_full(tokens, p, full_ids,
                                                   low_ids), p)
    # full regions untouched
    r = p.region
    np.testing.assert_allclose(np.asarray(restored[0, 0:4, 4:8]),
                               np.asarray(x[0, 0:4, 4:8]), rtol=1e-6)
    # low region 0 = patches [0:4, 0:4]: every 2x2 block holds its mean
    blk = np.asarray(x[0, 0:2, 0:2]).mean(axis=(0, 1))
    np.testing.assert_allclose(np.asarray(restored[0, 0, 0]), blk, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(restored[0, 1, 1]), blk, rtol=1e-5)


def test_pack_restore_per_sample_ids_match_solo():
    """(B, n) per-sample region ids (multi-client batching) must equal
    running each sample alone with its own (n,) ids."""
    p = small_partition()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 4))
    masks = []
    for sel in ((0, 9), (3, 12)):
        m = np.zeros(16, np.int32)
        m[list(sel)] = 1
        masks.append(m)
    ids = [pt.mask_to_region_ids(m, 2) for m in masks]
    fb = jnp.asarray(np.stack([f for f, _ in ids]))
    lb = jnp.asarray(np.stack([l for _, l in ids]))
    tok_b, _ = mr.pack_mixed(x, p, fb, lb)
    res_b = mr.restore_full(tok_b, p, fb, lb)
    for i in range(2):
        f, l = (jnp.asarray(a) for a in ids[i])
        tok_s, _ = mr.pack_mixed(x[i:i + 1], p, f, l)
        np.testing.assert_allclose(np.asarray(tok_b[i]),
                                   np.asarray(tok_s[0]), rtol=1e-6)
        res_s = mr.restore_full(tok_s, p, f, l)
        np.testing.assert_allclose(np.asarray(res_b[i]),
                                   np.asarray(res_s[0]), rtol=1e-6)
    pos = jax.random.normal(jax.random.PRNGKey(3), (16, 16, 4))
    pos_b = mr.pack_positions(pos, p, fb, lb)
    assert pos_b.shape == (2, p.n_tokens(2), 4)
    for i in range(2):
        f, l = (jnp.asarray(a) for a in ids[i])
        np.testing.assert_allclose(np.asarray(pos_b[i]),
                                   np.asarray(mr.pack_positions(pos, p,
                                                                f, l)),
                                   rtol=1e-6)


def test_forward_features_per_sample_ids_match_solo():
    """Batched forward with per-sample layouts == per-sample forwards."""
    cfg = get_reduced("vitdet-l")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    part = vb.vit_partition(cfg)
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (2, *cfg.vit.img_size, 3))
    masks = []
    for sel in ((0,), (part.n_regions - 1,)):
        m = np.zeros(part.n_regions, np.int32)
        m[list(sel)] = 1
        masks.append(m)
    ids = [pt.mask_to_region_ids(m, 1) for m in masks]
    fb = jnp.asarray(np.stack([f for f, _ in ids]))
    lb = jnp.asarray(np.stack([l for _, l in ids]))
    feats = vb.forward_features(cfg, params, img, fb, lb, beta=2)
    for i in range(2):
        f, l = (jnp.asarray(a) for a in ids[i])
        solo = vb.forward_features(cfg, params, img[i:i + 1], f, l, beta=2)
        np.testing.assert_allclose(np.asarray(feats[i]),
                                   np.asarray(solo[0]),
                                   rtol=2e-5, atol=2e-5)


def test_vitdet_full_vs_mixed_beta0_equal():
    """beta=0 (restore at input) == feeding the pre-upsampled image."""
    cfg = get_reduced("vitdet-l")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (1, *cfg.vit.img_size, 3))
    part = vb.vit_partition(cfg)
    mask = np.zeros(part.n_regions, np.int32)
    mask[0] = 1
    full_ids, low_ids = (jnp.asarray(a)
                         for a in pt.mask_to_region_ids(mask, 1))
    f_mixed = vb.forward_features(cfg, params, img, full_ids, low_ids, beta=0)
    assert f_mixed.shape == (1, part.grid_h, part.grid_w, cfg.d_model)
    assert np.isfinite(np.asarray(f_mixed)).all()


@pytest.mark.parametrize("beta", [1, 2])
def test_vitdet_mixed_betas_finite_and_distinct(beta):
    cfg = get_reduced("vitdet-l")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (1, *cfg.vit.img_size, 3))
    part = vb.vit_partition(cfg)
    n_low = part.n_regions // 2
    mask = np.zeros(part.n_regions, np.int32)
    mask[:n_low] = 1
    full_ids, low_ids = (jnp.asarray(a)
                         for a in pt.mask_to_region_ids(mask, n_low))
    feats = vb.forward_features(cfg, params, img, full_ids, low_ids,
                                beta=beta)
    assert feats.shape == (1, part.grid_h, part.grid_w, cfg.d_model)
    assert np.isfinite(np.asarray(feats)).all()
    # must differ from full-res features (downsampling loses detail)
    f_full = vb.forward_features(cfg, params, img)
    assert not np.allclose(np.asarray(feats), np.asarray(f_full))


def test_vitdet_no_downsampling_any_beta_equals_full():
    """With an empty low set the mixed path must equal plain inference."""
    cfg = get_reduced("vitdet-l")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (1, *cfg.vit.img_size, 3))
    part = vb.vit_partition(cfg)
    full_ids = jnp.arange(part.n_regions, dtype=jnp.int32)
    low_ids = jnp.zeros((0,), jnp.int32)
    f_full = vb.forward_features(cfg, params, img)
    f_mix = vb.forward_features(cfg, params, img, full_ids, low_ids, beta=2)
    np.testing.assert_allclose(np.asarray(f_mix), np.asarray(f_full),
                               rtol=1e-5, atol=1e-5)


def test_flops_monotonic_in_beta_and_nlow():
    """Paper Fig. 5: later restoration -> fewer FLOPs; more low regions ->
    fewer FLOPs."""
    cfg = get_reduced("vitdet-l")
    part = vb.vit_partition(cfg)
    n_low = part.n_regions // 2
    f = [vb.backbone_flops(cfg, n_low, b)
         for b in range(cfg.vit.n_subsets + 1)]
    assert all(f[i] >= f[i + 1] for i in range(len(f) - 1)), f
    assert f[0] == vb.backbone_flops(cfg, 0, 0)       # beta=0 == full res
    g = [vb.backbone_flops(cfg, n, cfg.vit.n_subsets)
         for n in range(0, part.n_regions + 1, 4)]
    assert all(g[i] >= g[i + 1] for i in range(len(g) - 1)), g


def test_det_head_and_decode_shapes():
    cfg = get_reduced("vitdet-l")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (2, *cfg.vit.img_size, 3))
    outs = vb.forward_det(cfg, params, img)
    assert len(outs) == 3
    from repro.core import det_head as dh
    boxes, scores, classes = dh.decode_detections(cfg, outs, top_k=16)
    assert boxes.shape == (2, 16, 4)
    assert scores.shape == (2, 16)
    assert np.isfinite(np.asarray(boxes)).all()
