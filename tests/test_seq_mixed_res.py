"""1-D sequence adaptation (mixed-granularity prefill) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import seq_mixed_res as smr
from repro.models import registry
from repro.models import transformer as tfm

SEQ = 64
BATCH = 2


def _pack_for(cfg, n_low, which=None):
    part = smr.seq_partition(cfg, SEQ)
    mask = np.zeros(part.n_spans, np.int32)
    if which is None:
        which = list(range(n_low))
    mask[which] = 1
    plan = smr.build_seq_pack(mask, n_low, part)
    return part, {k: jnp.asarray(v) for k, v in plan.items()}


def test_build_seq_pack_invariants():
    cfg = get_reduced("qwen3-4b")
    part = smr.seq_partition(cfg, SEQ)          # span=16 -> 4 spans
    assert part.n_spans == 4
    mask = np.array([0, 1, 0, 1])
    plan = smr.build_seq_pack(mask, 2, part)
    assert plan["mix_idx"].shape[0] == part.n_tokens(2) == 64 - 2 * 8
    # positions strictly increasing (temporal order preserved)
    assert (np.diff(plan["pos_mix"]) > 0).all()
    # restore_idx covers every full position with a valid mixed slot
    assert plan["restore_idx"].shape == (SEQ,)
    assert plan["restore_idx"].max() < plan["mix_idx"].shape[0]
    # full spans map back to themselves
    t = 5                                        # span 0 is full
    assert plan["pos_mix"][plan["restore_idx"][t]] == t


def test_build_seq_pack_bucket_adjustment():
    cfg = get_reduced("qwen3-4b")
    part = smr.seq_partition(cfg, SEQ)
    # mask selects 3 spans but bucket is 2 -> keep first two
    plan = smr.build_seq_pack(np.array([1, 1, 1, 0]), 2, part)
    assert plan["low_spans"].tolist() == [0, 1]
    # mask selects 1 span but bucket is 2 -> earliest unselected added
    plan = smr.build_seq_pack(np.array([0, 0, 1, 0]), 2, part)
    assert plan["low_spans"].tolist() == [0, 2]


def test_pool_and_pack_values():
    x = jnp.arange(2 * 8 * 1, dtype=jnp.float32).reshape(2, 8, 1)
    pooled = smr.pool_groups(x, 2)
    np.testing.assert_allclose(np.asarray(pooled[0, 0, 0]), 0.5)
    np.testing.assert_allclose(np.asarray(pooled[1, 3, 0]), 14.5)


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-236b",
                                  "dbrx-132b", "llava-next-mistral-7b"])
def test_mixed_forward_beta0_equals_plain(arch):
    cfg = get_reduced(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    _, plan = _pack_for(cfg, 2)
    h_plain, _ = tfm.forward_hidden(cfg, params, tokens)
    h_mixed, _ = smr.mixed_forward_hidden(cfg, params, tokens, plan, beta=0)
    np.testing.assert_allclose(np.asarray(h_mixed), np.asarray(h_plain),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch,beta", [("qwen3-4b", 2), ("qwen3-4b", 4),
                                       ("deepseek-v2-236b", 2),
                                       ("dbrx-132b", 2)])
def test_mixed_forward_finite_and_distinct(arch, beta):
    cfg = get_reduced(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    _, plan = _pack_for(cfg, 2)
    h_mixed, _ = smr.mixed_forward_hidden(cfg, params, tokens, plan,
                                          beta=beta)
    assert h_mixed.shape == (BATCH, SEQ, cfg.d_model)
    assert np.isfinite(np.asarray(h_mixed)).all()
    h_plain, _ = tfm.forward_hidden(cfg, params, tokens)
    assert not np.allclose(np.asarray(h_mixed), np.asarray(h_plain))


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-236b"])
@pytest.mark.slow
def test_mixed_prefill_cache_restoration_enables_decode(arch):
    """After a mixed prefill the cache must be full-resolution: a decode
    step from it must be finite, and with beta=0 must exactly match the
    plain prefill+decode path."""
    cfg = get_reduced(arch)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    max_len = SEQ + 4
    _, plan = _pack_for(cfg, 2)

    # beta=0: exact equality with the standard path
    c0 = tfm.init_caches(cfg, BATCH, max_len, jnp.float32)
    h0, c0, _ = smr.mixed_prefill(cfg, params, tokens, plan, 0, c0)
    c1 = tfm.init_caches(cfg, BATCH, max_len, jnp.float32)
    h1, c1, _ = tfm.prefill(cfg, params, tokens, c1)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=2e-5,
                               atol=2e-5)

    tok = tokens[:, -1:]
    l0, _ = tfm.decode_step(cfg, params, tok, SEQ, c0)
    l1, _ = tfm.decode_step(cfg, params, tok, SEQ, c1)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=2e-4,
                               atol=2e-4)

    # beta=2: decode from restored caches is finite and sane
    c2 = tfm.init_caches(cfg, BATCH, max_len, jnp.float32)
    h2, c2, _ = smr.mixed_prefill(cfg, params, tokens, plan, 2, c2)
    l2, _ = tfm.decode_step(cfg, params, tok, SEQ, c2)
    assert np.isfinite(np.asarray(l2)).all()


def test_mixed_prefill_saves_flops():
    cfg = get_reduced("qwen3-4b")
    full = smr.prefill_flops(cfg, 4096, 0, 4)
    part = smr.seq_partition(cfg, 4096)
    half = smr.prefill_flops(cfg, 4096, part.n_spans // 2, 4)
    assert half < full
    # beta=0 gives no savings regardless of n_low
    assert smr.prefill_flops(cfg, 4096, part.n_spans // 2, 0) == full


def test_mixed_forward_ssm_runs():
    cfg = get_reduced("mamba2-370m")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab_size)
    _, plan = _pack_for(cfg, 2)
    h, _ = smr.mixed_forward_ssm(cfg, params, tokens, plan, beta=2)
    assert h.shape == (BATCH, SEQ, cfg.d_model)
    assert np.isfinite(np.asarray(h)).all()


def test_encode_mixed_whisper():
    cfg = get_reduced("whisper-medium")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    T_enc = cfg.encdec.encoder_seq_len
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (BATCH, T_enc, cfg.d_model))
    part = smr.SeqPartition(T_enc, cfg.mixed_res.window,
                            cfg.mixed_res.downsample)
    # reduced whisper: enc seq 64, window 10 doesn't divide; use window 4
    part = smr.SeqPartition(T_enc, 4, 2)
    mask = np.zeros(part.n_spans, np.int32)
    mask[0] = 1
    plan = {k: jnp.asarray(v)
            for k, v in smr.build_seq_pack(mask, 1, part).items()}
    from repro.models import whisper as whs
    enc_plain = whs.encode(cfg, params, frames)
    enc_mixed = smr.encode_mixed(cfg, params, frames, plan, beta=1)
    assert enc_mixed.shape == enc_plain.shape
    assert np.isfinite(np.asarray(enc_mixed)).all()
    enc_b0 = smr.encode_mixed(cfg, params, frames, plan, beta=0)
    np.testing.assert_allclose(np.asarray(enc_b0), np.asarray(enc_plain),
                               rtol=1e-5, atol=1e-5)
