"""Failure-aware offloading: fault injection, deadline-bounded offload
lifecycle, cache epochs, and edge admission control (PR 6).

Ordering note: the tests that CRASH-RESTART the shared module server
without ``preserve_executables`` (wiping its compiled grid) run LAST in
this file so earlier tests keep their lazily-compiled executables.
"""
import jax
import numpy as np
import pytest

from repro.configs.vitdet_l import SIM
from repro.core import vit_backbone as vb
from repro.core.partition import FULL, LOW, REUSE, RegionPlan
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.estimator import ThroughputEstimator
from repro.offload.faults import (BLACKOUT_TPUT_BPS, DegradationLadder,
                                  FaultInjector, FaultSpec, FaultyTrace,
                                  RobustConfig)
from repro.offload.optimizer import build_reuse_plan
from repro.offload.simulator import Policy, ServerModel, Simulation
from repro.offload.tracker import LKTracker
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)
from repro.serve.request import FeatureCache, StaleCacheEpoch

PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]


# ---------------------------------------------------------------------------
# pure-python layer: injector, trace knobs, estimator, tracker, ladder


def test_fault_injector_deterministic_per_profile_index():
    a = FaultInjector.from_profile("blackout", 3)
    b = FaultInjector.from_profile("blackout", 3)
    c = FaultInjector.from_profile("blackout", 4)
    assert a.spec == b.spec
    assert a.spec != c.spec
    with pytest.raises(ValueError):
        FaultInjector.from_profile("nope")


def test_faulty_trace_blackout_and_bloat_windows():
    base = make_trace("4g", 0, duration_s=30)
    inj = FaultInjector(FaultSpec(blackouts=((5.0, 2.0),),
                                  bufferbloat=((10.0, 3.0, 5.0),)))
    tr = FaultyTrace(base, inj)
    t_in, r_in = tr.at(5.5)
    assert t_in <= BLACKOUT_TPUT_BPS
    assert tr.at(3.0) == base.at(3.0)          # outside: untouched
    t_bl, r_bl = tr.at(11.0)
    tb, rb = base.at(11.0)
    assert r_bl == pytest.approx(rb * 5.0)
    assert t_bl == pytest.approx(tb * 0.7)
    # handover storm: periodic micro-blackouts inside the window
    storm = FaultInjector(FaultSpec(storms=((0.0, 4.0, 1.0, 0.5),)))
    assert storm.uplink_down(0.2) and not storm.uplink_down(0.7)
    assert storm.uplink_down(1.3) and not storm.uplink_down(4.5)


def test_make_trace_impairment_knobs():
    base = make_trace("4g", 7)
    again = make_trace("4g", 7, blackouts=0, storms=0, bufferbloat=0)
    np.testing.assert_array_equal(base.tput_bps, again.tput_bps)

    bl = make_trace("4g", 7, blackouts=1)
    dead = bl.tput_bps == BLACKOUT_TPUT_BPS
    assert 2 <= dead.sum() <= 6
    # deterministic overlays too
    np.testing.assert_array_equal(bl.tput_bps,
                                  make_trace("4g", 7, blackouts=1).tput_bps)
    # outside the blackout the base process is untouched
    np.testing.assert_array_equal(base.tput_bps[~dead], bl.tput_bps[~dead])

    st = make_trace("4g", 7, storms=1)
    storm_dead = st.tput_bps == BLACKOUT_TPUT_BPS
    assert storm_dead.sum() >= 2
    # alternating seconds: no two consecutive dead seconds
    assert not (storm_dead[:-1] & storm_dead[1:]).any()

    bb = make_trace("4g", 7, bufferbloat=1)
    assert (bb.rtt_s > base.rtt_s).any()
    assert bb.rtt_s.max() <= 3.0


def test_trace_at_past_end_hold_vs_wrap():
    hold = make_trace("4g", 1, duration_s=20)
    assert hold.extend == "hold"
    assert hold.at(1e6) == (float(hold.tput_bps[-1]), float(hold.rtt_s[-1]))
    wrap = make_trace("4g", 1, duration_s=20, extend="wrap")
    assert wrap.at(20.0) == wrap.at(0.0)
    assert wrap.at(45.0) == wrap.at(5.0)
    # the extend mode changes only lookup, not the data
    np.testing.assert_array_equal(hold.tput_bps, wrap.tput_bps)


def test_throughput_estimator_floor():
    est = ThroughputEstimator(window=2)
    est.observe(1e3, 0.04)             # blackout-era sample
    est.observe(1e3, 0.04)
    assert est.throughput == est.min_tput_bps
    assert est.throughput > 0          # Eq. (2) terms stay finite


def test_throughput_estimator_staleness_horizon():
    est = ThroughputEstimator(window=2, max_age_s=30.0)
    est.observe(20e6, 0.04, t=0.0)
    est.observe(10e6, 0.04, t=1.0)
    assert est.throughput == pytest.approx(15e6)
    # first sample after a long blackout: pre-gap samples expire instead
    # of being averaged across the outage
    est.observe(2e6, 0.04, t=100.0)
    assert len(est.obs_tput) == 1
    assert est.throughput == pytest.approx(2e6)
    # legacy t-free path ages 1s per observation (window test unchanged)
    legacy = ThroughputEstimator(window=2)
    for i in range(50):
        legacy.observe(10e6 + i, 0.04)
    assert legacy.throughput == pytest.approx(10e6 + 48.5)


def test_tracker_holds_position_through_featureless_gap():
    flat = np.full((96, 96, 3), 0.5, np.float32)   # zero gradients
    tr = LKTracker()
    tr.reinit(flat, [{"box": (20, 30, 40, 50), "cls": 0}])
    box0 = tr.tracks[0].box
    tr.step(flat)
    # the box HOLDS position with decayed confidence, not instant death
    assert tr.boxes(), "track must survive one featureless frame"
    assert tr.tracks[0].box == box0
    assert 0.0 < tr.retention < 1.0
    kappas = [tr.retention]
    for _ in range(8):
        tr.step(flat)
        kappas.append(tr.retention)
    # kappa decays monotonically to a finite value; the track eventually
    # dies at the confidence floor instead of degenerating
    assert all(0.0 <= k <= 1.0 and np.isfinite(k) for k in kappas)
    assert not tr.boxes()


def test_degradation_ladder_backoff_and_recovery():
    rc = RobustConfig(backoff_base_s=0.2, backoff_max_s=1.0,
                      recover_after=2)
    lad = DegradationLadder(rc)
    assert lad.level == 0 and lad.retry_at == 0.0
    lad.on_failure(1.0)
    assert lad.level == 1 and lad.retry_at == pytest.approx(1.2)
    lad.on_failure(2.0)
    assert lad.level == 2 and lad.retry_at == pytest.approx(2.4)
    lad.on_failure(3.0)
    lad.on_failure(4.0)
    assert lad.level == rc.ladder_max and lad.shedding
    assert lad.backoff == rc.backoff_max_s
    # successes: backoff resets at once, level steps down every
    # ``recover_after`` completions
    lad.on_success()
    assert lad.backoff == rc.backoff_base_s and lad.retry_at == 0.0
    assert lad.level == rc.ladder_max
    lad.on_success()
    assert lad.level == rc.ladder_max - 1


def test_degradation_ladder_degrade_rewrites_plan():
    lad = DegradationLadder(RobustConfig())
    m = np.linspace(0, 1, 16).astype(np.float32)
    base = {"mask": np.zeros(16, np.int32), "quality": 95, "beta": 0}
    assert lad.degrade(base, m) is base              # level 0: identity
    lad.on_failure(0.0)
    d1 = lad.degrade(dict(base), m)
    assert d1["plan"].n_low == 8 and d1["quality"] == 85
    assert d1["beta"] == lad.rc.degrade_beta
    # lowest-motion regions go LOW first
    assert d1["mask"][:8].sum() == 8
    lad.on_failure(1.0)
    d2 = lad.degrade(dict(base), m)
    assert d2["plan"].n_low == 16 and d2["quality"] == 75


def test_degradation_ladder_never_touches_reuse():
    lad = DegradationLadder(RobustConfig())
    lad.on_failure(0.0)
    lad.on_failure(1.0)                  # level 2: ALL FULL -> LOW
    states = np.zeros(16, np.int8)
    states[:4] = REUSE
    states[4:6] = LOW
    plan = RegionPlan(states)
    d = lad.degrade({"plan": plan, "mask": plan.low_mask(),
                     "quality": 90, "beta": 2}, None)
    out = np.asarray(d["plan"].states)
    assert (out[:4] == REUSE).all()
    assert (out[4:] == LOW).all()


def test_demoted_regions_are_not_splice_sources():
    """A ladder-demoted region goes out LOW, so its captured tile is a
    low-fidelity stopgap: ``degrade`` reports the demoted ids and
    ``FeatureCache.expire`` pins them at the staleness bound — they must
    not be reused until a genuine FULL re-transmission resets their age
    (one degraded offload must not poison the next K splices)."""
    lad = DegradationLadder(RobustConfig())
    lad.on_failure(0.0)                              # level 1
    m = np.linspace(0, 1, 16).astype(np.float32)
    d = lad.degrade({"mask": np.zeros(16, np.int32), "quality": 95,
                     "beta": 0}, m)
    demoted = np.asarray(d["demoted"])
    assert demoted.size == 8
    assert (np.asarray(d["plan"].states)[demoted] == LOW).all()

    c = FeatureCache(16, max_age=4)
    c.note(np.array([], np.int64), beta=2, frame=0, epoch=1)
    assert c.eligible(2).all()
    c.expire(demoted)
    elig = c.eligible(2)
    assert not elig[demoted].any() and c.warm     # tiles kept, just cold
    assert elig.sum() == 16 - demoted.size
    # a FULL re-transmission (not in reuse_ids) resets the age and the
    # region re-enters the eligible set
    c.note(np.array([], np.int64), beta=2, frame=1, epoch=1)
    assert c.eligible(2).all()


def test_feature_cache_epoch_and_invalidate():
    c = FeatureCache(8, max_age=2)
    c.note(np.array([], np.int64), beta=2, frame=0, epoch=5)
    assert c.warm and c.epoch == 5
    # age reset on transmit, forced FULL at K: region reused twice hits
    # the staleness bound and drops out of the eligible set
    c.note(np.array([0], np.int64), beta=2, frame=1, epoch=5)
    c.note(np.array([0], np.int64), beta=2, frame=2, epoch=5)
    assert c.age[0] == 2 and not c.eligible(2)[0]
    assert c.eligible(2)[1:].all()
    # epoch-less note keeps the current epoch (legacy callers)
    c.note(np.array([], np.int64), beta=2, frame=3)
    assert c.epoch == 5
    c.invalidate()
    assert not c.warm and c.tiles is None and not c.eligible(2).any()


# ---------------------------------------------------------------------------
# server-backed: deadline lifecycle, epochs, admission control


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    return params, server, vb.vit_partition(SIM)


class FixedPolicy(Policy):
    name = "fixed"
    use_tracker = True

    def __init__(self, n_regions, lows=(0, 1, 2, 3), beta=2):
        self.n_regions = n_regions
        self.lows = list(lows)
        self.beta = beta

    def decide(self, sim, frame_idx):
        mask = np.zeros(self.n_regions, np.int32)
        mask[self.lows] = 1
        return {"mask": mask, "quality": 85, "beta": self.beta}


class FullResPolicy(Policy):
    name = "fullres"
    use_tracker = True

    def __init__(self, n_regions):
        self.n_regions = n_regions

    def decide(self, sim, frame_idx):
        return {"mask": np.zeros(self.n_regions, np.int32),
                "quality": 95, "beta": 0}


class FixedReusePolicy(Policy):
    name = "fixed-reuse"
    use_tracker = True
    reuse_k = 3

    def __init__(self, n_regions, lows=(0, 1, 2, 3), beta=2):
        self.n_regions = n_regions
        self.lows = list(lows)
        self.beta = beta

    def decide(self, sim, frame_idx):
        mask = np.zeros(self.n_regions, np.int32)
        mask[self.lows] = 1
        cache = sim.feature_cache
        elig = (cache.eligible(self.beta) if cache is not None
                else np.zeros(self.n_regions, bool))
        plan = build_reuse_plan(sim.part, mask, sim.m, elig)
        return {"mask": mask, "quality": 85, "beta": self.beta,
                "plan": plan, "capture_beta": self.beta}


def _client(server, part, seed, policy, video="parkS", n_frames=12,
            inf_delay=None, faults=None, robust=None):
    frames, _ = sv.make_clip(video, n_frames, size=SIZE, seed=seed)
    gt = [server.infer(f) for f in frames]
    trace = make_trace("4g", seed, duration_s=60)
    return Simulation(frames, gt, trace, policy, server, part, PATCH,
                      fps=10, inf_delay=inf_delay, faults=faults,
                      robust=robust)


def test_deadline_timeout_sheds_to_tracker(setup):
    """Inference slower than the SLO: every offload is abandoned at its
    deadline, the ladder climbs to shed, and rendering rides the LK
    tracker for the whole clip — no hang, no stale render."""
    _, server, part = setup
    c = _client(server, part, seed=3,
                policy=FixedPolicy(part.n_regions), n_frames=12,
                inf_delay=lambda beta, n_d: 60.0,
                robust=RobustConfig(slo_s=0.3, backoff_base_s=0.2))
    res = c.run("v")
    assert c.inflight is None
    assert res.e2e_latency == []               # nothing ever completed
    assert c.rstats["timeouts"] >= 2
    assert c.rstats["max_ladder_level"] >= 2
    assert c.rstats["degraded_offloads"] >= 1  # retries went out degraded
    assert len(res.rendering_f1) == 12
    assert c.rstats["tracker_frames"] > 0


def test_lost_response_reaped_then_degraded_retry_completes(setup):
    """A dropped response never arrives: the deadline reaps it, the
    retry goes out degraded and completes; a duplicated response is
    discarded without changing what is rendered."""
    _, server, part = setup
    faults = FaultInjector(FaultSpec(drop_responses=(0,)))
    c = _client(server, part, seed=4,
                policy=FixedPolicy(part.n_regions), n_frames=20,
                faults=faults,
                robust=RobustConfig(slo_s=0.5, backoff_base_s=0.2))
    res = c.run("v")
    assert c.rstats["lost_responses"] == 1
    assert c.rstats["degraded_offloads"] >= 1
    assert len(res.e2e_latency) >= 1           # recovery happened
    assert c.inflight is None


def test_duplicated_response_never_rendered(setup):
    _, server, part = setup
    base = _client(server, part, seed=5,
                   policy=FixedPolicy(part.n_regions), n_frames=10)
    res_base = base.run("v")
    dup = _client(server, part, seed=5,
                  policy=FixedPolicy(part.n_regions), n_frames=10,
                  faults=FaultInjector(FaultSpec(dup_responses=(0,))))
    res_dup = dup.run("v")
    assert dup.rstats["dup_discards"] == 1
    np.testing.assert_allclose(res_base.rendering_f1, res_dup.rendering_f1)
    np.testing.assert_allclose(res_base.e2e_latency, res_dup.e2e_latency)


def test_stale_epoch_splice_refused_and_rebootstrap(setup):
    """The restart invariant, server-side: a REUSE plan whose cache
    predates the replica's epoch raises StaleCacheEpoch (never splices);
    after a FULL re-bootstrap at the new epoch, reuse serves again."""
    _, server, part = setup
    frames, _ = sv.make_clip("parkS", 2, size=SIZE, seed=9)
    cache = FeatureCache(part.n_regions, max_age=3)
    full = RegionPlan(np.zeros(part.n_regions, np.int8))
    e0 = server.epoch
    server.infer_plan(frames[0], full, 0, cache=cache, frame_idx=0,
                      capture_beta=2)
    assert cache.warm and cache.epoch == e0

    states = np.zeros(part.n_regions, np.int8)
    states[:4] = REUSE
    states[4:8] = LOW
    reuse_plan = RegionPlan(states)
    splices_before = server.stats.reuse_splices
    server.infer_plan(frames[1], reuse_plan, 2, cache=cache, frame_idx=1)
    assert server.stats.reuse_splices == splices_before + 1

    server.restart(preserve_executables=True)
    rejects_before = server.stats.stale_epoch_rejects
    with pytest.raises(StaleCacheEpoch):
        server.infer_plan(frames[1], reuse_plan, 2, cache=cache,
                          frame_idx=1)
    assert server.stats.stale_epoch_rejects == rejects_before + 1

    # the recovery contract: invalidate, bootstrap FULL, reuse again
    cache.invalidate()
    server.infer_plan(frames[0], full, 0, cache=cache, frame_idx=2,
                      capture_beta=2)
    assert cache.epoch == server.epoch == e0 + 1
    server.infer_plan(frames[1], reuse_plan, 2, cache=cache, frame_idx=3)
    assert server.stats.reuse_splices == splices_before + 2


def test_epoch_bump_isolates_clients(setup):
    """Two-client invariant: one client's post-restart recovery never
    touches the other's tiles — the other cache keeps its (dead) epoch
    and tiles untouched until its own refusal + re-bootstrap."""
    _, server, part = setup
    frames, _ = sv.make_clip("parkS", 2, size=SIZE, seed=11)
    full = RegionPlan(np.zeros(part.n_regions, np.int8))
    ca = FeatureCache(part.n_regions, max_age=3)
    cb = FeatureCache(part.n_regions, max_age=3)
    server.infer_plan(frames[0], full, 0, cache=ca, frame_idx=0,
                      capture_beta=2)
    server.infer_plan(frames[0], full, 0, cache=cb, frame_idx=0,
                      capture_beta=2)
    e_old = server.epoch
    tiles_b = cb.tiles
    server.restart(preserve_executables=True)

    # client A recovers: invalidate + FULL bootstrap at the new epoch
    ca.invalidate()
    server.infer_plan(frames[1], full, 0, cache=ca, frame_idx=1,
                      capture_beta=2)
    assert ca.epoch == server.epoch == e_old + 1
    # client B's session is untouched by A's recovery...
    assert cb.epoch == e_old and cb.tiles is tiles_b and cb.warm
    # ...and its stale tiles remain unsplicable
    states = np.zeros(part.n_regions, np.int8)
    states[:4] = REUSE
    with pytest.raises(StaleCacheEpoch):
        server.infer_plan(frames[1], RegionPlan(states), 2, cache=cb,
                          frame_idx=1)


def test_admission_control_degrades_then_sheds(setup):
    """Sustained overload at the edge: arrivals are first DEGRADED
    (FULL -> LOW, one length bucket down) and past the shed threshold
    REJECTED; rejected clients count the explicit response and keep
    rendering from the tracker."""
    _, server, part = setup
    slow = lambda beta, n_d: 1.5
    # mutually incompatible configs (different length buckets) so waves
    # serialize and the backlog builds for real
    policies = [FullResPolicy(part.n_regions),
                FixedPolicy(part.n_regions, lows=(0, 1, 2, 3)),
                FixedPolicy(part.n_regions, lows=tuple(range(8)))]
    n = 60
    clients = [
        _client(server, part, seed=20 + i, policy=p, n_frames=n,
                inf_delay=slow, robust=RobustConfig(slo_s=8.0))
        for i, p in enumerate(policies)
    ]
    ec = EdgeConfig(batched=True, admission=True,
                    degrade_backlog_s=0.3, shed_backlog_s=1.0,
                    degrade_depth=2, shed_depth=4)
    mc = MultiClientSimulation(clients, server, ec)
    results = mc.run()
    assert mc.stats.degraded >= 1
    assert mc.stats.shed >= 1
    assert sum(c.rstats["rejected"] for c in clients) == mc.stats.shed
    assert all(c.inflight is None for c in clients)
    for r in results:
        assert len(r.rendering_f1) == n
        for e2e, parts in zip(r.e2e_latency, r.delay_parts):
            assert e2e == pytest.approx(parts["enc"] + parts["net"]
                                        + parts["dec"] + parts["inf"]
                                        + parts["queue"])


def test_mc_edge_restart_loses_queue_and_clients_recover(setup):
    """Crash-restart of the shared replica: the pending queue dies, the
    outage holds the replica down, stale-epoch reuse is NACKed, and
    every client re-bootstraps and completes offloads afterwards."""
    _, server, part = setup
    # 4 s clip: the lost in-flight job needs its 1 s deadline plus the
    # backoff to elapse BEFORE the retry/NACK/re-bootstrap cycle runs
    clients = [
        _client(server, part, seed=30 + i,
                policy=FixedReusePolicy(part.n_regions), n_frames=40,
                robust=RobustConfig(slo_s=1.0))
        for i in range(2)
    ]
    faults = FaultInjector(FaultSpec(edge_restarts=((0.55, 0.2),)))
    ec = EdgeConfig(batched=True, preserve_executables=True)
    mc = MultiClientSimulation(clients, server, ec, faults=faults)
    results = mc.run()
    assert mc.stats.restarts == 1
    # each client completed offloads AFTER the restart (recovery)
    for c, r in zip(clients, results):
        assert len(r.e2e_latency) >= 2
        assert c.feature_cache.epoch == server.epoch
        assert c.inflight is None
    # work died with the old process or was refused on epoch grounds
    assert (mc.stats.lost_jobs + mc.stats.stale_nacks
            + sum(c.rstats["stale_epoch_nacks"] for c in clients)) >= 1


# ---------------------------------------------------------------------------
# real-wipe restarts LAST (they cold the module server's compiled grid)


def test_restart_wipes_executables_and_bumps_epoch(setup):
    """The real restart contract: warmed executables die with the
    process (compiles after it count as re-warmup, not steady stalls)
    and the epoch advances."""
    params, _, _ = setup
    srv = ServerModel(SIM, params, top_k=8, score_thresh=0.0)
    # populate the grid without paying XLA compiles
    srv._fns[(0, 0, 0, 1)] = lambda *a: None
    srv._zero_tiles[1] = np.zeros(1)
    srv.stats.warmed = True
    e = srv.epoch
    srv.restart()
    assert srv.epoch == e + 1
    assert srv.stats.restarts == 1
    assert not srv._fns and not srv._zero_tiles
    assert not srv.stats.warmed
    # the bench shortcut keeps the grid but still bumps the epoch
    srv._fns[(0, 0, 0, 1)] = lambda *a: None
    srv.stats.warmed = True
    srv.restart(preserve_executables=True)
    assert srv.epoch == e + 2 and srv._fns and srv.stats.warmed


def test_single_client_edge_restart_recovers(setup):
    """End-to-end single-client crash-restart (REAL wipe): the in-flight
    response dies, reuse is refused once, the client re-bootstraps FULL
    and finishes the clip with offloads completing again."""
    _, server, part = setup
    faults = FaultInjector(FaultSpec(edge_restarts=((0.45, 0.2),)))
    c = _client(server, part, seed=40,
                policy=FixedReusePolicy(part.n_regions), n_frames=40,
                faults=faults, robust=RobustConfig(slo_s=1.0))
    res = c.run("v")
    assert c.rstats["edge_restarts"] == 1
    assert c.rstats["stale_epoch_nacks"] >= 1
    assert c.feature_cache.epoch == server.epoch
    assert len(res.e2e_latency) >= 2           # completions resumed
    assert c.inflight is None
    # staleness bound K honoured across the failure: ages were zeroed
    # by the re-bootstrap, never carried across the epoch bump
    assert (c.feature_cache.age <= c.feature_cache.max_age).all()
