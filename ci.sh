#!/usr/bin/env bash
# CI lane: fast tests + a smoke run of the backbone perf benchmark, so
# hot-path regressions (shape breaks, backend dispatch, retracing) fail
# loudly.  Full suite: PYTHONPATH=src pytest -m "slow or not slow".
set -euo pipefail
cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== fast test lane (pytest -m 'not slow') =="
python -m pytest -x -q

echo "== fast-lane marker audit (slow tests stay deselected) =="
# pytest.ini pins addopts = -m "not slow"; this fails if the slow
# closed-loop suite ever loses its markers (silently bloating the fast
# lane) or a slow-marked test leaks into the fast selection
python - <<'PY'
import subprocess
import sys


def ids(expr):
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         "-m", expr, "-o", "addopts="],
        capture_output=True, text=True)
    return {ln for ln in r.stdout.splitlines() if "::" in ln}


slow, fast = ids("slow"), ids("not slow")
assert slow, "no tests carry @pytest.mark.slow -- closed-loop lane lost its marker"
leak = slow & fast
assert not leak, f"slow tests leak into the fast lane: {sorted(leak)[:5]}"
print(f"[marker audit] fast lane {len(fast)} tests, "
      f"slow lane {len(slow)} deselected")
PY

echo "== pallas parity lane (REPRO_BACKEND=pallas, interpret mode) =="
# pins the env-var override end to end: every kernel/dispatch test must
# pass with the whole process forced onto the Pallas lane (interpret
# mode off-TPU), including the backend-resolution tests themselves
REPRO_BACKEND=pallas REPRO_AUTOTUNE=0 python -m pytest -x -q \
    tests/test_kernels.py tests/test_backend_dispatch.py

echo "== backbone benchmark smoke + regression gate =="
# --check compares fresh rows against the committed BENCH_backbone.json
# per (workload, beta, backend, dtype) — the fp32 lane plus the
# compressed int8 / int8+fp16 / fp16 weight lanes — and fails on a >15%
# regression (rows from a different device kind are skipped); writes to
# artifacts, never the committed baseline
mkdir -p benchmarks/artifacts
python benchmarks/bench_backbone.py --smoke --check \
    --out benchmarks/artifacts/BENCH_backbone.smoke.json

echo "== quantized serving lane smoke + accuracy gate =="
# --check enforces the compression deployment gates on the trained sim
# server: an int8 point reaches >=3.5x compression, the calibration
# gate SHIPS a point whose rendering-F1 delta stays <= 0.005 on every
# calibration scenario (parkS, driveN), the quantized ServerModel
# compiles the identical executable grid (no new keys), and serving
# after warmup incurs zero steady-state compiles
python benchmarks/bench_quant.py --smoke --check \
    --out benchmarks/artifacts/BENCH_quant.smoke.json

echo "== multi-client serving bench smoke (2 clients) =="
python benchmarks/bench_multiclient.py --smoke --clients 1 2 \
    --out benchmarks/artifacts/BENCH_multiclient.smoke.json

echo "== temporal-reuse ablation smoke =="
python benchmarks/bench_reuse.py --smoke \
    --out benchmarks/artifacts/BENCH_reuse.smoke.json

echo "== serving hot-path smoke (warmup / cache / coalesce / sched) =="
# --check enforces the zero-stall gates: steady-state compile count 0
# after warmup, the COLLAPSED compile surface (executables_total <= the
# bench's EXEC_BUDGET=16 — a regression back toward the old 56-exec
# (n_low, n_reuse)-keyed grid fails fast), warmup wall time within
# --max-warmup-s, zero tile bytes with the device-resident cache, waves
# strictly larger with coalescing (plus mixed-n_low waves sharing one
# executable), scenario F1 deltas 0.000, and the scheduling-plane
# gates: continuous scheduling beats barrier on p50 queue delay AND
# device_idle_frac on the contended 4-client workload, reuses the
# warmed executable grid (zero new keys, zero steady-state compiles),
# and moves only timestamps (rendering-F1 delta 0.000); plus the
# speculation gates on the slow-uplink 4-client workload: speculative
# continuous beats plain continuous on p50 e2e, the lane actually
# launches AND patches, the zero-tolerance probe exercises >=1
# discard-and-rerun, rendering-F1 deltas stay <= 0.005 on parkS and
# driveN, and speculation adds ZERO executables / steady compiles on
# top of the warmed grid
python benchmarks/bench_serving.py --smoke --check --max-warmup-s 90 \
    --out benchmarks/artifacts/BENCH_serving.smoke.json

echo "== robustness fault-matrix smoke (faults / deadlines / epochs) =="
# --check enforces the failure-model gates: every faulted run's
# rendering F1 recovers to within F1_TOL of the no-fault median inside
# RECOVERY_FRAMES, blackouts actually hit deadlines and engage the
# degradation ladder, an edge restart yields stale-epoch NACKs (and
# ZERO stale-epoch splices served — structural, the replica raises
# before splicing), overload both degrades and sheds with exact
# shed/REJECTED accounting, and no client ends wedged on an in-flight
# offload (the no-hang gate)
python benchmarks/bench_robustness.py --smoke --check \
    --out benchmarks/artifacts/BENCH_robustness.smoke.json

echo "== perf trajectory (committed BENCH_*.json) =="
python benchmarks/report.py

echo "CI OK"
