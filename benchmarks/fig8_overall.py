"""Paper Fig. 8 — overall performance of all solutions.

Per policy, pooled over the (video x trace) grid: median rendering F1,
mean inference F1, median E2E offloading latency, median offload
interval.  Expected (paper): ViTMAlis has the highest rendering accuracy
and the lowest E2E latency; Back2Back the lowest rendering accuracy
despite the highest inference accuracy.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C


def run(ctx: dict) -> list:
    results = C.get_sim_results()
    groups = C.by_policy(results)
    rows = []
    summary = {}
    for name, rs in groups.items():
        rend = C.pooled(rs, "rendering_f1")
        inf = C.pooled(rs, "inference_f1")
        e2e = C.pooled(rs, "e2e_latency")
        itv = C.pooled(rs, "offload_interval")
        summary[name] = dict(rend=float(np.median(rend)),
                             inf=float(np.mean(inf)),
                             e2e=float(np.median(e2e)),
                             itv=float(np.median(itv)))
        rows.append((f"fig8/{name}", float(np.median(e2e) * 1e6),
                     f"median_rend_f1={np.median(rend):.3f} "
                     f"mean_inf_f1={np.mean(inf):.3f} "
                     f"median_e2e_s={np.median(e2e):.3f} "
                     f"median_interval={np.median(itv):.1f}"))

    vit = summary.get("ViTMAlis", {})
    others = [v for k, v in summary.items() if k != "ViTMAlis"]
    best_rend = vit and all(vit["rend"] >= o["rend"] - 1e-9 for o in others)
    rows.append(("fig8/vitmalis_best_rendering", 0.0, f"holds={best_rend}"))
    ctx["fig8_summary"] = summary
    return rows
