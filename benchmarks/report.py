"""Render the §Dry-run / §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.report > /tmp/roofline.md
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"

ARCH_ORDER = ["mamba2-370m", "qwen3-4b", "mistral-nemo-12b",
              "phi4-mini-3.8b", "deepseek-7b", "llava-next-mistral-7b",
              "dbrx-132b", "deepseek-v2-236b", "zamba2-1.2b",
              "whisper-medium"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(arch, shape, mesh, opt="base"):
    tag = f"{arch}__{shape}__{mesh}"
    if opt != "base":
        tag += f"__{opt}"
    p = ART / f"{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def _fix(rec):
    r = rec["roofline"]
    t_max = max(r["t_compute"], r["t_memory"], r["t_collective"])
    return r["t_compute"] / t_max if t_max > 0 else 0.0


def one_liner(rec) -> str:
    """What would move the dominant term down."""
    r = rec["roofline"]
    b = r["bound"]
    shape = rec["shape"]
    if b == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return ("KV/state reads dominate: quantize cache to int8 or "
                    "shrink via MLA/GQA ratio")
        return ("materialised attention + activation traffic: Pallas "
                "flash/window kernels keep logits in VMEM (see §Perf)")
    if b == "collective":
        if rec["arch"].startswith("phi4"):
            return ("24 heads % TP16 != 0: GSPMD full-tensor reshard per "
                    "layer — fix: TP=8 (measured in §Perf)")
        return ("TP activation all-reduce dominates: sequence-parallel "
                "reduce-scatter layout (sp variant)")
    return "MXU-bound: raise per-chip batch or quantise weights to int8"


def roofline_table(mesh="pod1", opt="base") -> str:
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound "
        "| frac | useful | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = load(arch, shape, mesh, opt)
            if rec is None:
                lines.append(f"| {arch} | {shape} | - | - | - | missing "
                             f"| - | - | |")
                continue
            if rec["status"] == "skipped":
                lines.append(
                    f"| {arch} | {shape} | — | — | — | SKIP | — | — | "
                    f"{rec['reason'][:60]} |")
                continue
            if rec["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | ERROR | "
                             f"- | - | {rec.get('error', '')[:50]} |")
                continue
            r = rec["roofline"]
            u = rec.get("useful_flop_ratio")
            lines.append(
                f"| {arch} | {shape} "
                f"| {r['t_compute']*1e3:.1f} | {r['t_memory']*1e3:.1f} "
                f"| {r['t_collective']*1e3:.1f} | {r['bound']} "
                f"| {_fix(rec):.2f} "
                f"| {(f'{u:.2f}' if u is not None else 'n/a')} "
                f"| {one_liner(rec)} |")
    return "\n".join(lines)


def memory_table(mesh="pod2") -> str:
    lines = ["| arch | shape | status | mem/dev (GiB) | compile (s) |",
             "|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = load(arch, shape, mesh)
            if rec is None:
                lines.append(f"| {arch} | {shape} | missing | - | - |")
            elif rec["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skip | — | — |")
            elif rec["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | - | - |")
            else:
                gib = rec["memory"]["total_with_donation"] / 2 ** 30
                lines.append(f"| {arch} | {shape} | ok | {gib:.2f} "
                             f"| {rec['compile_s']:.0f} |")
    return "\n".join(lines)


def main():
    print("### Roofline table (single pod, 256 chips, base)\n")
    print(roofline_table("pod1"))
    print("\n### Multi-pod compile proof (512 chips)\n")
    print(memory_table("pod2"))


if __name__ == "__main__":
    sys.exit(main())
