"""One-line-per-suite trajectory summary over the committed
``BENCH_*.json`` artifacts at the repo root.

Each benchmark suite writes a JSON report with its own schema; this
renders the headline number(s) of every committed report on a single
line so a CI log (ci.sh calls this last) or a quick terminal glance
shows the whole perf trajectory — compile surface, serving latency,
reuse savings, speculation wins — without opening the files.

  PYTHONPATH=src python benchmarks/report.py [--root DIR]

Unknown or future ``BENCH_*.json`` files degrade to a key listing
instead of failing, so adding a new suite never breaks the summary.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _fmt_s(x) -> str:
    return f"{x:.3f}s" if isinstance(x, (int, float)) else "n/a"


def _backbone(d: dict) -> str:
    rows = d.get("backbone", [])
    full = [r for r in rows if r.get("workload") == "full"
            and r.get("dtype") == "fp32"]
    bits = [f"{len(rows)} rows"]
    if full:
        best = min(full, key=lambda r: r["us_per_call"])
        bits.append(f"full fp32 {best['us_per_call']:.0f}us/call "
                    f"({best.get('backend', '?')})")
    si = d.get("server_infer", {})
    if "speedup" in si:
        bits.append(f"server jit {si['speedup']:.1f}x vs eager")
    return "; ".join(bits)


def _quant(d: dict) -> str:
    cal, grid = d.get("calibration", {}), d.get("grid", {})
    return (f"ships {cal.get('shipped', '?')} "
            f"{grid.get('ratio', 0):.2f}x compression; "
            f"grid keys match={grid.get('keys_match')} "
            f"steady_compiles={grid.get('steady_compiles')}")


def _multiclient(d: dict) -> str:
    rows = d.get("rows", [])
    if not rows:
        return "no rows"
    n = max(r.get("n_clients", 0) for r in rows)

    def pick(mode, slow):
        for r in rows:
            if r.get("mode") == mode and r.get("n_clients") == n \
                    and (r.get("uplink") == "slow") == slow:
                return r
        return None

    bits = []
    b, c = pick("batched", False), pick("continuous", False)
    if b and c:
        bits.append(f"{n}c p50 e2e continuous {_fmt_s(c['p50_e2e_s'])} "
                    f"vs batched {_fmt_s(b['p50_e2e_s'])}")
    cs, cc = pick("continuous+speculative", True), pick("continuous", True)
    if cs and cc:
        bits.append(f"slow-uplink spec {_fmt_s(cs['p50_e2e_s'])} vs "
                    f"{_fmt_s(cc['p50_e2e_s'])} "
                    f"(hidden p50 {_fmt_s(cs.get('p50_spec_hidden_s'))}, "
                    f"L/P/D {cs.get('spec_launched')}/"
                    f"{cs.get('spec_patched')}/{cs.get('spec_discarded')})")
    return "; ".join(bits) or f"{len(rows)} rows"


def _reuse(d: dict) -> str:
    red = d.get("reduction", {})
    k = "parkS/single"
    if k in red:
        r = red[k]
        return (f"{k}: -{r['bytes_reduction'] * 100:.0f}% bytes, "
                f"-{r['e2e_reduction'] * 100:.0f}% e2e, "
                f"F1 delta {r['rendering_f1_delta']:+.3f}")
    return f"{len(red)} scenarios"


def _serving(d: dict) -> str:
    w = d.get("warmup", {}).get("warmed", {})
    bits = [f"{w.get('executables_total', '?')} executables, "
            f"{w.get('steady_compiles', '?')} steady compiles"]
    sp = d.get("speculation", {})
    if sp:
        s, c = sp.get("speculative", {}), sp.get("continuous", {})
        bits.append(f"spec p50 e2e {_fmt_s(s.get('p50_e2e_s'))} vs "
                    f"{_fmt_s(c.get('p50_e2e_s'))}, "
                    f"hidden {_fmt_s(s.get('spec_hidden_s'))} total")
    ck = d.get("check", {})
    if ck:
        bits.append(f"check {'OK' if ck.get('passed') else 'FAIL'}")
    return "; ".join(bits)


def _robustness(d: dict) -> str:
    ov, ck = d.get("overload", {}), d.get("check", {})
    runs = d.get("fault_matrix", {}).get("runs", [])
    return (f"{len(runs)} fault runs; overload shed "
            f"{ov.get('shed_at_edge', '?')} / degraded "
            f"{ov.get('degraded_at_edge', '?')}; "
            f"check {'OK' if ck.get('passed') else 'FAIL'}")


SUMMARIZERS = {
    "BENCH_backbone.json": _backbone,
    "BENCH_quant.json": _quant,
    "BENCH_multiclient.json": _multiclient,
    "BENCH_reuse.json": _reuse,
    "BENCH_serving.json": _serving,
    "BENCH_robustness.json": _robustness,
}


def summarize(path: Path) -> str:
    try:
        d = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return f"unreadable ({e.__class__.__name__})"
    fn = SUMMARIZERS.get(path.name)
    try:
        if fn is not None:
            return fn(d)
        return "keys: " + ", ".join(list(d)[:8])
    except (KeyError, TypeError, ValueError) as e:
        return f"schema drift ({e.__class__.__name__}: {e})"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path, default=ROOT,
                    help="directory holding BENCH_*.json")
    args = ap.parse_args(argv)
    files = sorted(args.root.glob("BENCH_*.json"))
    if not files:
        print(f"[report] no BENCH_*.json under {args.root}")
        return 0
    width = max(len(p.name) for p in files)
    print(f"[report] perf trajectory ({len(files)} committed reports)")
    for p in files:
        print(f"  {p.name:<{width}}  {summarize(p)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
