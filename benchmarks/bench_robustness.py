"""Failure-aware offloading benchmark — the fault matrix of the
robustness spine: deterministic fault injection, deadline-bounded
offloads, the degradation ladder, cache epochs, and edge admission
control.

Emits ``BENCH_robustness.json`` with two sections:

  * ``fault_matrix`` — one single-client run per fault profile (none,
    blackout, handover_storm, edge_restart) on the same clip / trace /
    reuse policy, reporting rendering-F1 during and after the fault,
    time-to-recover (frames until per-frame F1 is back within
    ``F1_TOL`` of the no-fault median), the fraction of frames rendered
    from the LK tracker, p95 e2e latency, the client's robustness
    counters, and the replica's epoch/splice stats.  The edge_restart
    run additionally pins the invariant that NO reuse splice is ever
    served from a pre-restart cache epoch (stale attempts are refused
    with StaleCacheEpoch and counted, never spliced).
  * ``overload``   — N clients with mutually incompatible length-bucket
    configs against one admission-controlled replica whose service rate
    cannot keep up: incoming jobs are first DEGRADED (FULL -> LOW, one
    length bucket down) and past the shed threshold REJECTED; clients
    track locally and retry degraded after backoff.

``--check`` enforces the acceptance gates: every faulted run recovers
within ``RECOVERY_FRAMES`` of the fault clearing and its post-fault
median F1 sits within ``F1_TOL`` of the no-fault median; the fault
machinery demonstrably fired (timeouts / NACKs / sheds per profile);
zero stale-epoch splices served; no client left with a wedged in-flight
offload (the no-hang gate).

Standalone:  python benchmarks/bench_robustness.py [--smoke] [--check]
Harness:     picked up by benchmarks/run.py as the ``bench_robustness``
             suite (smoke settings, check enabled).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.vitdet_l import SIM
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.estimator import InferenceDelayModel
from repro.offload.faults import FaultInjector, RobustConfig
from repro.offload.simulator import Policy, Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)

DEFAULT_OUT = (Path(__file__).resolve().parent.parent
               / "BENCH_robustness.json")
PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]
FPS = 10
FULL_RES_DELAY_S = 0.281
BETA = 2
# Staleness bound K for the bench policy's FeatureCache.  K counts
# OFFLOADS, not seconds: a fault that stretches the inter-offload gap
# (storm outage + deadline + backoff) leaves reused tiles temporally
# stale, and they keep getting spliced for up to K more offloads once
# traffic resumes.  K=2 bounds that post-fault poison window to ~2
# offload periods so rendering F1 re-locks to the clean run quickly,
# while steady-state reuse (and the guaranteed post-restart stale-epoch
# splice attempt) is unaffected — the alternating halves keep every
# reused tile at age <= 1.
REUSE_K = 2
# rendering-F1 recovery tolerance vs. the no-fault median, and the
# bound (frames after the fault clears) within which it must be met —
# the bound absorbs the detection lag (up to slo_s before the client
# knows), the backed-off retry, and the ladder stepping back down
F1_TOL = 0.02
NOFAULT_F1_FLOOR = 0.5
RECOVERY_FRAMES = 40

PROFILES = ("none", "blackout", "handover_storm", "edge_restart")


def _params():
    return registry.init_params(SIM, jax.random.PRNGKey(0))


def _inf_delay_model() -> InferenceDelayModel:
    part = vb.vit_partition(SIM)
    edges = pt.length_bucket_set(part)
    return InferenceDelayModel.fit_from_flops(
        lambda n, b, r=0: vb.backbone_flops(SIM, n, b, r,
                                            length_edges=edges),
        part.n_regions,
        betas=tuple(range(SIM.vit.n_subsets + 1)),
        full_res_delay_s=FULL_RES_DELAY_S)


class GreedyReusePolicy(Policy):
    """Alternating-halves reuse (deterministic, motion gating
    deliberately bypassed): after the FULL bootstrap every offload
    transmits one half of the non-low regions FULL and splices the other
    half from the cache, flipping halves each offload.  Three properties
    the gates depend on fall out of this shape:

    - every post-bootstrap offload carries REUSE, so the first offload
      to reach a restarted edge is GUARANTEED to attempt a stale-epoch
      splice — NACK -> invalidate -> FULL bootstrap on every
      edge_restart run (no reliance on where the cache's age phase
      happens to land);
    - tile ages never exceed 1, comfortably inside the staleness bound;
    - the steady-state plan composition is CONSTANT, so after a fault
      the run re-locks to the clean run's rendering F1.  (Greedy
      reuse-everything variants leave the post-fault age distribution —
      and hence the FULL/REUSE composition cycle — permanently phase
      shifted, which with random weights is a permanent F1 offset that
      reads as "never recovered".)

    Ladder interactions still perturb the composition briefly (demoted
    regions are expired from the cache and re-enter as FULL), which is
    exactly the transient the recovery gates measure."""
    name = "greedy-reuse"
    use_tracker = True
    reuse_k = REUSE_K

    def __init__(self, n_regions, lows=(0, 1, 2, 3), beta=BETA):
        self.n_regions = n_regions
        self.lows = list(lows)
        self.beta = beta
        others = [r for r in range(n_regions) if r not in self.lows]
        h = len(others) // 2
        self.halves = (np.array(others[:h]), np.array(others[h:]))
        self.flip = 0

    def decide(self, sim, frame_idx):
        mask = np.zeros(self.n_regions, np.int32)
        mask[self.lows] = 1
        cache = sim.feature_cache
        elig = (cache.eligible(self.beta) if cache is not None
                else np.zeros(self.n_regions, bool))
        states = np.where(mask != 0, pt.LOW, pt.FULL).astype(np.int8)
        reuse_half = self.halves[self.flip]
        states[reuse_half[elig[reuse_half]]] = pt.REUSE
        self.flip ^= 1
        plan = pt.RegionPlan(states)
        return {"mask": mask, "quality": 85, "beta": self.beta,
                "plan": plan, "capture_beta": self.beta}


class FixedMaskPolicy(Policy):
    name = "fixedmask"
    use_tracker = True

    def __init__(self, lows, n_regions, beta=BETA):
        self.lows = list(lows)
        self.n_regions = n_regions
        self.beta = beta

    def decide(self, sim, frame_idx):
        m = np.zeros(self.n_regions, np.int32)
        m[self.lows] = 1
        beta = self.beta if self.lows else 0
        return {"mask": m, "quality": 85 if self.lows else 95,
                "beta": beta}


# ---------------------------------------------------------------------------
# fault matrix (single client)


def _fault_window(inj: FaultInjector) -> Optional[Tuple[float, float]]:
    """[start, end) union of every scheduled fault of the injector."""
    s = inj.spec
    spans = ([(t0, t0 + d) for (t0, d) in s.blackouts]
             + [(t0, t0 + d) for (t0, d, _, _) in s.storms]
             + [(t0, t0 + d) for (t0, d, _) in s.bufferbloat]
             + [(r, r + o) for (r, o) in s.edge_restarts]
             + [(t0, t0 + d) for (t0, d, _) in s.edge_stalls])
    if not spans:
        return None
    return (min(a for a, _ in spans), max(b for _, b in spans))


def _run_profile(server, part, frames, gt, profile: str, n: int,
                 inf_delay) -> Tuple[Dict, Simulation]:
    sim_len = n / FPS
    inj = FaultInjector.from_profile(profile, index=0,
                                     start_s=0.3 * sim_len, dur_s=1.0)
    sim = Simulation(frames, gt, make_trace("4g", 0, duration_s=120),
                     GreedyReusePolicy(part.n_regions), server, part,
                     PATCH, fps=FPS, inf_delay=inf_delay,
                     faults=inj, robust=RobustConfig())
    res = sim.run("parkS")
    e2e = np.asarray(res.e2e_latency, np.float64)
    window = _fault_window(inj)
    f1 = np.asarray(res.rendering_f1, np.float64)
    t = np.arange(n) / FPS
    row = {
        "profile": profile,
        "fault_window_s": list(window) if window else None,
        "frames": n,
        "offloads_completed": int(e2e.size),
        "median_rendering_f1": float(np.median(f1)),
        "p95_e2e_s": float(np.percentile(e2e, 95)) if e2e.size else None,
        "tracker_frame_fraction": sim.rstats["tracker_frames"] / n,
        "rstats": dict(sim.rstats),
        "edge": {
            "epoch": server.epoch,
            "restarts": server.stats.restarts,
            "reuse_splices": server.stats.reuse_splices,
            "stale_epoch_rejects": server.stats.stale_epoch_rejects,
        },
        "no_inflight_left": sim.inflight is None,
    }
    if window:
        during = f1[(t >= window[0]) & (t < window[1])]
        post = f1[t >= window[1]]
        row["during_fault_f1"] = (float(np.median(during))
                                  if during.size else None)
        row["post_fault_f1"] = (float(np.median(post))
                                if post.size else None)
        row["post_fault_frames"] = int(post.size)
    return row, sim, f1


def _attach_recovery(row: Dict, f1: np.ndarray, clean_f1: np.ndarray,
                     n: int) -> None:
    """Frames (and seconds) after the fault clears until per-frame
    rendering F1 is back within F1_TOL of what the NO-FAULT run scored
    on the very same frame — the interval covers the full detection lag
    (the SLO must elapse before the client even KNOWS an offload died)
    plus backoff, retries, and the ladder walking back down.  The
    comparison is frame-aligned rather than against the clean run's
    whole-clip median because per-frame F1 tracks clip content: a clip
    whose tail is intrinsically harder would otherwise read as "never
    recovered" even when the faulted run matches the clean run exactly.
    ``post_recovery_f1`` is the median from the recovery frame to the
    end of the clip (gated against the clean run's median over those
    SAME frames): recovery must be sustained, not one lucky frame."""
    window = row["fault_window_s"]
    t = np.arange(n) / FPS
    post_idx = np.nonzero(t >= window[1])[0]
    # the recovery point is the earliest post-window frame from which
    # the MEDIAN of the remaining clip clears the bar — a per-frame
    # first-crossing would declare victory on the tracker-held frames
    # right after the window, before the deadline-lagged dip (the SLO
    # must elapse before the client even notices the failure) has played
    # out, and its trailing median would then read as "not sustained"
    rec = next((k for k, i in enumerate(post_idx)
                if np.median(f1[i:]) >= np.median(clean_f1[i:]) - F1_TOL),
               None)
    row["recovery"] = {
        "recovered": rec is not None,
        "frames_to_recover": rec,
        "time_to_recover_s": None if rec is None else rec / FPS,
        "post_recovery_f1": (float(np.median(f1[post_idx[rec]:]))
                             if rec is not None else None),
        "clean_same_frames_f1": (float(np.median(clean_f1[post_idx[rec]:]))
                                 if rec is not None else None),
    }


def bench_fault_matrix(server, part, n: int) -> Dict:
    frames, _ = sv.make_clip("parkS", n, size=SIZE, seed=7)
    gt = [server.infer(f) for f in frames]
    inf_delay = _inf_delay_model()
    rows: Dict[str, Dict] = {}
    f1s: Dict[str, np.ndarray] = {}
    for profile in PROFILES:
        splices0 = server.stats.reuse_splices
        row, sim, f1 = _run_profile(server, part, frames, gt, profile, n,
                                    inf_delay)
        row["edge"]["reuse_splices_this_run"] = (server.stats.reuse_splices
                                                 - splices0)
        if profile == "edge_restart":
            # the invariant, observed end to end: the splice counter only
            # ever advances for plans whose cache carries the LIVE epoch
            # (stale attempts raise server-side and are NACKed), and the
            # client's cache finished the run re-warmed at the new epoch
            row["edge"]["cache_epoch_matches_replica"] = (
                sim.feature_cache.epoch == server.epoch)
        rows[profile] = row
        f1s[profile] = f1
    # recovery is measured frame-aligned against the clean run
    nofault = rows["none"]["median_rendering_f1"]
    for profile in PROFILES:
        if profile != "none":
            _attach_recovery(rows[profile], f1s[profile], f1s["none"], n)
    return {"nofault_median_f1": nofault, "runs": rows}


# ---------------------------------------------------------------------------
# sustained overload (multi-client, admission control)


def bench_overload(server, part, n: int) -> Dict:
    policies = [FixedMaskPolicy((), part.n_regions),
                FixedMaskPolicy(tuple(range(4)), part.n_regions),
                FixedMaskPolicy(tuple(range(8)), part.n_regions)]
    clients = []
    for i, pol in enumerate(policies):
        frames, _ = sv.make_clip("driveN", n, size=SIZE, seed=30 + i)
        gt = [server.infer(f) for f in frames]
        clients.append(Simulation(
            frames, gt, make_trace("4g", i, duration_s=120), pol, server,
            part, PATCH, fps=FPS, inf_delay=lambda beta, n_d: 1.5,
            robust=RobustConfig(slo_s=8.0)))
    ec = EdgeConfig(batched=True, admission=True,
                    degrade_backlog_s=0.3, shed_backlog_s=1.0,
                    degrade_depth=2, shed_depth=4)
    mc = MultiClientSimulation(clients, server, ec)
    results = mc.run()
    e2e = np.array([x for r in results for x in r.e2e_latency],
                   np.float64)
    return {
        "clients": len(clients),
        "frames": n,
        "offloads_completed": int(e2e.size),
        "p95_e2e_s": float(np.percentile(e2e, 95)) if e2e.size else None,
        "degraded_at_edge": mc.stats.degraded,
        "shed_at_edge": mc.stats.shed,
        "rejected_tracked_by_clients": sum(c.rstats["rejected"]
                                           for c in clients),
        "client_max_ladder_levels": [c.rstats["max_ladder_level"]
                                     for c in clients],
        "tracker_frame_fraction": sum(c.rstats["tracker_frames"]
                                      for c in clients) / (n * len(clients)),
        "median_rendering_f1": float(np.median(
            [x for r in results for x in r.rendering_f1])),
        "no_inflight_left": all(c.inflight is None for c in clients),
    }


# ---------------------------------------------------------------------------


def check(report: Dict) -> List[str]:
    """The acceptance gates ci.sh enforces on the smoke lane."""
    errs = []
    fm = report["fault_matrix"]
    nofault = fm["nofault_median_f1"]
    clean = fm["runs"]["none"]
    if clean["offloads_completed"] <= 0:
        errs.append("clean run completed no offloads")
    # Absolute floor: the recovery gates below are RELATIVE to the
    # no-fault median, so a policy regression that zeroes rendering F1
    # outright would make every one of them vacuously pass.  Pin the
    # clean run to a healthy baseline first.
    if nofault < NOFAULT_F1_FLOOR:
        errs.append(f"no-fault median rendering F1 {nofault:.3f} < "
                    f"{NOFAULT_F1_FLOOR} (relative recovery gates vacuous)")
    if clean["rstats"]["timeouts"] or clean["rstats"]["rejected"]:
        errs.append("clean run hit failure paths")
    for profile, row in fm["runs"].items():
        if not row["no_inflight_left"]:
            errs.append(f"{profile}: wedged in-flight offload (hang)")
        if profile == "none":
            continue
        rec = row["recovery"]
        if not rec["recovered"]:
            errs.append(f"{profile}: rendering F1 never recovered to "
                        "within F1_TOL of the clean run")
            continue
        if rec["frames_to_recover"] > RECOVERY_FRAMES:
            errs.append(f"{profile}: recovery took "
                        f"{rec['frames_to_recover']} frames "
                        f"(> {RECOVERY_FRAMES})")
        if rec["post_recovery_f1"] < rec["clean_same_frames_f1"] - F1_TOL:
            errs.append(f"{profile}: post-recovery median F1 "
                        f"{rec['post_recovery_f1']:.3f} < "
                        f"{rec['clean_same_frames_f1'] - F1_TOL:.3f} "
                        "(not sustained vs clean run, same frames)")
    r = fm["runs"]
    bl = r["blackout"]["rstats"]
    if bl["timeouts"] + bl["lost_responses"] < 1:
        errs.append("blackout: no offload hit its deadline")
    if bl["max_ladder_level"] < 1:
        errs.append("blackout: degradation ladder never engaged")
    st = r["handover_storm"]["rstats"]
    if st["timeouts"] + st["lost_responses"] + st["degraded_offloads"] < 1:
        errs.append("handover_storm: fault left no trace in the client")
    er = r["edge_restart"]
    if er["rstats"]["edge_restarts"] != 1:
        errs.append("edge_restart: restart did not fire exactly once")
    if er["rstats"]["stale_epoch_nacks"] < 1:
        errs.append("edge_restart: no stale-epoch NACK observed")
    if er["edge"]["stale_epoch_rejects"] < 1:
        errs.append("edge_restart: replica refused no stale splice")
    if not er["edge"].get("cache_epoch_matches_replica", False):
        errs.append("edge_restart: client cache not re-warmed at the "
                    "live epoch")
    ov = report["overload"]
    if ov["degraded_at_edge"] < 1:
        errs.append("overload: admission control degraded no job")
    if ov["shed_at_edge"] < 1:
        errs.append("overload: admission control shed no job")
    if ov["rejected_tracked_by_clients"] != ov["shed_at_edge"]:
        errs.append("overload: shed/REJECTED accounting mismatch "
                    f"({ov['shed_at_edge']} shed, "
                    f"{ov['rejected_tracked_by_clients']} tracked)")
    if not ov["no_inflight_left"]:
        errs.append("overload: wedged in-flight offload (hang)")
    return errs


def run_bench(smoke: bool = False, out: Path = DEFAULT_OUT,
              do_check: bool = False) -> dict:
    # the faulted clip must be long enough for the whole arc: fault at
    # 0.3 * clip, detection lag (slo), backoff, degraded retries, and
    # the ladder walking back to level 0 before the clip ends
    n = 80 if smoke else 150
    n_ov = 40 if smoke else 80
    part = vb.vit_partition(SIM)
    server = BatchedServerModel(SIM, _params(), top_k=8, score_thresh=0.0)
    report = {
        "meta": {
            "config": "vitdet-l/SIM",
            "device": jax.default_backend(),
            "smoke": smoke,
            "n_frames": n,
            "fps": FPS,
            "beta": BETA,
            "reuse_k": REUSE_K,
            "f1_tol": F1_TOL,
            "recovery_frames_bound": RECOVERY_FRAMES,
            "slo_s": RobustConfig().slo_s,
        },
        # overload FIRST: the edge_restart run cold-wipes the replica's
        # executables, and everything after it would pay the recompiles
        "overload": bench_overload(server, part, n_ov),
        "fault_matrix": bench_fault_matrix(server, part, n),
    }
    errs = check(report)
    report["check"] = {"passed": not errs, "errors": errs}
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[bench_robustness] wrote {out}")
    if do_check and errs:
        raise SystemExit("[bench_robustness] CHECK FAILED: "
                         + "; ".join(errs))
    return report


def run(ctx: dict) -> list:
    """benchmarks/run.py adapter: smoke settings, CSV rows."""
    out = Path(__file__).resolve().parent / "artifacts"
    out.mkdir(parents=True, exist_ok=True)
    rep = run_bench(smoke=True, out=out / "BENCH_robustness.smoke.json",
                    do_check=True)
    fm, ov = rep["fault_matrix"], rep["overload"]
    rows = []
    for profile, r in fm["runs"].items():
        rec = r.get("recovery", {})
        rows.append((
            f"bench_robustness/{profile}",
            (rec.get("time_to_recover_s") or 0.0) * 1e6,
            f"f1={r['median_rendering_f1']:.3f} "
            f"tracker={r['tracker_frame_fraction']:.2f} "
            f"timeouts={r['rstats']['timeouts']} "
            f"nacks={r['rstats']['stale_epoch_nacks']}"))
    rows.append((
        "bench_robustness/overload", 0.0,
        f"degraded={ov['degraded_at_edge']} shed={ov['shed_at_edge']} "
        f"p95={ov['p95_e2e_s']}"))
    ctx["bench_robustness"] = rows
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fewer frames (CI sanity lane)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless all acceptance gates hold")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)
    rep = run_bench(smoke=args.smoke, out=args.out, do_check=args.check)
    fm = rep["fault_matrix"]
    print(f"  no-fault median rendering F1: "
          f"{fm['nofault_median_f1']:.3f}")
    for profile, r in fm["runs"].items():
        rec = r.get("recovery")
        extra = ""
        if rec:
            extra = (f" during={r['during_fault_f1']} "
                     f"post={r['post_fault_f1']} recover="
                     f"{rec['frames_to_recover']}fr")
        print(f"  {profile:15s} f1={r['median_rendering_f1']:.3f} "
              f"tracker={r['tracker_frame_fraction']:.2f} "
              f"p95={r['p95_e2e_s']}{extra}")
    ov = rep["overload"]
    print(f"  overload: degraded={ov['degraded_at_edge']} "
          f"shed={ov['shed_at_edge']} "
          f"rejected={ov['rejected_tracked_by_clients']} "
          f"p95={ov['p95_e2e_s']} f1={ov['median_rendering_f1']:.3f}")
    print(f"  check: {'OK' if rep['check']['passed'] else 'FAILED'} "
          f"{rep['check']['errors']}")
    return 0 if rep["check"]["passed"] or not args.check else 1


if __name__ == "__main__":
    import sys
    sys.exit(main(sys.argv[1:]))
