"""Roofline table — reads the dry-run artifacts (benchmarks/artifacts/
dryrun/*.json) and prints the three roofline terms, the dominant bound,
and the useful-FLOP ratio per (arch x shape x mesh x opt) cell.

This is the §Roofline deliverable; EXPERIMENTS.md is generated from the
same artifacts (benchmarks.report).
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load_records(mesh: str = None, opt: str = None) -> list:
    recs = []
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        r["_file"] = p.name
        if mesh and r.get("mesh") != mesh:
            continue
        if opt and r.get("opt", "base") != opt:
            continue
        recs.append(r)
    return recs


def fraction_of_roofline(rec: dict) -> float:
    """Achievable fraction: ideal (compute-bound) time / bound time."""
    r = rec["roofline"]
    t_bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
    return r["t_compute"] / t_bound if t_bound > 0 else 0.0


def run(ctx: dict) -> list:
    rows = []
    n_ok = n_skip = n_err = 0
    for rec in load_records():
        tag = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("opt", "base") != "base":
            tag += f"/{rec['opt']}"
        if rec["status"] == "skipped":
            n_skip += 1
            rows.append((tag, 0.0, f"SKIP {rec['reason'][:50]}"))
            continue
        if rec["status"] != "ok":
            n_err += 1
            rows.append((tag, 0.0, f"ERROR {rec.get('error', '?')[:60]}"))
            continue
        n_ok += 1
        r = rec["roofline"]
        ufr = rec.get("useful_flop_ratio")
        useful = f"{ufr:.2f}" if ufr is not None else "n/a"
        rows.append((
            tag, r[max(("t_compute", "t_memory", "t_collective"),
                       key=lambda k: r[k])] * 1e6,
            f"t_comp={r['t_compute']*1e3:.2f}ms "
            f"t_mem={r['t_memory']*1e3:.2f}ms "
            f"t_coll={r['t_collective']*1e3:.2f}ms "
            f"bound={r['bound']} "
            f"frac={fraction_of_roofline(rec):.2f} "
            f"useful={useful}"))
    rows.append(("roofline/summary", 0.0,
                 f"ok={n_ok} skipped={n_skip} errors={n_err}"))
    ctx["roofline_ok"] = n_err == 0
    return rows
