"""Paper Fig. 5 — restoration-point (RP) accuracy/delay trade-off.

Pilot setup (paper §III-B): decision regions that do not contain objects
are downsampled by 2; restoration is applied at each candidate RP
beta in {0..4}.  We report, per beta:
  * measured wall-time per inference on the sim model (us_per_call),
  * the paper-scale delay from LM^inf_beta (full ViTDet-L FLOP curve
    anchored at 281 ms),
  * F1 against the full-resolution model output.
Expected (paper): delay falls and accuracy falls monotonically with beta.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.data import synthetic_video as sv
from repro.offload import detection as det
from repro.offload import motion as mo


def run(ctx: dict) -> list:
    server = C.get_server()
    part = C.get_part()
    inf_delay = C.paper_delay_model()
    frames, gts = sv.make_clip("walkS", 10, size=C.SIZE, seed=31)

    # downsample exactly the object-free regions (paper's pilot setup)
    masks = []
    for g in gts:
        rho = mo.region_density(g, part, C.PATCH)
        masks.append((rho == 0).astype(np.int32))
    n_d = int(np.median([m.sum() for m in masks]))

    rows = []
    gt_dets = [server.infer(f) for f in frames]
    for beta in range(0, C.SIM.vit.n_subsets + 1):
        f1s, walls = [], []
        for f, m, g in zip(frames, masks, gt_dets):
            if beta == 0:
                us = C.timer(lambda: server.infer(f, m, 0), reps=3)
                dets = server.infer(f, m, 0)
            else:
                us = C.timer(lambda: server.infer(f, m, beta), reps=3)
                dets = server.infer(f, m, beta)
            walls.append(us)
            f1s.append(det.frame_f1(dets, g))
        paper_ms = inf_delay(beta, n_d) * 1e3
        rows.append((f"fig5/beta{beta}", float(np.median(walls)),
                     f"f1={np.mean(f1s):.3f} paper_inf_ms={paper_ms:.1f} "
                     f"n_low={n_d}"))

    # validation: paper-scale delay strictly decreases with beta
    delays = [inf_delay(b, n_d) for b in range(5)]
    mono = all(delays[i + 1] < delays[i] + 1e-9 for i in range(4))
    rows.append(("fig5/monotone_delay", 0.0, f"decreasing={mono}"))
    ctx["fig5"] = rows
    return rows
