"""Paper Fig. 10 — encoding/decoding delays per policy.

Median modelled codec delay (Eq. 2 terms) per policy plus the measured
wall time of our actual DCT+zlib codec on this host.  Expected (paper):
mixed-resolution policies pay a small encode overhead but win on decode;
totals stay within a few ms of the baselines (~30-39 ms at 1080p scale).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C


def run(ctx: dict) -> list:
    groups = C.by_policy(C.get_sim_results())
    rows = []
    for name, rs in groups.items():
        codec = C.pooled_delay(rs, "codec")
        walls = []
        for r in rs:
            walls.extend(r.overhead.get("codec_wall", []))
        rows.append((f"fig10/{name}",
                     float(np.median(walls) * 1e6) if walls else 0.0,
                     f"median_codec_ms={np.median(codec)*1e3:.1f} "
                     f"(modelled, 1080p scale)"))
    return rows
