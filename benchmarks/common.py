"""Shared benchmark substrate: the trained sim-scale ViTDet server model,
profiling clips, estimator fitting, and the simulation runner.

Everything expensive is cached under benchmarks/artifacts/cache so the
harness is fast on re-runs; delete the cache directory to rebuild.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.vitdet_l import SIM
from repro.core import det_head as dh
from repro.core import vit_backbone as vb
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.offload import baselines as bl
from repro.offload import motion as mo
from repro.offload.codec import CodecDelayModel, MixedResCodec
from repro.offload.estimator import (InferenceDelayModel, LinearEstimator,
                                     MLPEstimator, OfflineMean,
                                     ThroughputEstimator, feature_vector)
from repro.offload.optimizer import (DelayModels, OffloadOptimizer,
                                     candidate_configs)
from repro.offload.simulator import ServerModel, Simulation
from repro.optim import adam
from repro.train import checkpoint as ckpt

ART = Path(__file__).resolve().parent / "artifacts"
CACHE = ART / "cache"
PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]
FPS = 10

# benchmark workload (kept small enough for CPU; the structure — videos x
# traces x policies — matches the paper's 300-pair evaluation)
SIM_VIDEOS = ("walkS", "cycleS", "driveN")
SIM_TRACES = (("4g", 0), ("5g", 0))
SIM_FRAMES = 48
PROFILE_VIDEOS = ("walkS", "walkB", "cycleS")
PROFILE_FRAMES = 12


def timer(fn, *args, reps: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------------------------------------------------------------------
# server model (trained once on the synthetic domain, checkpointed)


def train_server_params(steps: int = 1800, peak_lr: float = 5e-4,
                        batch: int = 2, log_every: int = 200):
    """Train the sim ViTDet on synthetic clips (analytic GT targets).

    peak_lr above ~5e-4 destabilises this run (the loss climbs back
    after the warmup), leaving detection scores hovering around the
    serving threshold — which makes every accuracy gate that compares
    different arithmetic (the quantization calibration bound) flaky.
    """
    from repro.optim.schedules import warmup_cosine
    params = registry_init()
    opt = adam.init_adam(params)

    def loss_fn(p, img, tgt):
        outs = vb.forward_det(SIM, p, img)
        return dh.det_loss(SIM, outs, tgt)[0]

    step_fn = jax.jit(jax.value_and_grad(loss_fn))

    # training pool: frames + targets from the profiling scenarios
    frames, targets = [], []
    for name in PROFILE_VIDEOS:
        fs, gts = sv.make_clip(name, 16, size=SIZE, seed=7)
        for f, g in zip(fs, gts):
            frames.append(f)
            targets.append(sv.render_targets(g, SIZE))
    frames = np.stack(frames)
    rng = np.random.default_rng(0)

    for s in range(steps):
        idx = rng.integers(0, len(frames), batch)
        img = jnp.asarray(frames[idx])
        tgt = [{k: jnp.asarray(np.stack([targets[i][lv][k] for i in idx]))
                for k in ("cls", "box", "pos")}
               for lv in range(len(targets[0]))]
        loss, grads = step_fn(params, img, tgt)
        lr = warmup_cosine(jnp.asarray(s), peak_lr=peak_lr,
                           warmup_steps=50, total_steps=steps)
        params, opt, _ = adam.adam_update(grads, opt, params, lr=lr,
                                          grad_clip=1.0)
        if log_every and s % log_every == 0:
            print(f"[server-train] step {s} loss {float(loss):.3f} "
                  f"lr {float(lr):.1e}", flush=True)
    flat = jax.tree_util.tree_leaves(params)
    assert all(np.isfinite(np.asarray(l)).all() for l in flat), \
        "server training diverged"
    return params


def registry_init():
    from repro.models import registry
    return registry.init_params(SIM, jax.random.PRNGKey(0))


_SERVER: Optional[ServerModel] = None


def get_server(train_steps: int = 1800) -> ServerModel:
    """The (cached) trained sim server model."""
    global _SERVER
    if _SERVER is not None:
        return _SERVER
    ckdir = str(CACHE / "server_model")
    like = registry_init()
    if ckpt.latest_step(ckdir) is not None:
        params = ckpt.restore(like, ckdir)
    else:
        params = train_server_params(train_steps)
        ckpt.save(params, ckdir, step=train_steps)
    _SERVER = ServerModel(SIM, params, top_k=32, score_thresh=0.4)
    return _SERVER


def get_part():
    return vb.vit_partition(SIM)


def paper_delay_model() -> InferenceDelayModel:
    """LM^inf_beta(N_d) from the FULL ViTDet-L FLOP curve, anchored to
    the paper's measured 281 ms full-res inference delay.

    Deliberately the EXACT-length curve: Algorithm 1 uses this model to
    discriminate configs, and the padded-bucket cost
    (``backbone_flops(..., length_edges=...)``) is a step function that
    erases the marginal-latency differences it selects on (see
    bench_reuse._inf_delay_model).  Serving-side accounting of the
    collapsed executable grid costs the padded bucket instead (edge
    coalescer, bench_serving)."""
    cfg = get_config("vitdet-l")
    part = vb.vit_partition(cfg)
    return InferenceDelayModel.fit_from_flops(
        lambda n, b: vb.backbone_flops(cfg, n, b), part.n_regions,
        betas=(0, 1, 2, 3, 4), full_res_delay_s=0.281)


# ---------------------------------------------------------------------------
# profiling dataset -> estimators (paper §IV-D / Table II)


def build_profile_dataset(server: ServerModel,
                          n_frames: int = PROFILE_FRAMES) -> Dict:
    """Offline profiling: (features, size, accuracy) samples across the
    profiling clips and a spread of configs.  Cached."""
    cache = CACHE / "profile_dataset.npz"
    if cache.exists():
        z = np.load(cache)
        return {k: z[k] for k in z.files}

    part = get_part()
    codec = MixedResCodec(part, PATCH, part.downsample)
    sample_cfgs = [c for c in candidate_configs()
                   if c.quality in (70, 85, 100)
                   and c.beta in (0, 1, 2, 4)]
    X, y_size, y_acc = [], [], []
    for name in PROFILE_VIDEOS:
        frames, gts = sv.make_clip(name, n_frames, size=SIZE, seed=11)
        analyzer = mo.RegionMotionAnalyzer(part, PATCH)
        for fi, frame in enumerate(frames):
            m, m_f = analyzer.update(frame)
            if fi < 2:                 # background model warm-up
                continue
            gt_dets = server.infer(frame)          # full-res reference
            rho = mo.region_density(gts[fi], part, PATCH)
            phi = mo.classify_regions(m, rho)
            mu_r, sg_r = float(rho.mean()), float(rho.std())
            for c in sample_cfgs:
                mask = mo.downsample_mask(phi, c.tau_d)
                n_d = int(mask.sum())
                m_d = float((mask * m).sum())
                enc, decoded = codec.encode(frame, mask, c.quality)
                from repro.offload import detection as det
                dets = server.infer(decoded, mask if n_d > 0 else None,
                                    c.beta if n_d > 0 else 0)
                f1 = det.frame_f1(dets, gt_dets)
                X.append(feature_vector(c.tau_d, n_d, m_d, m_f, c.quality,
                                        mu_r, sg_r, c.beta))
                y_size.append(enc.payload_bytes / 1024.0)    # KiB
                y_acc.append(f1)
    out = {"X": np.stack(X), "y_size": np.array(y_size, np.float32),
           "y_acc": np.array(y_acc, np.float32)}
    CACHE.mkdir(parents=True, exist_ok=True)
    np.savez(cache, **out)
    return out


def fit_estimators(data: Dict) -> Dict[str, Dict]:
    """Fit {MLP, Linear, OfflineMean} x {size, acc} with a split."""
    X, ys, ya = data["X"], data["y_size"], data["y_acc"]
    n = len(X)
    rng = np.random.default_rng(0)
    idx = rng.permutation(n)
    tr, te = idx[:int(n * 0.8)], idx[int(n * 0.8):]
    out = {}
    for name, cls in (("MLP", MLPEstimator), ("Linear", LinearEstimator),
                      ("OfflineMean", OfflineMean)):
        size_e, acc_e = cls(), cls()
        size_e.fit(X[tr], ys[tr], steps=1500)
        acc_e.fit(X[tr], ya[tr], steps=1500)
        out[name] = {"size": size_e, "acc": acc_e, "test_idx": te,
                     "train_idx": tr}
    out["data"] = data
    return out


_ESTIMATORS: Optional[Dict] = None


def get_estimators() -> Dict:
    global _ESTIMATORS
    if _ESTIMATORS is None:
        _ESTIMATORS = fit_estimators(build_profile_dataset(get_server()))
    return _ESTIMATORS


# ---------------------------------------------------------------------------
# simulation runner


def make_optimizer(size_est, acc_est) -> OffloadOptimizer:
    part = get_part()
    delays = DelayModels(enc=CodecDelayModel(), inf=paper_delay_model(),
                         net=ThroughputEstimator())
    return OffloadOptimizer(part, size_est, acc_est, delays)


def make_policies() -> List[bl.Policy]:
    est = get_estimators()
    return [
        bl.Back2Back(),
        bl.TrackB2B(),
        bl.TrackRoI(),
        bl.TrackUD(fps=FPS, n_subsets=SIM.vit.n_subsets),
        bl.ViTMAlis(make_optimizer(est["MLP"]["size"], est["MLP"]["acc"])),
    ]


def make_ablations() -> List[bl.Policy]:
    est = get_estimators()
    return [
        bl.ViTMAlisNoRegType(make_optimizer(est["MLP"]["size"],
                                            est["MLP"]["acc"])),
        _no_mlps_policy(est),
        bl.ViTMAlisNoDynaRes(make_optimizer(est["MLP"]["size"],
                                            est["MLP"]["acc"]),
                             n_subsets=SIM.vit.n_subsets),
    ]


def _no_mlps_policy(est) -> bl.Policy:
    p = bl.ViTMAlis(make_optimizer(est["OfflineMean"]["size"],
                                   est["OfflineMean"]["acc"]))
    p.name = "w/o MLPs"
    return p


_GT_CACHE: Dict[str, Tuple[np.ndarray, List]] = {}


def video_with_gt(name: str, n_frames: int = SIM_FRAMES):
    """Frames + the full-res model outputs (= the paper's ground truth)."""
    key = f"{name}_{n_frames}"
    if key not in _GT_CACHE:
        server = get_server()
        frames, _ = sv.make_clip(name, n_frames, size=SIZE, seed=23)
        gt = [server.infer(f) for f in frames]
        _GT_CACHE[key] = (frames, gt)
    return _GT_CACHE[key]


def run_sims(policies: Sequence[bl.Policy]) -> List:
    """Run every (policy x video x trace) simulation.  Returns SimResults."""
    server = get_server()
    part = get_part()
    inf_delay = paper_delay_model()
    results = []
    for vname in SIM_VIDEOS:
        frames, gt = video_with_gt(vname)
        for (kind, seed) in SIM_TRACES:
            trace = make_trace(kind, seed,
                               duration_s=int(SIM_FRAMES / FPS) + 60)
            for policy in policies:
                # fresh policy state per run
                pol = policy.__class__.__new__(policy.__class__)
                pol.__dict__.update(policy.__dict__)
                if hasattr(pol, "opt"):
                    pol.opt.delays.net = ThroughputEstimator()
                if hasattr(pol, "last_e2e"):
                    pol.last_e2e = None
                s = Simulation(frames, gt, trace, pol, server, part, PATCH,
                               fps=FPS, inf_delay=inf_delay)
                results.append(s.run(video_name=vname))
    return results


_SIM_RESULTS: Optional[List] = None


def get_sim_results() -> List:
    """The fig-8/9/10 simulation grid (shared across benchmarks)."""
    global _SIM_RESULTS
    if _SIM_RESULTS is None:
        _SIM_RESULTS = run_sims(make_policies())
    return _SIM_RESULTS


def by_policy(results) -> Dict[str, List]:
    out: Dict[str, List] = {}
    for r in results:
        out.setdefault(r.policy, []).append(r)
    return out


def pooled(results, attr: str) -> np.ndarray:
    vals: List[float] = []
    for r in results:
        vals.extend(getattr(r, attr))
    return np.asarray(vals, np.float64)


def pooled_delay(results, key: str) -> np.ndarray:
    vals = []
    for r in results:
        for d in r.delay_parts:
            if key == "codec":
                vals.append(d["enc"] + d["dec"])
            else:
                vals.append(d[key])
    return np.asarray(vals, np.float64)
