"""Multi-client edge-serving benchmark — batched waves vs. sequential,
barrier vs. continuous scheduling.

Emits ``BENCH_multiclient.json`` with one row per (n_clients, mode):

  * ``throughput_fps``   — offloaded frames served per second of
                           SIMULATED time (the edge-capacity metric);
  * ``p50_e2e_s`` / ``p95_e2e_s`` — Eq. (2) end-to-end latency incl.
                           queueing delay at the shared replica;
  * ``p50_queue_s`` / ``p95_queue_s`` — queueing delay percentiles,
                           with the per-job BREAKDOWN surfaced as
                           ``p50_queue_admit_s`` (arrival -> bound to a
                           wave) + ``p50_queue_slot_s`` (bound ->
                           compute start);
  * ``device_idle_frac`` / ``decode_hidden_s`` / ``mean_wave`` —
                           scheduler telemetry (the continuous policy's
                           overlap win);
  * ``wall_s``           — real wall-clock of the run (the batched
                           forward also wins real compute time).

Modes: ``batched`` (barrier waves of same-(n_low bucket, beta) frames
through one batched ``forward_det``), ``continuous`` (same waves, but
decode/h2d staging overlapped under compute and late admission into
padded B-bucket slots — serve/scheduler.py), and ``sequential`` (one
frame per wave) on the SAME workload.  The harness also cross-checks
that all three modes' detections match box-for-box.

A final slow-uplink section re-runs a reuse-heavy 4-client workload
(3x parkS + driveN, rotating REUSE plans) over a compounded
bufferbloat overlay (offload/faults.py) in three modes — barrier,
continuous, and continuous+speculative — surfacing the speculative
REUSE lane's telemetry (``spec_launched``/``spec_patched``/
``spec_discarded`` and p50/p95 ``spec_hidden_s``, the transmission
time hidden behind the spliced forward) in every row.

Standalone:  python benchmarks/bench_multiclient.py [--smoke] [--out P]
Harness:     picked up by benchmarks/run.py as the ``bench_multiclient``
suite (smoke settings).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.vitdet_l import SIM
from repro.core import vit_backbone as vb
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.faults import FaultInjector, FaultSpec, FaultyTrace
from repro.offload.optimizer import build_reuse_plan
from repro.offload.simulator import Policy, Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)

DEFAULT_OUT = Path(__file__).resolve().parent.parent / \
    "BENCH_multiclient.json"
CLIENT_COUNTS = (1, 2, 4)
VIDEOS = ("walkS", "cycleS", "driveN", "walkB")
PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]
FPS = 10
# shared-edge service time: the paper's measured full-res ViTDet-L
# delay, scaled by the mixed-res FLOP ratio (offload/estimator.py does
# the same for the single-client grid) — makes the replica a genuine
# bottleneck so queueing/batching behaviour is visible
FULL_RES_DELAY_S = 0.281


class RotatingMaskPolicy(Policy):
    """Deterministic per-client region layout (distinct across clients,
    same n_low bucket) — the co-batching worst case for the old
    shared-layout packing."""
    name = "rotating"
    use_tracker = True

    def __init__(self, offset: int, n_low: int, n_regions: int,
                 beta: int = 2):
        self.offset = offset
        self.n_low = n_low
        self.n_regions = n_regions
        self.beta = beta

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        mask = np.zeros(self.n_regions, np.int32)
        for k in range(self.n_low):
            mask[(self.offset + k) % self.n_regions] = 1
        return {"mask": mask, "quality": 85, "beta": self.beta}


class ReuseRotatingPolicy(Policy):
    """Rotating low mask + motion-gated REUSE lift (offload/optimizer.py)
    — the reuse-heavy workload the speculative lane targets."""
    name = "reuse-rotating"
    use_tracker = True
    reuse_k = 4

    def __init__(self, offset: int, n_low: int, n_regions: int,
                 beta: int = 2):
        self.offset = offset
        self.n_low = n_low
        self.n_regions = n_regions
        self.beta = beta

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        mask = np.zeros(self.n_regions, np.int32)
        for k in range(self.n_low):
            mask[(self.offset + k) % self.n_regions] = 1
        cache = sim.feature_cache
        elig = (cache.eligible(self.beta) if cache is not None
                else np.zeros(self.n_regions, bool))
        plan = build_reuse_plan(sim.part, mask, sim.m, elig)
        return {"mask": mask, "quality": 85, "beta": self.beta,
                "plan": plan, "capture_beta": self.beta}


def _inf_delay_model():
    from repro.core import partition as pt
    from repro.offload.estimator import InferenceDelayModel
    part = vb.vit_partition(SIM)
    # cost the padded length bucket the collapsed grid actually serves
    edges = pt.length_bucket_set(part)
    return InferenceDelayModel.fit_from_flops(
        lambda n, b: vb.backbone_flops(SIM, n, b, length_edges=edges),
        part.n_regions,
        betas=tuple(range(SIM.vit.n_subsets + 1)),
        full_res_delay_s=FULL_RES_DELAY_S)


def make_clients(server: BatchedServerModel, n_clients: int,
                 n_frames: int, gt_cache: Dict) -> List[Simulation]:
    part = vb.vit_partition(SIM)
    inf_delay = _inf_delay_model()
    n_low = part.n_regions // 4
    clients = []
    for i in range(n_clients):
        vname = VIDEOS[i % len(VIDEOS)]
        key = (vname, n_frames)
        if key not in gt_cache:
            frames, _ = sv.make_clip(vname, n_frames, size=SIZE, seed=17)
            gt_cache[key] = (frames, [server.infer(f) for f in frames])
        frames, gt = gt_cache[key]
        pol = RotatingMaskPolicy(offset=i * n_low, n_low=n_low,
                                 n_regions=part.n_regions)
        clients.append(Simulation(frames, gt, make_trace("4g", i,
                                                         duration_s=120),
                                  pol, server, part, PATCH, fps=FPS,
                                  inf_delay=inf_delay))
    return clients


# congested-cell uplink for the speculative lane: stacked bufferbloat
# windows COMPOUND (offload/faults.py dents throughput to 70 % per
# window), leaving ~3 % of the 4g uplink at ~4x RTT for the whole run —
# the regime where transmission dominates Eq. (2)
SLOW_UPLINK = FaultSpec(
    bufferbloat=tuple((0.0, 3600.0, 1.15) for _ in range(10)))
SLOW_VIDEOS = ("parkS", "parkS", "parkS", "driveN")


def make_slow_clients(server: BatchedServerModel, n_clients: int,
                      n_frames: int, gt_cache: Dict) -> List[Simulation]:
    part = vb.vit_partition(SIM)
    inf_delay = _inf_delay_model()
    n_low = part.n_regions // 4
    clients = []
    for i in range(n_clients):
        vname = SLOW_VIDEOS[i % len(SLOW_VIDEOS)]
        key = (vname, n_frames)
        if key not in gt_cache:
            frames, _ = sv.make_clip(vname, n_frames, size=SIZE, seed=17)
            gt_cache[key] = (frames, [server.infer(f) for f in frames])
        frames, gt = gt_cache[key]
        pol = ReuseRotatingPolicy(offset=i * n_low, n_low=n_low,
                                  n_regions=part.n_regions)
        trace = FaultyTrace(make_trace("4g", i, duration_s=240),
                            FaultInjector(SLOW_UPLINK))
        clients.append(Simulation(frames, gt, trace, pol, server, part,
                                  PATCH, fps=FPS, inf_delay=inf_delay))
    return clients


def run_mode(server: BatchedServerModel, n_clients: int, n_frames: int,
             batched: bool, gt_cache: Dict,
             scheduler: str = "barrier", speculate: bool = False,
             clients_fn=None, videos: Sequence[str] = VIDEOS) -> Dict:
    clients = (clients_fn or make_clients)(server, n_clients, n_frames,
                                           gt_cache)
    mc = MultiClientSimulation(clients, server,
                               EdgeConfig(batched=batched,
                                          scheduler=scheduler,
                                          speculate=speculate,
                                          keep_dets=True))
    t0 = time.perf_counter()
    results = mc.run([videos[i % len(videos)] for i in range(n_clients)])
    wall = time.perf_counter() - t0

    e2e = np.array([x for r in results for x in r.e2e_latency], np.float64)
    queue = np.asarray(mc.stats.queue_delays, np.float64)
    admit = np.asarray(mc.stats.queue_admit, np.float64)
    slot = np.asarray(mc.stats.queue_slot, np.float64)
    sim_seconds = n_frames / FPS

    def p(x, q):
        return float(np.percentile(x, q)) if x.size else 0.0

    return {
        "n_clients": n_clients,
        "mode": ("continuous+speculative" if speculate
                 else "continuous" if scheduler == "continuous"
                 else "batched" if batched else "sequential"),
        "offloads": int(e2e.size),
        "throughput_fps": float(e2e.size / sim_seconds),
        "p50_e2e_s": float(np.percentile(e2e, 50)) if e2e.size else None,
        "p95_e2e_s": float(np.percentile(e2e, 95)) if e2e.size else None,
        "p50_queue_s": p(queue, 50),
        "p95_queue_s": p(queue, 95),
        "p50_queue_admit_s": p(admit, 50),
        "p50_queue_slot_s": p(slot, 50),
        "device_idle_frac": mc.stats.device_idle_frac,
        "decode_hidden_s": mc.stats.decode_hidden_s,
        "mean_wave": mc.stats.mean_wave_size,
        "spec_launched": mc.stats.spec_launched,
        "spec_patched": mc.stats.spec_patched,
        "spec_discarded": mc.stats.spec_discarded,
        "spec_hidden_s": mc.stats.spec_hidden_s,
        "p50_spec_hidden_s": mc.stats.spec_hidden_percentile(50),
        "p95_spec_hidden_s": mc.stats.spec_hidden_percentile(95),
        "wall_s": wall,
        "_jobs": {f"{j['client']}:{j['frame']}": j["dets"]
                  for j in mc.stats.jobs},
    }


def _dets_close(a: List[Dict], b: List[Dict], atol: float = 0.5) -> bool:
    if len(a) != len(b):
        return False
    for da, db in zip(a, b):
        if da["cls"] != db["cls"]:
            return False
        if not np.allclose(np.asarray(da["box"], np.float64),
                           np.asarray(db["box"], np.float64), atol=atol):
            return False
    return True


def run_slow_uplink(server: BatchedServerModel,
                    n_frames: int) -> List[Dict]:
    """Barrier vs continuous vs continuous+speculative on the same
    reuse-heavy 4-client workload over the SLOW_UPLINK overlay — the
    uplink-dominated regime where the speculative lane hides the
    payload transit behind the spliced forward."""
    gt_cache: Dict = {}
    rows = []
    for scheduler, speculate in (("barrier", False),
                                 ("continuous", False),
                                 ("continuous", True)):
        row = run_mode(server, len(SLOW_VIDEOS), n_frames, batched=True,
                       gt_cache=gt_cache, scheduler=scheduler,
                       speculate=speculate, clients_fn=make_slow_clients,
                       videos=SLOW_VIDEOS)
        row.pop("_jobs")
        row["uplink"] = "slow"
        rows.append(row)
    return rows


def run_bench(smoke: bool = False, out: Path = DEFAULT_OUT,
              client_counts: Sequence[int] = CLIENT_COUNTS) -> dict:
    n_frames = 16 if smoke else 48
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    gt_cache: Dict = {}

    rows, match = [], {}
    for n in client_counts:
        row_b = run_mode(server, n, n_frames, batched=True,
                         gt_cache=gt_cache)
        row_c = run_mode(server, n, n_frames, batched=True,
                         gt_cache=gt_cache, scheduler="continuous")
        row_s = run_mode(server, n, n_frames, batched=False,
                         gt_cache=gt_cache)
        jobs_b = row_b.pop("_jobs")
        jobs_c = row_c.pop("_jobs")
        jobs_s = row_s.pop("_jobs")
        shared = set(jobs_b) & set(jobs_s)
        shared_c = set(jobs_b) & set(jobs_c)
        match[n] = {
            "compared": len(shared),
            "all_match": bool(shared) and all(
                _dets_close(jobs_b[k], jobs_s[k]) for k in shared),
            "compared_continuous": len(shared_c),
            "continuous_match": bool(shared_c) and all(
                _dets_close(jobs_b[k], jobs_c[k]) for k in shared_c),
        }
        rows.extend([row_b, row_c, row_s])

    slow_rows = run_slow_uplink(server, max(n_frames, 40))
    rows.extend(slow_rows)

    report = {
        "meta": {
            "config": "vitdet-l/SIM",
            "device": jax.default_backend(),
            "smoke": smoke,
            "n_frames": n_frames,
            "fps": FPS,
            "full_res_delay_s": FULL_RES_DELAY_S,
            "batch_alpha": EdgeConfig().batch_alpha,
        },
        "rows": rows,
        "detections_match": match,
    }
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[bench_multiclient] wrote {out}")
    return report


def run(ctx: dict) -> list:
    """benchmarks/run.py adapter: smoke settings, CSV rows."""
    out = Path(__file__).resolve().parent / "artifacts"
    out.mkdir(parents=True, exist_ok=True)
    rep = run_bench(smoke=True, out=out / "BENCH_multiclient.smoke.json",
                    client_counts=(1, 2))
    rows = []
    for r in rep["rows"]:
        tag = "/slow" if r.get("uplink") == "slow" else ""
        rows.append((f"bench_multiclient/{r['n_clients']}c"
                     f"/{r['mode']}{tag}",
                     r["throughput_fps"],
                     f"p95_e2e={r['p95_e2e_s']:.3f}s "
                     f"wave={r['mean_wave']:.2f}"))
    ctx["bench_multiclient"] = rows
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fewer frames/clients (CI sanity lane)")
    ap.add_argument("--clients", type=int, nargs="*", default=None,
                    help=f"client counts (default {CLIENT_COUNTS})")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)
    counts = tuple(args.clients) if args.clients else CLIENT_COUNTS
    rep = run_bench(smoke=args.smoke, out=args.out, client_counts=counts)
    for r in rep["rows"]:
        extra = ""
        if r.get("uplink") == "slow":
            extra = (f"  [slow uplink] spec L/P/D "
                     f"{r['spec_launched']}/{r['spec_patched']}"
                     f"/{r['spec_discarded']} "
                     f"hidden p50 {r['p50_spec_hidden_s']:.3f}s")
        print(f"  {r['n_clients']}c {r['mode']:>22}: "
              f"{r['throughput_fps']:6.2f} offloads/s  "
              f"p50 {r['p50_e2e_s']:.3f}s  p95 {r['p95_e2e_s']:.3f}s  "
              f"queue p50 {r['p50_queue_s']:.3f}s  "
              f"wave {r['mean_wave']:.2f}{extra}")
    for n, m in rep["detections_match"].items():
        print(f"  {n}c detections batched==sequential: {m['all_match']} "
              f"({m['compared']} jobs)  "
              f"continuous==batched: {m['continuous_match']} "
              f"({m['compared_continuous']} jobs)")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
