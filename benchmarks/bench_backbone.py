"""Backbone hot-path benchmark — tracks the perf trajectory of the ViTDet
forward from this PR onward.

Emits ``BENCH_backbone.json`` with:

  * ``backbone``: us/call of the jitted ``forward_features`` for the
    full-resolution workload and the mixed-resolution workload at every
    restoration point beta.  Off-TPU the default is the XLA backend
    ONLY: pallas runs in INTERPRET mode there — a correctness path
    ~2-3x slower that only inflates bench wall time — so it must be
    opted back in with ``--backends pallas,xla`` (on TPU both backends
    are benched by default; ``meta.backends``/``meta.interpret`` record
    what ran);
  * ``server_infer``: us/call of ``ServerModel.infer`` on a fig5-style
    workload (object-free regions downsampled, per-frame calls) with the
    jitted bucketed (n_low, beta) cache vs the same model run eagerly —
    what the bucketed jit cache actually buys per frame.

Standalone:  python benchmarks/bench_backbone.py [--smoke] [--out PATH]
Harness:     picked up by benchmarks/run.py as the ``bench_backbone``
suite (smoke settings).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vitdet_l import SIM
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.data import synthetic_video as sv
from repro.models import registry
from repro.offload import motion as mo
from repro.offload.simulator import ServerModel
from repro.quant import QuantSpec, ptq

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_backbone.json"

# compressed weight lanes benched alongside fp32 (repro.quant) — prune=0
# so every lane runs the identical SIM config/shapes and rows differ
# only in the executed dtype
QUANT_SPECS = (QuantSpec("int8", "fp32", 0), QuantSpec("int8", "fp16", 0),
               QuantSpec("fp16"))


def default_backends() -> tuple:
    """Both backends on TPU; XLA only elsewhere (pallas-interpret is a
    parity path ~2-3x slower on CPU — opt in with --backends)."""
    if jax.default_backend() == "tpu":
        return ("xla", "pallas")
    return ("xla",)


def _timer(fn, *args, reps: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in us (blocks on async dispatch)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def bench_backbone(params, img, part, reps: int, backends,
                   dtype: str = "fp32") -> list:
    """forward_features us/call: full-res + mixed at each beta, per
    backend.  ``dtype`` tags the rows ("fp32" or a repro.quant spec
    name); the params are expected to already carry that compression."""
    rows = []
    n_low = part.n_regions // 2
    mask = np.zeros(part.n_regions, np.int32)
    mask[:n_low] = 1
    fi, li = (jnp.asarray(x) for x in pt.mask_to_region_ids(mask, n_low))

    for backend in backends:
        full_fn = jax.jit(
            lambda p, i, _b=backend: vb.forward_features(SIM, p, i,
                                                         backend=_b))
        us = _timer(full_fn, params, img, reps=reps)
        rows.append({"workload": "full", "beta": None, "n_low": 0,
                     "backend": backend, "dtype": dtype, "us_per_call": us})
        for beta in range(SIM.vit.n_subsets + 1):
            fn = jax.jit(
                lambda p, i, a, b, _beta=beta, _b=backend:
                vb.forward_features(SIM, p, i, a, b, _beta, backend=_b))
            us = _timer(fn, params, img, fi, li, reps=reps)
            rows.append({"workload": "mixed", "beta": beta, "n_low": n_low,
                         "backend": backend, "dtype": dtype,
                         "us_per_call": us})

    # the padded serving hot path (PlanLayout-driven, what ServerModel
    # executes) — on the pallas backend this runs the fused
    # prologue/epilogue kernels (kernels/fused_serving)
    states = np.zeros((part.n_regions,), np.int8)
    states[:n_low] = pt.LOW
    lb = pt.length_bucket(pt.plan_n_windows(pt.RegionPlan(states), part),
                          pt.length_bucket_set(part))
    lay = pt.plan_layout(states, lb, part)
    layout = {k: jnp.asarray(getattr(lay, k))
              for k in ("win_src", "win_dst", "low_src", "low_ids",
                        "reuse_ids", "out_src", "out_map")}
    layout["nw"] = jnp.asarray([lay.nw], jnp.int32)
    for backend in backends:
        for beta in (1, 2):
            fn = jax.jit(
                lambda p, i, _beta=beta, _b=backend:
                vb.forward_features(SIM, p, i, beta=_beta, layout=layout,
                                    backend=_b))
            us = _timer(fn, params, img, reps=reps)
            rows.append({"workload": "padded", "beta": beta,
                         "n_low": n_low, "backend": backend,
                         "dtype": dtype, "us_per_call": us})
    return rows


def bench_quant_lanes(params, img, part, reps: int, backends) -> list:
    """Per-dtype rows for the compressed weight lanes: the SAME
    workloads as the fp32 pass, on params compressed to each
    ``QUANT_SPECS`` point (int8 weights run the quantized GEMM lane;
    fp16 runs the half-cast tree)."""
    rows = []
    for spec in QUANT_SPECS:
        _, cparams, rep = ptq.compress(SIM, params, spec)
        lane = bench_backbone(cparams, img, part, reps, backends,
                              dtype=spec.name)
        for r in lane:
            r["ratio"] = round(rep["ratio"], 3)
        rows.extend(lane)
    return rows


def bench_server_infer(params, n_frames: int, reps: int) -> dict:
    """fig5-style workload: per-frame ServerModel.infer, jitted bucketed
    cache vs the untraced (eager) path."""
    frames, gts = sv.make_clip("walkS", n_frames, size=SIM.vit.img_size[0],
                               seed=31)
    part = vb.vit_partition(SIM)
    patch = SIM.vit.patch_size
    masks = [(mo.region_density(g, part, patch) == 0).astype(np.int32)
             for g in gts]
    beta = 2

    def per_frame_us(server, reps):
        # warm up every (n_low, beta) bucket once, then time per-frame calls
        for f, m in zip(frames, masks):
            server.infer(f, m if m.sum() else None, beta if m.sum() else 0)
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            for f, m in zip(frames, masks):
                server.infer(f, m if m.sum() else None,
                             beta if m.sum() else 0)
            ts.append((time.perf_counter() - t0) / len(frames))
        return float(np.median(ts) * 1e6)

    jit_us = per_frame_us(ServerModel(SIM, params), reps)
    eager_us = per_frame_us(ServerModel(SIM, params, jit=False),
                            max(1, reps // 2))
    return {"workload": f"fig5-style walkS x{n_frames} frames, beta={beta}",
            "jit_us": jit_us, "eager_us": eager_us,
            "speedup": eager_us / jit_us if jit_us else float("nan")}


def run_bench(smoke: bool = False, out: Path = DEFAULT_OUT,
              backends=None) -> dict:
    # smoke keeps full-strength reps: compiles dominate smoke wall time
    # anyway, and median-of-2 timings are too noisy for the 1.15x
    # regression gate on shared/virtualised CPUs
    reps = 5
    n_frames = 2 if smoke else 6
    backends = tuple(backends) if backends else default_backends()
    params = registry.init_params(SIM, jax.random.PRNGKey(0))
    img = jax.random.uniform(jax.random.PRNGKey(1),
                             (1, *SIM.vit.img_size, 3))
    part = vb.vit_partition(SIM)

    report = {
        "meta": {
            "config": "vitdet-l/SIM",
            "device": jax.default_backend(),
            "backends": list(backends),
            "interpret": ("pallas" in backends
                          and jax.default_backend() != "tpu"),
            "smoke": smoke,
            "img_size": list(SIM.vit.img_size),
            "n_regions": part.n_regions,
        },
        "backbone": (bench_backbone(params, img, part, reps, backends)
                     + bench_quant_lanes(params, img, part, reps, backends)),
        "server_infer": bench_server_infer(params, n_frames, reps),
    }
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[bench_backbone] wrote {out}")
    return report


def check_regressions(report: dict, baseline: Path = DEFAULT_OUT,
                      tol: float = 1.15, tol_quant: float = 1.6) -> list:
    """Regression gate: compare fresh ``backbone`` rows against the
    committed baseline per (workload, beta, backend, dtype); a row more
    than ``tol``x slower is a failure.  Rows missing from the baseline
    and baselines from a different device kind are skipped (the
    committed numbers only bind the machine class that produced them).
    Pre-quant baselines have no dtype field — their rows default to
    "fp32" so old baselines keep matching.  The compressed lanes get
    the wider ``tol_quant`` band: their long emulated-fp16/int8 CPU
    calls jitter 20-40% run-to-run, so 1.15x would fire on noise — the
    regressions this gate exists to catch on those rows (steady-state
    retracing, dispatch falling off the quantized GEMM lane) are >1.6x
    shifts."""
    try:
        base = json.loads(Path(baseline).read_text())
    except (OSError, ValueError):
        print(f"[bench_backbone] no readable baseline at {baseline} — "
              "check skipped")
        return []
    if base.get("meta", {}).get("device") != report["meta"]["device"]:
        print(f"[bench_backbone] baseline device "
              f"{base.get('meta', {}).get('device')!r} != current "
              f"{report['meta']['device']!r} — check skipped")
        return []
    floors = {(r["workload"], r["beta"], r["backend"],
               r.get("dtype", "fp32")): r["us_per_call"]
              for r in base.get("backbone", [])}
    fails = []
    for r in report["backbone"]:
        key = (r["workload"], r["beta"], r["backend"],
               r.get("dtype", "fp32"))
        floor = floors.get(key)
        if floor is None:
            continue
        t = tol if r.get("dtype", "fp32") == "fp32" else tol_quant
        if r["us_per_call"] > floor * t:
            fails.append(f"{key}: {r['us_per_call']:.0f} us > "
                         f"{t:.2f}x baseline {floor:.0f} us")
    for f in fails:
        print(f"[bench_backbone] REGRESSION {f}")
    if not fails:
        print(f"[bench_backbone] check ok: {len(report['backbone'])} rows "
              f"within {tol:.2f}x (fp32) / {tol_quant:.2f}x (compressed) "
              "of baseline")
    return fails


def run(ctx: dict) -> list:
    """benchmarks/run.py adapter: smoke settings, CSV rows.  Writes to
    the artifacts dir so harness runs never clobber the committed
    full-mode BENCH_backbone.json with low-rep smoke numbers."""
    out = Path(__file__).resolve().parent / "artifacts"
    out.mkdir(parents=True, exist_ok=True)
    rep = run_bench(smoke=True, out=out / "BENCH_backbone.smoke.json")
    rows = []
    for r in rep["backbone"]:
        dt = r.get("dtype", "fp32")
        name = (f"bench_backbone/{r['workload']}"
                + (f"_b{r['beta']}" if r["beta"] is not None else "")
                + f"/{r['backend']}"
                + (f"/{dt}" if dt != "fp32" else ""))
        rows.append((name, r["us_per_call"], f"n_low={r['n_low']}"))
    s = rep["server_infer"]
    rows.append(("bench_backbone/server_infer_jit", s["jit_us"],
                 f"eager_us={s['eager_us']:.0f} speedup={s['speedup']:.1f}x"))
    ctx["bench_backbone"] = rows
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="minimal reps/frames (CI sanity lane)")
    ap.add_argument("--backends", type=str, default=None,
                    help="comma-separated backends to bench (default: "
                         "xla,pallas on TPU; xla only elsewhere — "
                         "pallas-interpret is a slow parity path)")
    ap.add_argument("--out", type=Path, default=None,
                    help=f"output JSON path (default {DEFAULT_OUT}; "
                         "--check runs default to benchmarks/artifacts "
                         "so they never clobber the committed baseline)")
    ap.add_argument("--check", action="store_true",
                    help="compare fresh rows against the committed "
                         "BENCH_backbone.json per (workload, beta, "
                         "backend, dtype); exit 1 on a >15%% regression")
    args = ap.parse_args(argv)
    backends = (tuple(b.strip() for b in args.backends.split(","))
                if args.backends else None)
    out = args.out
    if out is None:
        if args.check:
            out = Path(__file__).resolve().parent / "artifacts" \
                / "BENCH_backbone.check.json"
            out.parent.mkdir(parents=True, exist_ok=True)
        else:
            out = DEFAULT_OUT
    rep = run_bench(smoke=args.smoke, out=out, backends=backends)
    for r in rep["backbone"]:
        beta = "-" if r["beta"] is None else r["beta"]
        print(f"  {r['workload']:>5} beta={beta} {r['backend']:>6} "
              f"{r.get('dtype', 'fp32'):>9}: "
              f"{r['us_per_call']:10.0f} us/call")
    s = rep["server_infer"]
    print(f"  server.infer jit {s['jit_us']:.0f} us vs eager "
          f"{s['eager_us']:.0f} us  ({s['speedup']:.1f}x)")
    if args.check:
        return 1 if check_regressions(rep) else 0
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
