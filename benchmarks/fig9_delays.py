"""Paper Fig. 9 — in-depth delay decomposition.

Median network-communication delay and model-inference delay per policy
(from the shared fig-8 simulation grid).  Expected (paper): ViTMAlis cuts
BOTH — network delay ~51% below Back2Back/TrackB2B and inference delay
well below the full-resolution baselines.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C


def run(ctx: dict) -> list:
    groups = C.by_policy(C.get_sim_results())
    rows = []
    med = {}
    for name, rs in groups.items():
        net = C.pooled_delay(rs, "net")
        inf = C.pooled_delay(rs, "inf")
        med[name] = (float(np.median(net)), float(np.median(inf)))
        rows.append((f"fig9/{name}", 0.0,
                     f"median_net_ms={np.median(net)*1e3:.0f} "
                     f"median_inf_ms={np.median(inf)*1e3:.0f}"))

    if "ViTMAlis" in med and "TrackB2B" in med:
        net_cut = 1.0 - med["ViTMAlis"][0] / max(med["TrackB2B"][0], 1e-9)
        inf_cut = 1.0 - med["ViTMAlis"][1] / max(med["TrackB2B"][1], 1e-9)
        rows.append(("fig9/vitmalis_reduction", 0.0,
                     f"net_cut={net_cut:.0%} inf_cut={inf_cut:.0%} "
                     f"(paper: ~51% net, 263->162ms inf)"))
    return rows
