"""Temporal region reuse ablation — three-state RegionPlan vs. the
no-reuse ViTMAlis policy.

Emits ``BENCH_reuse.json`` with one row per (video, mode, policy):

  * ``median_bytes``     — median offload payload (after SIZE_SCALE);
  * ``median_e2e_s``     — Eq. (2) end-to-end latency;
  * ``median_rendering_f1`` / ``mean_inference_f1``;
  * ``reuse_fraction``   — mean fraction of regions shipped as REUSE;

plus a ``reduction`` block quoting the static-scene trade-off the
acceptance bar asks for: payload-byte and latency reduction at the
rendering-F1 delta, for BOTH the single-client ``Simulation`` and the
batched ``MultiClientSimulation``.

Videos: ``parkS`` (static surveillance scene — the reuse best case) and
``driveN`` (fast camera — the worst case; reuse should engage rarely and
cost nothing).

Standalone:  python benchmarks/bench_reuse.py [--smoke] [--out P]
Harness:     picked up by benchmarks/run.py as the ``bench_reuse`` suite
             (smoke settings).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.configs.vitdet_l import SIM
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.offload import baselines as bl
from repro.offload import motion as mo
from repro.offload.codec import CodecDelayModel, MixedResCodec
from repro.offload.estimator import (InferenceDelayModel,
                                     ThroughputEstimator, feature_vector)
from repro.offload.optimizer import (DelayModels, OffloadOptimizer,
                                     candidate_configs)
from repro.offload.simulator import Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_reuse.json"
PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]
FPS = 10
FULL_RES_DELAY_S = 0.281
VIDEOS = ("parkS", "driveN")
REUSE_K = 4
# trimmed Algorithm-1 config space (bounded compile set for the bench)
CONFIGS = candidate_configs(qualities=(70, 85, 95), betas=(2, 4))


def _inf_delay_model() -> InferenceDelayModel:
    part = vb.vit_partition(SIM)
    # Algorithm 1 keeps the EXACT-length LM^inf: the padded-bucket cost
    # (backbone_flops(..., length_edges=...), what the collapsed grid
    # really runs) is a step function that quantizes away the marginal-
    # latency differences the optimizer discriminates configs by — on
    # static scenes that re-opens the accuracy collapse the frontier's
    # a_floor guard exists for (aggressive configs stop paying a
    # latency-model penalty).  The exact curve is the strictly-monotone
    # surrogate for config SELECTION; serving-side accounting (the edge
    # coalescer, bench_serving's Eq. 2 terms) costs the padded bucket.
    return InferenceDelayModel.fit_from_flops(
        lambda n, b, r=0: vb.backbone_flops(SIM, n, b, r), part.n_regions,
        betas=tuple(range(SIM.vit.n_subsets + 1)),
        full_res_delay_s=FULL_RES_DELAY_S)


def build_estimators(server: BatchedServerModel, n_frames: int,
                     mlp_steps: int = 1200):
    """Offline profiling pass over THIS bench's scenario domain (static
    parkS + fast driveN) -> MLP size/accuracy estimators (the paper's
    estimator family; profiling on the deployment domain is what keeps
    Algorithm 1 away from configs that collapse accuracy on static
    scenes).

    Size labels come from the decode-free ``encode_size_only`` fast
    path; accuracy labels from the actual mixed forward on the decoded
    frame.
    """
    from repro.offload import detection as det
    from repro.offload.estimator import MLPEstimator
    part = vb.vit_partition(SIM)
    codec = MixedResCodec(part, PATCH, part.downsample)
    X, y_size, y_acc = [], [], []
    for name in VIDEOS:
        frames, gts = sv.make_clip(name, n_frames, size=SIZE, seed=11)
        analyzer = mo.RegionMotionAnalyzer(part, PATCH)
        for fi, frame in enumerate(frames):
            m, m_f = analyzer.update(frame)
            if fi < 2:
                continue
            gt_dets = server.infer(frame)
            rho = mo.region_density(gts[fi], part, PATCH)
            mu_r, sg_r = float(rho.mean()), float(rho.std())
            for c in CONFIGS:
                mask = mo.downsample_mask(
                    mo.classify_regions(m, rho), c.tau_d)
                n_d = int(mask.sum())
                m_d = float((mask * m).sum())
                X.append(feature_vector(c.tau_d, n_d, m_d, m_f, c.quality,
                                        mu_r, sg_r, c.beta))
                y_size.append(codec.encode_size_only(frame, mask,
                                                     c.quality) / 1024.0)
                enc, decoded = codec.encode(frame, mask, c.quality)
                dets = server.infer(decoded, mask if n_d > 0 else None,
                                    c.beta if n_d > 0 else 0)
                y_acc.append(det.frame_f1(dets, gt_dets))
    size_e, acc_e = MLPEstimator(), MLPEstimator()
    size_e.fit(np.stack(X), np.array(y_size), steps=mlp_steps)
    acc_e.fit(np.stack(X), np.array(y_acc), steps=mlp_steps)
    return size_e, acc_e


def make_optimizer(size_e, acc_e) -> OffloadOptimizer:
    part = vb.vit_partition(SIM)
    delays = DelayModels(enc=CodecDelayModel(), inf=_inf_delay_model(),
                         net=ThroughputEstimator())
    return OffloadOptimizer(part, size_e, acc_e, delays,
                            configs=list(CONFIGS))


def policy_factories(size_e, acc_e) -> Dict[str, Callable]:
    return {
        "ViTMAlis": lambda: bl.ViTMAlis(make_optimizer(size_e, acc_e)),
        "ViTMAlis+Reuse": lambda: bl.ViTMAlisReuse(
            make_optimizer(size_e, acc_e), reuse_k=REUSE_K),
    }


def _sim(server, part, frames, gt, policy, seed) -> Simulation:
    return Simulation(frames, gt, make_trace("4g", seed, duration_s=240),
                      policy, server, part, PATCH, fps=FPS,
                      inf_delay=_inf_delay_model())


def _row(res_list, video: str, mode: str, policy: str,
         clients) -> Dict:
    sizes = np.array([s for r in res_list for s in r.sizes], np.float64)
    e2e = np.array([x for r in res_list for x in r.e2e_latency],
                   np.float64)
    rf1 = np.array([x for r in res_list for x in r.rendering_f1],
                   np.float64)
    if1 = np.array([x for r in res_list for x in r.inference_f1],
                   np.float64)
    n_reg = clients[0].part.n_regions
    reuse_fracs = []
    for c in clients:
        if c.feature_cache is not None and c.feature_cache.warm:
            reuse_fracs.append(float((c.feature_cache.age > 0).mean()))
    return {
        "video": video, "mode": mode, "policy": policy,
        "offloads": int(e2e.size),
        "median_bytes": float(np.median(sizes)) if sizes.size else None,
        "median_e2e_s": float(np.median(e2e)) if e2e.size else None,
        "median_rendering_f1": float(np.median(rf1)) if rf1.size else None,
        "mean_inference_f1": float(np.mean(if1)) if if1.size else None,
        "reuse_fraction_last": (float(np.mean(reuse_fracs))
                                if reuse_fracs else 0.0),
        "n_regions": n_reg,
    }


def run_bench(smoke: bool = False, out: Path = DEFAULT_OUT) -> dict:
    n_frames = 16 if smoke else 40
    profile_frames = 4 if smoke else 6
    n_clients = 2
    if smoke:
        # smoke lane: random-init weights (fast; exercises the plumbing
        # and the byte/latency deltas — the F1 columns are only
        # meaningful with the trained model below)
        from repro.models import registry
        params = registry.init_params(SIM, jax.random.PRNGKey(0))
        server = BatchedServerModel(SIM, params, top_k=8, score_thresh=0.0)
    else:
        # full lane: the shared trained sim-scale server (cached under
        # benchmarks/artifacts/cache by benchmarks/common.py)
        import sys
        sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
        from benchmarks import common as C
        params = C.get_server().params
        server = BatchedServerModel(SIM, params, top_k=32,
                                    score_thresh=0.4)
    part = vb.vit_partition(SIM)
    size_e, acc_e = build_estimators(server, profile_frames,
                                     mlp_steps=300 if smoke else 1200)
    factories = policy_factories(size_e, acc_e)

    gt_cache: Dict[str, tuple] = {}

    def video_gt(name):
        if name not in gt_cache:
            frames, _ = sv.make_clip(name, n_frames, size=SIZE, seed=23)
            gt_cache[name] = (frames, [server.infer(f) for f in frames])
        return gt_cache[name]

    rows: List[Dict] = []
    for video in VIDEOS:
        frames, gt = video_gt(video)
        for pname, make_pol in factories.items():
            # single-client Simulation
            c = _sim(server, part, frames, gt, make_pol(), seed=0)
            rows.append(_row([c.run(video)], video, "single", pname, [c]))
            # batched multi-client (same scene class, distinct streams)
            clients = [_sim(server, part, frames, gt, make_pol(), seed=i)
                       for i in range(n_clients)]
            mc = MultiClientSimulation(clients, server,
                                       EdgeConfig(batched=True))
            rows.append(_row(mc.run([video] * n_clients), video,
                             f"multi{n_clients}", pname, clients))

    def find(video, mode, policy):
        return next(r for r in rows if (r["video"], r["mode"],
                                        r["policy"]) == (video, mode,
                                                         policy))

    reduction = {}
    for mode in ("single", f"multi{n_clients}"):
        for video in VIDEOS:
            base = find(video, mode, "ViTMAlis")
            reuse = find(video, mode, "ViTMAlis+Reuse")
            reduction[f"{video}/{mode}"] = {
                "bytes_reduction": 1.0 - reuse["median_bytes"]
                / base["median_bytes"],
                "e2e_reduction": 1.0 - reuse["median_e2e_s"]
                / base["median_e2e_s"],
                "rendering_f1_delta": base["median_rendering_f1"]
                - reuse["median_rendering_f1"],
            }

    report = {
        "meta": {
            "config": "vitdet-l/SIM",
            "device": jax.default_backend(),
            "smoke": smoke,
            "n_frames": n_frames,
            "fps": FPS,
            "reuse_k": REUSE_K,
            "full_res_delay_s": FULL_RES_DELAY_S,
            "videos": list(VIDEOS),
        },
        "rows": rows,
        "reduction": reduction,
    }
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[bench_reuse] wrote {out}")
    return report


def run(ctx: dict) -> list:
    """benchmarks/run.py adapter: smoke settings, CSV rows."""
    out = Path(__file__).resolve().parent / "artifacts"
    out.mkdir(parents=True, exist_ok=True)
    rep = run_bench(smoke=True, out=out / "BENCH_reuse.smoke.json")
    rows = []
    for r in rep["rows"]:
        rows.append((f"bench_reuse/{r['video']}/{r['mode']}/{r['policy']}",
                     (r["median_e2e_s"] or 0.0) * 1e6,
                     f"bytes={r['median_bytes']:.0f} "
                     f"rf1={r['median_rendering_f1']:.3f}"))
    for k, red in rep["reduction"].items():
        rows.append((f"bench_reuse/reduction/{k}", 0.0,
                     f"bytes=-{red['bytes_reduction']:.0%} "
                     f"e2e=-{red['e2e_reduction']:.0%} "
                     f"df1={red['rendering_f1_delta']:+.3f}"))
    ctx["bench_reuse"] = rows
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fewer frames (CI sanity lane)")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)
    rep = run_bench(smoke=args.smoke, out=args.out)
    for r in rep["rows"]:
        print(f"  {r['video']:>7} {r['mode']:>7} {r['policy']:>15}: "
              f"bytes {r['median_bytes']:9.0f}  "
              f"e2e {r['median_e2e_s']:.3f}s  "
              f"rf1 {r['median_rendering_f1']:.3f}  "
              f"({r['offloads']} offloads)")
    for k, red in rep["reduction"].items():
        print(f"  {k}: bytes -{red['bytes_reduction']:.0%}  "
              f"e2e -{red['e2e_reduction']:.0%}  "
              f"rendering-F1 delta {red['rendering_f1_delta']:+.3f}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
