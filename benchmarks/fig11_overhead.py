"""Paper Fig. 11 — on-device system overhead.

Measured wall time of the three device-side components on this host:
Region Motion Analyzer (per frame), Performance Estimator (all candidate
configs), Offload Optimizer (Algorithm 1 end-to-end).  The paper's
Jetson numbers are 10 / 9 / 2 ms — ours are CPU-host analogues.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.data import synthetic_video as sv
from repro.offload import motion as mo
from repro.offload.optimizer import SystemState


def run(ctx: dict) -> list:
    part = C.get_part()
    est = C.get_estimators()
    opt = C.make_optimizer(est["MLP"]["size"], est["MLP"]["acc"])
    frames, gts = sv.make_clip("walkB", 8, size=C.SIZE, seed=5)
    analyzer = mo.RegionMotionAnalyzer(part, C.PATCH)
    for f in frames[:-1]:
        m, m_f = analyzer.update(f)
    rho = mo.region_density(gts[-1], part, C.PATCH)

    us_motion = C.timer(lambda: analyzer.update(frames[-1]), reps=10)
    us_est = C.timer(lambda: opt.evaluate(m, m_f, rho), reps=10)
    us_alg1 = C.timer(lambda: opt.select(m, m_f, rho, SystemState()),
                      reps=10)

    return [
        ("fig11/motion_analyzer", us_motion,
         f"per-frame {us_motion/1e3:.1f}ms (paper: 10ms on Jetson GPU)"),
        ("fig11/perf_estimator", us_est,
         f"all {len(opt.configs)} configs {us_est/1e3:.1f}ms (paper: 9ms)"),
        ("fig11/offload_optimizer", us_alg1,
         f"Algorithm 1 {us_alg1/1e3:.1f}ms (paper: 2ms)"),
    ]
