"""Zero-stall serving benchmark — AOT warmup over the COLLAPSED
(length bucket, beta, capture, B bucket) executable grid,
device-resident feature caches, cross-bucket wave coalescing.

Emits ``BENCH_serving.json`` with three sections:

  * ``warmup``   — first-offload wall latency and p95 per-offload server
                   wall time for a lazy-compile replica vs. an
                   AOT-warmed one, plus the COMPILE SURFACE: total
                   executables, the warmed key list, warmup wall time
                   (gated by ``--max-warmup-s``), and steady-state
                   compiles (MUST be 0 after warmup — the bench fails
                   under ``--check`` otherwise, as does a surface
                   larger than ``EXEC_BUDGET`` executables);
  * ``cache``    — host<->device tile bytes per offload on a reuse-heavy
                   parkS workload, device-resident FeatureCache vs. the
                   legacy host-resident mode (device mode MUST be 0);
  * ``coalesce`` — mean wave size, mixed-plan wave counts (waves
                   batching >= 2 distinct n_low values in ONE
                   executable), throughput and p95 e2e (queueing
                   included) on a multi-length-bucket workload with and
                   without cross-bucket coalescing, plus rendering-F1
                   deltas on the parkS/driveN scenarios (promotion only
                   ever PADS the sequence, so the deltas must be 0.000);
  * ``speculation`` — speculative REUSE execution on a slow uplink
                   (stacked bufferbloat overlay): continuous vs.
                   continuous+speculative on a 4-client parkS/driveN
                   workload — speculation MUST cut p50 e2e, keep the
                   rendering-F1 delta within 0.005 per scenario, add
                   ZERO executable keys and steady compiles, launch and
                   patch at least once, and a zero-tolerance probe MUST
                   exercise the discard-and-rerun path;
  * ``scheduling`` — barrier vs. continuous wave scheduling
                   (``EdgeConfig(scheduler=...)``) on a contended
                   4-client workload against ONE pre-warmed replica:
                   p50 queue delay and ``device_idle_frac`` (continuous
                   must beat barrier on both — the decode/h2d overlap
                   win), zero steady-state compiles and ZERO new
                   executable keys for the continuous run (it must
                   reuse the warmed grid), and a 0.000 rendering-F1
                   delta (scheduling moves timestamps, never boxes).

Standalone:  python benchmarks/bench_serving.py [--smoke] [--check]
                    [--max-warmup-s S]
Harness:     picked up by benchmarks/run.py as the ``bench_serving``
             suite (smoke settings, check enabled).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.vitdet_l import SIM
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import REUSE, RegionPlan
from repro.data import synthetic_video as sv
from repro.data.network_traces import make_trace
from repro.models import registry
from repro.offload.estimator import InferenceDelayModel
from repro.offload.faults import FaultInjector, FaultSpec, FaultyTrace
from repro.offload.optimizer import build_reuse_plan
from repro.offload.simulator import Policy, ServerModel, Simulation
from repro.serve.edge import (BatchedServerModel, EdgeConfig,
                              MultiClientSimulation)
from repro.serve.request import FeatureCache

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"
PATCH = SIM.vit.patch_size
SIZE = SIM.vit.img_size[0]
FPS = 10
FULL_RES_DELAY_S = 0.281
BETA = 2
REUSE_K = 4
# the collapsed grid must stay within this many executables (vs 56 on
# the old (n_low bucket, n_reuse bucket, beta, capture, B) grid)
EXEC_BUDGET = 16


def _params():
    return registry.init_params(SIM, jax.random.PRNGKey(0))


def _inf_delay_model() -> InferenceDelayModel:
    part = vb.vit_partition(SIM)
    # LM^inf costs the PADDED length bucket the replica actually runs,
    # not the exact mixed length — the executable grid pads sequences
    # up to pt.length_bucket_set edges
    edges = pt.length_bucket_set(part)
    return InferenceDelayModel.fit_from_flops(
        lambda n, b, r=0: vb.backbone_flops(SIM, n, b, r,
                                            length_edges=edges),
        part.n_regions,
        betas=tuple(range(SIM.vit.n_subsets + 1)),
        full_res_delay_s=FULL_RES_DELAY_S)


def _mask(part, lows) -> np.ndarray:
    m = np.zeros(part.n_regions, np.int32)
    m[list(lows)] = 1
    return m


# ---------------------------------------------------------------------------
# section 1: AOT warmup vs. lazy compile


def _serving_trace(server: ServerModel, frames: np.ndarray,
                   with_reuse: bool) -> List[float]:
    """A representative steady-state serving trace (solo calls + waves
    across the plan space); returns per-call wall seconds."""
    part = server.part
    plan4 = RegionPlan.from_mask(_mask(part, range(4)))
    plan8 = RegionPlan.from_mask(_mask(part, range(8)))
    cache = FeatureCache(part.n_regions, max_age=REUSE_K)
    states = plan4.states.copy()
    states[8:12] = REUSE
    plan_r = RegionPlan(states)
    walls = []

    def call(fn, *a, **kw):
        t0 = time.perf_counter()
        fn(*a, **kw)
        walls.append(time.perf_counter() - t0)

    n = len(frames)
    for i in range(n):
        f = frames[i % n]
        call(server.infer, f)                              # full-res solo
        call(server.infer, f, _mask(part, range(4)), BETA)  # mixed solo
        call(server.infer_wave, frames[:2], [plan4, plan4], BETA)
        call(server.infer_wave, frames[:3], [plan8] * 3, BETA)
        if with_reuse:
            if not cache.warm:
                call(server.infer_plan, f, plan4, BETA, cache, i)
            else:
                call(server.infer_plan, f, plan_r, BETA, cache, i)
    return walls


def bench_warmup(n_frames: int) -> Dict:
    frames, _ = sv.make_clip("walkS", max(n_frames, 4), size=SIZE, seed=3)
    frames = frames[:max(n_frames, 4)]
    rows = {}
    for mode in ("lazy", "warmed"):
        server = ServerModel(SIM, _params(), top_k=8, score_thresh=0.0)
        warm_wall = 0.0
        if mode == "warmed":
            # captures include BETA: reuse sessions capture tiles at the
            # restoration point even on their (n_reuse = 0) warm-up
            # offloads, so those executables are part of the grid
            space = server.default_plan_space(
                betas=(BETA,), reuse_edges=(0, 4), captures=(0, BETA))
            server.warmup(space)
            warm_wall = server.stats.warmup_wall_s
        walls = _serving_trace(server, frames, with_reuse=True)
        rows[mode] = {
            "first_offload_wall_s": walls[0],
            "p50_offload_wall_s": float(np.percentile(walls, 50)),
            "p95_offload_wall_s": float(np.percentile(walls, 95)),
            "warmup_wall_s": warm_wall,
            "executables_total": server.stats.compiles,
            "compile_surface": sorted([list(k) for k in server._fns]),
            "steady_compiles": server.stats.steady_compiles,
            "steady_compile_keys": [list(k) for k in
                                    server.stats.steady_compile_keys],
        }
    rows["first_offload_speedup"] = (
        rows["lazy"]["first_offload_wall_s"]
        / max(rows["warmed"]["first_offload_wall_s"], 1e-12))
    return rows


# ---------------------------------------------------------------------------
# section 2: device-resident FeatureCache


class FixedReusePolicy(Policy):
    """Static low mask + motion-gated reuse at a fixed restoration point
    (deterministic; exercises the full plan/cache plumbing)."""
    name = "fixed-reuse"
    use_tracker = True
    reuse_k = REUSE_K

    def __init__(self, n_regions, lows=(0, 1, 2, 3), beta=BETA):
        self.n_regions = n_regions
        self.lows = list(lows)
        self.beta = beta

    def decide(self, sim, frame_idx):
        mask = np.zeros(self.n_regions, np.int32)
        mask[self.lows] = 1
        cache = sim.feature_cache
        elig = (cache.eligible(self.beta) if cache is not None
                else np.zeros(self.n_regions, bool))
        plan = build_reuse_plan(sim.part, mask, sim.m, elig)
        return {"mask": mask, "quality": 85, "beta": self.beta,
                "plan": plan, "capture_beta": self.beta}


def bench_cache(n_frames: int) -> Dict:
    part = vb.vit_partition(SIM)
    frames, _ = sv.make_clip("parkS", n_frames, size=SIZE, seed=23)
    rows = {}
    for mode, device in (("device", True), ("host", False)):
        server = ServerModel(SIM, _params(), top_k=8, score_thresh=0.0,
                             device_cache=device)
        gt = [server.infer(f) for f in frames]
        n_offloads0 = server.stats.offloads
        b0 = server.stats.tile_bytes
        sim = Simulation(frames, gt, make_trace("4g", 0, duration_s=120),
                         FixedReusePolicy(part.n_regions), server, part,
                         PATCH, fps=FPS, inf_delay=_inf_delay_model())
        sim.run("parkS")
        offloads = server.stats.offloads - n_offloads0
        reused = int((sim.feature_cache.age > 0).sum())
        rows[mode] = {
            "offloads": offloads,
            "tile_bytes_h2d": server.stats.tile_bytes_h2d,
            "tile_bytes_d2h": server.stats.tile_bytes_d2h,
            "tile_bytes_per_offload": (server.stats.tile_bytes - b0)
            / max(offloads, 1),
            "cache_on_device": bool(sim.feature_cache.tiles_on_device),
            "regions_reused_at_end": reused,
        }
    return rows


# ---------------------------------------------------------------------------
# section 3: cross-bucket wave coalescing


class FixedMaskPolicy(Policy):
    name = "fixedmask"
    use_tracker = True

    def __init__(self, lows, n_regions, beta=BETA):
        self.lows = list(lows)
        self.n_regions = n_regions
        self.beta = beta

    def decide(self, sim, frame_idx):
        m = np.zeros(self.n_regions, np.int32)
        m[self.lows] = 1
        return {"mask": m, "quality": 85, "beta": self.beta}


def _bucket_clients(server, part, video_specs, n_frames, gt_cache):
    inf_delay = _inf_delay_model()
    clients = []
    for i, (video, lows) in enumerate(video_specs):
        key = (video, n_frames)
        if key not in gt_cache:
            frames, _ = sv.make_clip(video, n_frames, size=SIZE, seed=23)
            gt_cache[key] = (frames, [server.infer(f) for f in frames])
        frames, gt = gt_cache[key]
        clients.append(Simulation(
            frames, gt, make_trace("4g", i, duration_s=240),
            FixedMaskPolicy(lows, part.n_regions), server, part, PATCH,
            fps=FPS, inf_delay=inf_delay))
    return clients


def _run_coalesce(server, part, video_specs, n_frames, coalesce,
                  gt_cache, keep=None) -> Dict:
    clients = _bucket_clients(server, part, video_specs, n_frames,
                              gt_cache)
    mc = MultiClientSimulation(clients, server,
                               EdgeConfig(batched=True,
                                          coalesce=coalesce),
                               on_complete=keep)
    results = mc.run([v for v, _ in video_specs])
    e2e = np.array([x for r in results for x in r.e2e_latency], np.float64)
    rf1 = {}
    for r in results:
        rf1.setdefault(r.video, []).extend(r.rendering_f1)
    return {
        "coalesce": coalesce,
        "offloads": int(e2e.size),
        "throughput_fps": float(e2e.size / (n_frames / FPS)),
        "p50_e2e_s": float(np.percentile(e2e, 50)) if e2e.size else None,
        "p95_e2e_s": float(np.percentile(e2e, 95)) if e2e.size else None,
        "mean_wave": mc.stats.mean_wave_size,
        "promoted_jobs": mc.stats.promoted,
        # waves that batched >= 2 distinct n_low values in ONE
        # executable — the collapsed-grid win coalescing builds on
        "mixed_plan_waves": mc.stats.mixed_plan_waves,
        "max_distinct_n_low_per_wave": (max(mc.stats.wave_n_low_mix)
                                        if mc.stats.wave_n_low_mix
                                        else 0),
        "median_rendering_f1": {v: float(np.median(x))
                                for v, x in rf1.items()},
    }


def bench_coalesce(n_frames: int) -> Dict:
    from repro.offload import detection as det
    part = vb.vit_partition(SIM)
    server = BatchedServerModel(SIM, _params(), top_k=8, score_thresh=0.0)
    gt_cache: Dict = {}

    # (a) multi-length-bucket workload: the clients span three length
    # buckets (n_low 4 -> 64 windows, 8/12 -> 48, 16 -> 24 at SIM
    # scale), so same-bucket jobs already co-batch WITHOUT coalescing
    # (the collapsed grid batches any n_low mix at one bucket); wave
    # growth beyond that is cross-bucket promotion — padding a shorter
    # job up to the wave's bucket.  For each promoted job we also quote
    # the inference-F1 cost of the promotion itself: F1(promoted dets)
    # - F1(the dets a solo run at the job's OWN bucket yields), timeline
    # effects excluded (promotion only pads, so this must be ~0).
    specs = [("parkS", range(4)), ("parkS", range(12)),
             ("driveN", range(8)), ("driveN", range(16))]
    promoted_jobs: List[Dict] = []

    def keep(ci, job):
        if "promoted_lb" in job:
            promoted_jobs.append({"video": specs[ci][0], **job})

    on = _run_coalesce(server, part, specs, n_frames, True, gt_cache,
                       keep=keep)
    off = _run_coalesce(server, part, specs, n_frames, False, gt_cache)

    f1_cost = []
    for job in promoted_jobs:
        gt = gt_cache[(job["video"], n_frames)][1][job["frame"]]
        own = server.infer_wave(job["decoded"][None], [job["plan"]],
                                job["beta"])[0]
        f1_cost.append(det.frame_f1(job["dets"], gt)
                       - det.frame_f1(own, gt))

    # (b) the EXISTING parkS/driveN scenarios (same-bucket clients, the
    # bench_reuse workload shape): enabling coalescing must be a perfect
    # no-op there — no cross-bucket jobs exist, so the scheduler, the
    # timeline, and the rendering F1 must be IDENTICAL (delta 0.000).
    deltas = {}
    for video in ("parkS", "driveN"):
        sp = [(video, range(4)), (video, range(4))]
        s_on = _run_coalesce(server, part, sp, n_frames, True, gt_cache)
        s_off = _run_coalesce(server, part, sp, n_frames, False, gt_cache)
        assert s_on["promoted_jobs"] == 0
        deltas[video] = (s_on["median_rendering_f1"][video]
                         - s_off["median_rendering_f1"][video])

    return {"on": on, "off": off,
            "promotion_inference_f1_delta": {
                "n": len(f1_cost),
                "mean": float(np.mean(f1_cost)) if f1_cost else 0.0,
                "median": float(np.median(f1_cost)) if f1_cost else 0.0,
            },
            "rendering_f1_delta": deltas}


# ---------------------------------------------------------------------------
# section 4: barrier vs. continuous wave scheduling


def _run_sched(server, part, video_specs, n_frames, scheduler,
               gt_cache) -> Dict:
    clients = _bucket_clients(server, part, video_specs, n_frames,
                              gt_cache)
    mc = MultiClientSimulation(clients, server,
                               EdgeConfig(batched=True,
                                          scheduler=scheduler))
    results = mc.run([v for v, _ in video_specs])
    e2e = np.array([x for r in results for x in r.e2e_latency], np.float64)
    queue = np.asarray(mc.stats.queue_delays, np.float64)
    admit = np.asarray(mc.stats.queue_admit, np.float64)
    slot = np.asarray(mc.stats.queue_slot, np.float64)
    rf1 = {}
    for r in results:
        rf1.setdefault(r.video, []).extend(r.rendering_f1)

    def p(x, q):
        return float(np.percentile(x, q)) if x.size else 0.0

    return {
        "scheduler": scheduler,
        "offloads": int(e2e.size),
        "p50_e2e_s": p(e2e, 50),
        "p95_e2e_s": p(e2e, 95),
        "p50_queue_s": p(queue, 50),
        "p95_queue_s": p(queue, 95),
        "p50_queue_admit_s": p(admit, 50),
        "p50_queue_slot_s": p(slot, 50),
        "device_idle_frac": mc.stats.device_idle_frac,
        "decode_hidden_s": mc.stats.decode_hidden_s,
        "mean_wave": mc.stats.mean_wave_size,
        "median_rendering_f1": {v: float(np.median(x))
                                for v, x in rf1.items()},
    }


def bench_scheduling(n_frames: int) -> Dict:
    part = vb.vit_partition(SIM)
    server = BatchedServerModel(SIM, _params(), top_k=8, score_thresh=0.0)
    gt_cache: Dict = {}
    # 4 same-bucket clients on a static scene: the shared replica is a
    # genuine bottleneck (FULL_RES_DELAY_S service model at 10 FPS), so
    # barrier waves idle the device through every decode window and
    # queue arrivals behind it; continuous stages decode/h2d under the
    # running wave.  parkS is static, so the timing shift cannot move
    # which boxes a frame renders — the F1 delta gate is exact.
    specs = [("parkS", range(4))] * 4
    # ground truth BEFORE warmup (full-res solo inference), then warm
    # the grid the workload needs — every compile after this point is a
    # steady-state stall, and the continuous run must add ZERO keys
    for video, _ in specs:
        key = (video, n_frames)
        if key not in gt_cache:
            frames, _ = sv.make_clip(video, n_frames, size=SIZE, seed=23)
            gt_cache[key] = (frames, [server.infer(f) for f in frames])
    server.warmup(server.default_plan_space(betas=(BETA,)))

    barrier = _run_sched(server, part, specs, n_frames, "barrier",
                         gt_cache)
    keys0, compiles0 = set(server._fns), server.stats.compiles
    cont = _run_sched(server, part, specs, n_frames, "continuous",
                      gt_cache)
    new_keys = sorted(list(k) for k in set(server._fns) - keys0)
    f1_delta = {v: cont["median_rendering_f1"][v]
                - barrier["median_rendering_f1"][v]
                for v in barrier["median_rendering_f1"]}
    return {"barrier": barrier, "continuous": cont,
            "steady_compiles": server.stats.steady_compiles,
            "continuous_new_executables": new_keys,
            "continuous_new_compiles": server.stats.compiles - compiles0,
            "rendering_f1_delta": f1_delta}


# ---------------------------------------------------------------------------
# section 5: speculative REUSE execution on a slow uplink


# The congested-cell overlay: bufferbloat windows from offload/faults.py
# COMPOUND when stacked (each dents throughput to 70 % and inflates RTT
# by its factor), so ten whole-run windows leave ~3 % of the uplink
# (0.7^10) at ~4x RTT (1.15^10) — the regime where transmission, not
# inference, dominates Eq. (2) and speculation has an uplink to hide in.
SLOW_UPLINK = FaultSpec(
    bufferbloat=tuple((0.0, 3600.0, 1.15) for _ in range(10)))


def _reuse_clients(server, part, video_specs, n_frames, gt_cache):
    inf_delay = _inf_delay_model()
    clients = []
    for i, (video, lows) in enumerate(video_specs):
        key = (video, n_frames)
        if key not in gt_cache:
            frames, _ = sv.make_clip(video, n_frames, size=SIZE, seed=23)
            gt_cache[key] = (frames, [server.infer(f) for f in frames])
        frames, gt = gt_cache[key]
        trace = FaultyTrace(make_trace("4g", i, duration_s=240),
                            FaultInjector(SLOW_UPLINK))
        clients.append(Simulation(
            frames, gt, trace,
            FixedReusePolicy(part.n_regions, lows=lows), server, part,
            PATCH, fps=FPS, inf_delay=inf_delay))
    return clients


def _run_spec(server, part, video_specs, n_frames, gt_cache,
              speculate, **spec_kw) -> Dict:
    clients = _reuse_clients(server, part, video_specs, n_frames,
                             gt_cache)
    mc = MultiClientSimulation(clients, server,
                               EdgeConfig(batched=True,
                                          scheduler="continuous",
                                          speculate=speculate, **spec_kw))
    results = mc.run([v for v, _ in video_specs])
    e2e = np.array([x for r in results for x in r.e2e_latency], np.float64)
    rf1 = {}
    for r in results:
        rf1.setdefault(r.video, []).extend(r.rendering_f1)

    def p(x, q):
        return float(np.percentile(x, q)) if x.size else 0.0

    return {
        "speculate": speculate,
        "offloads": int(e2e.size),
        "p50_e2e_s": p(e2e, 50),
        "p95_e2e_s": p(e2e, 95),
        "p50_queue_s": p(np.asarray(mc.stats.queue_delays), 50),
        "device_idle_frac": mc.stats.device_idle_frac,
        "spec_launched": mc.stats.spec_launched,
        "spec_patched": mc.stats.spec_patched,
        "spec_discarded": mc.stats.spec_discarded,
        "spec_hidden_s": mc.stats.spec_hidden_s,
        "p50_spec_hidden_s": mc.stats.spec_hidden_percentile(50),
        "p95_spec_hidden_s": mc.stats.spec_hidden_percentile(95),
        "median_rendering_f1": {v: float(np.median(x))
                                for v, x in rf1.items()},
    }


def bench_speculation(n_frames: int) -> Dict:
    part = vb.vit_partition(SIM)
    server = BatchedServerModel(SIM, _params(), top_k=8, score_thresh=0.0)
    gt_cache: Dict = {}
    # slow-uplink 4-client workload: three reuse-heavy parkS sessions
    # (static scene -> high REUSE fraction + high prediction confidence
    # -> speculation admits and converges) and one driveN session (real
    # motion -> low confidence, the lane mostly stands down).  Ground
    # truth before warmup, then warm the grid — speculation must add
    # ZERO keys on top of it.
    specs = [("parkS", range(4)), ("parkS", range(4, 8)),
             ("parkS", range(8, 12)), ("driveN", range(4))]
    for video, _ in specs:
        key = (video, n_frames)
        if key not in gt_cache:
            frames, _ = sv.make_clip(video, n_frames, size=SIZE, seed=23)
            gt_cache[key] = (frames, [server.infer(f) for f in frames])
    server.warmup(server.default_plan_space(
        betas=(BETA,), reuse_edges=(0, 4), captures=(0, BETA)))

    cont = _run_spec(server, part, specs, n_frames, gt_cache, False)
    keys0, compiles0 = set(server._fns), server.stats.compiles
    spec = _run_spec(server, part, specs, n_frames, gt_cache, True)
    new_keys = sorted(list(k) for k in set(server._fns) - keys0)
    # discard probe: zero tolerance + zero patch budget turns every
    # resolved speculation into a discard-and-rerun (codec noise always
    # diverges a transmitted region at tol=0), pinning the discard path
    # end to end on the real executor
    probe = _run_spec(server, part, specs[:1], n_frames, gt_cache, True,
                      spec_patch_tol=0.0, spec_max_patch_frac=0.0)
    f1_delta = {v: spec["median_rendering_f1"][v]
                - cont["median_rendering_f1"][v]
                for v in cont["median_rendering_f1"]}
    return {"continuous": cont, "speculative": spec,
            "discard_probe": probe,
            "steady_compiles": server.stats.steady_compiles,
            "speculation_new_executables": new_keys,
            "speculation_new_compiles": server.stats.compiles - compiles0,
            "rendering_f1_delta": f1_delta}


# ---------------------------------------------------------------------------


def check(report: Dict,
          max_warmup_s: Optional[float] = None) -> List[str]:
    """The acceptance gates ci.sh enforces on the smoke lane."""
    errs = []
    w = report["warmup"]
    if w["warmed"]["steady_compiles"] != 0:
        errs.append(f"steady-state compiles after warmup: "
                    f"{w['warmed']['steady_compiles']} "
                    f"{w['warmed']['steady_compile_keys']}")
    if not (w["warmed"]["first_offload_wall_s"]
            < w["lazy"]["first_offload_wall_s"]):
        errs.append("warmup did not reduce first-offload latency")
    # the collapsed compile surface: length buckets, not (n_low, n_reuse)
    if w["warmed"]["executables_total"] > EXEC_BUDGET:
        errs.append(f"compile surface regressed: "
                    f"{w['warmed']['executables_total']} executables "
                    f"> budget {EXEC_BUDGET}")
    if max_warmup_s is not None and \
            w["warmed"]["warmup_wall_s"] > max_warmup_s:
        errs.append(f"warmup wall time {w['warmed']['warmup_wall_s']:.1f}s"
                    f" > budget {max_warmup_s:.1f}s")
    if report["cache"]["device"]["tile_bytes_per_offload"] != 0:
        errs.append("device-resident cache shipped tile bytes")
    if report["cache"]["host"]["tile_bytes_per_offload"] <= 0:
        errs.append("host-resident baseline counted no tile bytes")
    c = report["coalesce"]
    if not c["on"]["mean_wave"] > c["off"]["mean_wave"]:
        errs.append(f"coalescing did not grow waves: "
                    f"{c['on']['mean_wave']} <= {c['off']['mean_wave']}")
    if c["on"]["promoted_jobs"] <= 0:
        errs.append("no jobs were promoted")
    if c["off"]["mixed_plan_waves"] + c["on"]["mixed_plan_waves"] <= 0:
        errs.append("no wave batched plans with distinct n_low values "
                    "in one executable")
    # promotion must not cost inference accuracy: F1(promoted dets) >=
    # F1(own-bucket dets) on average (promotion only PADS the sequence)
    if c["promotion_inference_f1_delta"]["mean"] < 0:
        errs.append(f"promotion degraded inference F1: "
                    f"{c['promotion_inference_f1_delta']}")
    for v, d in c["rendering_f1_delta"].items():
        if abs(d) > 1e-12:
            errs.append(f"rendering-F1 delta on {v}: {d:+.4f}")
    s = report["scheduling"]
    if not (s["continuous"]["p50_queue_s"]
            < s["barrier"]["p50_queue_s"]):
        errs.append(f"continuous did not cut p50 queue delay: "
                    f"{s['continuous']['p50_queue_s']:.3f}s >= "
                    f"{s['barrier']['p50_queue_s']:.3f}s")
    if not (s["continuous"]["device_idle_frac"]
            < s["barrier"]["device_idle_frac"]):
        errs.append(f"continuous did not cut device idle: "
                    f"{s['continuous']['device_idle_frac']:.3f} >= "
                    f"{s['barrier']['device_idle_frac']:.3f}")
    if s["steady_compiles"] != 0:
        errs.append(f"scheduling workload compiled in steady state: "
                    f"{s['steady_compiles']}")
    if s["continuous_new_executables"] or s["continuous_new_compiles"]:
        errs.append(f"continuous scheduling grew the executable grid: "
                    f"+{s['continuous_new_compiles']} compiles "
                    f"{s['continuous_new_executables']}")
    for v, d in s["rendering_f1_delta"].items():
        if abs(d) > 1e-12:
            errs.append(f"scheduler rendering-F1 delta on {v}: {d:+.4f}")
    sp = report["speculation"]
    if not (sp["speculative"]["p50_e2e_s"]
            < sp["continuous"]["p50_e2e_s"]):
        errs.append(f"speculation did not cut p50 e2e on the slow "
                    f"uplink: {sp['speculative']['p50_e2e_s']:.3f}s >= "
                    f"{sp['continuous']['p50_e2e_s']:.3f}s")
    if sp["speculative"]["spec_launched"] <= 0 \
            or sp["speculative"]["spec_patched"] <= 0:
        errs.append(f"speculation lane idle: launched "
                    f"{sp['speculative']['spec_launched']} patched "
                    f"{sp['speculative']['spec_patched']}")
    if sp["discard_probe"]["spec_discarded"] < 1:
        errs.append("discard path never exercised (zero-tolerance probe "
                    "produced no discards)")
    for v, d in sp["rendering_f1_delta"].items():
        if abs(d) > 0.005:
            errs.append(f"speculation rendering-F1 delta on {v}: "
                        f"{d:+.4f} (budget 0.005)")
    if sp["steady_compiles"] != 0:
        errs.append(f"speculation workload compiled in steady state: "
                    f"{sp['steady_compiles']}")
    if sp["speculation_new_executables"] or sp["speculation_new_compiles"]:
        errs.append(f"speculation grew the executable grid: "
                    f"+{sp['speculation_new_compiles']} compiles "
                    f"{sp['speculation_new_executables']}")
    return errs


def run_bench(smoke: bool = False, out: Path = DEFAULT_OUT,
              do_check: bool = False,
              max_warmup_s: Optional[float] = None) -> dict:
    n_frames = 16 if smoke else 40
    part = vb.vit_partition(SIM)
    report = {
        "meta": {
            "config": "vitdet-l/SIM",
            "device": jax.default_backend(),
            "smoke": smoke,
            "n_frames": n_frames,
            "fps": FPS,
            "beta": BETA,
            "reuse_k": REUSE_K,
            "full_res_delay_s": FULL_RES_DELAY_S,
            "batch_buckets": list(pt.BATCH_BUCKETS),
            "length_bucket_edges": list(pt.length_bucket_set(part)),
            "exec_budget": EXEC_BUDGET,
            "max_warmup_s": max_warmup_s,
        },
        "warmup": bench_warmup(4 if smoke else 8),
        "cache": bench_cache(n_frames),
        "coalesce": bench_coalesce(n_frames),
        "scheduling": bench_scheduling(n_frames),
        # the slow uplink needs enough sim time for bootstrap + several
        # speculative offloads per client even in smoke
        "speculation": bench_speculation(max(n_frames, 40)),
    }
    errs = check(report, max_warmup_s=max_warmup_s)
    report["check"] = {"passed": not errs, "errors": errs}
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[bench_serving] wrote {out}")
    if do_check and errs:
        raise SystemExit("[bench_serving] CHECK FAILED: " + "; ".join(errs))
    return report


def run(ctx: dict) -> list:
    """benchmarks/run.py adapter: smoke settings, CSV rows."""
    out = Path(__file__).resolve().parent / "artifacts"
    out.mkdir(parents=True, exist_ok=True)
    rep = run_bench(smoke=True, out=out / "BENCH_serving.smoke.json",
                    do_check=True, max_warmup_s=90.0)
    w, c = rep["warmup"], rep["coalesce"]
    rows = [
        ("bench_serving/first_offload/lazy",
         w["lazy"]["first_offload_wall_s"] * 1e6,
         f"execs={w['lazy']['executables_total']}"),
        ("bench_serving/first_offload/warmed",
         w["warmed"]["first_offload_wall_s"] * 1e6,
         f"execs={w['warmed']['executables_total']} "
         f"steady_compiles={w['warmed']['steady_compiles']}"),
        ("bench_serving/warmup_wall", w["warmed"]["warmup_wall_s"] * 1e6,
         f"execs={w['warmed']['executables_total']} "
         f"budget={EXEC_BUDGET}"),
        ("bench_serving/tile_bytes/device", 0.0,
         f"per_offload={rep['cache']['device']['tile_bytes_per_offload']:.0f}"),
        ("bench_serving/tile_bytes/host", 0.0,
         f"per_offload={rep['cache']['host']['tile_bytes_per_offload']:.0f}"),
        ("bench_serving/coalesce", 0.0,
         f"wave {c['off']['mean_wave']:.2f}->{c['on']['mean_wave']:.2f} "
         f"promoted={c['on']['promoted_jobs']} "
         f"mixed_waves={c['on']['mixed_plan_waves']}"),
        ("bench_serving/scheduling",
         rep["scheduling"]["continuous"]["p50_queue_s"] * 1e6,
         f"queue p50 "
         f"{rep['scheduling']['barrier']['p50_queue_s']:.3f}s->"
         f"{rep['scheduling']['continuous']['p50_queue_s']:.3f}s "
         f"idle {rep['scheduling']['barrier']['device_idle_frac']:.2f}->"
         f"{rep['scheduling']['continuous']['device_idle_frac']:.2f}"),
        ("bench_serving/speculation",
         rep["speculation"]["speculative"]["p50_e2e_s"] * 1e6,
         f"e2e p50 {rep['speculation']['continuous']['p50_e2e_s']:.3f}s"
         f"->{rep['speculation']['speculative']['p50_e2e_s']:.3f}s "
         f"launched={rep['speculation']['speculative']['spec_launched']} "
         f"patched={rep['speculation']['speculative']['spec_patched']} "
         f"hidden p50="
         f"{rep['speculation']['speculative']['p50_spec_hidden_s']:.3f}s"),
    ]
    ctx["bench_serving"] = rows
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fewer frames (CI sanity lane)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless all acceptance gates hold")
    ap.add_argument("--max-warmup-s", type=float, default=None,
                    help="fail --check when the warmed replica's warmup "
                         "wall time exceeds this budget (seconds)")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)
    rep = run_bench(smoke=args.smoke, out=args.out, do_check=args.check,
                    max_warmup_s=args.max_warmup_s)
    w = rep["warmup"]
    print(f"  first offload: lazy {w['lazy']['first_offload_wall_s']:.3f}s"
          f" -> warmed {w['warmed']['first_offload_wall_s']:.3f}s "
          f"({w['first_offload_speedup']:.1f}x); "
          f"p95 {w['lazy']['p95_offload_wall_s']:.3f}s -> "
          f"{w['warmed']['p95_offload_wall_s']:.3f}s")
    print(f"  executables: lazy {w['lazy']['executables_total']} "
          f"(all steady) vs warmed {w['warmed']['executables_total']} "
          f"(budget {EXEC_BUDGET}, steady "
          f"{w['warmed']['steady_compiles']}, warmup "
          f"{w['warmed']['warmup_wall_s']:.1f}s)")
    for mode in ("device", "host"):
        r = rep["cache"][mode]
        print(f"  tiles/{mode}: {r['tile_bytes_per_offload']:.0f} B/offload"
              f" (h2d {r['tile_bytes_h2d']}, d2h {r['tile_bytes_d2h']}, "
              f"{r['offloads']} offloads)")
    c = rep["coalesce"]
    print(f"  coalesce: wave {c['off']['mean_wave']:.2f} -> "
          f"{c['on']['mean_wave']:.2f}, promoted "
          f"{c['on']['promoted_jobs']}, mixed-plan waves "
          f"{c['off']['mixed_plan_waves']}->{c['on']['mixed_plan_waves']},"
          f" p95 e2e "
          f"{c['off']['p95_e2e_s']:.3f}s -> {c['on']['p95_e2e_s']:.3f}s")
    print(f"  promotion inference-F1 cost: "
          f"{c['promotion_inference_f1_delta']}; scenario rendering-F1 "
          f"deltas {c['rendering_f1_delta']}")
    s = rep["scheduling"]
    print(f"  scheduling: queue p50 {s['barrier']['p50_queue_s']:.3f}s "
          f"(barrier) -> {s['continuous']['p50_queue_s']:.3f}s "
          f"(continuous), idle {s['barrier']['device_idle_frac']:.3f} -> "
          f"{s['continuous']['device_idle_frac']:.3f}, decode hidden "
          f"{s['continuous']['decode_hidden_s']:.2f}s, new execs "
          f"{s['continuous_new_compiles']}, F1 deltas "
          f"{s['rendering_f1_delta']}")
    sp = rep["speculation"]
    print(f"  speculation: e2e p50 {sp['continuous']['p50_e2e_s']:.3f}s "
          f"(continuous) -> {sp['speculative']['p50_e2e_s']:.3f}s "
          f"(speculative), launched "
          f"{sp['speculative']['spec_launched']}, patched "
          f"{sp['speculative']['spec_patched']}, discarded "
          f"{sp['speculative']['spec_discarded']} (+probe "
          f"{sp['discard_probe']['spec_discarded']}), hidden p50/p95 "
          f"{sp['speculative']['p50_spec_hidden_s']:.3f}/"
          f"{sp['speculative']['p95_spec_hidden_s']:.3f}s, new execs "
          f"{sp['speculation_new_compiles']}, F1 deltas "
          f"{sp['rendering_f1_delta']}")
    print(f"  check: {'OK' if rep['check']['passed'] else 'FAILED'} "
          f"{rep['check']['errors']}")
    return 0 if rep["check"]["passed"] or not args.check else 1


if __name__ == "__main__":
    import sys
    sys.exit(main(sys.argv[1:]))
