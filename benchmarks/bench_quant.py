"""Compressed serving executables: the accuracy-gated calibration bench.

Runs quant.calibrate on the TRAINED sim server (benchmarks/common) over
the parkS / driveN calibration scenarios, records every candidate's
compression ratio and per-scenario rendering-F1 delta, and verifies the
quantized ServerModel compiles the IDENTICAL executable grid with zero
steady-state compiles.  Writes BENCH_quant.json at the repo root;
``--check`` enforces the deployment gates:

  * int8 compression ratio >= 3.5x over fp32 parameter bytes
  * a point SHIPS, and its F1 delta <= 0.005 on EVERY scenario
  * the quantized grid adds no executable keys beyond the fp32 grid,
    and serving after warmup incurs zero steady-state compiles
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

import common
from repro.configs.vitdet_l import SIM
from repro.core.partition import RegionPlan
from repro.offload.simulator import ServerModel
from repro.quant import QuantSpec, calibrate as cal

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_quant.json"

RATIO_GATE = 3.5
# the smoke ladder skips the pruned candidate (head scoring runs eager
# forwards) — the gates it exercises are identical
SMOKE_CANDIDATES = (QuantSpec("int8", "fp16", 0), QuantSpec("int8", "fp32", 0))


def _point_row(p) -> dict:
    return {"spec": p.spec.name, "bytes": p.bytes,
            "ratio": round(p.ratio, 3),
            "deltas": {k: round(v, 5) for k, v in p.deltas.items()},
            "passed": p.passed}


def grid_invariance(params, spec: QuantSpec) -> dict:
    """Compile the fp32 and quantized grids over the same plan space and
    serve a few frames: same keys, zero steady compiles."""
    kw = dict(top_k=32, score_thresh=0.4, b_buckets=(1, 2))
    ref = ServerModel(SIM, params, **kw)
    space = ref.default_plan_space(betas=(2,))
    ref.warmup(space)

    s = ServerModel(SIM, params, quant=spec, **kw)
    s.warmup(space)
    part = s.part
    frames, _ = common.sv.make_clip("parkS", 3, size=common.SIZE, seed=5)
    mask = np.r_[np.ones(part.n_regions // 2, np.int32),
                 np.zeros(part.n_regions - part.n_regions // 2, np.int32)]
    s.infer(frames[0])
    s.infer(frames[1], mask, beta=2)
    s.infer_wave(np.stack(frames[:2]), [RegionPlan.from_mask(mask)] * 2,
                 beta=2)
    return {"spec": spec.name,
            "act_dtype": np.dtype(s.act_dtype).name,
            "ratio": round(s.quant_report["ratio"], 3),
            "fp32_keys": len(ref._fns), "quant_keys": len(s._fns),
            "keys_match": set(s._fns) == set(ref._fns),
            "new_keys": sorted(map(list, set(s._fns) - set(ref._fns))),
            "steady_compiles": int(s.stats.steady_compiles)}


def run_bench(smoke: bool = False, out: Path = DEFAULT_OUT) -> dict:
    server = common.get_server()
    params = server.params
    candidates = SMOKE_CANDIDATES if smoke else cal.DEFAULT_CANDIDATES
    n_frames = 3 if smoke else 8
    report_c = cal.calibrate(
        SIM, params, candidates=candidates, n_frames=n_frames,
        server_kw=dict(top_k=32, score_thresh=0.4))
    shipped = report_c.shipped
    grid = grid_invariance(
        params, shipped if shipped is not None else QuantSpec("int8"))
    report = {
        "meta": {"config": "vitdet-l/SIM",
                 "device": common.jax.default_backend(),
                 "smoke": smoke, "n_frames": n_frames,
                 "scenarios": list(report_c.scenarios),
                 "bound": report_c.bound},
        "calibration": {
            "shipped": shipped.name if shipped is not None else None,
            "bytes_fp32": report_c.bytes_fp32,
            "points": [_point_row(p) for p in report_c.points]},
        "grid": grid,
    }
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[bench_quant] wrote {out}")
    return report


def check_gates(report: dict) -> list:
    """The ISSUE acceptance gates; returns failure strings."""
    fails = []
    pts = report["calibration"]["points"]
    int8 = [p for p in pts if p["spec"].startswith("int8")]
    if not int8 or max(p["ratio"] for p in int8) < RATIO_GATE:
        fails.append(f"no int8 point reaches the {RATIO_GATE}x "
                     f"compression gate: {[(p['spec'], p['ratio']) for p in int8]}")
    shipped = report["calibration"]["shipped"]
    bound = report["meta"]["bound"]
    if shipped is None:
        fails.append("no candidate held the F1 bound — deployment stays "
                     "fp32")
    else:
        p = next(p for p in pts if p["spec"] == shipped)
        bad = {k: v for k, v in p["deltas"].items() if v > bound}
        if bad:
            fails.append(f"shipped point {shipped} breaks the bound: {bad}")
    g = report["grid"]
    if not g["keys_match"]:
        fails.append(f"quantized grid keys differ from fp32: "
                     f"new={g['new_keys']} "
                     f"({g['quant_keys']} vs {g['fp32_keys']})")
    if g["steady_compiles"]:
        fails.append(f"{g['steady_compiles']} steady-state compiles after "
                     "quantized warmup")
    for f in fails:
        print(f"[bench_quant] GATE FAIL {f}")
    if not fails:
        print(f"[bench_quant] gates ok: shipped={shipped} "
              f"ratio>={RATIO_GATE}x, deltas<={bound}, grid invariant, "
              "0 steady compiles")
    return fails


def run(ctx: dict) -> list:
    """benchmarks/run.py adapter: smoke settings, CSV rows."""
    out = Path(__file__).resolve().parent / "artifacts"
    out.mkdir(parents=True, exist_ok=True)
    rep = run_bench(smoke=True, out=out / "BENCH_quant.smoke.json")
    rows = []
    for p in rep["calibration"]["points"]:
        worst = max(p["deltas"].values()) if p["deltas"] else float("nan")
        rows.append((f"bench_quant/{p['spec']}", p["ratio"],
                     f"worst_dF1={worst:.4f} passed={p['passed']}"))
    g = rep["grid"]
    rows.append((f"bench_quant/grid/{g['spec']}", g["steady_compiles"],
                 f"keys_match={g['keys_match']} act={g['act_dtype']}"))
    ctx["bench_quant"] = rows
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2-candidate int8 ladder, 3 frames (CI lane)")
    ap.add_argument("--out", type=Path, default=None,
                    help=f"output JSON path (default {DEFAULT_OUT}; "
                         "--check runs default to benchmarks/artifacts)")
    ap.add_argument("--check", action="store_true",
                    help="enforce the deployment gates (ratio, F1 bound, "
                         "grid invariance, steady compiles); exit 1 on "
                         "failure")
    args = ap.parse_args(argv)
    out = args.out
    if out is None:
        if args.check:
            out = Path(__file__).resolve().parent / "artifacts" \
                / "BENCH_quant.check.json"
            out.parent.mkdir(parents=True, exist_ok=True)
        else:
            out = DEFAULT_OUT
    rep = run_bench(smoke=args.smoke, out=out)
    for p in rep["calibration"]["points"]:
        print(f"  {p['spec']:>12}: ratio {p['ratio']:5.2f}x  deltas "
              f"{p['deltas']}  {'PASS' if p['passed'] else 'fail'}")
    print(f"  shipped: {rep['calibration']['shipped']}")
    g = rep["grid"]
    print(f"  grid[{g['spec']}]: keys_match={g['keys_match']} "
          f"steady_compiles={g['steady_compiles']} act={g['act_dtype']}")
    if args.check:
        return 1 if check_gates(rep) else 0
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
