"""Paper Table II — performance-estimation quality.

MLP vs Linear Regression vs Offline Mean on the held-out profiling split,
for both targets (compressed size KiB, inference accuracy F1), reporting
MAE / RMSE / MAPE / R^2.  Expected ordering (paper): MLP best on all
metrics; Linear worst R^2.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.offload.estimator import regression_metrics


def run(ctx: dict) -> list:
    est = C.get_estimators()
    data = est["data"]
    te = est["MLP"]["test_idx"]
    X, ys, ya = data["X"][te], data["y_size"][te], data["y_acc"][te]

    rows = []
    metrics = {}
    for name in ("Linear", "OfflineMean", "MLP"):
        us = C.timer(lambda: est[name]["size"].predict(X), reps=3)
        m_s = regression_metrics(ys, est[name]["size"].predict(X))
        m_a = regression_metrics(ya, est[name]["acc"].predict(X))
        metrics[name] = (m_s, m_a)
        rows.append((f"table2/{name}/size", us,
                     f"MAE={m_s['MAE']:.1f}KiB RMSE={m_s['RMSE']:.1f} "
                     f"MAPE={m_s['MAPE']:.1f}% R2={m_s['R2']:.3f}"))
        rows.append((f"table2/{name}/acc", us,
                     f"MAE={m_a['MAE']:.3f} RMSE={m_a['RMSE']:.3f} "
                     f"MAPE={m_a['MAPE']:.1f}% R2={m_a['R2']:.3f}"))

    ok = (metrics["MLP"][0]["RMSE"] <= metrics["Linear"][0]["RMSE"] and
          metrics["MLP"][1]["RMSE"] <= metrics["Linear"][1]["RMSE"])
    rows.append(("table2/mlp_beats_linear", 0.0, f"holds={ok}"))
    ctx["table2"] = metrics
    return rows
