"""Paper Fig. 12 — ablation study.

Full system vs w/o RegType (downsample all non-DORs), w/o MLPs (static
Offline Mean estimator), w/o DynaRes (restoration deferred to the last
subset).  Expected (paper): each ablation lowers median rendering F1 but
all variants still beat the baselines.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C


def run(ctx: dict) -> list:
    abl_results = C.run_sims(C.make_ablations())
    groups = C.by_policy(abl_results)
    full = C.by_policy(C.get_sim_results())

    rows = []
    full_rend = float(np.median(C.pooled(full["ViTMAlis"], "rendering_f1")))
    rows.append(("fig12/FullSystem", 0.0,
                 f"median_rend_f1={full_rend:.3f}"))
    worst_baseline = max(
        float(np.median(C.pooled(rs, "rendering_f1")))
        for name, rs in full.items()
        if name in ("Back2Back", "TrackB2B", "TrackRoI", "TrackUD"))
    for name, rs in groups.items():
        rend = float(np.median(C.pooled(rs, "rendering_f1")))
        rows.append((f"fig12/{name}", 0.0,
                     f"median_rend_f1={rend:.3f} "
                     f"vs_full={rend - full_rend:+.3f} "
                     f"beats_best_baseline={rend >= worst_baseline - 0.02}"))
    return rows
