# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Expensive substrate (trained server model, profiling dataset,
# the fig-8 simulation grid) is cached under benchmarks/artifacts/cache.
import sys
import time


def main(argv=None) -> int:
    from benchmarks import (bench_backbone, bench_multiclient, bench_quant,
                            bench_reuse, bench_robustness, bench_serving,
                            fig5_restoration, fig8_overall, fig9_delays,
                            fig10_codec, fig11_overhead, fig12_ablation,
                            roofline, table2_estimator)

    only = set(argv[1:]) if argv and len(argv) > 1 else None
    suites = [
        ("bench_backbone", bench_backbone),
        ("bench_multiclient", bench_multiclient),
        ("bench_quant", bench_quant),
        ("bench_reuse", bench_reuse),
        ("bench_serving", bench_serving),
        ("bench_robustness", bench_robustness),
        ("fig5", fig5_restoration),
        ("table2", table2_estimator),
        ("fig8", fig8_overall),
        ("fig9", fig9_delays),
        ("fig10", fig10_codec),
        ("fig11", fig11_overhead),
        ("fig12", fig12_ablation),
        ("roofline", roofline),
    ]
    ctx: dict = {}
    print("name,us_per_call,derived")
    t_start = time.time()
    failed = 0
    for name, mod in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(ctx)
        except Exception as e:  # a failing suite is a bug; keep going
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            continue
        for r in rows:
            nm, us, derived = r
            print(f"{nm},{us:.1f},{derived}")
        print(f"{name}/_wall,{(time.time() - t0) * 1e6:.0f},suite wall time",
              flush=True)
    print(f"total/_wall,{(time.time() - t_start) * 1e6:.0f},full harness")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
