"""Straggler & failure handling for pod-scale jobs.

Two mechanisms (DESIGN.md §5):

* ``HeartbeatMonitor`` — tracks per-host step heartbeats; hosts that miss
  ``miss_limit`` consecutive deadlines are declared failed, triggering an
  elastic restart (see train/elastic.py).  Hosts whose step time exceeds
  ``straggle_factor`` x the fleet median are flagged stragglers; the
  driver's response is a backup-step skip (the slow host's microbatch is
  covered by the others re-splitting the global batch).

* ``DeadlineDispatcher`` — serving-side: requests dispatched to a replica
  are re-dispatched to the next-fastest replica if no completion arrives
  before the p99-based deadline (tail-latency mitigation).

Deterministic, simulation-friendly: time is injected, not read from the
wall clock, so the tests drive schedules explicitly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np


@dataclass
class HostState:
    last_beat: float = 0.0
    missed: int = 0
    step_times: List[float] = field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, hosts: List[int], interval: float = 10.0,
                 miss_limit: int = 3, straggle_factor: float = 2.0):
        self.hosts = {h: HostState() for h in hosts}
        self.interval = interval
        self.miss_limit = miss_limit
        self.straggle_factor = straggle_factor
        self.failed: Set[int] = set()

    def beat(self, host: int, now: float,
             step_time: Optional[float] = None) -> None:
        st = self.hosts[host]
        st.last_beat = now
        st.missed = 0
        if step_time is not None:
            st.step_times.append(step_time)

    def check(self, now: float) -> Dict[str, List[int]]:
        """Advance deadlines; returns {"failed": [...], "stragglers": [...]}"""
        newly_failed = []
        for h, st in self.hosts.items():
            if h in self.failed:
                continue
            if now - st.last_beat > self.interval:
                st.missed = int((now - st.last_beat) / self.interval)
                if st.missed >= self.miss_limit:
                    self.failed.add(h)
                    newly_failed.append(h)

        # straggler detection on recent step times
        recents = {h: np.mean(st.step_times[-5:])
                   for h, st in self.hosts.items()
                   if st.step_times and h not in self.failed}
        stragglers = []
        if len(recents) >= 2:
            med = float(np.median(list(recents.values())))
            stragglers = [h for h, t in recents.items()
                          if t > self.straggle_factor * med]
        return {"failed": newly_failed, "stragglers": stragglers}

    def healthy_hosts(self) -> List[int]:
        return [h for h in self.hosts if h not in self.failed]


# ---------------------------------------------------------------------------


@dataclass
class Dispatch:
    request_id: int
    replica: int
    sent_at: float
    deadline: float


class DeadlineDispatcher:
    """Serving-side re-dispatch on deadline miss (tail mitigation)."""

    def __init__(self, n_replicas: int, base_deadline: float = 0.5,
                 p99_window: int = 64):
        self.n = n_replicas
        self.base_deadline = base_deadline
        self.lat: List[float] = []
        self.p99_window = p99_window
        self.inflight: Dict[int, Dispatch] = {}
        self.redispatches = 0
        self._rr = 0

    def _deadline(self) -> float:
        if len(self.lat) < 8:
            return self.base_deadline
        recent = self.lat[-self.p99_window:]
        return float(np.percentile(recent, 99) * 1.5)

    def dispatch(self, request_id: int, now: float,
                 avoid: Optional[int] = None) -> Dispatch:
        replica = self._rr % self.n
        if avoid is not None and replica == avoid and self.n > 1:
            replica = (replica + 1) % self.n
        self._rr += 1
        d = Dispatch(request_id, replica, now, now + self._deadline())
        self.inflight[request_id] = d
        return d

    def complete(self, request_id: int, now: float) -> None:
        d = self.inflight.pop(request_id, None)
        if d is not None:
            self.lat.append(now - d.sent_at)

    def poll(self, now: float) -> List[Dispatch]:
        """Re-dispatch every request past its deadline; returns the new
        dispatches."""
        out = []
        for rid, d in list(self.inflight.items()):
            if now > d.deadline:
                self.redispatches += 1
                nd = self.dispatch(rid, now, avoid=d.replica)
                out.append(nd)
        return out
