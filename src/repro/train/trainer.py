"""Training step construction: pjit'd FSDP+TP train step with microbatch
gradient accumulation, remat, and (multi-pod) int8-compressed cross-pod
gradient all-reduce.

``make_train_step`` returns a function

    (params, opt_state, batch) -> (params, opt_state, metrics)

ready for jax.jit with the sharding trees from ``train_shardings``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import registry
from repro.models.config import ModelConfig
from repro.models.transformer import ParallelCtx
from repro.optim import adam
from repro.optim.schedules import warmup_cosine


@dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1             # microbatch gradient accumulation
    remat: bool = True
    sp: bool = False                 # sequence-parallel residual stream
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_pod_grads: bool = False  # int8+error-feedback across pods


def make_ctx(cfg: ModelConfig, mesh: Optional[Mesh],
             remat: bool = True, sp: bool = False) -> ParallelCtx:
    if mesh is None:
        return ParallelCtx(remat=remat, sp=sp)
    return ParallelCtx(mesh=mesh, data_axes=shd.dp_axes(mesh), remat=remat,
                       sp=sp)


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh],
                    tc: TrainConfig = TrainConfig()) -> Callable:
    ctx = make_ctx(cfg, mesh, tc.remat, tc.sp)

    def loss_fn(params, microbatch):
        loss, metrics = registry.lm_loss(cfg, params, microbatch, ctx)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if tc.accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # microbatch scan: batch (B, T) -> (A, B/A, T); grads are
            # accumulated in fp32 so the live working set is one microbatch
            def resh(x):
                A = tc.accum_steps
                return x.reshape((A, x.shape[0] // A) + x.shape[1:])

            mbatch = jax.tree_util.tree_map(resh, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                g_acc, l_acc = acc
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            from repro.models.layers import scan as _scan
            (grads, loss_sum), _ = _scan(body, (zero, 0.0), mbatch)
            grads = jax.tree_util.tree_map(
                lambda g: g / tc.accum_steps, grads)
            loss = loss_sum / tc.accum_steps
            metrics = {}

        lr = warmup_cosine(opt_state.step, peak_lr=tc.peak_lr,
                           warmup_steps=tc.warmup_steps,
                           total_steps=tc.total_steps)
        params, opt_state, om = adam.adam_update(
            grads, opt_state, params, lr=lr,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)
        out = {"loss": loss, "lr": lr, **om}
        out.update({k: v for k, v in metrics.items()})
        return params, opt_state, out

    return train_step


def train_shardings(cfg: ModelConfig, mesh: Mesh, params_shape,
                    batch_shape) -> Tuple[Any, Any, Any]:
    """(param, opt, batch) NamedSharding trees for jitting train_step."""
    pspecs = shd.param_specs(cfg, params_shape)
    opt_specs = adam.AdamState(step=P(), m=pspecs, v=pspecs)
    bspecs = shd.batch_specs(cfg, mesh, batch_shape)
    return (shd.to_named(mesh, pspecs), shd.to_named(mesh, opt_specs),
            shd.to_named(mesh, bspecs))


def init_train_state(cfg: ModelConfig, key, dtype=jnp.float32):
    params = registry.init_params(cfg, key, dtype)
    return params, adam.init_adam(params)
