"""Sharded, content-hashed checkpointing with restore-time resharding.

Layout on disk:
  <dir>/step_<N>/manifest.json        tree structure, shapes, dtypes,
                                      per-leaf shard list + sha256 hashes
  <dir>/step_<N>/shard_<host>_<i>.npz leaf arrays (one npz per host)

Fault-tolerance properties:
  * atomic publish: manifest written last, to a temp file then renamed —
    a crash mid-save never yields a manifest pointing at missing shards;
  * content hashes: corrupt/truncated shards are detected at restore;
  * restore-with-reshard: the target mesh/sharding may differ from the
    save-time mesh (elastic scaling) — leaves are loaded to host then
    device_put with the NEW sharding;
  * async save: ``save_async`` snapshots to host memory synchronously and
    writes in a daemon thread, so the training loop resumes immediately.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _key_str(p) -> str:
    for attr in ("key", "idx", "name"):   # DictKey / SequenceKey / GetAttrKey
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _tree_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [("/".join(_key_str(p) for p in path), leaf)
            for path, leaf in flat]


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


# numpy's savez stores ml_dtypes leaves (bfloat16, float8_*) as opaque
# void records; encode them as same-width unsigned views and restore the
# true dtype from the manifest.
_WIDTH_UINT = {1: np.uint8, 2: np.uint16, 4: np.uint32}


def _encode_np(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind not in "biufc":          # custom dtype (bf16, fp8)
        return arr.view(_WIDTH_UINT[arr.dtype.itemsize])
    return arr


def _decode_np(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    import ml_dtypes
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))


def save(tree: Any, directory: str, step: int, host_id: int = 0) -> str:
    """Synchronous sharded save.  Returns the checkpoint path."""
    ckpt = Path(directory) / f"step_{step:08d}"
    ckpt.mkdir(parents=True, exist_ok=True)

    leaves = _tree_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}}
    arrays: Dict[str, np.ndarray] = {}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        key = f"a{i}"
        arrays[key] = _encode_np(arr)
        manifest["leaves"][name] = {
            "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha": _sha(arr), "shard": f"shard_{host_id}_{i // 64}.npz",
        }

    # group leaves into shard files of <=64 arrays
    by_shard: Dict[str, Dict[str, np.ndarray]] = {}
    for name, meta in manifest["leaves"].items():
        by_shard.setdefault(meta["shard"], {})[meta["key"]] = \
            arrays[meta["key"]]
    for fname, group in by_shard.items():
        np.savez(ckpt / (fname + ".tmp"), **group)
        os.replace(ckpt / (fname + ".tmp.npz"), ckpt / fname)

    tmp = ckpt / "manifest.json.tmp"
    tmp.write_text(json.dumps(manifest))
    os.replace(tmp, ckpt / "manifest.json")      # atomic publish
    return str(ckpt)


_save_threads: List[threading.Thread] = []


def save_async(tree: Any, directory: str, step: int) -> threading.Thread:
    """Snapshot to host memory now; write in a background thread."""
    host_tree = jax.tree_util.tree_map(lambda l: np.asarray(l), tree)
    t = threading.Thread(target=save, args=(host_tree, directory, step),
                         daemon=True)
    t.start()
    _save_threads.append(t)
    return t


def wait_pending_saves() -> None:
    for t in _save_threads:
        t.join()
    _save_threads.clear()


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(tree_like: Any, directory: str, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional NamedSharding tree for the TARGET mesh —
    resharding happens on device_put, so the restoring job may run on a
    different device count than the saver (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    ckpt = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())

    cache: Dict[str, Any] = {}

    def load_leaf(name: str):
        meta = manifest["leaves"][name]
        if meta["shard"] not in cache:
            cache[meta["shard"]] = np.load(ckpt / meta["shard"])
        arr = _decode_np(cache[meta["shard"]][meta["key"]], meta["dtype"])
        if verify and _sha(arr) != meta["sha"]:
            raise IOError(f"checkpoint corruption in {name} "
                          f"({meta['shard']})")
        return arr

    names = [n for n, _ in _tree_paths(tree_like)]
    flat_shardings = (None if shardings is None else
                      [s for _, s in _tree_paths(shardings)])
    leaves = []
    for i, name in enumerate(names):
        arr = load_leaf(name)
        if flat_shardings is not None:
            arr = jax.device_put(arr, flat_shardings[i])
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def prune_old(directory: str, keep: int = 3) -> None:
    d = Path(directory)
    if not d.exists():
        return
    steps = sorted(int(p.name.split("_")[1]) for p in d.iterdir()
                   if p.name.startswith("step_")
                   and (p / "manifest.json").exists())
    for s in steps[:-keep]:
        shutil.rmtree(d / f"step_{s:08d}", ignore_errors=True)
