"""Elastic scaling: rebuild the mesh from the surviving device set and
reshard training state from the last checkpoint.

The flow on failure (driven by HeartbeatMonitor):
  1. drop failed hosts -> new device list
  2. ``plan_mesh``: choose the largest (data, model) grid that fits,
     keeping the model axis (TP degree) if possible — changing TP degree
     invalidates sharded-parameter layouts more expensively than changing
     the data axis;
  3. restore the last checkpoint with the NEW shardings
     (checkpoint.restore does host-side resharding);
  4. scale gradient accumulation to preserve the global batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.train import checkpoint as ckpt_lib


def plan_mesh(n_devices: int, model_par: int) -> Tuple[int, int]:
    """Largest (data, model) grid with model axis preserved if possible."""
    while model_par > 1 and n_devices % model_par:
        model_par //= 2
    data = n_devices // model_par
    return data, model_par


def rebuild_mesh(devices: List, model_par: int) -> Mesh:
    import numpy as np
    data, model = plan_mesh(len(devices), model_par)
    usable = devices[: data * model]
    arr = np.array(usable).reshape(data, model)
    return Mesh(arr, ("data", "model"))


@dataclass
class ElasticState:
    mesh: Mesh
    global_batch: int
    accum_steps: int

    def scaled_accum(self, old_dp: int, new_dp: int) -> int:
        """Keep the global batch constant across a size change."""
        return max(1, int(round(self.accum_steps * old_dp / new_dp)))


def elastic_restart(cfg, directory: str, devices: List, model_par: int,
                    make_state_shapes, make_shardings,
                    step: Optional[int] = None):
    """Full restart path: new mesh + resharded restore.

    ``make_state_shapes()`` -> pytree of ShapeDtypeStructs (params, opt);
    ``make_shardings(mesh)`` -> matching NamedSharding tree."""
    mesh = rebuild_mesh(devices, model_par)
    shapes = make_state_shapes()
    shardings = make_shardings(mesh)
    state = ckpt_lib.restore(shapes, directory, step=step,
                             shardings=shardings)
    return mesh, state
