"""The unified scheduling plane: every batch the system forms is
formed here.

Three entry points used to carry their own copy of the wave logic —
the multi-client edge (`serve/edge.py`), the solo N=1 simulator
(`offload/simulator.py`), and the sequence-serving `ServeEngine`
(`serve/engine.py`).  This module owns all of it now:

  * :func:`form_wave` — the one head-key grouping pass (queue order in,
    wave + remainder out) every caller uses.
  * :class:`WaveScheduler` — the edge-replica scheduling interface:
    admission control (degrade -> shed), cross-bucket coalescing
    (`_try_promote` + the ``backbone_flops_windows`` cost model),
    degradation-ladder retry slotting (jobs re-enter through the same
    ``enqueue``), the Eq. (2) queue bookkeeping, and the shared
    crash-restart application (:func:`edge_restart_tick`).
  * :class:`BarrierScheduler` — wave-at-a-time: the replica serves one
    wave to completion, then forms the next from whatever has arrived.
    A bit-compatible port of the pre-refactor behaviour, pinned by
    tests.
  * :class:`ContinuousScheduler` — continuous batching + async
    overlap: the NEXT wave's codec-decode/h2d staging runs while the
    current wave computes (``jax.device_put`` + deferred detection
    decode on the real executor; the modelled timeline mirrors it), and
    a job may be admitted into a forming wave's padded B-bucket slot
    as soon as a row frees — pad rows are already dropped from decode
    and barred from caches, so filling one costs no extra compute
    beyond the ``batch_alpha`` marginal share the cost model prices.
  * :class:`SoloScheduler` — the N=1 plane: dedicated immediate
    execution with the same stale-epoch NACK and crash-restart
    semantics as the edge.

Timeline, barrier vs continuous (D = codec decode, C = compute)::

    barrier     wave1 [DDD CCCCCC]
                wave2             [DDD CCCCCC]
                job j  --arrive--^ waits out ALL of wave2 + its decode

    continuous  wave1 [DDD CCCCCC]
                wave2      [DDD]  [CCCCCC]        (decode hidden)
                job j  --arrive--[DD]^ admitted into wave2's pad row

Queueing delay is a first-class Eq. (2) term, so the win is surfaced
per job: ``parts["queue"]`` splits into ``queue_admit`` (arrival ->
bound to a wave) + ``queue_slot`` (bound -> compute start), and
:attr:`EdgeStats.device_idle_frac` integrates the replica's compute
busy time over the serving horizon.
"""
from __future__ import annotations

import bisect
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import vit_backbone as vb
from repro.core.partition import FULL, LOW, REUSE, RegionPlan
from repro.offload.faults import FaultInjector
from repro.serve.request import StaleCacheEpoch

__all__ = ["EdgeConfig", "EdgeStats", "WaveScheduler", "BarrierScheduler",
           "ContinuousScheduler", "SoloScheduler", "SCHEDULERS",
           "make_scheduler", "form_wave", "edge_restart_tick"]


# ---------------------------------------------------------------------------
# the one wave-formation pass


def form_wave(items: Sequence, key_fn: Callable, cap: int,
              admit: Optional[Callable] = None,
              promote: Optional[Callable] = None):
    """Form one wave from an ordered queue.

    The head item seeds the wave; each later item joins iff the wave
    has room (``cap``), the ``admit`` predicate (arrival/staging cuts)
    passes, and its key matches the head's — or the ``promote`` hook
    (cross-bucket coalescing) accepts it.  Returns ``(wave, rest,
    head_key)``; ``rest`` preserves queue order, so callers never
    re-sort.
    """
    head = items[0]
    hk = key_fn(head)
    wave, rest = [head], []
    for it in items[1:]:
        ok = len(wave) < cap and (admit is None or admit(it))
        if ok:
            k = key_fn(it)
            ok = k == hk or (promote is not None
                             and promote(it, k, hk, wave))
        if ok:
            wave.append(it)
        else:
            rest.append(it)
    return wave, rest, hk


def edge_restart_tick(server, faults: Optional[FaultInjector],
                      prev: float, now: float, *,
                      preserve_executables: bool = False
                      ) -> List[Tuple[float, float]]:
    """Apply every replica crash-restart scheduled in ``(prev, now]``.

    The one copy of the restart application both planes share (it used
    to live twice, in ``Simulation._edge_fault_tick`` and the
    multi-client engine): each event bumps the server's cache epoch —
    wiping executables unless ``preserve_executables`` keeps the bench
    shortcut — and is returned as ``(restart_time, outage_s)`` for the
    caller's plane-specific loss bookkeeping (solo: the in-flight job;
    edge: the pending queue).
    """
    if faults is None:
        return []
    events = list(faults.restarts_between(prev, now))
    for _ in events:
        server.restart(preserve_executables=preserve_executables)
    return events


# ---------------------------------------------------------------------------
# config + telemetry


@dataclass
class EdgeConfig:
    max_batch: int = 8
    # serving mode: batched waves vs. one-job-at-a-time (the sequential
    # baseline bench_multiclient.py compares against)
    batched: bool = True
    # wave-formation policy: "barrier" serves one wave to completion
    # before forming the next; "continuous" overlaps the next wave's
    # decode/h2d staging with the current compute and admits late jobs
    # into padded B-bucket slots (see SCHEDULERS)
    scheduler: str = "barrier"
    # continuous only: actually pipeline the executor — stage wave N+1
    # h2d (jax.device_put) and defer wave N's blocking detection decode
    # so host decode hides under device compute.  Off = same modelled
    # timeline, strictly synchronous executor.
    stage_ahead: bool = True
    # marginal service time of each extra frame in a wave, as a fraction
    # of the solo inference delay: service = t_inf * (1 + alpha * (B-1)).
    # alpha < 1 is the batching win; alpha = 1 degenerates to sequential.
    # (wave compatibility buckets come from the server's n_buckets —
    # they MUST match infer_wave's bucketing, so there is no knob here)
    batch_alpha: float = 0.35
    # cross-bucket wave coalescing: promote a pending job from a larger
    # n_low bucket into the forming wave's smaller bucket when the
    # queueing delay saved exceeds the extra compute (cost model below)
    coalesce: bool = False
    # keep full per-job detection lists in EdgeStats.jobs (benchmarks
    # opt in; long simulations must not grow without bound)
    keep_dets: bool = False
    # edge-side admission control: when the queue is hot, first DEGRADE
    # incoming jobs (promote FULL regions to LOW so the job drops a
    # length bucket — the coalescing cost model's flops scaling prices
    # the new service time), then SHED with an explicit REJECTED
    # response the client handles by tracking locally
    admission: bool = False
    degrade_depth: int = 4           # pending jobs before degrading
    shed_depth: int = 10             # pending jobs before shedding
    degrade_backlog_s: float = 1.0   # or replica backlog seconds
    shed_backlog_s: float = 2.5
    degrade_beta: int = 2            # restoration point degraded
    #                                  full-res jobs restore at
    # crash-restart shortcut for benches: model the outage in sim time
    # but keep host-process executables warm (tests pin the real wipe)
    preserve_executables: bool = False
    # speculative REUSE execution (continuous scheduler only): when a
    # job's plan header — which ships ahead of the payload — shows a
    # REUSE + predicted-still-LOW fraction of at least spec_min_frac
    # AND a motion-prediction confidence of at least spec_min_conf,
    # launch the spliced forward immediately with the in-flight LOW/FULL
    # regions substituted from the session's prediction source
    # (FeatureCache.pred_frame, gated by staleness bound K + epoch).
    # On payload arrival a patch pass recomputes only regions whose
    # decoded content diverges from the prediction by more than
    # spec_patch_tol (mean |Δ| in [0,1] pixel units); when more than
    # spec_max_patch_frac of the transmitted regions diverged, the
    # speculation is discarded and the original plan reruns on the real
    # frame.  Every path reuses the warmed (lb, beta, capture, B)
    # executable grid — speculation adds zero keys.
    speculate: bool = False
    spec_min_frac: float = 0.5
    spec_min_conf: float = 0.6
    spec_patch_tol: float = 0.02
    spec_max_patch_frac: float = 0.5


@dataclass
class EdgeStats:
    """Edge-side telemetry: wave sizes, queueing, and per-job outcomes."""
    wave_sizes: List[int] = field(default_factory=list)
    queue_delays: List[float] = field(default_factory=list)
    # per-job queue-delay breakdown: admission wait (arrival -> bound to
    # a forming wave) + slot wait (bound -> compute start).  Barrier
    # binds a job only when its wave forms, so its wait is all
    # admission; continuous binds as soon as a row frees.
    queue_admit: List[float] = field(default_factory=list)
    queue_slot: List[float] = field(default_factory=list)
    jobs: List[Dict] = field(default_factory=list)
    promoted: int = 0            # jobs coalesced across length buckets
    # distinct n_low values per wave: > 1 means plans with different
    # region counts shared ONE executable (the collapsed-grid win)
    wave_n_low_mix: List[int] = field(default_factory=list)
    # robustness telemetry
    degraded: int = 0            # jobs admission control degraded
    shed: int = 0                # jobs REJECTED at admission
    restarts: int = 0            # crash-restarts of the replica
    stale_nacks: int = 0         # REUSE jobs refused on epoch mismatch
    lost_jobs: int = 0           # jobs that died with the replica
    # replica compute occupancy over the serving horizon: per-wave
    # (compute_start, compute_end) accumulated below.  Barrier idles
    # the device through every wave's codec decode; continuous hides
    # decode under the previous compute (decode_hidden_s counts the
    # seconds hidden), so its idle fraction is strictly lower under
    # load.
    compute_busy_s: float = 0.0
    compute_first: float = float("inf")
    compute_last: float = 0.0
    decode_hidden_s: float = 0.0
    # speculative-REUSE lane telemetry (mirrors the queue_admit /
    # queue_slot split): launches, how each resolved, and the uplink
    # seconds each launch hid under speculative compute — per-job in
    # ``spec_hidden`` for p50/p95, summed in ``spec_hidden_s``
    spec_launched: int = 0
    spec_patched: int = 0
    spec_discarded: int = 0
    spec_hidden_s: float = 0.0
    spec_hidden: List[float] = field(default_factory=list)

    @property
    def mean_wave_size(self) -> float:
        return float(np.mean(self.wave_sizes)) if self.wave_sizes else 0.0

    @property
    def mixed_plan_waves(self) -> int:
        """Waves that batched >= 2 distinct n_low values."""
        return sum(1 for m in self.wave_n_low_mix if m > 1)

    def note_compute(self, start: float, end: float) -> None:
        self.compute_busy_s += max(end - start, 0.0)
        self.compute_first = min(self.compute_first, start)
        self.compute_last = max(self.compute_last, end)

    @property
    def device_idle_frac(self) -> float:
        """1 - (compute busy) / (first compute start -> last compute
        end).  0.0 when fewer than two waves ran."""
        horizon = self.compute_last - self.compute_first
        if not np.isfinite(self.compute_first) or horizon <= 0.0:
            return 0.0
        return float(max(1.0 - self.compute_busy_s / horizon, 0.0))

    def queue_percentile(self, q: float) -> float:
        return (float(np.percentile(self.queue_delays, q))
                if self.queue_delays else 0.0)

    def spec_hidden_percentile(self, q: float) -> float:
        return (float(np.percentile(self.spec_hidden, q))
                if self.spec_hidden else 0.0)


# ---------------------------------------------------------------------------
# the edge-replica scheduling interface


class WaveScheduler:
    """Owns batch formation for one shared edge replica.

    ``host`` is the driving simulation (``MultiClientSimulation``);
    waves are dispatched through ``host._run_wave(wave, t_start, key)``
    so tests can intercept execution, and that method delegates right
    back to :meth:`execute_wave`.  Jobs are ``(client_idx, job_dict)``
    pairs; ``pending`` is kept sorted by edge-arrival time on insert
    and only ever consumed in order, so nothing re-sorts.
    """

    def __init__(self, server, clients: Sequence, ec: EdgeConfig,
                 faults: Optional[FaultInjector] = None, host=None):
        self.server = server
        self.clients = list(clients)
        self.ec = ec
        self.faults = faults
        self.host = host
        self.pending: List[Tuple[int, Dict]] = []   # (client_idx, job)
        self.free_at = 0.0                          # replica busy horizon
        # a wave can never exceed the largest batch bucket — padding
        # only rounds UP, so an oversized wave would have no executable
        self.max_wave = min(ec.max_batch, max(server.b_buckets))
        if self.max_wave < ec.max_batch:
            warnings.warn(
                f"EdgeConfig.max_batch={ec.max_batch} exceeds the "
                f"server's largest batch bucket "
                f"{max(server.b_buckets)}; waves are capped at "
                f"{self.max_wave} — raise b_buckets to serve bigger "
                f"waves", stacklevel=3)
        self.stats = EdgeStats()

    # ------------------------------------------------------------------
    # admission

    def enqueue(self, ci: int, job: Dict) -> None:
        """Insert a job keeping ``pending`` sorted by edge arrival time.

        Admission control happens here, at arrival: under queue pressure
        the job is first degraded (FULL -> LOW), and past the shed
        threshold it is REJECTED outright — an explicit response the
        client's completion path turns into tracker-only rendering plus
        a backed-off degraded retry (the ladder's retry re-enters
        through this same method, so retry slotting is scheduler-owned).
        """
        if self.faults is not None and self.faults.edge_down(
                job["arrival"]):
            # arrived at a crashed replica: never answered
            job["lost"] = True
            job["done_at"] = float("inf")
            self.stats.lost_jobs += 1
            return
        if self.ec.admission:
            depth = len(self.pending)
            backlog = max(self.free_at - job["arrival"], 0.0)
            if depth >= self.ec.shed_depth \
                    or backlog >= self.ec.shed_backlog_s:
                job["rejected"] = True
                job["done_at"] = job["arrival"] + job["rtt"]
                job["dets"] = []
                self.stats.shed += 1
                return
            if (depth >= self.ec.degrade_depth
                    or backlog >= self.ec.degrade_backlog_s) \
                    and self._degrade_job(ci, job):
                self.stats.degraded += 1
        bisect.insort(self.pending, (ci, job),
                      key=lambda cj: cj[1]["arrival"])

    def _degrade_job(self, ci: int, job: Dict) -> bool:
        """Promote FULL regions of an arriving job to LOW so it drops at
        least one length bucket — the payload is already uploaded, so
        this buys edge COMPUTE (shorter sequence), priced by the same
        ``backbone_flops_windows`` scaling the coalescer uses.  REUSE
        regions are untouched.  Returns True if the job changed."""
        part = self.server.part
        plan: RegionPlan = job["plan"]
        states = np.asarray(plan.states).copy()
        full_ids = np.nonzero(states == FULL)[0]
        if len(full_ids) == 0:
            return False
        dd = part.windows_per_full_region
        nw = part.n_windows(plan.n_low, plan.n_reuse)
        # current effective length: the dedicated full-res executable
        # runs the full sequence; mixed plans run at their bucket
        lb_cur = (nw if plan.n_low == 0 and plan.n_reuse == 0
                  else self.server.length_bucket(nw))
        nw_min = nw - len(full_ids) * (dd - 1)
        targets = [e for e in self.server.length_edges
                   if nw_min <= e < lb_cur]
        if not targets:
            return False
        target = max(targets)            # one bucket down: degrade least
        k = int(np.ceil((nw - target) / (dd - 1)))
        states[full_ids[:k]] = LOW
        new_plan = RegionPlan(states.astype(np.int8))
        beta = int(job["beta"]) if int(job["beta"]) >= 1 \
            else self.ec.degrade_beta
        f_own = vb.backbone_flops_windows(
            self.server.cfg, lb_cur,
            int(job["beta"]) if plan.n_low or plan.n_reuse else 0)
        f_new = vb.backbone_flops_windows(self.server.cfg, target, beta)
        job["t_inf_exec"] = job["t_inf"] * (f_new / f_own)
        job["plan"] = new_plan
        job["mask"] = new_plan.low_mask()
        job["n_d"] = int(new_plan.n_low)
        job["beta"] = beta
        job["t_dec"] = self.clients[ci].delay_model.decode_delay(
            part, new_plan.n_low, n_reuse=new_plan.n_reuse)
        job["edge_degraded"] = True
        return True

    def _job_key(self, job: Dict) -> Tuple[int, int, int]:
        """Wave compatibility: (length bucket, beta, capture point) —
        the collapsed executable key.  (n_low, n_reuse) are runtime
        data, so any plan mix at one length bucket co-batches; mixed
        executables always capture (capture == beta), so sessionful and
        stateless jobs co-batch too.  Full-res jobs (length bucket 0)
        keep the dedicated full-res executable at the deployment's
        canonical capture point."""
        plan: RegionPlan = job["plan"]
        lb = self.server.plan_length_bucket(plan)
        if lb == 0:
            want = (job.get("capture_beta", 0)
                    if self.clients[job["_client"]].feature_cache
                    is not None else 0)
            return (0, 0, self.server._full_cap(want))
        beta = job["beta"]
        return (lb, beta, beta)

    # ------------------------------------------------------------------
    # cross-bucket coalescing cost model

    def _wave_service_s(self, wave: List[Tuple[int, Dict]]) -> float:
        """Modelled service time of a wave (decode + amortised infer)."""
        B = len(wave)
        t_dec = max(j["t_dec"] for _, j in wave)
        t_inf = max(j.get("t_inf_exec", j["t_inf"]) for _, j in wave)
        if B > 1:
            t_inf = t_inf * (1.0 + self.ec.batch_alpha * (B - 1))
        return t_dec + t_inf

    def _wave_infer_s(self, wave: List[Tuple[int, Dict]],
                      stall_at: Optional[float] = None) -> float:
        """Amortised wave inference time (+ edge stall if scheduled)."""
        B = len(wave)
        t_inf = max(j.get("t_inf_exec", j["t_inf"]) for _, j in wave)
        if B > 1:
            t_inf = t_inf * (1.0 + self.ec.batch_alpha * (B - 1))
        if self.faults is not None and stall_at is not None:
            # edge service stall (GC pause / preemption) for work
            # starting inside the stall window
            t_inf = t_inf + self.faults.stall_extra(stall_at)
        return t_inf

    def _try_promote(self, job: Dict, jk: Tuple[int, int, int],
                     hk: Tuple[int, int, int],
                     wave: List[Tuple[int, Dict]]) -> bool:
        """Coalesce ``job`` (key ``jk``) into a wave of key ``hk``.

        Only padding UP is ever legal: the job's plan is untouched, its
        sequence is merely padded to the wave's LARGER length bucket —
        zero resolution changes, zero accuracy question (pad windows are
        masked/inert).  The restoration point shapes the executable, so
        beta must match outright; full-res jobs (length bucket 0) keep
        their dedicated executable and are never promoted.  Promotes iff
        the queueing delay the job avoids (waiting out this wave's
        service) exceeds the extra compute it buys: the padded-length
        flops-scaled inference-time increase plus its ``batch_alpha``
        marginal share of the wave.
        """
        lb_w, beta_w, cap_w = hk
        lb_j, beta_j, cap_j = jk
        if not (beta_j == beta_w and cap_j == cap_w
                and 0 < lb_j < lb_w):
            return False
        cfg = self.server.cfg
        f_own = vb.backbone_flops_windows(cfg, lb_j, beta_j)
        f_new = vb.backbone_flops_windows(cfg, lb_w, beta_w)
        t_inf_new = job["t_inf"] * (f_new / f_own)
        extra = (t_inf_new - job["t_inf"]) \
            + self.ec.batch_alpha * t_inf_new
        saved = self._wave_service_s(wave)
        if saved <= extra:
            return False
        job["t_inf_exec"] = t_inf_new
        job["promoted_lb"] = lb_w
        self.stats.promoted += 1
        return True

    # ------------------------------------------------------------------
    # execution

    def _dispatch_wave(self, wave, t_start: float, key) -> float:
        """Route execution through the host so tests can intercept."""
        if self.host is not None:
            return self.host._run_wave(wave, t_start, key)
        return self.execute_wave(wave, t_start, key)

    def _filter_stale(self, wave, nack_at: float):
        """Epoch guard: REUSE against tiles captured under a dead
        replica gets an instant control-plane NACK, never a splice —
        the client invalidates and bootstraps FULL at the new epoch
        (completion path handles it)."""
        live = []
        for ci, job in wave:
            cache = self.clients[ci].feature_cache
            if job["plan"].n_reuse > 0 and cache is not None \
                    and getattr(cache, "epoch", 0) != self.server.epoch:
                job["stale_epoch"] = True
                job["done_at"] = nack_at + job["rtt"]
                job["dets"] = []
                self.server.stats.stale_epoch_rejects += 1
                self.stats.stale_nacks += 1
                continue
            live.append((ci, job))
        return live

    def _wave_inputs(self, wave, key):
        """Stacked frames / plans / caches of a wave, with full-res
        per-job capture intent resolved (a sessionful job that did NOT
        ask for capture shares the canonical capturing executable but
        must not have its cache refreshed — its cache is dropped)."""
        lb, beta, cap = key
        imgs = np.stack([j["decoded"] for _, j in wave])
        plans = [j["plan"] for _, j in wave]
        caches = [self.clients[ci].feature_cache for ci, _ in wave]
        want_cap = 0
        if lb == 0:
            wants = [j.get("capture_beta", 0) if c is not None else 0
                     for c, (_, j) in zip(caches, wave)]
            want_cap = max(wants)
            caches = [c if w > 0 else None
                      for c, w in zip(caches, wants)]
        return imgs, plans, caches, want_cap

    def _infer(self, frames, wave, plans, caches, want_cap, key,
               defer: bool = False):
        lb, beta, cap = key
        if cap or any(c is not None for c in caches):
            return self.server.infer_wave(
                frames, plans, beta, caches=caches,
                frame_ids=[j["frame"] for _, j in wave],
                capture_beta=want_cap if lb == 0 else 0,
                lb_override=lb if lb > 0 else None, defer=defer)
        return self.server.infer_wave(
            frames, plans, beta,
            lb_override=lb if lb > 0 else None, defer=defer)

    def _record_job(self, ci: int, job: Dict, d, B: int, q: float,
                    admit: float, slot: float) -> None:
        self.stats.queue_delays.append(q)
        self.stats.queue_admit.append(admit)
        self.stats.queue_slot.append(slot)
        if job.get("parts") is not None:
            job["parts"]["queue_admit"] = admit
            job["parts"]["queue_slot"] = slot
        rec = {"client": ci, "frame": job["frame"], "wave_size": B,
               "queue": q, "queue_admit": admit, "queue_slot": slot,
               "e2e": job["e2e"],
               "promoted": "promoted_lb" in job}
        if self.ec.keep_dets:
            rec["dets"] = d
        self.stats.jobs.append(rec)

    def execute_wave(self, wave, t_start: float, key) -> float:
        raise NotImplementedError

    def drain(self, now: float) -> None:
        raise NotImplementedError

    def _reap_abandoned(self) -> None:
        if any(j.get("abandoned") for _, j in self.pending):
            # the client gave up on these (deadline) — don't serve them
            self.pending = [cj for cj in self.pending
                            if not cj[1].get("abandoned")]

    # ------------------------------------------------------------------
    # faults

    def fault_tick(self, prev: float, now: float) -> None:
        """Apply the shared replica's crash-restarts: bump the cache
        epoch (wiping executables unless the bench shortcut keeps them),
        hold the replica down for the outage, and lose the queue — jobs
        pending in a crashed process are never answered; their clients'
        deadlines reap them."""
        for (r, outage) in edge_restart_tick(
                self.server, self.faults, prev, now,
                preserve_executables=self.ec.preserve_executables):
            self.stats.restarts += 1
            self.free_at = max(self.free_at, r + outage)
            for ci, job in self.pending:
                job["lost"] = True
                job["done_at"] = float("inf")
            self.stats.lost_jobs += len(self.pending)
            self.pending = []


class BarrierScheduler(WaveScheduler):
    """Wave-at-a-time serving (the pre-refactor behaviour, pinned).

    The replica serves one wave to completion — codec decode, then the
    batched forward — before the next wave forms from whatever
    compatible jobs have arrived.  A job arriving just after a wave
    starts waits out the ENTIRE service, and the next wave's decode
    only starts once the replica frees: both costs the continuous
    policy removes.
    """

    def execute_wave(self, wave: List[Tuple[int, Dict]], t_start: float,
                     key: Tuple[int, int, int]) -> float:
        """Batched inference + Eq. (2) bookkeeping for one wave.
        Returns the time the replica frees up."""
        wave = self._filter_stale(wave, t_start)
        if not wave:
            return self.free_at
        imgs, plans, caches, want_cap = self._wave_inputs(wave, key)
        dets = self._infer(imgs, wave, plans, caches, want_cap, key)

        B = len(wave)
        t_dec = max(j["t_dec"] for _, j in wave)
        t_inf = self._wave_infer_s(wave, stall_at=t_start)
        done = t_start + t_dec + t_inf

        self.stats.wave_sizes.append(B)
        self.stats.wave_n_low_mix.append(
            len({p.n_low for p in plans}))
        # the replica decodes then computes, serially: the device sits
        # idle through t_dec
        self.stats.note_compute(t_start + t_dec, done)
        for (ci, job), d in zip(wave, dets):
            q = t_start - job["arrival"]
            self.clients[ci]._finish_offload(job, d, queue_delay=q,
                                             t_dec=t_dec, t_inf=t_inf)
            # barrier binds a job to a wave only at formation time, so
            # its whole wait is admission wait
            self._record_job(ci, job, d, B, q, q, 0.0)
        return done

    def drain(self, now: float) -> None:
        """Schedule every wave that can START before ``now``.

        The replica serves one wave at a time.  When it frees up, the
        earliest-arrived pending job seeds a wave; compatible jobs
        (same (n_low bucket, n_reuse bucket, beta, capture)) that have
        ALREADY arrived join it, up to ``max_batch`` — plus, with
        coalescing on, arrived jobs from LARGER n_low buckets whose
        promotion the cost model approves.  ``pending`` is kept sorted
        on insert (:meth:`enqueue`); the loop only ever removes jobs,
        and the kept remainder is a subsequence, so order is preserved
        without re-sorting.
        """
        self._reap_abandoned()
        while self.pending:
            head = self.pending[0]
            t_start = max(self.free_at, head[1]["arrival"])
            if t_start >= now:
                return
            cap = self.max_wave if self.ec.batched else 1
            wave, rest, hk = form_wave(
                self.pending, lambda cj: self._job_key(cj[1]), cap,
                admit=lambda cj: cj[1]["arrival"] <= t_start,
                promote=((lambda cj, jk, hk, w:
                          self._try_promote(cj[1], jk, hk, w))
                         if self.ec.coalesce else None))
            self.pending = rest
            self.free_at = self._dispatch_wave(wave, t_start, hk)


class ContinuousScheduler(WaveScheduler):
    """Continuous batching + async overlap.

    Two changes over the barrier, both pure scheduling (the executable
    grid is untouched — waves still pad to the warmed B buckets, so a
    steady-state run compiles NOTHING new):

      * **Overlap**: a wave's codec decode / h2d staging runs while the
        PREVIOUS wave computes, so compute starts at
        ``max(replica_free, arrival + t_dec)`` instead of
        ``max(replica_free, arrival) + t_dec``.  Under load the decode
        vanishes from the critical path (EdgeStats.decode_hidden_s).
        With ``EdgeConfig.stage_ahead`` the real executor pipelines the
        same way: frames are staged with ``jax.device_put``, the
        forward is dispatched asynchronously, and the blocking
        detection decode of wave N is deferred until wave N+1 has been
        dispatched.
      * **Slot admission**: a compatible job arriving while the wave
        waits to start is bound as soon as a row frees — fully-staged
        jobs join outright (never delaying the wave), and a job whose
        decode would finish LATE may still claim a padded B-bucket slot
        (pad rows cost nothing: they are dropped from decode and barred
        from caches) when the cost model prices the wave's wait below
        the ``t_inf`` the job would otherwise queue.

    Per-job Eq. (2) terms use the job's OWN ``t_dec`` (it overlapped,
    off the critical path): ``queue = c_start - arrival - t_dec``,
    split into admission wait (arrival -> row free) and slot wait (row
    free -> compute start).
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        # one-deep executor pipeline: (wave, pending_dets, timing)
        self._exec_q: List[Tuple] = []
        # in-flight speculations: launched spliced forwards awaiting
        # their payload (patch / discard / NACK at resolution)
        self._spec: List[Dict] = []

    # -- admission ------------------------------------------------------

    def _admit_unstaged(self, job: Dict, wave, c_start: float,
                        stage_done: float) -> bool:
        """May ``job`` (arrived, but its decode outlasts ``c_start``)
        claim a padded slot?  Only into a pad row — growing the padded
        bucket would re-shape the executable mid-formation — and only
        when the wave's wait (everyone pays the stage delay) plus the
        job's ``batch_alpha`` marginal share undercuts the wave's
        compute time the job would otherwise wait out."""
        B = len(wave)
        if self.server.batch_bucket(B + 1) != self.server.batch_bucket(B):
            return False
        t_inf_j = job.get("t_inf_exec", job["t_inf"])
        extra = B * (stage_done - c_start) + self.ec.batch_alpha * t_inf_j
        saved = self._wave_infer_s(wave)
        return saved > extra

    # -- schedule -------------------------------------------------------

    def drain(self, now: float) -> None:
        """Schedule every wave whose COMPUTE can start before ``now``.

        The head job's compute start is ``max(replica_free, arrival +
        t_dec)`` — its decode staged during the previous wave's
        compute.  Fully-staged compatible jobs fill rows for free;
        late-staging jobs may claim a pad row under the cost model
        (which pushes ``c_start`` to their staging point).
        """
        self._reap_abandoned()
        try:
            # resolve speculations whose payload has landed FIRST: a
            # resolved patch frees (or occupies) the replica before the
            # regular waves below price their compute start
            self._resolve_spec(now)
            while self.pending:
                head = self.pending[0]
                hj = head[1]
                bound_at = self.free_at   # rows free when compute ends
                c_start = max(self.free_at, hj["arrival"] + hj["t_dec"])
                if c_start >= now:
                    return
                hk = self._job_key(hj)
                wave, rest = [head], []
                for cj in self.pending[1:]:
                    job = cj[1]
                    ok = self.ec.batched and len(wave) < self.max_wave \
                        and job["arrival"] <= c_start
                    if ok:
                        jk = self._job_key(job)
                        ok = jk == hk or (
                            self.ec.coalesce
                            and self._try_promote(job, jk, hk, wave))
                    if ok:
                        stage_done = job["arrival"] + job["t_dec"]
                        if stage_done > c_start:
                            ok = self._admit_unstaged(job, wave, c_start,
                                                      stage_done)
                            if ok:
                                c_start = stage_done
                    if ok:
                        wave.append(cj)
                    else:
                        rest.append(cj)
                self.pending = rest
                for _, j in wave:
                    j["_bound_at"] = max(bound_at, j["arrival"])
                self.free_at = self._dispatch_wave(wave, c_start, hk)
        finally:
            # speculation launches go LAST: every wave startable before
            # ``now`` has been priced into free_at, so speculative
            # compute only ever claims replica time that would otherwise
            # idle under the uplink
            if self.ec.speculate:
                self._launch_spec(now)
                # a payload may already be due (notably the end-of-run
                # drain at now=inf): settle what just launched rather
                # than strand it in the speculative lane
                self._resolve_spec(now)
            self._flush_exec()

    # -- speculative REUSE execution ------------------------------------
    #
    # State machine per offload (README "Speculative REUSE lane"):
    #
    #   header lands --admit--> LAUNCH (spliced forward on the predicted
    #   canvas, capture into a session clone)
    #     payload lands, diverged frac <= spec_max_patch_frac
    #         --> PATCH (recompute only diverged windows at an equal-or-
    #             smaller length bucket; commit the clone)
    #     payload lands, diverged frac >  spec_max_patch_frac
    #         --> DISCARD (rerun the original plan on the real frame;
    #             drop the clone)
    #     client deadline reaps the job mid-payload (e.g. blackout)
    #         --> ABANDON (counted discarded; the degradation ladder
    #             already engaged client-side; prediction never renders)
    #     replica restarts between launch and patch
    #         --> stale-epoch NACK (the clone's tiles died with the old
    #             generation; the client invalidates and bootstraps FULL)

    def _try_speculate(self, ci: int, job: Dict,
                       now: float) -> Optional[Dict]:
        """Admission + launch of one speculative spliced forward.

        The plan header (frac/conf metadata) is on the wire as soon as
        the encode finishes; the payload lands at ``arrival``.  Launch
        requires thresholds cleared, a live warm session whose
        prediction source passes the staleness bound K and the epoch
        invariant, and replica idle time to hide the uplink in:
        ``s_start = max(free_at, header_at)`` must fall before both
        ``now`` and the payload's arrival."""
        from repro.offload import simulator as sim
        plan: RegionPlan = job["plan"]
        cache = self.clients[ci].feature_cache
        if (cache is None or job["beta"] < 1
                or self.server.plan_length_bucket(plan) == 0
                or job.get("spec_frac", 0.0) < self.ec.spec_min_frac
                or job.get("spec_conf", 0.0) < self.ec.spec_min_conf):
            return None
        if not cache.pred_ok(self.server.epoch):
            return None
        if plan.n_reuse > 0 and not (cache.warm
                                     and cache.epoch == self.server.epoch):
            return None
        header_at = job["submit"] + job["t_enc"]
        s_start = max(self.free_at, header_at)
        if s_start >= now or job["arrival"] <= s_start:
            return None
        part = self.server.part
        region_px = part.region * self.clients[ci].analyzer.patch_px
        predicted = sim.predict_canvas(part, region_px,
                                       cache.pred_frame, plan)
        dets, clone = self.server.infer_speculative(
            predicted, plan, job["beta"], cache, job["frame"])
        t_spec = self._wave_infer_s([(ci, job)], stall_at=s_start)
        s_done = s_start + t_spec
        self.free_at = max(self.free_at, s_done)
        self.stats.note_compute(s_start, s_done)
        self.stats.spec_launched += 1
        return {"ci": ci, "job": job, "dets": dets, "clone": clone,
                "predicted": predicted, "region_px": region_px,
                "epoch": self.server.epoch,
                "s_start": s_start, "s_done": s_done}

    def _launch_spec(self, now: float) -> None:
        """Move admissible pending jobs into the speculative lane."""
        keep: List[Tuple[int, Dict]] = []
        for ci, job in self.pending:
            rec = self._try_speculate(ci, job, now)
            if rec is None:
                keep.append((ci, job))
            else:
                self._spec.append(rec)
        self.pending = keep          # subsequence: arrival order kept

    def _resolve_spec(self, now: float) -> None:
        self._spec = [rec for rec in self._spec
                      if not self._resolve_one(rec, now)]

    def _resolve_one(self, rec: Dict, now: float) -> bool:
        """Patch, discard, or refuse one landed speculation.  Returns
        True once the record is settled."""
        from repro.offload import simulator as sim
        ci, job = rec["ci"], rec["job"]
        if job.get("abandoned"):
            # the client's deadline reaped the offload mid-payload
            # (blackout): the speculation dies with it — the prediction
            # is never rendered, and the client already climbed the
            # degradation ladder when it abandoned
            self.stats.spec_discarded += 1
            return True
        if rec["epoch"] != self.server.epoch:
            # replica restarted between launch and patch: the clone's
            # tiles (and the speculative result spliced from them)
            # belong to a dead generation — stale-epoch refusal applies
            # to speculative splices exactly as to real ones
            if job["arrival"] >= now:
                return False
            job["stale_epoch"] = True
            job["done_at"] = job["arrival"] + job["rtt"]
            job["dets"] = []
            self.server.stats.stale_epoch_rejects += 1
            self.stats.stale_nacks += 1
            self.stats.spec_discarded += 1
            return True
        r_start = max(self.free_at, job["arrival"] + job["t_dec"])
        if r_start >= now:
            return False
        part = self.server.part
        plan: RegionPlan = job["plan"]
        cache = self.clients[ci].feature_cache
        div = sim.region_divergence(part, rec["region_px"],
                                    job["decoded"], rec["predicted"],
                                    plan)
        states = np.asarray(plan.states)
        diverged = (states != REUSE) & (div > self.ec.spec_patch_tol)
        n_tx = plan.n_regions - plan.n_reuse
        stall = (self.faults.stall_extra(r_start)
                 if self.faults is not None else 0.0)
        t_inf_j = job.get("t_inf_exec", job["t_inf"])
        if diverged.sum() / max(n_tx, 1) > self.ec.spec_max_patch_frac:
            # gross mispredict: discard and rerun the original plan on
            # the real decoded frame (the normal path — the REAL cache
            # refreshes, the clone is dropped)
            dets = self._infer(job["decoded"][None], [(ci, job)],
                               [plan], [cache], 0,
                               self._job_key(job))[0]
            t_exec = t_inf_j + stall
            job["speculation"] = "discarded"
            self.stats.spec_discarded += 1
        else:
            if diverged.any():
                # patch pass: only diverged windows recompute; converged
                # transmitted regions splice the speculative capture —
                # an equal-or-smaller length bucket on the warmed grid
                patch_plan = sim.build_patch_plan(plan, diverged)
                lb = self.server.plan_length_bucket(plan)
                lb_p = self.server.plan_length_bucket(patch_plan)
                dets = self.server.infer_wave(
                    job["decoded"][None], [patch_plan], job["beta"],
                    caches=[rec["clone"]], frame_ids=[job["frame"]])[0]
                cfg = self.server.cfg
                scale = (vb.backbone_flops_windows(cfg, lb_p, job["beta"])
                         / vb.backbone_flops_windows(cfg, lb, job["beta"]))
                t_exec = t_inf_j * scale + stall
            else:
                # every in-flight region converged: the speculative
                # forward IS the answer; the patch pass is just the
                # host-side divergence check
                dets = rec["dets"]
                t_exec = stall
            # commit the clone: every region not freshly recomputed
            # from real pixels derives from reuse/prediction and ages by
            # one against the staleness bound K
            cache.commit_speculative(rec["clone"],
                                     np.nonzero(~diverged)[0],
                                     job["beta"], job["frame"],
                                     self.server.epoch)
            job["speculation"] = "patched"
            self.stats.spec_patched += 1
        done = r_start + t_exec
        self.free_at = max(self.free_at, done)
        if t_exec > 0.0:
            self.stats.note_compute(r_start, done)
        # hidden transmission: the slice of the uplink the speculative
        # compute overlapped — the Eq. (2) seconds this lane converts
        # from queue/idle into useful work
        hidden = max(0.0, min(rec["s_done"], job["arrival"])
                     - rec["s_start"])
        self.stats.spec_hidden.append(hidden)
        self.stats.spec_hidden_s += hidden
        cache.note_pred(job["decoded"], job["frame"], self.server.epoch)
        q = max(r_start - job["arrival"] - job["t_dec"], 0.0)
        self.clients[ci]._finish_offload(job, dets, queue_delay=q,
                                         t_dec=job["t_dec"],
                                         t_inf=t_exec)
        # bound at launch, before the payload even landed: the whole
        # queue residual is slot wait
        self._record_job(ci, job, dets, 1, q, 0.0, q)
        return True

    # -- execution ------------------------------------------------------

    def execute_wave(self, wave: List[Tuple[int, Dict]], t_start: float,
                     key: Tuple[int, int, int]) -> float:
        """Dispatch one wave at compute start ``t_start``.

        With ``stage_ahead`` the call is asynchronous: frames go to the
        device via ``jax.device_put``, the forward is dispatched, and
        the PREVIOUS wave's blocking detection decode runs only now —
        under this wave's device compute.  Returns the replica's new
        busy horizon (= compute end; decode is off the critical path).
        """
        wave = self._filter_stale(wave, t_start)
        if not wave:
            return self.free_at
        imgs, plans, caches, want_cap = self._wave_inputs(wave, key)
        defer = bool(self.ec.stage_ahead)
        frames = (self.server.stage_frames(imgs) if defer else imgs)
        try:
            dets = self._infer(frames, wave, plans, caches, want_cap,
                               key, defer=defer)
        except Exception:
            # deferred-dispatch failure after staging: drop the staged
            # device buffers, flush the one-deep executor pipeline so
            # the PREVIOUS wave's deferred decode still lands (its
            # PendingWave slot must not wedge), and mark this wave's
            # jobs lost so client deadlines reap them if the caller
            # survives the re-raise
            frames = None
            self._flush_exec()
            for _, job in wave:
                job["lost"] = True
                job["done_at"] = float("inf")
            self.stats.lost_jobs += len(wave)
            raise
        if self.ec.speculate:
            # record each session's decoded canvas as its prediction
            # source AFTER the refresh inside infer_wave (note() aged
            # it; note_pred resets the staleness clock)
            for ci, job in wave:
                cache = self.clients[ci].feature_cache
                if cache is not None:
                    cache.note_pred(job["decoded"], job["frame"],
                                    self.server.epoch)

        B = len(wave)
        t_inf = self._wave_infer_s(wave, stall_at=t_start)
        done = t_start + t_inf

        self.stats.wave_sizes.append(B)
        self.stats.wave_n_low_mix.append(
            len({p.n_low for p in plans}))
        self.stats.note_compute(t_start, done)
        prev_free = self.free_at
        for _, job in wave:
            self.stats.decode_hidden_s += min(
                job["t_dec"], max(prev_free - job["arrival"], 0.0))
        self._exec_q.append((wave, dets, t_start, t_inf, B))
        if len(self._exec_q) > 1:
            self._finalize(self._exec_q.pop(0))
        return done

    def _finalize(self, rec) -> None:
        wave, dets, t_start, t_inf, B = rec
        if hasattr(dets, "wait"):        # deferred decode (stage_ahead)
            dets = dets.wait()
        for (ci, job), d in zip(wave, dets):
            q = max(t_start - job["arrival"] - job["t_dec"], 0.0)
            admit = min(max(job.get("_bound_at", job["arrival"])
                            - job["arrival"], 0.0), q)
            self.clients[ci]._finish_offload(job, d, queue_delay=q,
                                             t_dec=job["t_dec"],
                                             t_inf=t_inf)
            self._record_job(ci, job, d, B, q, admit, q - admit)

    def _flush_exec(self) -> None:
        while self._exec_q:
            self._finalize(self._exec_q.pop(0))


SCHEDULERS = {"barrier": BarrierScheduler,
              "continuous": ContinuousScheduler}


def make_scheduler(server, clients, ec: EdgeConfig,
                   faults: Optional[FaultInjector] = None,
                   host=None) -> WaveScheduler:
    try:
        cls = SCHEDULERS[ec.scheduler]
    except KeyError:
        raise ValueError(
            f"unknown EdgeConfig.scheduler {ec.scheduler!r}; "
            f"choose from {sorted(SCHEDULERS)}") from None
    return cls(server, clients, ec, faults=faults, host=host)


# ---------------------------------------------------------------------------
# the N=1 plane


class SoloScheduler:
    """Scheduling plane of the single-client simulator.

    N=1 has no wave to form: an offload executes immediately on the
    dedicated replica.  What it shares with the edge is the rest of the
    plane — the stale-epoch control-plane NACK and the crash-restart
    application (:func:`edge_restart_tick`), so solo and multi-client
    restart recovery stay behaviourally identical.
    """

    def __init__(self, sim):
        self.sim = sim

    def submit(self, job: Dict, now: float) -> None:
        """Dedicated immediate inference for one prepared offload."""
        sim = self.sim
        try:
            if sim.feature_cache is not None:
                dets = sim.server.infer_plan(
                    job["decoded"], job["plan"], job["beta"],
                    cache=sim.feature_cache, frame_idx=job["frame"],
                    capture_beta=job["capture_beta"])
            else:
                dets = sim.server.infer(
                    job["decoded"],
                    job["mask"] if job["n_d"] > 0 else None, job["beta"])
        except StaleCacheEpoch:
            # control-plane NACK from a restarted edge: the splice was
            # refused; the completion path invalidates the cache and the
            # next offload bootstraps FULL at the new epoch
            job["stale_epoch"] = True
            job["done_at"] = now + job["rtt"]
            job["dets"] = []
            return
        sim._finish_offload(job, dets)

    def fault_tick(self, prev: float, now: float) -> None:
        """Single-client path owns its replica: apply crash-restarts
        (epoch bump + executable wipe via the shared plane) and lose
        any response that died with the old process."""
        sim = self.sim
        for (r, outage) in edge_restart_tick(sim.server, sim.faults,
                                             prev, now):
            sim.rstats["edge_restarts"] += 1
            j = sim.inflight
            if j is not None and j["submit"] <= r and j["done_at"] > r:
                j["lost"] = True
                j["done_at"] = float("inf")
