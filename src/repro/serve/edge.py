"""Multi-client batched edge serving (ROADMAP: the "millions of users"
direction; Arena-style patch-of-interest edge inference).

One edge replica serves N concurrent device streams:

  * :class:`BatchedServerModel` stacks decoded mixed-resolution frames
    from MANY clients that share a (bucketed n_low, bucket-exact
    n_reuse, beta) configuration into ONE batched ``forward_det`` call.
    Each frame keeps its OWN three-state region layout — the per-sample
    (B, n) region-id path of core.mixed_res — so co-batching never
    downsamples (or reuses) the wrong regions (the 2-D analogue of the
    ServeEngine wave-key fix).  Temporal reuse is sessionful: each
    client stream owns a :class:`~repro.serve.request.FeatureCache`
    whose restoration-point tiles are spliced in (REUSE regions) and
    refreshed (captured tiles) per sample, never across samples.  All
    of it is the one bucketed-executable + padded-batch code path of
    :meth:`ServerModel.infer_wave` — waves pad UP to a batch bucket, so
    the executable set stays the bounded warmup grid.
  * :class:`MultiClientSimulation` multiplexes N (video, trace, policy)
    device streams onto that replica.  ALL batch formation lives in the
    scheduling plane (:mod:`repro.serve.scheduler`): offloads queue at
    the edge and a :class:`~repro.serve.scheduler.WaveScheduler` policy
    — ``EdgeConfig(scheduler="barrier")`` wave-at-a-time, or
    ``"continuous"`` with decode/h2d staging overlapped under compute
    and late admission into padded B-bucket slots — forms waves of
    compatible jobs (same (length bucket, beta, capture point); ANY
    (n_low, n_reuse) mix co-batches, since the collapsed executable
    grid carries plan layouts as runtime data).  The resulting queueing
    delay is folded into Eq. (2)'s end-to-end latency
    (``parts["queue"]``, split into admission and slot wait);
    ``EdgeConfig.coalesce`` additionally promotes pending jobs across
    length buckets under the ``backbone_flops_windows`` cost model.

This module is deliberately thin: the simulation drives per-frame
client steps and the fault clock; the scheduler owns the queue, the
admission control, the cost model, and the wave execution.  The
single-client :class:`~repro.offload.simulator.Simulation` is the N=1
case: both drive the same per-frame step methods
(_motion_tick/_prepare_offload/_finish_offload/_complete_offload/
_render_tick); only the scheduling plane differs (SoloScheduler vs.
WaveScheduler).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import RegionPlan, stack_plan_ids, stack_region_ids
from repro.offload.faults import FaultInjector
from repro.offload.simulator import ServerModel, Simulation, SimResult
from repro.serve.request import FeatureCache
from repro.serve.scheduler import (EdgeConfig, EdgeStats, WaveScheduler,
                                   make_scheduler)

__all__ = ["BatchedServerModel", "EdgeConfig", "EdgeStats",
           "MultiClientSimulation", "stack_plan_ids", "stack_region_ids"]


class BatchedServerModel(ServerModel):
    """Edge replica shared by many clients.

    Kept as the multi-client API surface; both entry points are thin
    adapters over the inherited :meth:`ServerModel.infer_wave`, so solo
    B=1 calls and batched waves share one executable grid (and one
    warmup) per (length bucket, beta, capture, B bucket).
    """

    def infer_batch(self, frames: np.ndarray,
                    masks: Sequence[Optional[np.ndarray]],
                    beta: int = 0) -> List[List[Dict]]:
        """Batched inference over frames with ARBITRARY per-frame masks.

        frames: (B, H, W, 3); masks: per-frame (n_regions,) binary masks
        (or None for full-res).  Masks may land in DIFFERENT n_low
        buckets — the wave runs at the length bucket of its longest
        plan, shorter plans are padded (the collapsed-executable
        contract).  An all-full-res batch keeps the dedicated full-res
        executable.  Returns per-frame detection lists.
        """
        B = frames.shape[0]
        assert len(masks) == B
        plans = [RegionPlan.from_mask(m) if m is not None
                 and int(np.asarray(m).sum()) > 0
                 else RegionPlan(np.zeros((self.part.n_regions,), np.int8))
                 for m in masks]
        return self.infer_wave(frames, plans, beta)

    def infer_plans(self, frames: np.ndarray,
                    plans: Sequence[RegionPlan],
                    beta: int,
                    caches: Sequence[FeatureCache],
                    frame_ids: Sequence[int],
                    capture_beta: int = 0) -> List[List[Dict]]:
        """Batched three-state inference over same-bucket frames.

        Every plan must share ONE (n_low bucket, bucket-exact n_reuse)
        pair and every frame restores at the same ``beta`` — the wave
        compatibility contract the scheduler enforces.  Each sample's
        REUSE tiles come from (and the refreshed restoration-point tiles
        go back to) its OWN client's :class:`FeatureCache`, so co-batched
        sessions never see each other's features.  Returns per-frame
        detection lists.
        """
        assert len(plans) == len(caches) == len(frame_ids) == \
            frames.shape[0]
        return self.infer_wave(frames, plans, beta, caches=caches,
                               frame_ids=frame_ids,
                               capture_beta=capture_beta)


# ---------------------------------------------------------------------------
# event-driven multi-client engine


class MultiClientSimulation:
    """N device streams -> one shared edge replica.

    clients: per-stream :class:`Simulation` objects (build them with
    this same replica as their ``server`` so a standalone N=1 run uses
    identical weights).  ``on_complete(client_idx, job)`` fires as each
    offload's result reaches its client.

    Batch formation, admission control, coalescing, and the replica's
    fault application all live in ``self.scheduler`` (a
    :class:`~repro.serve.scheduler.WaveScheduler` chosen by
    ``EdgeConfig.scheduler``); the legacy ``pending`` / ``free_at`` /
    ``max_wave`` / ``_enqueue`` / ``_drain`` / ``_run_wave`` surface is
    kept as thin delegates so callers (and test monkeypatches of
    ``_run_wave``) keep working.
    """

    def __init__(self, clients: Sequence[Simulation],
                 server: BatchedServerModel,
                 ec: Optional[EdgeConfig] = None,
                 on_complete: Optional[Callable[[int, Dict], None]] = None,
                 faults: Optional[FaultInjector] = None):
        assert clients, "need at least one client"
        self.clients = list(clients)
        self.server = server
        self.ec = ec or EdgeConfig()
        self.on_complete = on_complete
        # edge-plane fault schedule (crash-restarts, service stalls,
        # arrivals into an outage).  Network/response-plane faults
        # belong on the CLIENTS' injectors — keep the planes on separate
        # injectors or edge stalls would be double-counted.
        self.faults = faults
        self.dt = self.clients[0].dt
        assert all(c.dt == self.dt for c in self.clients), \
            "clients must share a frame rate"
        self.scheduler: WaveScheduler = make_scheduler(
            server, self.clients, self.ec, faults=faults, host=self)
        self.stats = self.scheduler.stats

    # ------------------------------------------------------------------
    # scheduling-plane delegates (the legacy surface)

    @property
    def pending(self) -> List[Tuple[int, Dict]]:
        return self.scheduler.pending

    @pending.setter
    def pending(self, value: List[Tuple[int, Dict]]) -> None:
        self.scheduler.pending = value

    @property
    def free_at(self) -> float:
        return self.scheduler.free_at

    @free_at.setter
    def free_at(self, value: float) -> None:
        self.scheduler.free_at = value

    @property
    def max_wave(self) -> int:
        return self.scheduler.max_wave

    def _enqueue(self, ci: int, job: Dict) -> None:
        self.scheduler.enqueue(ci, job)

    def _drain(self, now: float) -> None:
        self.scheduler.drain(now)

    def _run_wave(self, wave: List[Tuple[int, Dict]], t_start: float,
                  key: Tuple[int, int, int]) -> float:
        """Execution hook the scheduler dispatches through — tests
        monkeypatch this to intercept waves."""
        return self.scheduler.execute_wave(wave, t_start, key)

    def _edge_fault_tick(self, prev: float, now: float) -> None:
        self.scheduler.fault_tick(prev, now)

    # ------------------------------------------------------------------
    def run(self, video_names: Optional[Sequence[str]] = None
            ) -> List[SimResult]:
        """Run all streams to completion.  Returns per-client results."""
        names = (list(video_names) if video_names is not None
                 else [f"client{i}" for i in range(len(self.clients))])
        results = [SimResult(policy=c.policy.name, video=names[i],
                             trace=getattr(c.trace, "name", "trace"))
                   for i, c in enumerate(self.clients)]

        n_max = max(len(c.frames) for c in self.clients)
        prev = -1.0
        for fi in range(n_max):
            now = fi * self.dt
            self._edge_fault_tick(prev, now)
            self._drain(now)
            for ci, c in enumerate(self.clients):
                if fi >= len(c.frames):
                    continue
                c._motion_tick(fi, results[ci])
                job = c._poll_inflight(now, fi, results[ci])
                if job is not None and self.on_complete:
                    self.on_complete(ci, job)
                if c._should_offload(fi):
                    c._note_offload_gap(fi, results[ci])
                    job = c._prepare_offload(fi, now, results[ci])
                    # arrival at the edge: encode + uplink transfer
                    job["arrival"] = now + job["t_enc"] + job["t_up"]
                    job["_client"] = ci
                    self._enqueue(ci, job)
                c._render_tick(fi, results[ci])
            prev = now

        # end of all clips: run the edge dry and flush in-flight
        # offloads (each client's deadline still applies)
        self._drain(float("inf"))
        for ci, c in enumerate(self.clients):
            job = c._poll_inflight(float("inf"), len(c.frames),
                                   results[ci])
            if job is not None and self.on_complete:
                self.on_complete(ci, job)
        return results
