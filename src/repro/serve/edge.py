"""Multi-client batched edge serving (ROADMAP: the "millions of users"
direction; Arena-style patch-of-interest edge inference).

One edge replica serves N concurrent device streams:

  * :class:`BatchedServerModel` stacks decoded mixed-resolution frames
    from MANY clients that share a (bucketed n_low, bucket-exact
    n_reuse, beta) configuration into ONE batched ``forward_det`` call.
    Each frame keeps its OWN three-state region layout — the per-sample
    (B, n) region-id path of core.mixed_res — so co-batching never
    downsamples (or reuses) the wrong regions (the 2-D analogue of the
    ServeEngine wave-key fix).  Temporal reuse is sessionful: each
    client stream owns a :class:`~repro.serve.request.FeatureCache`
    whose restoration-point tiles are spliced in (REUSE regions) and
    refreshed (captured tiles) per sample, never across samples.
  * :class:`MultiClientSimulation` multiplexes N (video, trace, policy)
    device streams onto that replica with an event-driven wave
    scheduler.  Offloads queue at the edge; waves form from whatever
    compatible jobs — same (n_low bucket, n_reuse bucket, beta, capture
    point) — have arrived when the replica frees up; the resulting
    queueing delay is folded into Eq. (2)'s end-to-end latency
    (``parts["queue"]``).

The single-client :class:`~repro.offload.simulator.Simulation` is the
N=1 case: both drive the same per-frame step methods
(_motion_tick/_prepare_offload/_finish_offload/_complete_offload/
_render_tick); only the server call differs (dedicated vs. waved).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import partition as pt
from repro.core.partition import RegionPlan
from repro.offload import detection as det
from repro.offload.simulator import ServerModel, Simulation, SimResult
from repro.serve.request import FeatureCache


def stack_region_ids(masks: Sequence[np.ndarray], n_low: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-sample (B, nF) / (B, nL) region ids for a same-bucket wave."""
    ids = [pt.mask_to_region_ids(m, n_low) for m in masks]
    return (np.stack([f for f, _ in ids]).astype(np.int32),
            np.stack([l for _, l in ids]).astype(np.int32))


def stack_plan_ids(plans: Sequence[RegionPlan], n_low: int, n_reuse: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-sample (B, nF) / (B, nL) / (B, nR) ids for a same-bucket wave."""
    ids = [pt.plan_to_region_ids(p.states, n_low, n_reuse) for p in plans]
    return (np.stack([f for f, _, _ in ids]).astype(np.int32),
            np.stack([l for _, l, _ in ids]).astype(np.int32),
            np.stack([r for _, _, r in ids]).astype(np.int32))


class BatchedServerModel(ServerModel):
    """Edge replica shared by many clients.

    Extends :class:`ServerModel` with :meth:`infer_batch`: frames with
    the same (bucketed n_low, beta) but DIFFERENT masks run as one
    batched forward through the PR-1 backend dispatch layer.  The
    inherited ``_fns`` cache is reused — jit re-specializes per wave
    shape (B and id rank included), so B=1 solo calls and batched waves
    share one compiled-fn cache entry per (n_low bucket, beta).
    """

    def infer_batch(self, frames: np.ndarray,
                    masks: Sequence[Optional[np.ndarray]],
                    beta: int = 0) -> List[List[Dict]]:
        """Batched inference over same-bucket frames.

        frames: (B, H, W, 3); masks: per-frame (n_regions,) binary masks
        (or None for full-res).  Every mask must land in the SAME n_low
        bucket — that is the wave compatibility contract the scheduler
        enforces.  Returns per-frame detection lists.
        """
        B = frames.shape[0]
        assert len(masks) == B
        n_lows = [0 if m is None else self.bucket(int(m.sum()))
                  for m in masks]
        n_low = n_lows[0]
        assert all(n == n_low for n in n_lows), \
            f"wave mixes n_low buckets: {n_lows}"
        imgs = jnp.asarray(frames)
        if n_low == 0:
            fn = self._get_fn(0, 0)
            boxes, scores, classes = fn(self.params, imgs)
        else:
            full_ids, low_ids = stack_region_ids(masks, n_low)
            fn = self._get_fn(n_low, beta)
            boxes, scores, classes = fn(self.params, imgs,
                                        jnp.asarray(full_ids),
                                        jnp.asarray(low_ids))
        return [det.detections_from_arrays(boxes[i], scores[i], classes[i],
                                           self.score_thresh)
                for i in range(B)]

    def infer_plans(self, frames: np.ndarray,
                    plans: Sequence[RegionPlan],
                    beta: int,
                    caches: Sequence[FeatureCache],
                    frame_ids: Sequence[int],
                    capture_beta: int = 0) -> List[List[Dict]]:
        """Batched three-state inference over same-bucket frames.

        Every plan must share ONE (n_low bucket, bucket-exact n_reuse)
        pair and every frame restores at the same ``beta`` — the wave
        compatibility contract the scheduler enforces.  Each sample's
        REUSE tiles come from (and the refreshed restoration-point tiles
        go back to) its OWN client's :class:`FeatureCache`, so co-batched
        sessions never see each other's features.  Returns per-frame
        detection lists.
        """
        B = frames.shape[0]
        assert len(plans) == len(caches) == len(frame_ids) == B
        buckets = [self.plan_buckets(p) for p in plans]
        n_low, n_reuse = buckets[0]
        assert all(b == (n_low, n_reuse) for b in buckets), \
            f"wave mixes (n_low, n_reuse) buckets: {buckets}"
        cap = beta if beta >= 1 else capture_beta
        imgs = jnp.asarray(frames)
        reuse_b = np.zeros((B, 0), np.int32)
        if n_low == 0 and n_reuse == 0:
            fn = self._get_fn(0, 0, 0, cap)
            out = fn(self.params, imgs)
        else:
            full_b, low_b, reuse_b = stack_plan_ids(plans, n_low, n_reuse)
            fn = self._get_fn(n_low, beta, n_reuse, cap)
            if n_reuse == 0:
                out = fn(self.params, imgs, jnp.asarray(full_b),
                         jnp.asarray(low_b))
            else:
                tiles = jnp.asarray(np.stack(
                    [c.gather(reuse_b[i]) for i, c in enumerate(caches)]))
                out = fn(self.params, imgs, jnp.asarray(full_b),
                         jnp.asarray(low_b), jnp.asarray(reuse_b), tiles)
        if cap:
            (boxes, scores, classes), tiles_out = out
            tiles_np = np.asarray(tiles_out)
            for i, c in enumerate(caches):
                c.update(tiles_np[i], reuse_b[i], cap, frame_ids[i])
        else:
            boxes, scores, classes = out
        return [det.detections_from_arrays(boxes[i], scores[i], classes[i],
                                           self.score_thresh)
                for i in range(B)]


# ---------------------------------------------------------------------------
# event-driven multi-client engine


@dataclass
class EdgeConfig:
    max_batch: int = 8
    # serving mode: batched waves vs. one-job-at-a-time (the sequential
    # baseline bench_multiclient.py compares against)
    batched: bool = True
    # marginal service time of each extra frame in a wave, as a fraction
    # of the solo inference delay: service = t_inf * (1 + alpha * (B-1)).
    # alpha < 1 is the batching win; alpha = 1 degenerates to sequential.
    # (wave compatibility buckets come from the server's n_buckets —
    # they MUST match infer_batch's bucketing, so there is no knob here)
    batch_alpha: float = 0.35


@dataclass
class EdgeStats:
    """Edge-side telemetry: wave sizes, queueing, and per-job outcomes."""
    wave_sizes: List[int] = field(default_factory=list)
    queue_delays: List[float] = field(default_factory=list)
    jobs: List[Dict] = field(default_factory=list)

    @property
    def mean_wave_size(self) -> float:
        return float(np.mean(self.wave_sizes)) if self.wave_sizes else 0.0


class MultiClientSimulation:
    """N device streams -> one shared edge replica.

    clients: per-stream :class:`Simulation` objects (build them with
    this same replica as their ``server`` so a standalone N=1 run uses
    identical weights).  ``on_complete(client_idx, job)`` fires as each
    offload's result reaches its client.
    """

    def __init__(self, clients: Sequence[Simulation],
                 server: BatchedServerModel,
                 ec: Optional[EdgeConfig] = None,
                 on_complete: Optional[Callable[[int, Dict], None]] = None):
        assert clients, "need at least one client"
        self.clients = list(clients)
        self.server = server
        self.ec = ec or EdgeConfig()
        self.on_complete = on_complete
        self.dt = self.clients[0].dt
        assert all(c.dt == self.dt for c in self.clients), \
            "clients must share a frame rate"
        self.pending: List[Tuple[int, Dict]] = []   # (client_idx, job)
        self.free_at = 0.0                          # replica busy horizon
        self.stats = EdgeStats()

    # ------------------------------------------------------------------
    def _job_key(self, job: Dict) -> Tuple[int, int, int, int]:
        """Wave compatibility: (n_low bucket, n_reuse bucket, beta,
        capture point).  Sessionful (reuse-capable) jobs capture
        restoration-point tiles, so their compiled forward differs from
        stateless jobs even at (n_low, n_reuse, beta) parity — the
        capture field keeps them in separate waves."""
        n_low = self.server.bucket(job["n_d"])
        n_reuse = job.get("n_r", 0)
        beta = job["beta"] if (n_low > 0 or n_reuse > 0) else 0
        if self.clients[self._client_of(job)].feature_cache is None:
            cap = 0
        else:
            cap = beta if beta >= 1 else job.get("capture_beta", 0)
        return (n_low, n_reuse, beta, cap)

    def _client_of(self, job: Dict) -> int:
        return job["_client"]

    def _run_wave(self, wave: List[Tuple[int, Dict]], t_start: float,
                  key: Tuple[int, int, int, int]) -> float:
        """Batched inference + Eq. (2) bookkeeping for one wave.
        Returns the time the replica frees up."""
        n_low, n_reuse, beta, cap = key
        imgs = np.stack([j["decoded"] for _, j in wave])
        if cap or n_reuse > 0:
            dets = self.server.infer_plans(
                imgs, [j["plan"] for _, j in wave], beta,
                [self.clients[ci].feature_cache for ci, _ in wave],
                [j["frame"] for _, j in wave],
                capture_beta=cap if beta < 1 else 0)
        else:
            masks = [j["mask"] if n_low > 0 else None for _, j in wave]
            dets = self.server.infer_batch(imgs, masks, beta)

        B = len(wave)
        t_dec = max(j["t_dec"] for _, j in wave)
        t_inf = max(j["t_inf"] for _, j in wave)
        if B > 1:
            t_inf = t_inf * (1.0 + self.ec.batch_alpha * (B - 1))
        done = t_start + t_dec + t_inf

        self.stats.wave_sizes.append(B)
        for (ci, job), d in zip(wave, dets):
            q = t_start - job["arrival"]
            self.clients[ci]._finish_offload(job, d, queue_delay=q,
                                             t_dec=t_dec, t_inf=t_inf)
            self.stats.queue_delays.append(q)
            self.stats.jobs.append({"client": ci, "frame": job["frame"],
                                    "wave_size": B, "queue": q,
                                    "e2e": job["e2e"], "dets": d})
        return done

    def _drain(self, now: float) -> None:
        """Schedule every wave that can START before ``now``.

        The replica serves one wave at a time.  When it frees up, the
        earliest-arrived pending job seeds a wave; compatible jobs
        (same (n_low bucket, beta)) that have ALREADY arrived join it,
        up to ``max_batch``.
        """
        # one sort per drain: the loop only ever REMOVES jobs, and the
        # kept remainder is a subsequence, so order is preserved
        self.pending.sort(key=lambda cj: cj[1]["arrival"])
        while self.pending:
            head = self.pending[0]
            t_start = max(self.free_at, head[1]["arrival"])
            if t_start >= now:
                return
            hk = self._job_key(head[1])
            wave, rest = [head], []
            for cj in self.pending[1:]:
                if (self.ec.batched and len(wave) < self.ec.max_batch
                        and cj[1]["arrival"] <= t_start
                        and self._job_key(cj[1]) == hk):
                    wave.append(cj)
                else:
                    rest.append(cj)
            self.pending = rest
            self.free_at = self._run_wave(wave, t_start, hk)

    # ------------------------------------------------------------------
    def run(self, video_names: Optional[Sequence[str]] = None
            ) -> List[SimResult]:
        """Run all streams to completion.  Returns per-client results."""
        names = (list(video_names) if video_names is not None
                 else [f"client{i}" for i in range(len(self.clients))])
        results = [SimResult(policy=c.policy.name, video=names[i],
                             trace=getattr(c.trace, "name", "trace"))
                   for i, c in enumerate(self.clients)]

        n_max = max(len(c.frames) for c in self.clients)
        for fi in range(n_max):
            now = fi * self.dt
            self._drain(now)
            for ci, c in enumerate(self.clients):
                if fi >= len(c.frames):
                    continue
                c._motion_tick(fi, results[ci])
                if c.inflight is not None and c.inflight["done_at"] <= now:
                    job = c._complete_offload(results[ci], fi)
                    if self.on_complete:
                        self.on_complete(ci, job)
                if c._should_offload(fi):
                    c._note_offload_gap(fi, results[ci])
                    job = c._prepare_offload(fi, now, results[ci])
                    # arrival at the edge: encode + uplink transfer
                    job["arrival"] = now + job["t_enc"] + job["t_up"]
                    job["_client"] = ci
                    self.pending.append((ci, job))
                c._render_tick(fi, results[ci])

        # end of all clips: run the edge dry and flush in-flight offloads
        self._drain(float("inf"))
        for ci, c in enumerate(self.clients):
            if c.inflight is not None:
                job = c._complete_offload(results[ci], len(c.frames))
                if self.on_complete:
                    self.on_complete(ci, job)
        return results
