"""Multi-client batched edge serving (ROADMAP: the "millions of users"
direction; Arena-style patch-of-interest edge inference).

One edge replica serves N concurrent device streams:

  * :class:`BatchedServerModel` stacks decoded mixed-resolution frames
    from MANY clients that share a (bucketed n_low, bucket-exact
    n_reuse, beta) configuration into ONE batched ``forward_det`` call.
    Each frame keeps its OWN three-state region layout — the per-sample
    (B, n) region-id path of core.mixed_res — so co-batching never
    downsamples (or reuses) the wrong regions (the 2-D analogue of the
    ServeEngine wave-key fix).  Temporal reuse is sessionful: each
    client stream owns a :class:`~repro.serve.request.FeatureCache`
    whose restoration-point tiles are spliced in (REUSE regions) and
    refreshed (captured tiles) per sample, never across samples.  All
    of it is the one bucketed-executable + padded-batch code path of
    :meth:`ServerModel.infer_wave` — waves pad UP to a batch bucket, so
    the executable set stays the bounded warmup grid.
  * :class:`MultiClientSimulation` multiplexes N (video, trace, policy)
    device streams onto that replica with an event-driven wave
    scheduler.  Offloads queue at the edge (kept sorted on insert);
    waves form from whatever compatible jobs — same (length bucket,
    beta, capture point) — have arrived when the replica frees up;
    ANY (n_low, n_reuse) mix at one length bucket is compatible, since
    the collapsed executable grid carries plan layouts as runtime data
    (core.partition.PlanLayout).  The resulting queueing delay is
    folded into Eq. (2)'s end-to-end latency (``parts["queue"]``).
    With ``EdgeConfig.coalesce`` the scheduler additionally promotes a
    pending job from a SMALLER length bucket into the forming wave's
    larger bucket — the job's plan is untouched, it is merely padded
    further (zero resolution changes, zero accuracy question) —
    whenever a cost model built on ``backbone_flops_windows`` and
    ``batch_alpha`` says the queueing delay saved exceeds the extra
    padded compute bought.

The single-client :class:`~repro.offload.simulator.Simulation` is the
N=1 case: both drive the same per-frame step methods
(_motion_tick/_prepare_offload/_finish_offload/_complete_offload/
_render_tick); only the server call differs (dedicated vs. waved).
"""
from __future__ import annotations

import bisect
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import (FULL, LOW, RegionPlan, stack_plan_ids,
                                  stack_region_ids)
from repro.offload.faults import FaultInjector
from repro.offload.simulator import ServerModel, Simulation, SimResult
from repro.serve.request import FeatureCache

__all__ = ["BatchedServerModel", "EdgeConfig", "EdgeStats",
           "MultiClientSimulation", "stack_plan_ids", "stack_region_ids"]


class BatchedServerModel(ServerModel):
    """Edge replica shared by many clients.

    Kept as the multi-client API surface; both entry points are thin
    adapters over the inherited :meth:`ServerModel.infer_wave`, so solo
    B=1 calls and batched waves share one executable grid (and one
    warmup) per (length bucket, beta, capture, B bucket).
    """

    def infer_batch(self, frames: np.ndarray,
                    masks: Sequence[Optional[np.ndarray]],
                    beta: int = 0) -> List[List[Dict]]:
        """Batched inference over frames with ARBITRARY per-frame masks.

        frames: (B, H, W, 3); masks: per-frame (n_regions,) binary masks
        (or None for full-res).  Masks may land in DIFFERENT n_low
        buckets — the wave runs at the length bucket of its longest
        plan, shorter plans are padded (the collapsed-executable
        contract).  An all-full-res batch keeps the dedicated full-res
        executable.  Returns per-frame detection lists.
        """
        B = frames.shape[0]
        assert len(masks) == B
        plans = [RegionPlan.from_mask(m) if m is not None
                 and int(np.asarray(m).sum()) > 0
                 else RegionPlan(np.zeros((self.part.n_regions,), np.int8))
                 for m in masks]
        return self.infer_wave(frames, plans, beta)

    def infer_plans(self, frames: np.ndarray,
                    plans: Sequence[RegionPlan],
                    beta: int,
                    caches: Sequence[FeatureCache],
                    frame_ids: Sequence[int],
                    capture_beta: int = 0) -> List[List[Dict]]:
        """Batched three-state inference over same-bucket frames.

        Every plan must share ONE (n_low bucket, bucket-exact n_reuse)
        pair and every frame restores at the same ``beta`` — the wave
        compatibility contract the scheduler enforces.  Each sample's
        REUSE tiles come from (and the refreshed restoration-point tiles
        go back to) its OWN client's :class:`FeatureCache`, so co-batched
        sessions never see each other's features.  Returns per-frame
        detection lists.
        """
        assert len(plans) == len(caches) == len(frame_ids) == \
            frames.shape[0]
        return self.infer_wave(frames, plans, beta, caches=caches,
                               frame_ids=frame_ids,
                               capture_beta=capture_beta)


# ---------------------------------------------------------------------------
# event-driven multi-client engine


@dataclass
class EdgeConfig:
    max_batch: int = 8
    # serving mode: batched waves vs. one-job-at-a-time (the sequential
    # baseline bench_multiclient.py compares against)
    batched: bool = True
    # marginal service time of each extra frame in a wave, as a fraction
    # of the solo inference delay: service = t_inf * (1 + alpha * (B-1)).
    # alpha < 1 is the batching win; alpha = 1 degenerates to sequential.
    # (wave compatibility buckets come from the server's n_buckets —
    # they MUST match infer_wave's bucketing, so there is no knob here)
    batch_alpha: float = 0.35
    # cross-bucket wave coalescing: promote a pending job from a larger
    # n_low bucket into the forming wave's smaller bucket when the
    # queueing delay saved exceeds the extra compute (cost model below)
    coalesce: bool = False
    # keep full per-job detection lists in EdgeStats.jobs (benchmarks
    # opt in; long simulations must not grow without bound)
    keep_dets: bool = False
    # edge-side admission control: when the queue is hot, first DEGRADE
    # incoming jobs (promote FULL regions to LOW so the job drops a
    # length bucket — the coalescing cost model's flops scaling prices
    # the new service time), then SHED with an explicit REJECTED
    # response the client handles by tracking locally
    admission: bool = False
    degrade_depth: int = 4           # pending jobs before degrading
    shed_depth: int = 10             # pending jobs before shedding
    degrade_backlog_s: float = 1.0   # or replica backlog seconds
    shed_backlog_s: float = 2.5
    degrade_beta: int = 2            # restoration point degraded
    #                                  full-res jobs restore at
    # crash-restart shortcut for benches: model the outage in sim time
    # but keep host-process executables warm (tests pin the real wipe)
    preserve_executables: bool = False


@dataclass
class EdgeStats:
    """Edge-side telemetry: wave sizes, queueing, and per-job outcomes."""
    wave_sizes: List[int] = field(default_factory=list)
    queue_delays: List[float] = field(default_factory=list)
    jobs: List[Dict] = field(default_factory=list)
    promoted: int = 0            # jobs coalesced across length buckets
    # distinct n_low values per wave: > 1 means plans with different
    # region counts shared ONE executable (the collapsed-grid win)
    wave_n_low_mix: List[int] = field(default_factory=list)
    # robustness telemetry
    degraded: int = 0            # jobs admission control degraded
    shed: int = 0                # jobs REJECTED at admission
    restarts: int = 0            # crash-restarts of the replica
    stale_nacks: int = 0         # REUSE jobs refused on epoch mismatch
    lost_jobs: int = 0           # jobs that died with the replica

    @property
    def mean_wave_size(self) -> float:
        return float(np.mean(self.wave_sizes)) if self.wave_sizes else 0.0

    @property
    def mixed_plan_waves(self) -> int:
        """Waves that batched >= 2 distinct n_low values."""
        return sum(1 for m in self.wave_n_low_mix if m > 1)


class MultiClientSimulation:
    """N device streams -> one shared edge replica.

    clients: per-stream :class:`Simulation` objects (build them with
    this same replica as their ``server`` so a standalone N=1 run uses
    identical weights).  ``on_complete(client_idx, job)`` fires as each
    offload's result reaches its client.
    """

    def __init__(self, clients: Sequence[Simulation],
                 server: BatchedServerModel,
                 ec: Optional[EdgeConfig] = None,
                 on_complete: Optional[Callable[[int, Dict], None]] = None,
                 faults: Optional[FaultInjector] = None):
        assert clients, "need at least one client"
        self.clients = list(clients)
        self.server = server
        self.ec = ec or EdgeConfig()
        self.on_complete = on_complete
        # edge-plane fault schedule (crash-restarts, service stalls,
        # arrivals into an outage).  Network/response-plane faults
        # belong on the CLIENTS' injectors — keep the planes on separate
        # injectors or edge stalls would be double-counted.
        self.faults = faults
        self.dt = self.clients[0].dt
        assert all(c.dt == self.dt for c in self.clients), \
            "clients must share a frame rate"
        self.pending: List[Tuple[int, Dict]] = []   # (client_idx, job)
        self.free_at = 0.0                          # replica busy horizon
        # a wave can never exceed the largest batch bucket — padding
        # only rounds UP, so an oversized wave would have no executable
        self.max_wave = min(self.ec.max_batch, max(self.server.b_buckets))
        if self.max_wave < self.ec.max_batch:
            warnings.warn(
                f"EdgeConfig.max_batch={self.ec.max_batch} exceeds the "
                f"server's largest batch bucket "
                f"{max(self.server.b_buckets)}; waves are capped at "
                f"{self.max_wave} — raise b_buckets to serve bigger "
                f"waves", stacklevel=2)
        self.stats = EdgeStats()

    # ------------------------------------------------------------------
    def _enqueue(self, ci: int, job: Dict) -> None:
        """Insert a job keeping ``pending`` sorted by edge arrival time —
        the scheduler never re-sorts (satellite fix: the old per-tick
        sort was O(n log n) on every frame even when nothing arrived).

        Admission control happens here, at arrival: under queue pressure
        the job is first degraded (FULL -> LOW), and past the shed
        threshold it is REJECTED outright — an explicit response the
        client's completion path turns into tracker-only rendering plus
        a backed-off degraded retry."""
        if self.faults is not None and self.faults.edge_down(
                job["arrival"]):
            # arrived at a crashed replica: never answered
            job["lost"] = True
            job["done_at"] = float("inf")
            self.stats.lost_jobs += 1
            return
        if self.ec.admission:
            depth = len(self.pending)
            backlog = max(self.free_at - job["arrival"], 0.0)
            if depth >= self.ec.shed_depth \
                    or backlog >= self.ec.shed_backlog_s:
                job["rejected"] = True
                job["done_at"] = job["arrival"] + job["rtt"]
                job["dets"] = []
                self.stats.shed += 1
                return
            if (depth >= self.ec.degrade_depth
                    or backlog >= self.ec.degrade_backlog_s) \
                    and self._degrade_job(ci, job):
                self.stats.degraded += 1
        bisect.insort(self.pending, (ci, job),
                      key=lambda cj: cj[1]["arrival"])

    def _degrade_job(self, ci: int, job: Dict) -> bool:
        """Promote FULL regions of an arriving job to LOW so it drops at
        least one length bucket — the payload is already uploaded, so
        this buys edge COMPUTE (shorter sequence), priced by the same
        ``backbone_flops_windows`` scaling the coalescer uses.  REUSE
        regions are untouched.  Returns True if the job changed."""
        part = self.server.part
        plan: RegionPlan = job["plan"]
        states = np.asarray(plan.states).copy()
        full_ids = np.nonzero(states == FULL)[0]
        if len(full_ids) == 0:
            return False
        dd = part.windows_per_full_region
        nw = part.n_windows(plan.n_low, plan.n_reuse)
        # current effective length: the dedicated full-res executable
        # runs the full sequence; mixed plans run at their bucket
        lb_cur = (nw if plan.n_low == 0 and plan.n_reuse == 0
                  else self.server.length_bucket(nw))
        nw_min = nw - len(full_ids) * (dd - 1)
        targets = [e for e in self.server.length_edges
                   if nw_min <= e < lb_cur]
        if not targets:
            return False
        target = max(targets)            # one bucket down: degrade least
        k = int(np.ceil((nw - target) / (dd - 1)))
        states[full_ids[:k]] = LOW
        new_plan = RegionPlan(states.astype(np.int8))
        beta = int(job["beta"]) if int(job["beta"]) >= 1 \
            else self.ec.degrade_beta
        f_own = vb.backbone_flops_windows(
            self.server.cfg, lb_cur,
            int(job["beta"]) if plan.n_low or plan.n_reuse else 0)
        f_new = vb.backbone_flops_windows(self.server.cfg, target, beta)
        job["t_inf_exec"] = job["t_inf"] * (f_new / f_own)
        job["plan"] = new_plan
        job["mask"] = new_plan.low_mask()
        job["n_d"] = int(new_plan.n_low)
        job["beta"] = beta
        job["t_dec"] = self.clients[ci].delay_model.decode_delay(
            part, new_plan.n_low, n_reuse=new_plan.n_reuse)
        job["edge_degraded"] = True
        return True

    def _job_key(self, job: Dict) -> Tuple[int, int, int]:
        """Wave compatibility: (length bucket, beta, capture point) —
        the collapsed executable key.  (n_low, n_reuse) are runtime
        data, so any plan mix at one length bucket co-batches; mixed
        executables always capture (capture == beta), so sessionful and
        stateless jobs co-batch too.  Full-res jobs (length bucket 0)
        keep the dedicated full-res executable at the deployment's
        canonical capture point."""
        plan: RegionPlan = job["plan"]
        lb = self.server.plan_length_bucket(plan)
        if lb == 0:
            want = (job.get("capture_beta", 0)
                    if self.clients[self._client_of(job)].feature_cache
                    is not None else 0)
            return (0, 0, self.server._full_cap(want))
        beta = job["beta"]
        return (lb, beta, beta)

    def _client_of(self, job: Dict) -> int:
        return job["_client"]

    # ------------------------------------------------------------------
    # cross-bucket coalescing cost model

    def _wave_service_s(self, wave: List[Tuple[int, Dict]]) -> float:
        """Modelled service time of a wave (decode + amortised infer)."""
        B = len(wave)
        t_dec = max(j["t_dec"] for _, j in wave)
        t_inf = max(j.get("t_inf_exec", j["t_inf"]) for _, j in wave)
        if B > 1:
            t_inf = t_inf * (1.0 + self.ec.batch_alpha * (B - 1))
        return t_dec + t_inf

    def _try_promote(self, job: Dict, jk: Tuple[int, int, int],
                     hk: Tuple[int, int, int],
                     wave: List[Tuple[int, Dict]]) -> bool:
        """Coalesce ``job`` (key ``jk``) into a wave of key ``hk``.

        Only padding UP is ever legal: the job's plan is untouched, its
        sequence is merely padded to the wave's LARGER length bucket —
        zero resolution changes, zero accuracy question (pad windows are
        masked/inert).  The restoration point shapes the executable, so
        beta must match outright; full-res jobs (length bucket 0) keep
        their dedicated executable and are never promoted.  Promotes iff
        the queueing delay the job avoids (waiting out this wave's
        service) exceeds the extra compute it buys: the padded-length
        flops-scaled inference-time increase plus its ``batch_alpha``
        marginal share of the wave.
        """
        lb_w, beta_w, cap_w = hk
        lb_j, beta_j, cap_j = jk
        if not (beta_j == beta_w and cap_j == cap_w
                and 0 < lb_j < lb_w):
            return False
        cfg = self.server.cfg
        f_own = vb.backbone_flops_windows(cfg, lb_j, beta_j)
        f_new = vb.backbone_flops_windows(cfg, lb_w, beta_w)
        t_inf_new = job["t_inf"] * (f_new / f_own)
        extra = (t_inf_new - job["t_inf"]) \
            + self.ec.batch_alpha * t_inf_new
        saved = self._wave_service_s(wave)
        if saved <= extra:
            return False
        job["t_inf_exec"] = t_inf_new
        job["promoted_lb"] = lb_w
        self.stats.promoted += 1
        return True

    # ------------------------------------------------------------------
    def _run_wave(self, wave: List[Tuple[int, Dict]], t_start: float,
                  key: Tuple[int, int, int]) -> float:
        """Batched inference + Eq. (2) bookkeeping for one wave.
        Returns the time the replica frees up."""
        lb, beta, cap = key
        live = []
        for ci, job in wave:
            cache = self.clients[ci].feature_cache
            if job["plan"].n_reuse > 0 and cache is not None \
                    and getattr(cache, "epoch", 0) != self.server.epoch:
                # REUSE against tiles captured under a dead replica:
                # instant control-plane NACK, never a splice — the
                # client invalidates and bootstraps FULL at the new
                # epoch (completion path handles it)
                job["stale_epoch"] = True
                job["done_at"] = t_start + job["rtt"]
                job["dets"] = []
                self.server.stats.stale_epoch_rejects += 1
                self.stats.stale_nacks += 1
                continue
            live.append((ci, job))
        if not live:
            return self.free_at
        wave = live
        imgs = np.stack([j["decoded"] for _, j in wave])
        plans = [j["plan"] for _, j in wave]
        caches = [self.clients[ci].feature_cache for ci, _ in wave]
        want_cap = 0
        if lb == 0:
            # full-res waves carry per-job capture intent: a sessionful
            # job that did NOT ask for capture shares the (capturing)
            # canonical executable but must not have its cache
            # refreshed — drop its cache from the wave.  Capturing jobs
            # in one wave share a single want (the wave key separates
            # distinct nonzero capture points).
            wants = [j.get("capture_beta", 0) if c is not None else 0
                     for c, (_, j) in zip(caches, wave)]
            want_cap = max(wants)
            caches = [c if w > 0 else None
                      for c, w in zip(caches, wants)]
        if cap or any(c is not None for c in caches):
            dets = self.server.infer_wave(
                imgs, plans, beta, caches=caches,
                frame_ids=[j["frame"] for _, j in wave],
                capture_beta=want_cap if lb == 0 else 0,
                lb_override=lb if lb > 0 else None)
        else:
            dets = self.server.infer_wave(
                imgs, plans, beta,
                lb_override=lb if lb > 0 else None)

        B = len(wave)
        t_dec = max(j["t_dec"] for _, j in wave)
        t_inf = max(j.get("t_inf_exec", j["t_inf"]) for _, j in wave)
        if B > 1:
            t_inf = t_inf * (1.0 + self.ec.batch_alpha * (B - 1))
        if self.faults is not None:
            # edge service stall (GC pause / preemption) for work
            # starting inside the stall window
            t_inf = t_inf + self.faults.stall_extra(t_start)
        done = t_start + t_dec + t_inf

        self.stats.wave_sizes.append(B)
        self.stats.wave_n_low_mix.append(
            len({p.n_low for p in plans}))
        for (ci, job), d in zip(wave, dets):
            q = t_start - job["arrival"]
            self.clients[ci]._finish_offload(job, d, queue_delay=q,
                                             t_dec=t_dec, t_inf=t_inf)
            self.stats.queue_delays.append(q)
            rec = {"client": ci, "frame": job["frame"], "wave_size": B,
                   "queue": q, "e2e": job["e2e"],
                   "promoted": "promoted_lb" in job}
            if self.ec.keep_dets:
                rec["dets"] = d
            self.stats.jobs.append(rec)
        return done

    def _drain(self, now: float) -> None:
        """Schedule every wave that can START before ``now``.

        The replica serves one wave at a time.  When it frees up, the
        earliest-arrived pending job seeds a wave; compatible jobs
        (same (n_low bucket, n_reuse bucket, beta, capture)) that have
        ALREADY arrived join it, up to ``max_batch`` — plus, with
        coalescing on, arrived jobs from LARGER n_low buckets whose
        promotion the cost model approves.  ``pending`` is kept sorted
        on insert (:meth:`_enqueue`); the loop only ever removes jobs,
        and the kept remainder is a subsequence, so order is preserved
        without re-sorting.
        """
        if any(j.get("abandoned") for _, j in self.pending):
            # the client gave up on these (deadline) — don't serve them
            self.pending = [cj for cj in self.pending
                            if not cj[1].get("abandoned")]
        while self.pending:
            head = self.pending[0]
            t_start = max(self.free_at, head[1]["arrival"])
            if t_start >= now:
                return
            hk = self._job_key(head[1])
            wave, rest = [head], []
            for cj in self.pending[1:]:
                joinable = (self.ec.batched
                            and len(wave) < self.max_wave
                            and cj[1]["arrival"] <= t_start)
                if joinable:
                    jk = self._job_key(cj[1])
                    joinable = jk == hk or (
                        self.ec.coalesce
                        and self._try_promote(cj[1], jk, hk, wave))
                if joinable:
                    wave.append(cj)
                else:
                    rest.append(cj)
            self.pending = rest
            self.free_at = self._run_wave(wave, t_start, hk)

    # ------------------------------------------------------------------
    def _edge_fault_tick(self, prev: float, now: float) -> None:
        """Apply the shared replica's crash-restarts: bump the cache
        epoch (wiping executables unless the bench shortcut keeps them),
        hold the replica down for the outage, and lose the queue — jobs
        pending in a crashed process are never answered; their clients'
        deadlines reap them."""
        if self.faults is None:
            return
        for (r, outage) in self.faults.restarts_between(prev, now):
            self.server.restart(
                preserve_executables=self.ec.preserve_executables)
            self.stats.restarts += 1
            self.free_at = max(self.free_at, r + outage)
            for ci, job in self.pending:
                job["lost"] = True
                job["done_at"] = float("inf")
            self.stats.lost_jobs += len(self.pending)
            self.pending = []

    # ------------------------------------------------------------------
    def run(self, video_names: Optional[Sequence[str]] = None
            ) -> List[SimResult]:
        """Run all streams to completion.  Returns per-client results."""
        names = (list(video_names) if video_names is not None
                 else [f"client{i}" for i in range(len(self.clients))])
        results = [SimResult(policy=c.policy.name, video=names[i],
                             trace=getattr(c.trace, "name", "trace"))
                   for i, c in enumerate(self.clients)]

        n_max = max(len(c.frames) for c in self.clients)
        prev = -1.0
        for fi in range(n_max):
            now = fi * self.dt
            self._edge_fault_tick(prev, now)
            self._drain(now)
            for ci, c in enumerate(self.clients):
                if fi >= len(c.frames):
                    continue
                c._motion_tick(fi, results[ci])
                job = c._poll_inflight(now, fi, results[ci])
                if job is not None and self.on_complete:
                    self.on_complete(ci, job)
                if c._should_offload(fi):
                    c._note_offload_gap(fi, results[ci])
                    job = c._prepare_offload(fi, now, results[ci])
                    # arrival at the edge: encode + uplink transfer
                    job["arrival"] = now + job["t_enc"] + job["t_up"]
                    job["_client"] = ci
                    self._enqueue(ci, job)
                c._render_tick(fi, results[ci])
            prev = now

        # end of all clips: run the edge dry and flush in-flight
        # offloads (each client's deadline still applies)
        self._drain(float("inf"))
        for ci, c in enumerate(self.clients):
            job = c._poll_inflight(float("inf"), len(c.frames),
                                   results[ci])
            if job is not None and self.on_complete:
                self.on_complete(ci, job)
        return results
