"""Serving request/response types + per-client feature-cache sessions.

:class:`FeatureCache` is the session state behind temporal region reuse
(core.partition.RegionPlan): one cache per client stream, holding the
per-region backbone-feature tiles captured at the restoration point of
that client's previous offload, plus the bookkeeping that bounds
staleness — a region may be reused at most ``max_age`` (the K of the
README's state machine) CONSECUTIVE offloads before it must be
transmitted (FULL/LOW) again.  The vision edge (serve/edge.py,
offload/simulator.py) stores real tiles; the sequence engine
(serve/engine.py) uses the same bookkeeping tiles-free to gate and
bucket reuse spans.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


class StaleCacheEpoch(RuntimeError):
    """A REUSE plan tried to splice tiles captured under a cache epoch
    that died with a restarted replica.  The server refuses the splice
    (the invariant: no splice ever reads tiles from a dead replica); the
    client must invalidate its FeatureCache and bootstrap FULL again.
    """


@dataclass
class ServingStats:
    """Replica-side serving telemetry: the compile surface and tile
    traffic of the bucketed-executable hot path.

    ``warmed`` flips once :meth:`warmup` has compiled the executable
    grid; every compile after that is a steady-state stall — exactly the
    p95 spike warmup exists to remove — so tests and bench_serving treat
    ``steady_compiles > 0`` as a failure, not a perf footnote.  The tile
    byte counters account the host<->device traffic of the temporal-
    reuse FeatureCache (zero in device-resident mode).
    """
    compiles: int = 0
    steady_compiles: int = 0
    steady_compile_keys: List[Tuple] = field(default_factory=list)
    warmed: bool = False
    warmup_wall_s: float = 0.0
    offloads: int = 0
    tile_bytes_d2h: int = 0
    tile_bytes_h2d: int = 0
    # robustness telemetry: crash-restarts of this replica, reuse
    # splices actually served, and splices REFUSED because the client's
    # tiles were captured under a pre-restart epoch (StaleCacheEpoch) —
    # bench_robustness gates on stale splices SERVED staying zero, which
    # is structural: a mismatched epoch always raises before splicing
    restarts: int = 0
    reuse_splices: int = 0
    stale_epoch_rejects: int = 0

    @property
    def tile_bytes(self) -> int:
        return self.tile_bytes_d2h + self.tile_bytes_h2d

    def tile_bytes_per_offload(self) -> float:
        return self.tile_bytes / max(self.offloads, 1)

    def note_compile(self, key: Tuple) -> None:
        """Record one executable compile; after warmup it counts as a
        steady-state stall."""
        self.compiles += 1
        if self.warmed:
            self.steady_compiles += 1
            self.steady_compile_keys.append(key)

    def finish_warmup(self, t0: float, compiles_before: int,
                      now: float) -> int:
        """Close a warmup pass: flip ``warmed``, account its wall time,
        return the number of executables it compiled."""
        self.warmed = True
        self.warmup_wall_s += now - t0
        return self.compiles - compiles_before


@dataclass
class FeatureCache:
    """Per-client cached restoration-point feature tiles + reuse ages.

    ``tiles``: (n_regions, d^2, w^2, D) window-blocked per-region tiles
    (None until the first capture, and always None for bookkeeping-only
    sessions such as the sequence engine's).  Tiles are stored in
    whatever residence the server hands them: the serving hot path keeps
    them as DEVICE (jax) arrays so reuse gathers and capture refreshes
    never cross PCIe (core.mixed_res.gather_tiles / refresh_tiles, the
    stale buffer donated on update); host numpy tiles remain supported
    as the legacy / debugging mode.  ``beta``: the restoration point the
    tiles were captured at — reuse is only valid at the SAME restoration
    point.  ``age[j]``: consecutive offloads region j has been reused;
    at ``max_age`` (K) the region is forced back to FULL/LOW.
    ``epoch``: the replica generation the tiles were captured under
    (ServerModel.epoch); a restart bumps the replica's generation, so a
    REUSE plan carrying an old epoch is refused (StaleCacheEpoch) and
    the client must :meth:`invalidate` and bootstrap FULL again.

    Speculative REUSE execution additionally keeps a **prediction
    source** per session: the last payload the edge decoded for this
    client (``pred_frame`` at ``pred_frame_idx``, captured under
    ``pred_epoch``).  A speculative forward substitutes the in-flight
    LOW/FULL regions' pixels with this frame's; :meth:`pred_ok` gates
    the substitution on the SAME staleness bound K (``max_age``, in
    offloads — the prediction buffer refreshes once per served offload,
    so offload count is its native clock) and on the epoch invariant —
    a speculative result must never render from a stale epoch.
    """
    n_regions: int
    max_age: int = 4
    beta: int = -1
    tiles: Optional[np.ndarray] = None
    age: np.ndarray = None
    frame: int = -1
    warm: bool = False
    epoch: int = 0
    # speculative-prediction source (edge-side): the last decoded canvas
    # served for this session + the replica generation that decoded it
    pred_frame: Optional[np.ndarray] = None
    pred_frame_idx: int = -1
    pred_age: int = 0
    pred_epoch: int = -1
    # False on speculative clones: the tiles buffer is SHARED with the
    # real session cache, so update() must not donate it to XLA (the
    # clone owns its buffer again after its first refresh)
    owns_tiles: bool = True

    def __post_init__(self):
        if self.age is None:
            self.age = np.zeros((self.n_regions,), np.int32)

    # ------------------------------------------------------------------
    @property
    def tiles_on_device(self) -> bool:
        return self.tiles is not None and not isinstance(self.tiles,
                                                         np.ndarray)

    def eligible(self, beta: int) -> np.ndarray:
        """(n_regions,) bool: regions whose cached tile may be reused for
        an offload restoring at ``beta`` (cache warm, same restoration
        point, staleness bound not yet hit)."""
        if not self.warm or beta < 1 or beta != self.beta:
            return np.zeros((self.n_regions,), bool)
        return self.age < self.max_age

    def gather(self, reuse_ids: np.ndarray) -> np.ndarray:
        """(n_reuse, d^2, w^2, D) tiles for the plan's reuse set, in the
        cache's residence (a device gather never touches the host)."""
        assert self.tiles is not None, "cache holds no tiles yet"
        if self.tiles_on_device:
            from repro.core import mixed_res as mr
            import jax.numpy as jnp
            return mr.gather_tiles(self.tiles,
                                   jnp.asarray(reuse_ids, jnp.int32))
        return self.tiles[np.asarray(reuse_ids, np.int64)]

    def expire(self, ids) -> None:
        """Force regions out of the reuse-eligible set (age pinned to
        ``max_age``) without dropping their tiles: used for regions the
        degradation ladder transmitted at LOW fidelity — a stopgap, not
        a durable splice source.  They re-enter reuse only after a
        genuine FULL re-transmission resets their age."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        self.age[ids] = self.max_age

    def invalidate(self) -> None:
        """Drop every cached tile and the warm flag — the edge replied
        StaleCacheEpoch (or the session is otherwise dead); the next
        offload must be a FULL bootstrap."""
        self.tiles = None
        self.age = np.zeros((self.n_regions,), np.int32)
        self.beta = -1
        self.frame = -1
        self.warm = False
        self.pred_frame = None
        self.pred_frame_idx = -1
        self.pred_age = 0
        self.pred_epoch = -1

    # ------------------------------------------------------------------
    # speculative-prediction source

    def note_pred(self, frame: np.ndarray, frame_idx: int,
                  epoch: int) -> None:
        """Record a served offload's decoded canvas as the session's
        prediction source (resets the prediction-staleness clock)."""
        self.pred_frame = frame
        self.pred_frame_idx = int(frame_idx)
        self.pred_age = 0
        self.pred_epoch = int(epoch)

    def pred_ok(self, epoch: int) -> bool:
        """May the stored prediction source seed a speculative forward?

        Requires a source, the staleness bound K (``max_age`` offloads
        since the source was decoded — the same K that bounds tile
        reuse), and the epoch invariant: a source decoded by a dead
        replica generation predicts nothing about the live one."""
        return (self.pred_frame is not None
                and self.pred_age < self.max_age
                and self.pred_epoch == int(epoch))

    def speculative_clone(self) -> "FeatureCache":
        """A session clone for a speculative forward to capture into.

        Shares the tile buffer (gathers never mutate it) but does NOT
        own it — the clone's first refresh allocates instead of donating
        the shared device buffer — so a discarded speculation leaves the
        real session byte-identical.  Commit via
        :meth:`commit_speculative`.
        """
        clone = FeatureCache(self.n_regions, max_age=self.max_age,
                             beta=self.beta, tiles=self.tiles,
                             age=self.age.copy(), frame=self.frame,
                             warm=self.warm, epoch=self.epoch,
                             owns_tiles=False)
        return clone

    def commit_speculative(self, clone: "FeatureCache",
                           reuse_ids: np.ndarray, beta: int, frame: int,
                           epoch: int) -> None:
        """Adopt a resolved speculation's tiles into the real session.

        ``reuse_ids``: the regions whose content derives from reuse or
        from the (converged) prediction rather than freshly transmitted
        pixels — their age advances by ONE from this cache's own
        pre-speculation ages (the clone's intermediate refreshes during
        the speculative and patch forwards are bookkeeping noise), so
        prediction-derived regions burn the staleness budget exactly
        like spliced ones and K still forces a real re-transmission.
        """
        self.tiles = clone.tiles
        self.note(reuse_ids, beta, frame, epoch=epoch)

    # ------------------------------------------------------------------
    def note(self, reuse_ids: np.ndarray, beta: int, frame: int,
             epoch: Optional[int] = None) -> None:
        """Bookkeeping-only refresh: regions in ``reuse_ids`` were reused
        this offload (age + 1), every other region was transmitted
        (age reset to 0).  ``epoch``: the replica generation serving the
        refresh (None keeps the current one — legacy callers)."""
        ids = np.asarray(reuse_ids, np.int64).reshape(-1)
        new_age = np.zeros((self.n_regions,), np.int32)
        new_age[ids] = self.age[ids] + 1
        self.age = new_age
        self.beta = int(beta)
        self.frame = int(frame)
        self.warm = True
        if epoch is not None:
            self.epoch = int(epoch)
        if self.pred_frame is not None:
            # prediction staleness advances per served offload; a
            # subsequent note_pred (the serving path records the new
            # decoded canvas right after the refresh) resets it
            self.pred_age += 1

    def update(self, tiles, reuse_ids: np.ndarray,
               beta: int, frame: int, epoch: Optional[int] = None) -> None:
        """Full refresh after a forward that captured tiles.

        Device tiles stay on device; when the cache already holds a
        same-shaped device buffer the refresh donates the stale buffer
        (mixed_res.refresh_tiles) so steady-state reuse serving never
        grows the live set.  Host (numpy) tiles keep the legacy
        host-resident behaviour.
        """
        if isinstance(tiles, np.ndarray):
            self.tiles = tiles
        else:
            if (self.owns_tiles and self.tiles_on_device
                    and self.tiles.shape == tiles.shape
                    and self.tiles.dtype == tiles.dtype):
                from repro.core import mixed_res as mr
                self.tiles = mr.refresh_tiles(self.tiles, tiles)
            else:
                # a speculative clone's first refresh: the stale buffer
                # is shared with the real session, so allocate instead
                # of donating it — the clone owns this one
                self.tiles = tiles
        self.owns_tiles = True
        self.note(reuse_ids, beta, frame, epoch=epoch)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # paper technique: spans of the prompt that may be pooled at prefill
    low_span_mask: Optional[np.ndarray] = None
    beta: int = 0
    arrival_time: float = 0.0
    # temporal reuse: the client's session identity and the spans it
    # claims unchanged since its previous request (serve/engine.py gates
    # them against the per-client FeatureCache staleness bound)
    client_id: int = -1
    reuse_span_mask: Optional[np.ndarray] = None

    def _spans(self, mask: Optional[np.ndarray],
               n: Optional[int]) -> np.ndarray:
        if mask is None or self.beta <= 0:
            return np.zeros((0,), np.int32)
        sel = np.nonzero(np.asarray(mask).reshape(-1) != 0)[0]
        if n is not None:
            sel = sel[:n]
        return sel.astype(np.int32)

    def low_spans(self, n_low: Optional[int] = None) -> np.ndarray:
        """Span indices actually pooled, in selection order.

        ``n_low``: static bucket — extra selections beyond it are dropped
        (the same trimming rule as seq_mixed_res.build_seq_pack), so the
        returned ids are the pack's identity: two requests with equal
        ``low_spans(n_low)`` produce byte-identical packs and may share a
        wave.
        """
        return self._spans(self.low_span_mask, n_low)

    def reuse_spans(self, n_reuse: Optional[int] = None) -> np.ndarray:
        """Span indices the client marked temporally reusable, with the
        same bucket-trimming rule as :meth:`low_spans`."""
        return self._spans(self.reuse_span_mask, n_reuse)

    def mask_key(self, n_low: Optional[int] = None,
                 reuse_ids: Optional[np.ndarray] = None) -> bytes:
        """Canonical wave-key bytes of the (bucket-trimmed) span layout.

        ``reuse_ids``: the EFFECTIVE reuse spans (after the engine's
        session-staleness gate) — part of the identity because co-batched
        requests must share one pack layout.
        """
        key = self.low_spans(n_low).tobytes()
        if reuse_ids is not None and len(reuse_ids):
            key += b"|" + np.asarray(reuse_ids, np.int32).tobytes()
        return key


@dataclass
class Response:
    rid: int
    tokens: List[int] = field(default_factory=list)
    prefill_done: float = 0.0
    finished: float = 0.0
    slot: int = -1

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)
