"""Serving request/response types + per-client feature-cache sessions.

:class:`FeatureCache` is the session state behind temporal region reuse
(core.partition.RegionPlan): one cache per client stream, holding the
per-region backbone-feature tiles captured at the restoration point of
that client's previous offload, plus the bookkeeping that bounds
staleness — a region may be reused at most ``max_age`` (the K of the
README's state machine) CONSECUTIVE offloads before it must be
transmitted (FULL/LOW) again.  The vision edge (serve/edge.py,
offload/simulator.py) stores real tiles; the sequence engine
(serve/engine.py) uses the same bookkeeping tiles-free to gate and
bucket reuse spans.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class FeatureCache:
    """Per-client cached restoration-point feature tiles + reuse ages.

    ``tiles``: (n_regions, d^2, w^2, D) window-blocked per-region tiles
    (None until the first capture, and always None for bookkeeping-only
    sessions such as the sequence engine's).  ``beta``: the restoration
    point the tiles were captured at — reuse is only valid at the SAME
    restoration point.  ``age[j]``: consecutive offloads region j has
    been reused; at ``max_age`` (K) the region is forced back to
    FULL/LOW.
    """
    n_regions: int
    max_age: int = 4
    beta: int = -1
    tiles: Optional[np.ndarray] = None
    age: np.ndarray = None
    frame: int = -1
    warm: bool = False

    def __post_init__(self):
        if self.age is None:
            self.age = np.zeros((self.n_regions,), np.int32)

    # ------------------------------------------------------------------
    def eligible(self, beta: int) -> np.ndarray:
        """(n_regions,) bool: regions whose cached tile may be reused for
        an offload restoring at ``beta`` (cache warm, same restoration
        point, staleness bound not yet hit)."""
        if not self.warm or beta < 1 or beta != self.beta:
            return np.zeros((self.n_regions,), bool)
        return self.age < self.max_age

    def gather(self, reuse_ids: np.ndarray) -> np.ndarray:
        """(n_reuse, d^2, w^2, D) tiles for the plan's reuse set."""
        assert self.tiles is not None, "cache holds no tiles yet"
        return self.tiles[np.asarray(reuse_ids, np.int64)]

    # ------------------------------------------------------------------
    def note(self, reuse_ids: np.ndarray, beta: int, frame: int) -> None:
        """Bookkeeping-only refresh: regions in ``reuse_ids`` were reused
        this offload (age + 1), every other region was transmitted
        (age reset to 0)."""
        ids = np.asarray(reuse_ids, np.int64).reshape(-1)
        new_age = np.zeros((self.n_regions,), np.int32)
        new_age[ids] = self.age[ids] + 1
        self.age = new_age
        self.beta = int(beta)
        self.frame = int(frame)
        self.warm = True

    def update(self, tiles: np.ndarray, reuse_ids: np.ndarray,
               beta: int, frame: int) -> None:
        """Full refresh after a forward that captured tiles."""
        self.tiles = np.asarray(tiles)
        self.note(reuse_ids, beta, frame)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # paper technique: spans of the prompt that may be pooled at prefill
    low_span_mask: Optional[np.ndarray] = None
    beta: int = 0
    arrival_time: float = 0.0
    # temporal reuse: the client's session identity and the spans it
    # claims unchanged since its previous request (serve/engine.py gates
    # them against the per-client FeatureCache staleness bound)
    client_id: int = -1
    reuse_span_mask: Optional[np.ndarray] = None

    def _spans(self, mask: Optional[np.ndarray],
               n: Optional[int]) -> np.ndarray:
        if mask is None or self.beta <= 0:
            return np.zeros((0,), np.int32)
        sel = np.nonzero(np.asarray(mask).reshape(-1) != 0)[0]
        if n is not None:
            sel = sel[:n]
        return sel.astype(np.int32)

    def low_spans(self, n_low: Optional[int] = None) -> np.ndarray:
        """Span indices actually pooled, in selection order.

        ``n_low``: static bucket — extra selections beyond it are dropped
        (the same trimming rule as seq_mixed_res.build_seq_pack), so the
        returned ids are the pack's identity: two requests with equal
        ``low_spans(n_low)`` produce byte-identical packs and may share a
        wave.
        """
        return self._spans(self.low_span_mask, n_low)

    def reuse_spans(self, n_reuse: Optional[int] = None) -> np.ndarray:
        """Span indices the client marked temporally reusable, with the
        same bucket-trimming rule as :meth:`low_spans`."""
        return self._spans(self.reuse_span_mask, n_reuse)

    def mask_key(self, n_low: Optional[int] = None,
                 reuse_ids: Optional[np.ndarray] = None) -> bytes:
        """Canonical wave-key bytes of the (bucket-trimmed) span layout.

        ``reuse_ids``: the EFFECTIVE reuse spans (after the engine's
        session-staleness gate) — part of the identity because co-batched
        requests must share one pack layout.
        """
        key = self.low_spans(n_low).tobytes()
        if reuse_ids is not None and len(reuse_ids):
            key += b"|" + np.asarray(reuse_ids, np.int32).tobytes()
        return key


@dataclass
class Response:
    rid: int
    tokens: List[int] = field(default_factory=list)
    prefill_done: float = 0.0
    finished: float = 0.0
    slot: int = -1

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)
