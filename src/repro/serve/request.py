"""Serving request/response types."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # paper technique: spans of the prompt that may be pooled at prefill
    low_span_mask: Optional[np.ndarray] = None
    beta: int = 0
    arrival_time: float = 0.0

    def low_spans(self, n_low: Optional[int] = None) -> np.ndarray:
        """Span indices actually pooled, in selection order.

        ``n_low``: static bucket — extra selections beyond it are dropped
        (the same trimming rule as seq_mixed_res.build_seq_pack), so the
        returned ids are the pack's identity: two requests with equal
        ``low_spans(n_low)`` produce byte-identical packs and may share a
        wave.
        """
        if self.low_span_mask is None or self.beta <= 0:
            return np.zeros((0,), np.int32)
        sel = np.nonzero(
            np.asarray(self.low_span_mask).reshape(-1) != 0)[0]
        if n_low is not None:
            sel = sel[:n_low]
        return sel.astype(np.int32)

    def mask_key(self, n_low: Optional[int] = None) -> bytes:
        """Canonical wave-key bytes of the (bucket-trimmed) span mask."""
        return self.low_spans(n_low).tobytes()


@dataclass
class Response:
    rid: int
    tokens: List[int] = field(default_factory=list)
    prefill_done: float = 0.0
    finished: float = 0.0
    slot: int = -1

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)
