"""Serving request/response types."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # paper technique: spans of the prompt that may be pooled at prefill
    low_span_mask: Optional[np.ndarray] = None
    beta: int = 0
    arrival_time: float = 0.0


@dataclass
class Response:
    rid: int
    tokens: List[int] = field(default_factory=list)
    prefill_done: float = 0.0
    finished: float = 0.0
    slot: int = -1

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)
