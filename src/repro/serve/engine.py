"""Batched KV-cache serving engine (wave scheduling, static shapes).

Serving model for MVA-style workloads (DESIGN.md): every offloaded unit
is a full *prefill* (the paper's frame analogy) followed by a bounded
decode.  The engine batches requests into **waves**:

  * requests are grouped by (bucketed prompt length, bucketed n_low,
    bucketed n_reuse, beta, span-layout identity) — static shapes, so
    XLA never retraces per request (the TPU-native adaptation of the
    paper's per-frame dynamic resolution), and co-batched requests share
    the SAME span layout, so one pack is correct for the whole wave;
  * one jitted ``prefill_fn`` per (bucket, bucketed n_low, bucketed
    n_reuse, beta) tuple — the paper's mixed-granularity prefill plugs
    in through ``low_span_mask`` and ``beta`` on the request
    (core.seq_mixed_res);
  * temporal reuse is SESSIONFUL: requests carrying a ``client_id`` get
    a per-client :class:`~repro.serve.request.FeatureCache` whose
    bookkeeping gates ``reuse_span_mask`` — a span may ride the reuse
    discount at most K consecutive requests before it is forced back to
    full granularity (staleness bound).  The sequence prefill has no
    feature splice (tokens are always transmitted), so effective reuse
    spans are conservatively POOLED like low spans; the session/wave
    machinery is shared verbatim with the vision edge (serve/edge.py),
    which does splice cached tiles;
  * greedy decode runs the whole wave in lock-step with per-slot EOS
    masking; finished slots keep decoding (masked) until the wave drains
    below ``refill_fraction`` — the static-shape analogue of continuous
    batching.

Fault hooks: the engine exposes per-wave latencies to the
``DeadlineDispatcher`` (train/straggler.py) so a fleet-level dispatcher
can re-issue requests stuck behind a straggling replica.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seq_mixed_res as smr
from repro.core.partition import bucket_n_low
from repro.kernels import autotune, dispatch
from repro.models import registry
from repro.models.config import ModelConfig
from repro.models.transformer import LOCAL, ParallelCtx
from repro.serve.scheduler import form_wave
from repro.serve.request import (FeatureCache, Request, Response,
                                 ServingStats)


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512                 # prompt + generated
    buckets: Tuple[int, ...] = (64, 128, 256)
    cache_dtype: object = jnp.float32
    greedy: bool = True
    # n_low / n_reuse are rounded down to one of this many bucket edges
    # so the prefill jit-cache stays bounded (partition.bucket_n_low)
    n_low_buckets: int = 4
    # staleness bound K for per-client reuse sessions
    reuse_max_age: int = 4
    # wave sizes are padded UP to these edges so the prefill/decode
    # executable set is bounded in B too (padded slots replicate slot 0
    # and are masked out of the responses) — the same batch-bucketing
    # contract as ServerModel.infer_wave on the vision edge
    b_buckets: Tuple[int, ...] = (1, 2, 4, 8)
    # kernel backend for the prefill/decode executables ("auto" |
    # "pallas" | "xla" | None = process default, kernels.dispatch).  On
    # the Pallas lane the decode step runs through the one-token GQA
    # decode kernel (kernels/decode_attention) instead of the dense
    # masked sdpa.
    backend: Optional[str] = None


class ServeEngine:
    """Single-replica engine over one model's params."""

    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig = None,
                 ctx: ParallelCtx = LOCAL):
        self.cfg = cfg
        self.params = params
        self.sc = sc or ServeConfig()
        self.ctx = ctx
        self.queue: List[Request] = []
        self.responses: Dict[int, Response] = {}
        self._prefill_fns: Dict = {}
        self._decode_fns: Dict = {}
        self.wave_latencies: List[float] = []
        # compile-surface telemetry: every executable is keyed on static
        # shapes (prompt bucket, POOLED length n_low + n_reuse, beta,
        # B bucket — the sequence-side length-bucket collapse), so a
        # key miss is exactly one XLA compile; after warmup() a miss is
        # a steady-state stall (stats.steady_compiles)
        self.stats = ServingStats()
        if self.sc.max_batch > max(self.sc.b_buckets):
            import warnings
            warnings.warn(
                f"ServeConfig.max_batch={self.sc.max_batch} exceeds the "
                f"largest batch bucket {max(self.sc.b_buckets)}; waves "
                f"are capped at the bucket — raise b_buckets to serve "
                f"bigger waves", stacklevel=2)
        # per-client reuse sessions (bookkeeping-only FeatureCaches:
        # the seq prefill transmits every token, so only the staleness
        # state machine applies here)
        self.sessions: Dict[int, FeatureCache] = {}

    def batch_bucket(self, b: int) -> int:
        from repro.core.partition import batch_bucket
        return batch_bucket(b, self.sc.b_buckets)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def session(self, client_id: int, n_spans: int) -> FeatureCache:
        sess = self.sessions.get(client_id)
        if sess is None or sess.n_regions != n_spans:
            sess = FeatureCache(n_spans, max_age=self.sc.reuse_max_age)
            self.sessions[client_id] = sess
        return sess

    def _effective_reuse(self, r: Request) -> np.ndarray:
        """Reuse spans that survive the per-client staleness gate.

        READ-ONLY: wave keying must not create or replace sessions (a
        queued request with a different span geometry would otherwise
        discard another request's warm session just by being keyed).
        Anonymous requests (no client_id), cold/stale sessions, and
        span-geometry mismatches get no reuse; spans also claimed low
        stay low; the survivors are bucketed like low spans so the
        prefill jit-cache stays bounded."""
        spans = r.reuse_spans()
        if spans.shape[0] == 0 or r.client_id < 0 or \
                r.reuse_span_mask is None:
            return np.zeros((0,), np.int32)
        low = set(r.low_spans().tolist())
        spans = np.array([s for s in spans if s not in low], np.int32)
        n_spans = int(np.asarray(r.reuse_span_mask).reshape(-1).shape[0])
        sess = self.sessions.get(r.client_id)
        if sess is None or sess.n_regions != n_spans:
            return np.zeros((0,), np.int32)
        ok = sess.eligible(r.beta)
        spans = spans[ok[spans]] if spans.shape[0] else spans
        n_reuse = bucket_n_low(int(spans.shape[0]), n_spans,
                               self.sc.n_low_buckets)
        return spans[:n_reuse]

    def _bucket(self, n: int) -> int:
        for b in self.sc.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds largest bucket "
                         f"{self.sc.buckets[-1]}")

    # ------------------------------------------------------------------
    def _build_prefill(self, beta: int, mixed: bool) -> Callable:
        cfg, ctx = self.cfg, self.ctx
        backend = self.sc.backend
        # dispatch.backend_scope pins every backend=None dispatch site
        # inside the jit TRACE, so the compiled executable carries the
        # engine's kernel lane without threading a backend argument
        # through the whole registry/transformer stack
        if not mixed:
            def fn(params, tokens, state):
                with dispatch.backend_scope(backend):
                    hidden, state, _ = registry.prefill(
                        cfg, params, {"tokens": tokens}, state, ctx)
                    from repro.models import transformer as tfm
                    logits = tfm.logits_from_hidden(cfg, params,
                                                    hidden[:, -1:, :], ctx)
                return logits, state
        else:
            def fn(params, tokens, state, mix_idx, pos_mix, restore_idx):
                pack = {"mix_idx": mix_idx, "pos_mix": pos_mix,
                        "restore_idx": restore_idx}
                with dispatch.backend_scope(backend):
                    hidden, state, _ = smr.mixed_prefill(
                        cfg, params, tokens, pack, beta, state, ctx)
                    from repro.models import transformer as tfm
                    logits = tfm.logits_from_hidden(cfg, params,
                                                    hidden[:, -1:, :], ctx)
                return logits, state
        return fn

    def _get_prefill(self, T: int, n_pool: int, beta: int,
                     batch: int = 1) -> Callable:
        """Prefill executable for the POOLED-LENGTH key.

        ``n_pool`` is the total pooled span count (n_low + n_reuse): the
        seq pack's static shapes depend on the packed LENGTH
        ``T - n_pool * (span - window)`` only, so keying on the sum
        collapses every (n_low, n_reuse) split of it onto one executable
        — the sequence-side analogue of the vision edge's length-bucket
        grid.  Which spans are pooled stays runtime data (the pack
        arrays), gated per wave by the span-layout identity in
        :meth:`_wave_key`.
        """
        key = ("prefill", T, n_pool, beta, batch)
        if key not in self._prefill_fns:
            mixed = n_pool > 0 and beta > 0
            fn = self._build_prefill(beta, mixed)
            # every argument shape is pinned by the key (tokens (B, T),
            # state from init_decode_state(B), pack sizes from the
            # pooled-length key), so this jit traces exactly once
            self._prefill_fns[key] = jax.jit(fn, donate_argnums=(2,))
            self.stats.note_compile(key)
        return self._prefill_fns[key]

    def _get_decode(self, batch: int = 1) -> Callable:
        key = ("decode", batch)
        if key not in self._decode_fns:
            cfg, ctx = self.cfg, self.ctx

            # pos is a TRACED int32 scalar: the old static_argnums pos
            # recompiled the decode step at EVERY token position — a
            # per-step XLA stall in steady-state serving.  All decode
            # paths index caches dynamically, so one executable serves
            # every position.
            backend = self.sc.backend

            def fn(params, token, pos, state):
                # trace-time backend pin: on the Pallas lane the cache
                # read goes through kernels/decode_attention
                with dispatch.backend_scope(backend):
                    return registry.decode_step(cfg, params, token, pos,
                                                state, ctx)
            self._decode_fns[key] = jax.jit(fn, donate_argnums=(3,))
            self.stats.note_compile(key)
        return self._decode_fns[key]

    # ------------------------------------------------------------------
    def _pack_for(self, T: int, n_low: int, n_reuse: int,
                  mask: Optional[np.ndarray] = None) -> Dict[str,
                                                             np.ndarray]:
        """Build the (shared) seq pack for a wave — or, with no mask, a
        representative pack of the same static shapes (warmup)."""
        part = smr.seq_partition(self.cfg, T)
        if mask is None:
            mask = np.zeros((part.n_spans,), np.int32)
            mask[:n_low + n_reuse] = 1
        return smr.build_seq_pack(mask, n_low + n_reuse, part)

    def warmup(self, prompt_lens: Optional[Tuple[int, ...]] = None,
               plan_space: Optional[List[Tuple[int, int, int]]] = None,
               batch_buckets: Optional[Tuple[int, ...]] = None) -> int:
        """AOT-compile the serving executables off the critical path.

        ``prompt_lens``: prompt buckets to warm (default: all of
        ``sc.buckets``); ``plan_space``: (n_low, n_reuse, beta) mixed-
        prefill shapes on top of the always-warmed plain prefill;
        ``batch_buckets``: wave sizes (default ``sc.b_buckets`` up to
        ``max_batch``).  Returns the number of executables compiled;
        afterwards ``stats.steady_compiles`` counts every further
        compile (a steady-state stall).
        """
        t0 = time.perf_counter()
        before = self.stats.compiles
        cfg, sc = self.cfg, self.sc
        lens = tuple(prompt_lens or sc.buckets)
        if batch_buckets is None:
            # buckets a wave can actually land on: up to the bucket that
            # covers max_batch (a B=max_batch wave pads to that edge)
            cover = self.batch_bucket(min(sc.max_batch,
                                          max(sc.b_buckets)))
            batch_buckets = tuple(b for b in sc.b_buckets if b <= cover)
        batches = tuple(batch_buckets)
        if dispatch.use_pallas(sc.backend):
            # sweep decode-kernel block sizes before any executable is
            # traced so the winners are baked into the compiled graphs
            for B in batches:
                autotune.tune_decode(B, sc.max_len, cfg.n_heads,
                                     cfg.head_dim, KV=cfg.n_kv_heads,
                                     dtype=sc.cache_dtype)
        for B in batches:
            state = registry.init_decode_state(cfg, B, sc.max_len,
                                               sc.cache_dtype)
            decode = self._get_decode(B)
            decode(self.params, jnp.zeros((B, 1), jnp.int32),
                   jnp.asarray(lens[0], jnp.int32), state)
            for T in lens:
                toks = jnp.zeros((B, T), jnp.int32)
                state = registry.init_decode_state(cfg, B, sc.max_len,
                                                   sc.cache_dtype)
                self._get_prefill(T, 0, 0, B)(self.params, toks, state)
                # collapse the plan space onto pooled-length keys:
                # (4, 0) and (0, 4) share one executable
                pools = dict.fromkeys(
                    (n_low + n_reuse, beta)
                    for (n_low, n_reuse, beta) in (plan_space or ())
                    if (n_low + n_reuse) > 0 and beta > 0)
                for (n_pool, beta) in pools:
                    pack = self._pack_for(T, n_pool, 0)
                    state = registry.init_decode_state(cfg, B, sc.max_len,
                                                       sc.cache_dtype)
                    self._get_prefill(T, n_pool, beta, B)(
                        self.params, jnp.zeros((B, T), jnp.int32), state,
                        jnp.asarray(pack["mix_idx"]),
                        jnp.asarray(pack["pos_mix"]),
                        jnp.asarray(pack["restore_idx"]))
        return self.stats.finish_warmup(t0, before, time.perf_counter())

    # ------------------------------------------------------------------
    def _form_wave(self) -> Optional[List[Request]]:
        if not self.queue:
            return None
        # batch formation lives in the scheduling plane: one head-key
        # grouping pass (serve/scheduler.form_wave) shared with the
        # edge wave schedulers — single pass keeps queue order.  Waves
        # are additionally capped at the largest batch bucket — padding
        # only rounds UP, so a larger wave would have no executable.
        cap = min(self.sc.max_batch, max(self.sc.b_buckets))
        wave, rest, _ = form_wave(self.queue, self._wave_key, cap)
        self.queue = rest
        return wave

    def _wave_key(self, r: Request) -> Tuple[int, int, int, int, bytes]:
        """(prompt bucket, bucketed n_low, bucketed n_reuse, beta,
        span-layout identity).

        The mask CONTENT (which spans are pooled/reused, after bucket
        trimming and the session-staleness gate) is part of the key:
        requests with equal counts but different span layouts need
        different packs and must not share a wave.
        """
        T = self._bucket(len(r.prompt))
        spans = r.low_spans()
        reuse = self._effective_reuse(r)
        n_reuse = int(reuse.shape[0])
        if spans.shape[0] == 0 and n_reuse == 0:
            return (T, 0, 0, 0, b"")
        n_low = 0
        if spans.shape[0] > 0:
            n_spans = int(np.asarray(r.low_span_mask).reshape(-1).shape[0])
            n_low = bucket_n_low(int(spans.shape[0]), n_spans,
                                 self.sc.n_low_buckets)
        if n_low == 0 and n_reuse == 0:   # bucketed away: plain prefill
            return (T, 0, 0, 0, b"")
        return (T, n_low, n_reuse, r.beta, r.mask_key(n_low, reuse))

    # ------------------------------------------------------------------
    def run_wave(self, now: float = 0.0) -> List[Response]:
        """Serve one wave to completion.  Returns finished responses."""
        wave = self._form_wave()
        if wave is None:
            return []
        t0 = time.perf_counter()
        cfg, sc = self.cfg, self.sc
        T, n_low, n_reuse, beta, _ = self._wave_key(wave[0])
        B = len(wave)
        # pad the wave up to a batch bucket: slot 0 is replicated into
        # the padded slots, which are masked out of the responses and
        # decode as done from step 0 — so the executable set stays the
        # bounded (T x n_low x n_reuse x beta x B bucket) warmup grid
        Bp = self.batch_bucket(B)

        toks = np.zeros((Bp, T), np.int32)
        for i, r in enumerate(wave):
            p = np.asarray(r.prompt, np.int32)
            toks[i, :len(p)] = p
            if len(p) < T:          # right-pad with the last prompt token
                toks[i, len(p):] = p[-1] if len(p) else 0
        toks[B:] = toks[0]

        state = registry.init_decode_state(cfg, Bp, sc.max_len,
                                           sc.cache_dtype)
        if (n_low > 0 or n_reuse > 0) and beta > 0:
            r0 = wave[0]
            span_mask = (r0.low_span_mask if r0.low_span_mask is not None
                         else r0.reuse_span_mask)
            n_spans = int(np.asarray(span_mask).reshape(-1).shape[0])
            # conservative fallback for reuse on the seq path: effective
            # reuse spans are POOLED alongside the low spans (tokens are
            # always transmitted here; only the vision edge splices
            # cached features)
            mask = np.zeros((n_spans,), np.int32)
            mask[r0.low_spans(n_low)] = 1
            mask[self._effective_reuse(r0)] = 1
            pack = self._pack_for(T, n_low, n_reuse, mask)
            fn = self._get_prefill(T, n_low + n_reuse, beta, Bp)
            logits, state = fn(self.params, jnp.asarray(toks), state,
                               jnp.asarray(pack["mix_idx"]),
                               jnp.asarray(pack["pos_mix"]),
                               jnp.asarray(pack["restore_idx"]))
        else:
            fn = self._get_prefill(T, 0, 0, Bp)
            logits, state = fn(self.params, jnp.asarray(toks), state)

        # refresh reuse sessions: effective reuse spans age by one, every
        # other span of a sessionful request resets (it was transmitted)
        for r in wave:
            if r.client_id >= 0 and r.reuse_span_mask is not None:
                n_sp = int(np.asarray(r.reuse_span_mask).reshape(-1)
                           .shape[0])
                self.session(r.client_id, n_sp).note(
                    self._effective_reuse(r), r.beta, int(now))

        decode = self._get_decode(Bp)
        resp = {r.rid: Response(rid=r.rid, slot=i, prefill_done=now)
                for i, r in enumerate(wave)}
        done = np.zeros((Bp,), bool)
        done[B:] = True                   # padded slots never emit tokens
        max_new = max(r.max_new_tokens for r in wave)
        tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                         np.int32).reshape(Bp, 1)

        for i, r in enumerate(wave):
            resp[r.rid].tokens.append(int(tok[i, 0]))
            if r.eos_id is not None and tok[i, 0] == r.eos_id:
                done[i] = True

        for step in range(1, max_new):
            pos = T + step - 1
            if pos >= sc.max_len or done.all():
                break
            logits, state = decode(self.params, jnp.asarray(tok),
                                   jnp.asarray(pos, jnp.int32), state)
            tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                             np.int32).reshape(Bp, 1)
            for i, r in enumerate(wave):
                if done[i] or len(resp[r.rid].tokens) >= r.max_new_tokens:
                    done[i] = True
                    continue
                resp[r.rid].tokens.append(int(tok[i, 0]))
                if r.eos_id is not None and tok[i, 0] == r.eos_id:
                    done[i] = True

        wall = time.perf_counter() - t0
        self.wave_latencies.append(wall)
        out = []
        for r in wave:
            resp[r.rid].finished = now + wall
            self.responses[r.rid] = resp[r.rid]
            out.append(resp[r.rid])
        return out

    def run(self, now: float = 0.0) -> List[Response]:
        """Drain the queue."""
        out = []
        while self.queue:
            out.extend(self.run_wave(now))
        return out
