"""Synthetic 4G/5G uplink traces matching the paper's trace statistics
(§VI-A): 300 s at 1 s resolution; 4G mean uplink 10.4–36.4 Mbps, 5G
12.2–135.5 Mbps; mean RTT ~39 ms (4G) / ~34 ms (5G).

AR(1) log-throughput with occasional deep fades (handover/blockage),
deterministic per (kind, index).  ``make_trace`` can additionally bake
deterministic impairments INTO the trace — uplink blackouts, handover
storms (alternating-second micro-blackouts), and bufferbloat RTT
inflation — seeded independently of the base process, so the base trace
is byte-identical whether or not impairments are requested.  (The
offload-side alternative is layering offload/faults.FaultInjector on an
untouched trace; the knobs here serve trace-only consumers.)
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

# throughput during a baked-in blackout second (dead but finite)
BLACKOUT_TPUT_BPS = 1e3


@dataclass
class NetworkTrace:
    name: str
    kind: str                 # "4g" | "5g"
    tput_bps: np.ndarray      # (T,) per-second uplink throughput
    rtt_s: np.ndarray         # (T,) per-second RTT
    # past-end behaviour of ``at``: "hold" freezes the final second
    # (the legacy implicit behaviour, now explicit), "wrap" loops the
    # trace — long simulations over short traces pick one deliberately
    extend: str = "hold"

    def at(self, t: float) -> Tuple[float, float]:
        n = len(self.tput_bps)
        if self.extend == "wrap":
            i = int(t) % n
        else:
            i = min(int(t), n - 1)
        return float(self.tput_bps[i]), float(self.rtt_s[i])

    @property
    def mean_mbps(self) -> float:
        return float(self.tput_bps.mean() / 1e6)


def make_trace(kind: str, index: int, duration_s: int = 300, *,
               blackouts: int = 0, blackout_s: Tuple[float, float] = (2, 6),
               storms: int = 0, storm_s: Tuple[float, float] = (4, 10),
               bufferbloat: int = 0,
               bloat_s: Tuple[float, float] = (5, 15),
               bloat_factor: Tuple[float, float] = (3.0, 8.0),
               extend: str = "hold") -> NetworkTrace:
    # NOT hash(): str hashing is salted per process (PYTHONHASHSEED), so
    # trace statistics would differ from run to run
    seed = zlib.crc32(f"{kind}-{index}".encode())
    rng = np.random.default_rng(seed)
    if kind == "4g":
        mean_mbps = rng.uniform(10.4, 36.4)
        rtt_mean = 0.039
        vol = 0.25
    else:
        mean_mbps = rng.uniform(12.2, 135.5)
        rtt_mean = 0.034
        vol = 0.35

    # AR(1) in log space around the mean
    log_mu = np.log(mean_mbps)
    x = np.empty(duration_s)
    x[0] = log_mu
    phi = 0.92
    sigma = vol * np.sqrt(1 - phi ** 2)
    for t in range(1, duration_s):
        x[t] = log_mu + phi * (x[t - 1] - log_mu) + rng.normal(0, sigma)
    tput = np.exp(x)

    # deep fades: 1-4 events of 2-6 s at 10-30% capacity
    for _ in range(rng.integers(1, 5)):
        t0 = rng.integers(0, duration_s - 6)
        dur = rng.integers(2, 7)
        tput[t0:t0 + dur] *= rng.uniform(0.1, 0.3)

    rtt = np.clip(rtt_mean * (1.0 + 0.5 * (mean_mbps / tput - 1.0)),
                  0.015, 0.5)
    tput = tput * 1e6

    # impairment overlays, seeded SEPARATELY so knob-free calls return
    # the byte-identical base trace
    if blackouts or storms or bufferbloat:
        irng = np.random.default_rng(
            zlib.crc32(f"{kind}-{index}-impair".encode()))

        def window(span: Tuple[float, float]) -> Tuple[int, int]:
            dur = int(np.ceil(irng.uniform(*span)))
            t0 = int(irng.integers(0, max(duration_s - dur, 1)))
            return t0, min(t0 + dur, duration_s)

        for _ in range(blackouts):
            a, b = window(blackout_s)
            tput[a:b] = BLACKOUT_TPUT_BPS
        for _ in range(storms):
            a, b = window(storm_s)
            # handover storm: the uplink drops every other second
            tput[a:b:2] = BLACKOUT_TPUT_BPS
        for _ in range(bufferbloat):
            a, b = window(bloat_s)
            rtt[a:b] = np.clip(rtt[a:b] * irng.uniform(*bloat_factor),
                               None, 3.0)

    return NetworkTrace(name=f"{kind}-{index:02d}", kind=kind,
                        tput_bps=tput, rtt_s=rtt, extend=extend)


def trace_set(n_per_kind: int = 30, duration_s: int = 300
              ) -> List[NetworkTrace]:
    """The evaluation trace set: n 4G + n 5G traces (paper: 30 + 30)."""
    out = []
    for kind in ("4g", "5g"):
        out.extend(make_trace(kind, i, duration_s)
                   for i in range(n_per_kind))
    return out
