"""Synthetic mobile-video generator with analytic ground truth.

Mirrors the paper's Table I scenarios: a moving camera over a textured
world with static structures (trees/buildings -> CMRs under camera
motion), a static far background (sky -> SBRs), and independently moving
objects (pedestrians/vehicles -> DORs).  Every frame comes with exact
bounding boxes, so the full detection/tracking/offloading pipeline can be
trained and evaluated end-to-end without external data.

All arrays are numpy (host-side data pipeline); frames are float32 in
[0, 1], HxWx3.
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class Scenario:
    name: str
    camera_speed: float          # px/frame world scroll
    camera_jitter: float
    n_objects: int
    object_speed: float          # px/frame independent motion
    brightness: float            # illumination scale
    noise: float                 # sensor noise sigma (rain/night grain)
    texture_scale: int           # world texture feature size
    sky_fraction: float          # top fraction of frame = static background


SCENARIOS: Dict[str, Scenario] = {
    # paper Table I analogues
    "walkS": Scenario("walkS", 1.5, 0.3, 4, 1.2, 1.00, 0.005, 48, 0.35),
    "walkR": Scenario("walkR", 1.2, 0.5, 4, 1.0, 0.75, 0.030, 48, 0.30),
    "walkB": Scenario("walkB", 1.0, 0.2, 6, 1.5, 0.90, 0.010, 24, 0.15),
    "cycleS": Scenario("cycleS", 5.0, 1.0, 5, 2.5, 1.00, 0.008, 64, 0.40),
    "driveN": Scenario("driveN", 7.0, 0.8, 6, 3.5, 0.45, 0.040, 64, 0.30),
    # static surveillance camera (parked, near-stationary objects): the
    # temporal-reuse best case — almost every region is motionless
    "parkS": Scenario("parkS", 0.0, 0.0, 4, 0.0, 1.00, 0.002, 48, 0.35),
}

N_CLASSES = 8


@dataclass
class ObjectState:
    x: float
    y: float
    w: float
    h: float
    vx: float
    vy: float
    cls: int
    color: np.ndarray


class VideoGenerator:
    """Deterministic synthetic video stream."""

    def __init__(self, scenario: str, size: int = 512, seed: int = 0,
                 fps: int = 10):
        self.sc = SCENARIOS[scenario]
        self.size = size
        self.fps = fps
        # stable hash: python's hash() is salted per process, which would
        # make "deterministic" clips differ across runs
        self.rng = np.random.default_rng(
            seed * 1000 + zlib.crc32(scenario.encode()) % 1000)
        self.t = 0
        self._world = self._make_world()
        self._sky = self._make_sky()
        self.objects = [self._spawn() for _ in range(self.sc.n_objects)]
        self.cam_x = 0.0

    # ------------------------------------------------------------------
    def _make_world(self) -> np.ndarray:
        """Procedural texture strip the camera scrolls over (world map)."""
        S, ts = self.size, self.sc.texture_scale
        W = S * 8
        rng = self.rng
        coarse = rng.uniform(0.15, 0.9, (S // ts + 2, W // ts + 2, 3))
        world = np.kron(coarse, np.ones((ts, ts, 1)))[:S, :W]
        # vertical structures (trees / buildings): high-frequency columns
        for _ in range(W // 96):
            cx = rng.integers(0, W - 40)
            cw = rng.integers(16, 64)
            top = rng.integers(int(S * 0.25), int(S * 0.6))
            col = rng.uniform(0.1, 0.8, 3)
            stripes = (np.sin(np.arange(S - top) / 3.0) * 0.15)[:, None,
                                                                None]
            world[top:, cx:cx + cw] = col + stripes
        fine = rng.normal(0, 0.03, world.shape)
        return np.clip(world + fine, 0, 1).astype(np.float32)

    def _make_sky(self) -> np.ndarray:
        S = self.size
        g = np.linspace(0.9, 0.55, S)[:, None, None]
        base = np.array([0.55, 0.7, 0.95])[None, None, :]
        return (g * base).astype(np.float32)

    def _spawn(self) -> ObjectState:
        S = self.size
        rng = self.rng
        w = float(rng.uniform(S * 0.05, S * 0.16))
        h = float(rng.uniform(S * 0.07, S * 0.2))
        sky = int(S * self.sc.sky_fraction)
        return ObjectState(
            x=float(rng.uniform(0, S - w)),
            y=float(rng.uniform(sky, S - h)),
            w=w, h=h,
            vx=float(rng.normal(0, self.sc.object_speed)),
            vy=float(rng.normal(0, self.sc.object_speed * 0.3)),
            cls=int(rng.integers(0, N_CLASSES)),
            color=rng.uniform(0.2, 1.0, 3),
        )

    # ------------------------------------------------------------------
    def frame(self) -> Tuple[np.ndarray, List[Dict]]:
        """Render the next frame.  Returns (HxWx3 float32, gt boxes)."""
        S = self.size
        sc = self.sc
        self.cam_x += sc.camera_speed + self.rng.normal(0, sc.camera_jitter)
        W = self._world.shape[1]
        x0 = int(self.cam_x) % (W - S)

        img = self._world[:, x0:x0 + S].copy()
        sky_h = int(S * sc.sky_fraction)
        img[:sky_h] = self._sky[:sky_h]            # static background (SBR)

        boxes = []
        for ob in self.objects:
            ob.x += ob.vx + self.rng.normal(0, 0.2)
            ob.y += ob.vy + self.rng.normal(0, 0.1)
            if ob.x < -ob.w or ob.x > S or ob.y < sky_h * 0.5 or \
                    ob.y > S - ob.h * 0.5:
                new = self._spawn()
                ob.__dict__.update(new.__dict__)
            xi, yi = int(ob.x), int(ob.y)
            x1, y1 = max(xi, 0), max(yi, 0)
            x2, y2 = min(int(ob.x + ob.w), S), min(int(ob.y + ob.h), S)
            if x2 - x1 < 4 or y2 - y1 < 4:
                continue
            patch = img[y1:y2, x1:x2]
            yy = np.linspace(-1, 1, y2 - y1)[:, None]
            xx = np.linspace(-1, 1, x2 - x1)[None, :]
            blob = np.exp(-(yy ** 2 + xx ** 2) * 1.2)[..., None]
            img[y1:y2, x1:x2] = (patch * (1 - 0.9 * blob)
                                 + ob.color * 0.9 * blob)
            # class-identifying stripe pattern
            stripe = (np.sin(xx * (2 + ob.cls)) * 0.5 + 0.5) * 0.25
            img[y1:y2, x1:x2, ob.cls % 3] = np.clip(
                img[y1:y2, x1:x2, ob.cls % 3] + stripe * blob[..., 0], 0, 1)
            boxes.append({"box": (x1, y1, x2, y2), "cls": ob.cls})

        img = img * sc.brightness
        if sc.noise:
            img = img + self.rng.normal(0, sc.noise, img.shape)
        self.t += 1
        return np.clip(img, 0, 1).astype(np.float32), boxes


# ---------------------------------------------------------------------------
# detection targets for training the ViTDet reduced model (FCOS-lite)


def render_targets(boxes: List[Dict], size: int, strides=(8, 16, 32),
                   n_classes: int = N_CLASSES) -> List[Dict]:
    """Per-level target maps matching det_head outputs."""
    out = []
    for s in strides:
        H = W = size // s
        cls = np.zeros((H, W, n_classes), np.float32)
        box = np.zeros((H, W, 4), np.float32)
        pos = np.zeros((H, W, 1), np.float32)
        for b in boxes:
            x1, y1, x2, y2 = b["box"]
            # assign to the level whose stride matches object size; the
            # finest level takes everything below its band so small
            # objects are never left without a positive location
            scale = max(x2 - x1, y2 - y1)
            lo = 0 if s == strides[0] else 4 * s
            hi = 1e9 if s == strides[-1] else 16 * s
            if not (lo <= scale < hi):
                continue
            cx1, cy1 = int(x1 / s), int(y1 / s)
            cx2, cy2 = max(int(np.ceil(x2 / s)), cx1 + 1), \
                max(int(np.ceil(y2 / s)), cy1 + 1)
            # centre third is positive
            mx1 = cx1 + (cx2 - cx1) // 3
            mx2 = max(cx2 - (cx2 - cx1) // 3, mx1 + 1)
            my1 = cy1 + (cy2 - cy1) // 3
            my2 = max(cy2 - (cy2 - cy1) // 3, my1 + 1)
            for gy in range(max(my1, 0), min(my2, H)):
                for gx in range(max(mx1, 0), min(mx2, W)):
                    px = (gx + 0.5) * s
                    py = (gy + 0.5) * s
                    cls[gy, gx, b["cls"]] = 1.0
                    box[gy, gx] = [(px - x1) / s, (py - y1) / s,
                                   (x2 - px) / s, (y2 - py) / s]
                    pos[gy, gx] = 1.0
        out.append({"cls": cls, "box": box, "pos": pos})
    return out


def make_clip(scenario: str, n_frames: int, size: int = 512, seed: int = 0):
    """Materialise a clip: (frames (N,H,W,3), list of gt box lists)."""
    gen = VideoGenerator(scenario, size=size, seed=seed)
    frames, gts = [], []
    for _ in range(n_frames):
        f, b = gen.frame()
        frames.append(f)
        gts.append(b)
    return np.stack(frames), gts
