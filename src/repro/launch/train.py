"""Production training launcher: pjit'd FSDP+TP training with sharded
checkpointing, async saves, heartbeat/straggler monitoring, and elastic
restart hooks.

On this CPU container it runs reduced configs end-to-end (the examples
use it to train a ~100M model for a few hundred steps); on a real pod the
same entry point runs the full configs on the production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
      --steps 200 --batch 8 --seq 256 [--ckpt-dir /tmp/ckpt] [--resume]
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shd
from repro.models import registry
from repro.models.config import ModelConfig
from repro.optim import adam
from repro.train import checkpoint as ckpt
from repro.train import trainer as tr
from repro.train.straggler import HeartbeatMonitor


# ---------------------------------------------------------------------------
# data pipeline: deterministic synthetic LM token stream (self-contained —
# no external data per the assignment; structured enough for loss to fall)


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int,
                      seed: int = 0,
                      active_vocab: int = 4096
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-chain token stream with a learnable bigram structure.

    Tokens are drawn from an ``active_vocab``-sized head of the
    vocabulary so each bigram recurs often enough to be learnable within
    a few hundred steps even for 100k+ vocab configs (a full-vocab
    random table would need ~V tokens just to see every entry once).
    """
    rng = np.random.default_rng(seed)
    V = min(cfg.vocab_size, active_vocab)
    # sparse deterministic bigram table: each token has 4 likely successors
    succ = rng.integers(0, V, (V, 4))
    while True:
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, (batch,))
        r = rng.random((batch, seq))
        pick = rng.integers(0, 4, (batch, seq))
        for t in range(seq):
            nxt = succ[toks[:, t], pick[:, t]]
            rand = rng.integers(0, V, (batch,))
            toks[:, t + 1] = np.where(r[:, t] < 0.9, nxt, rand)
        batch_d = {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}
        if cfg.family == "encdec":
            batch_d["frames"] = rng.normal(
                0, 1, (batch, cfg.encdec.encoder_seq_len, cfg.d_model)
            ).astype(np.float32)
        if cfg.family == "vlm":
            batch_d["image_embeds"] = rng.normal(
                0, 1, (batch, cfg.vlm.n_image_tokens, cfg.vlm.vision_hidden)
            ).astype(np.float32)
        yield batch_d


# ---------------------------------------------------------------------------


def make_mesh(model_par: int = 1) -> Optional[Mesh]:
    devs = jax.devices()
    if len(devs) == 1:
        return None
    data = len(devs) // model_par
    return jax.make_mesh((data, model_par), ("data", "model"))


def train(cfg: ModelConfig, steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str] = None, resume: bool = False,
          save_every: int = 100, mesh: Optional[Mesh] = None,
          tc: Optional[tr.TrainConfig] = None, log_every: int = 10,
          seed: int = 0) -> Dict[str, float]:
    """Run the training loop; returns final metrics."""
    tc = tc or tr.TrainConfig(remat=False, total_steps=steps,
                              warmup_steps=max(steps // 20, 5))
    params, opt_state = tr.init_train_state(cfg, jax.random.PRNGKey(seed))
    start_step = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        start_step = ckpt.latest_step(ckpt_dir)
        params, opt_state = ckpt.restore((params, opt_state), ckpt_dir)
        print(f"[train] resumed from step {start_step}", flush=True)

    step_fn = tr.make_train_step(cfg, mesh, tc)
    if mesh is not None:
        p_shape = jax.eval_shape(lambda: params)
        p_shard, o_shard, _ = tr.train_shardings(
            cfg, mesh, p_shape, None)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    monitor = HeartbeatMonitor(hosts=[jax.process_index()],
                               interval=300.0)
    data = synthetic_batches(cfg, batch, seq, seed=seed + start_step)
    metrics = {}
    losses = []
    t_start = time.time()
    for s in range(start_step, steps):
        b = next(data)
        t0 = time.time()
        params, opt_state, metrics = step_fn(
            params, opt_state, {k: jnp.asarray(v) for k, v in b.items()})
        loss = float(metrics["loss"])
        losses.append(loss)
        monitor.beat(jax.process_index(), time.time(),
                     step_time=time.time() - t0)
        if log_every and (s % log_every == 0 or s == steps - 1):
            print(f"[train] step {s} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({time.time() - t0:.2f}s/step)", flush=True)
        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {s}")
        if ckpt_dir and save_every and (s + 1) % save_every == 0:
            ckpt.save_async((params, opt_state), ckpt_dir, s + 1)

    if ckpt_dir:
        ckpt.wait_pending_saves()
        ckpt.save((params, opt_state), ckpt_dir, steps)
    out = {"final_loss": losses[-1] if losses else float("nan"),
           "mean_last10": float(np.mean(losses[-10:])) if losses else
           float("nan"),
           "first_loss": losses[0] if losses else float("nan"),
           "wall_s": time.time() - t_start}
    print(f"[train] done: first={out['first_loss']:.4f} "
          f"last10={out['mean_last10']:.4f} wall={out['wall_s']:.0f}s",
          flush=True)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--backend", choices=("auto", "pallas", "xla"),
                    default=None,
                    help="kernel backend for the training graph "
                         "(kernels.dispatch process default; the Pallas "
                         "kernels are grad-capable via custom VJPs)")
    args = ap.parse_args(argv)

    if args.backend is not None:
        from repro.kernels import dispatch
        dispatch.set_backend(args.backend)
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_mesh(args.model_par)
    out = train(cfg, args.steps, args.batch, args.seq,
                ckpt_dir=args.ckpt_dir, resume=args.resume,
                save_every=args.save_every, mesh=mesh)
    return 0 if np.isfinite(out["final_loss"]) else 1


if __name__ == "__main__":
    sys.exit(main())
