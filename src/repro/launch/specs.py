"""Per-cell step functions and ShapeDtypeStruct input specs.

``build_cell(arch, shape, mesh)`` returns everything the dry-run needs:
the step callable, its example-argument shapes (no allocation), and the
in/out sharding trees — for every (architecture x input-shape) cell.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.distributed import sharding as shd
from repro.models import registry
from repro.models.config import ModelConfig
from repro.optim import adam
from repro.train import trainer as tr

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


# ---------------------------------------------------------------------------
# input ShapeDtypeStructs


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs for a training/prefill step (ShapeDtypeStructs)."""
    B, T = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, T), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sds((B, T), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = sds((B, cfg.encdec.encoder_seq_len, cfg.d_model),
                              PARAM_DTYPE)
    if cfg.family == "vlm":
        batch["image_embeds"] = sds(
            (B, cfg.vlm.n_image_tokens, cfg.vlm.vision_hidden), PARAM_DTYPE)
    return batch


def params_shape(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: registry.init_params(cfg, jax.random.PRNGKey(0),
                                     PARAM_DTYPE))


def decode_state_shape(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    state = jax.eval_shape(
        lambda: registry.init_decode_state(cfg, batch, max_len, CACHE_DTYPE))
    if cfg.family == "encdec":      # whisper decode state = (enc_out, caches)
        enc = sds((batch, cfg.encdec.encoder_seq_len, cfg.d_model),
                  PARAM_DTYPE)
        state = (enc, state)
    return state


# ---------------------------------------------------------------------------
# cell construction


@dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: Callable
    args: Tuple[Any, ...]             # ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _accum_for(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               scale: int = 1) -> int:
    """Microbatch count keeping per-device live tokens ~<= 8k.

    The scan-over-layers carry is one microbatch activation per layer;
    8k tokens/device keeps that under ~1 GiB even for d_model 6k x 60L."""
    dp = shd.dp_size(mesh)
    local_tokens = shape.global_batch * shape.seq_len / max(dp, 1)
    accum = max(1, int(local_tokens // 8192)) * scale
    # accumulate only in powers of two dividing the local batch
    while shape.global_batch % (accum * dp) and accum > 1:
        accum //= 2
    return accum


OPT_VARIANTS = {
    "base": {},
    "sp": {"train": {"sp": True}},
    "accum2x": {"accum_scale": 2},
    "accum4x": {"accum_scale": 4},
    "sp_accum2x": {"train": {"sp": True}, "accum_scale": 2},
    # pure-accounting variants (graph unchanged; §Perf applies a measured
    # byte correction): "flash" — Pallas attention kernels keep the
    # (B,H,T,S) logits in VMEM.  Compose tokens with '+': "flash+sp".
    "flash": {},
}


def _opt_variant(opt: str) -> dict:
    var: dict = {"train": {}}
    for tok in opt.split("+"):
        v = OPT_VARIANTS.get(tok, {})
        var["train"].update(v.get("train", {}))
        if "accum_scale" in v:
            var["accum_scale"] = v["accum_scale"]
    return var


def accum_for_cell(arch: str, shape_name: str, mesh: Mesh,
                   opt: str = "base") -> int:
    """The grad-accum trip count the real cell uses (costing needs it)."""
    shape = SHAPES[shape_name]
    if shape.kind != "train":
        return 1
    scale = _opt_variant(opt).get("accum_scale", 1)
    return _accum_for(get_config(arch), shape, mesh, scale)


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               train_overrides: Optional[dict] = None,
               opt: str = "base") -> Cell:
    return build_cell_from(get_config(arch), SHAPES[shape_name], mesh,
                           train_overrides, opt, arch_name=arch)


def _strip_data_axis(spec: P) -> P:
    """Replicate over the data axis (TP-only layout for serving)."""
    def fix(e):
        if e == "data":
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(x for x in e if x != "data")
            return kept if kept else None
        return e
    return P(*[fix(e) for e in spec])


def build_cell_from(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    train_overrides: Optional[dict] = None,
                    opt: str = "base", accum: Optional[int] = None,
                    arch_name: Optional[str] = None) -> Cell:
    """Cell from explicit config/shape (costing probes pass overridden
    configs and forced accum counts)."""
    arch = arch_name or cfg.name
    var = _opt_variant(opt)
    train_overrides = {**var.get("train", {}), **(train_overrides or {})}
    accum_scale = var.get("accum_scale", 1)
    p_shape = params_shape(cfg)
    pspecs = shd.param_specs(cfg, p_shape, mesh)
    if "tponly" in opt.split("+") and shape.kind != "train":
        # §Perf serving-layout variant: replicate params over data —
        # inference has no optimizer state, so FSDP buys nothing and its
        # per-layer all-gathers dominate the collective term
        pspecs = jax.tree_util.tree_map(
            _strip_data_axis, pspecs,
            is_leaf=lambda s: isinstance(s, P))
    p_shard = shd.to_named(mesh, pspecs)

    if shape.kind == "train":
        accum = accum if accum is not None else \
            _accum_for(cfg, shape, mesh, accum_scale)
        tc = tr.TrainConfig(accum_steps=accum, **(train_overrides or {}))
        step = tr.make_train_step(cfg, mesh, tc)
        batch = input_specs(cfg, shape)
        opt_shape = jax.eval_shape(lambda p: adam.init_adam(p), p_shape)
        opt_specs = adam.AdamState(step=P(), m=pspecs, v=pspecs)
        opt_shard = shd.to_named(mesh, opt_specs)
        b_shard = shd.to_named(mesh, shd.batch_specs(cfg, mesh, batch))
        metrics_shard = shd.to_named(mesh, P())
        return Cell(
            arch=arch, shape=shape, fn=step,
            args=(p_shape, opt_shape, batch),
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, metrics_shard),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        ctx = tr.make_ctx(cfg, mesh, remat=False)
        batch = input_specs(cfg, shape)
        n_img = cfg.vlm.n_image_tokens if cfg.family == "vlm" else 0
        max_len = shape.seq_len + n_img
        state = decode_state_shape(cfg, shape.global_batch, max_len)
        if cfg.family == "encdec":
            state = state[1]          # prefill builds enc_out itself

        mixed_pack = None
        if "mixed" in opt.split("+") and cfg.mixed_res is not None and \
                cfg.family in ("dense", "moe", "vlm"):
            # §Perf variant: the paper's technique — pool HALF the prompt
            # spans (oldest context) for the first beta=2 of 4 subsets
            import numpy as np
            from repro.core import seq_mixed_res as smr
            T_total = shape.seq_len + n_img
            part1d = smr.seq_partition(cfg, T_total)
            span_mask = np.zeros((part1d.n_spans,), np.int32)
            n_low = part1d.n_spans // 2
            span_mask[:n_low] = 1
            plan = smr.build_seq_pack(span_mask, n_low, part1d)
            mixed_pack = {k: jnp.asarray(v) for k, v in plan.items()
                          if k != "low_spans"}

        def prefill_step(params, batch, state):
            if mixed_pack is not None:
                from repro.core import seq_mixed_res as smr
                hidden, new_state, _ = smr.mixed_prefill(
                    cfg, params, batch["tokens"], mixed_pack, 2, state,
                    ctx, image_embeds=batch.get("image_embeds"))
            else:
                hidden, new_state, _ = registry.prefill(
                    cfg, params, batch, state, ctx)
            logits = _last_logits(cfg, params, hidden, ctx)
            return logits, new_state

        s_specs = shd.fix_specs(
            mesh, shd.decode_state_specs(cfg, mesh, state,
                                         shard_batch=True), state)
        b_shard = shd.to_named(mesh, shd.batch_specs(cfg, mesh, batch))
        s_shard = shd.to_named(mesh, s_specs)
        dp = shd.dp_axes(mesh)
        logits_sds = sds((shape.global_batch, 1, cfg.vocab_size),
                         PARAM_DTYPE)
        logits_shard = shd.to_named(
            mesh, shd.fix_spec(mesh, P(dp, None, "model"), logits_sds.shape))
        out_state_shard = s_shard
        if cfg.family == "encdec":
            enc_spec = shd.to_named(mesh, P(dp, None, None))
            out_state_shard = (enc_spec, s_shard)
        return Cell(
            arch=arch, shape=shape, fn=prefill_step,
            args=(p_shape, batch, state),
            in_shardings=(p_shard, b_shard, s_shard),
            out_shardings=(logits_shard, out_state_shard),
            donate_argnums=(2,),
        )

    # decode
    shard_batch = shape.global_batch >= shd.dp_size(mesh)
    ctx = tr.make_ctx(cfg, mesh, remat=False)
    max_len = shape.seq_len
    state = decode_state_shape(cfg, shape.global_batch, max_len)
    token = sds((shape.global_batch, 1), jnp.int32)
    pos = shape.seq_len - 1

    def decode_fn(params, token, state):
        logits, new_state = registry.decode_step(cfg, params, token, pos,
                                                 state, ctx)
        return logits, new_state

    s_specs = shd.fix_specs(
        mesh, shd.decode_state_specs(cfg, mesh, state,
                                     shard_batch=shard_batch), state)
    dp = shd.dp_axes(mesh)
    bspec = dp if shard_batch else None
    t_shard = shd.to_named(mesh, P(bspec, None))
    s_shard = shd.to_named(mesh, s_specs)
    logits_shard = shd.to_named(
        mesh, shd.fix_spec(mesh, P(bspec, None, "model"),
                           (shape.global_batch, 1, cfg.vocab_size)))
    return Cell(
        arch=arch, shape=shape, fn=decode_fn,
        args=(p_shape, token, state),
        in_shardings=(p_shard, t_shard, s_shard),
        out_shardings=(logits_shard, s_shard),
        donate_argnums=(2,),
    )


def _last_logits(cfg: ModelConfig, params, hidden, ctx):
    from repro.models import transformer as tfm
    return tfm.logits_from_hidden(cfg, params, hidden[:, -1:, :], ctx)
