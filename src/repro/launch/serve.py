"""Serving launcher: wave-batched KV-cache serving with the paper's
mixed-granularity prefill as a per-request knob.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 16 --prompt-len 128 --max-new 16 [--mixed --beta 2]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import registry
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.request import Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mixed", action="store_true",
                    help="pool low-relevance prompt spans (paper C1, 1-D)")
    ap.add_argument("--beta", type=int, default=2)
    ap.add_argument("--low-frac", type=float, default=0.5,
                    help="fraction of spans pooled when --mixed")
    ap.add_argument("--mask-variants", type=int, default=1,
                    help="with --mixed: rotate the pooled spans across K "
                    "distinct layouts — same n_low, different content, so "
                    "requests split into K waves (the wave-key fix demo)")
    ap.add_argument("--quant", choices=("fp32", "fp16", "bf16", "int8"),
                    default="fp32",
                    help="serving weight lane: int8 quantizes the "
                    "projection weights (per-output-channel, "
                    "repro.quant), fp16/bf16 cast the whole tree")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family in ("ssm", "hybrid", "encdec", "vit"):
        print(f"[serve] mixed prefill demo targets decoder LMs; "
              f"{args.arch} family={cfg.family} runs the plain path")
        args.mixed = False

    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    if args.quant != "fp32":
        from repro.quant import DTYPES, qtensor, quantize_lm_params
        bytes0 = qtensor.tree_bytes(params)
        if args.quant == "int8":
            params = quantize_lm_params(params)
        else:
            params = qtensor.cast_tree(params, DTYPES[args.quant])
        print(f"[serve] quant={args.quant}: {bytes0 / 2**20:.1f} MiB -> "
              f"{qtensor.tree_bytes(params) / 2**20:.1f} MiB")
    sc = ServeConfig(max_batch=args.batch,
                     max_len=args.prompt_len + args.max_new + 8,
                     buckets=(args.prompt_len,))
    engine = ServeEngine(cfg, params, sc)

    rng = np.random.default_rng(0)
    masks = [None]
    beta = 0
    if args.mixed and cfg.mixed_res is not None:
        span = cfg.mixed_res.window * cfg.mixed_res.downsample
        n_spans = args.prompt_len // span
        n_low = int(n_spans * args.low_frac)
        masks = []
        for k in range(max(args.mask_variants, 1)):
            m = np.zeros((n_spans,), np.int32)
            for j in range(n_low):                # rotated pooled spans
                m[(k + j) % n_spans] = 1
            masks.append(m)
        beta = args.beta

    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              (args.prompt_len,)).astype(np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              low_span_mask=masks[rid % len(masks)],
                              beta=beta))

    t0 = time.time()
    responses = engine.run()
    wall = time.time() - t0
    n_tok = sum(r.n_tokens for r in responses)
    print(f"[serve] {len(responses)} requests, {n_tok} tokens, "
          f"{wall:.2f}s ({n_tok / max(wall, 1e-9):.1f} tok/s), "
          f"waves={len(engine.wave_latencies)} "
          f"mixed={'on' if beta else 'off'}")
    ok = (len(responses) == args.requests and
          all(r.n_tokens == args.max_new for r in responses))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
