import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production mesh, record memory/cost analyses and the collective schedule.

This file MUST set XLA_FLAGS before any other import (jax locks the host
device count on first initialisation) — hence the two lines above.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k [--multi-pod] [--out benchmarks/artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, cells, get_config, shape_runnable
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.roofline import collectives as coll
from repro.roofline import model as rm


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_level: str = "base", probe_cost: bool = True) -> dict:
    cfg = get_config(arch)
    ok, reason = shape_runnable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "pod2" if multi_pod else "pod1",
           "opt": opt_level}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    if "tp8" in opt_level.split("+"):
        # §Perf sharding variant: TP degree 8 (divides every head count —
        # phi4's 24 heads on TP=16 force GSPMD full-tensor resharding)
        shape_ = (2, 32, 8) if multi_pod else (32, 8)
        axes_ = ("pod", "data", "model") if multi_pod else ("data", "model")
        mesh = jax.make_mesh(shape_, axes_)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    cell = sp.build_cell(arch, shape_name, mesh, opt=opt_level)
    with mesh:
        jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
        lowered = jf.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll_stats = coll.collective_bytes(hlo)

    # cost_analysis counts scan bodies ONCE — probe-and-extrapolate gives
    # trip-count-exact flops/bytes/collectives (launch/costing.py)
    cost_src = "hlo-rolled (scan bodies counted once: UNDERESTIMATE)"
    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    coll_dev = coll_stats["bytes_per_device"]
    if probe_cost:
        from repro.launch import costing
        accum = sp.accum_for_cell(arch, shape_name, mesh, opt_level)

        def bc(cfg_, shp_, mesh_, opt_, accum_):
            return sp.build_cell_from(cfg_, shp_, mesh_, opt=opt_,
                                      accum=accum_, arch_name=arch)

        t0 = time.time()
        probe = costing.probe_costs(arch, shape_name, mesh, bc, accum,
                                    opt_level)
        rec["probe_cost"] = {**probe, "wall_s": round(time.time() - t0, 1)}
        flops_dev = probe["flops_per_device"]
        bytes_dev = probe["bytes_per_device"]
        coll_dev = probe["collective_bytes_per_device"]
        cost_src = "probe-extrapolate (trip-count exact)"

        if "flash" in opt_level.split("+"):
            mixed_lb = t_mix = 0
            if "mixed" in opt_level.split("+") and \
                    SHAPES[shape_name].kind == "prefill" and \
                    cfg.mixed_res is not None:
                from repro.core import seq_mixed_res as smr
                n_img = (cfg.vlm.n_image_tokens
                         if cfg.family == "vlm" else 0)
                part1d = smr.seq_partition(
                    cfg, SHAPES[shape_name].seq_len + n_img)
                t_mix = part1d.n_tokens(part1d.n_spans // 2)
                mixed_lb = smr.layers_before_rp(cfg, 2, cfg.n_layers)
            corr = costing.flash_correction(
                cfg, SHAPES[shape_name], mesh, accum,
                costing.attn_layer_count(cfg),
                mixed_lb=mixed_lb, t_mix=t_mix)
            rec["flash_correction"] = corr
            # floor: real traffic can never go below reading the params
            # once per pass + one activation write per layer + the
            # kernel's own attention IO (guards the micro-probe
            # substitution against over-subtraction)
            floor = costing.min_traffic_floor(
                cfg, SHAPES[shape_name], mesh, accum,
                mixed_lb=mixed_lb, t_mix=t_mix)
            rec["byte_floor"] = floor
            bytes_dev = max(bytes_dev - corr["bytes_saved_per_device"],
                            floor["bytes_per_device"])
            cost_src += " + pallas-flash byte substitution (floored)"

        if "zero2" in opt_level.split("+") and accum > 1:
            # gather params once per step instead of per microbatch
            # (ZeRO-2 layout).  Feasible only if the gathered bf16 params
            # fit next to the existing per-device peak.
            ag = (probe.get("collective_by_op") or {}).get("all-gather",
                                                           0.0)
            tp = mesh.shape["model"]
            gathered_bytes = cfg.param_count() * 2 / tp
            peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    + gathered_bytes)
            feasible = peak < 16e9          # v5e HBM
            saved = ag * (accum - 1) / accum if feasible else 0.0
            rec["zero2"] = {
                "allgather_bytes": ag, "saved_bytes": saved,
                "gathered_param_bytes": gathered_bytes,
                "projected_peak_bytes": peak, "feasible": feasible,
            }
            coll_dev = max(coll_dev - saved, 0.0)
            cost_src += (" + zero2 gather-once"
                         if feasible else " (zero2 INFEASIBLE: params "
                         "don't fit gathered)")

    terms = rm.roofline_terms(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_dev,
        n_chips=n_chips)
    shape = SHAPES[shape_name]
    model_fl = rm.model_flops(cfg, shape)

    rec.update(
        status="ok",
        n_chips=int(n_chips),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=int(ma.argument_size_in_bytes),
            output_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            alias_bytes=int(ma.alias_size_in_bytes),
            total_per_device=int(ma.argument_size_in_bytes
                                 + ma.output_size_in_bytes
                                 + ma.temp_size_in_bytes
                                 - ma.alias_size_in_bytes),
            # CPU backend does not implement buffer donation, so outputs
            # that WOULD alias donated inputs on TPU are double-counted;
            # this is the donation-adjusted figure (args + temps).
            total_with_donation=int(ma.argument_size_in_bytes
                                    + ma.temp_size_in_bytes),
        ),
        cost=dict(flops_per_device=flops_dev,
                  bytes_per_device=bytes_dev,
                  source=cost_src,
                  hlo_rolled_flops=float(ca.get("flops", 0.0)),
                  hlo_rolled_bytes=float(ca.get("bytes accessed", 0.0))),
        collectives={**coll_stats, "bytes_per_device": coll_dev,
                     "hlo_rolled_bytes_per_device":
                         coll_stats["bytes_per_device"]},
        roofline=terms,
        model_flops=model_fl,
        useful_flop_ratio=(model_fl / (terms["total_flops"] + 1e-30)
                           if terms["total_flops"] else None),
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", default="base",
                    help="optimization variant label for §Perf iterations")
    ap.add_argument("--no-probe-cost", action="store_true",
                    help="skip probe-extrapolated exact costing")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    args = ap.parse_args(argv)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    todo = cells() if args.all else [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in todo:
        tag = f"{arch}__{shape}__{'pod2' if args.multi_pod else 'pod1'}"
        if args.opt != "base":
            tag += f"__{args.opt}"
        path = out / f"{tag}.json"
        try:
            rec = run_cell(arch, shape, args.multi_pod, args.opt,
                           probe_cost=not args.no_probe_cost)
        except Exception as e:          # a failure here is a bug in our system
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "mesh": "pod2" if args.multi_pod else "pod1",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            n_fail += 1
        path.write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compile={rec['compile_s']:.0f}s"
                     f" mem/dev={rec['memory']['total_with_donation']/2**30:.2f}GiB"
                     f" t_comp={r['t_compute']*1e3:.2f}ms"
                     f" t_mem={r['t_memory']*1e3:.2f}ms"
                     f" t_coll={r['t_collective']*1e3:.2f}ms"
                     f" bound={r['bound']}")
        elif status == "skipped":
            extra = f" ({rec['reason'][:60]})"
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
