"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces a 512
host-device count while tests/benches must see a single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
