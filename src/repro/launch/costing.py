"""Trip-count-exact cost accounting via probe-and-extrapolate.

XLA's HloCostAnalysis counts every while-loop (lax.scan) body ONCE, so
flops / bytes / collective traffic read off a compiled scanned model are
low by ~the trip count (layers x grad-accum microbatches).  Verified on
this container:

    scan(length=8) over a 512^3 matmul  -> cost_analysis flops = 1 matmul

Unrolling the full model for costing is intractable at depth (compile
blows up), so we measure SMALL fully-unrolled probes on the SAME mesh
and extrapolate linearly — the model is exactly linear in layer count
and microbatch count by construction:

  step cost = O + Sum_k L_k * o_k  +  A * (F + Sum_k L_k * f_k)

    O   once-per-step cost at zero extra layers (optimizer update, data
        movement outside the accum loop)
    o_k once-per-step marginal cost of one layer of stack k (its Adam
        update, grad finalisation)
    F   per-microbatch fwd+bwd cost at base layers (embedding, logits, CE)
    f_k per-microbatch marginal cost of one layer of stack k
    A   grad-accum trip count;  L_k  extra layers of stack k vs the base

Probes (all with every scan unrolled via models.layers.SCAN_UNROLL, all
at the true microbatch size so data-dependent scans — attention q-blocks,
SSD chunks, CE chunks — have their real trip counts):

  P1      base layers, accum=1
  P2_k    base + 1 layer of stack k, accum=1        (one per stack)
  P3      base layers, accum=2                      (train cells only)
  P4_k    base + 1 layer of stack k, accum=2        (train cells only)

  F  = P3 - P1          f_k = (P4_k - P2_k) - F
  O  = P1 - F           o_k = (P2_k - P1) - f_k

Prefill/decode cells have no accum loop: cost = P1 + Sum_k L_k*(P2_k-P1).

Peak-memory figures still come from the FULL rolled lowering (XLA's
buffer assignment is exact); only flops / bytes / collective bytes are
extrapolated.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax

from repro.configs import SHAPES, ShapeSpec, get_config
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.roofline import collectives as coll


@dataclass(frozen=True)
class Stack:
    """One homogeneous layer stack of an architecture."""
    name: str
    n_layers: int                  # layer count in the full config
    base: int                      # layer count in the base probe
    bump: Dict[str, int]           # config overrides adding ONE layer


def stacks_for(cfg: ModelConfig) -> Tuple[Dict[str, int], List[Stack]]:
    """(base-config overrides, stacks).  The base probe keeps exactly
    ``base`` layers of each stack; each stack's ``bump`` adds one."""
    if cfg.family == "encdec":
        base = {"n_layers": 1,
                "encdec": dataclasses.replace(cfg.encdec,
                                              n_encoder_layers=1)}
        return base, [
            Stack("enc", cfg.encdec.n_encoder_layers, 1,
                  {"encdec": dataclasses.replace(cfg.encdec,
                                                 n_encoder_layers=2)}),
            Stack("dec", cfg.n_layers, 1, {"n_layers": 2}),
        ]
    if cfg.family == "hybrid":
        if cfg.layer_pattern is not None:
            pat = cfg.layer_pattern
            kinds = list(dict.fromkeys(pat))      # e.g. ['m', 'A']
            base_pat = tuple(kinds)
            base = {"layer_pattern": base_pat, "n_layers": len(base_pat)}
            stacks = []
            for k in kinds:
                bump_pat = base_pat + (k,)
                stacks.append(
                    Stack(f"pat_{k}", sum(1 for p in pat if p == k), 1,
                          {"layer_pattern": bump_pat,
                           "n_layers": len(bump_pat)}))
            return base, stacks
        # zamba2-style shared block applied via lax.cond inside the mamba
        # scan: the cond branch is counted once per layer by the cost
        # model, a conservative (upper-bound) accounting of the shared
        # attention — recorded in the artifact's method string.
        return {"n_layers": 1}, [Stack("mamba", cfg.n_layers, 1,
                                       {"n_layers": 2})]
    if cfg.moe is not None and cfg.moe.first_dense_layers > 0:
        d = cfg.moe.first_dense_layers
        base = {"n_layers": 2,
                "moe": dataclasses.replace(cfg.moe, first_dense_layers=1)}
        return base, [
            Stack("dense", d, 1,
                  {"n_layers": 3,
                   "moe": dataclasses.replace(cfg.moe,
                                              first_dense_layers=2)}),
            Stack("moe", cfg.n_layers - d, 1,
                  {"n_layers": 3,
                   "moe": dataclasses.replace(cfg.moe,
                                              first_dense_layers=1)}),
        ]
    # dense / moe(all-moe) / ssm / vlm: one homogeneous stack
    return {"n_layers": 1}, [Stack("blocks", cfg.n_layers, 1,
                                   {"n_layers": 2})]


def _op_merge(a: Optional[Dict], b: Optional[Dict], f) -> Dict:
    a, b = a or {}, b or {}
    return {k: f(a.get(k, 0.0), b.get(k, 0.0)) for k in set(a) | set(b)}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: float = 0.0
    coll_by_op: Optional[Dict[str, float]] = None

    def __sub__(self, o):
        return Cost(self.flops - o.flops, self.bytes - o.bytes,
                    self.coll - o.coll,
                    _op_merge(self.coll_by_op, o.coll_by_op,
                              lambda x, y: x - y))

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll + o.coll,
                    _op_merge(self.coll_by_op, o.coll_by_op,
                              lambda x, y: x + y))

    def __mul__(self, k: float):
        return Cost(self.flops * k, self.bytes * k, self.coll * k,
                    {kk: v * k for kk, v in (self.coll_by_op or {}).items()})

    __rmul__ = __mul__

    def clamped(self):
        return Cost(max(self.flops, 0.0), max(self.bytes, 0.0),
                    max(self.coll, 0.0),
                    {k: max(v, 0.0)
                     for k, v in (self.coll_by_op or {}).items()})


# ---------------------------------------------------------------------------
# Pallas flash-attention byte correction (§Perf hillclimb, opt="flash")
#
# XLA (and the cost model) materialises the (B, H, T, S) attention logits
# and probabilities in HBM; the Pallas flash/decode kernels keep them in
# VMEM.  We measure the materialised traffic with a single-device
# micro-probe of the exact local attention shapes and replace it with the
# kernel's analytic HBM traffic:
#
#   fwd   reads q,k,v; writes o (+O(T) lse)          ~ 2*QB + 2*KB
#   bwd   reads q,k,v,o,do; writes dq,dk,dv          ~ 3*QB + 4*KB
#   remat re-runs fwd inside bwd                     + fwd again
#
#   QB = B*T*H*Dh*bytes,  KB = B*S*KV*Dh*bytes
#
# FLOPs are untouched (the kernel computes the same matmuls).


def _attn_local_shapes(cfg: ModelConfig, shape: ShapeSpec, mesh,
                       accum: int) -> Optional[Dict]:
    """Per-device attention operand shapes under the production sharding."""
    if cfg.family in ("ssm",):
        return None
    from repro.distributed import sharding as shd
    dp = shd.dp_size(mesh)
    tp = mesh.shape["model"]
    if shape.kind == "train":
        b_loc = max(shape.global_batch // accum // dp, 1)
        T = S = shape.seq_len
        mode = "train"
    elif shape.kind == "prefill":
        b_loc = max(shape.global_batch // dp, 1)
        T = S = shape.seq_len
        mode = "prefill"
    else:
        b_loc = max(shape.global_batch // dp, 1)
        T, S = 1, shape.seq_len
        mode = "decode"
    h_loc = max(cfg.n_heads // tp, 1)
    kv_loc = max(cfg.n_kv_heads // tp, 1)
    return dict(b=b_loc, t=T, s=S, h=h_loc, kv=kv_loc, dh=cfg.head_dim,
                mode=mode)


def _attn_site_saving(mode: str, b: int, t: int, s: int, h: int, kv: int,
                      dh: int, dtype_bytes: int) -> Dict:
    """Measured XLA traffic minus analytic kernel traffic for ONE
    attention site at the given local lengths."""
    import jax.numpy as jnp
    from repro.models.attention import sdpa

    dt = jnp.bfloat16 if dtype_bytes == 2 else jnp.float32
    q = jax.ShapeDtypeStruct((b, t, h, dh), dt)
    k = jax.ShapeDtypeStruct((b, s, kv, dh), dt)
    v = jax.ShapeDtypeStruct((b, s, kv, dh), dt)

    prev = L.SCAN_UNROLL
    L.SCAN_UNROLL = True
    try:
        if mode == "train":
            def f(q, k, v):
                out = jax.remat(lambda a, b_, c: sdpa(a, b_, c, causal=True)
                                )(q, k, v)
                return jnp.sum(out.astype(jnp.float32))
            probe = jax.jit(jax.value_and_grad(f, argnums=(0, 1, 2)))
        elif mode == "prefill":
            probe = jax.jit(lambda q, k, v: sdpa(q, k, v, causal=True))
        else:
            probe = jax.jit(lambda q, k, v: sdpa(
                q, k, v, kv_len=jnp.full((q.shape[0],), s)))
        ca = probe.lower(q, k, v).compile().cost_analysis() or {}
    finally:
        L.SCAN_UNROLL = prev
    xla_bytes = float(ca.get("bytes accessed", 0.0))

    QB = b * t * h * dh * dtype_bytes
    KB = b * s * kv * dh * dtype_bytes
    if mode == "train":                 # fwd + remat-fwd + bwd
        kernel_bytes = (2 * QB + 2 * KB) * 2 + (3 * QB + 4 * KB)
    else:                               # fwd only
        kernel_bytes = 2 * QB + 2 * KB
    return {"xla": xla_bytes, "kernel": kernel_bytes,
            "saved": max(xla_bytes - kernel_bytes, 0.0)}


def flash_correction(cfg: ModelConfig, shape: ShapeSpec, mesh,
                     accum: int, n_attn_layers: int,
                     dtype_bytes: int = 2,
                     mixed_lb: int = 0, t_mix: int = 0) -> Dict:
    """Per-device bytes saved by the Pallas attention kernels for one
    step of this cell.

    ``mixed_lb``/``t_mix``: with the mixed-granularity prefill variant,
    the first ``mixed_lb`` layers attend over ``t_mix`` tokens — the
    correction is computed per length segment so it never over-subtracts.
    """
    loc = _attn_local_shapes(cfg, shape, mesh, accum)
    if loc is None or cfg.mla is not None:
        # SSD has no attention; MLA needs its own kernel (future work)
        return {"bytes_saved_per_device": 0.0, "sites": 0,
                "note": "no GQA attention sites (ssm/mla)"}
    b, t, s, h, kv, dh = (loc[k] for k in ("b", "t", "s", "h", "kv", "dh"))
    reps = accum if shape.kind == "train" else 1

    segments = []
    if mixed_lb > 0 and t_mix > 0 and loc["mode"] == "prefill":
        segments.append((mixed_lb * reps, t_mix, t_mix))
        segments.append(((n_attn_layers - mixed_lb) * reps, t, s))
    else:
        segments.append((n_attn_layers * reps, t, s))

    saved = 0.0
    details = []
    for n_sites, tt, ss in segments:
        site = _attn_site_saving(loc["mode"], b, tt, ss, h, kv, dh,
                                 dtype_bytes)
        saved += site["saved"] * n_sites
        details.append({"sites": n_sites, "t": tt, "s": ss, **site})
    return {"bytes_saved_per_device": saved,
            "segments": details,
            "sites": sum(d["sites"] for d in details),
            "local_shapes": loc}


def min_traffic_floor(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      accum: int, mixed_lb: int = 0,
                      t_mix: int = 0) -> Dict:
    """Analytic lower bound on per-device HBM traffic for one step:
    parameters streamed once per pass (x3 for fwd+remat+bwd in training,
    + optimizer state), ~8 residual-stream tensors per layer, and the
    flash kernel's attention IO.  Used as a floor under the byte
    substitution so §Perf numbers never over-claim."""
    from repro.distributed import sharding as shd
    dp = shd.dp_size(mesh)
    tp = mesh.shape["model"]
    N = cfg.param_count()
    L_n = cfg.n_layers
    D = cfg.d_model
    is_train = shape.kind == "train"
    reps = accum if is_train else 1
    b_loc = max(shape.global_batch // (accum if is_train else 1) // dp, 1)
    T = 1 if shape.kind == "decode" else shape.seq_len
    if cfg.family == "vlm" and shape.kind == "prefill":
        T += cfg.vlm.n_image_tokens

    param_bytes = 2 * N / tp                    # bf16, TP-sharded stream
    passes = 3 if is_train else 1               # fwd + remat + bwd
    opt_bytes = (N / (dp * tp)) * (4 + 4 + 4 + 2) * 2 if is_train else 0

    def act(t_eff, n_layers):
        return n_layers * 8 * b_loc * t_eff * D * 2

    if mixed_lb > 0 and t_mix > 0:
        act_bytes = act(t_mix, mixed_lb) + act(T, L_n - mixed_lb)
    else:
        act_bytes = act(T, L_n)
    # kv-cache write (prefill) / read (decode)
    cache_bytes = 0
    if shape.kind == "prefill":
        cache_bytes = 2 * b_loc * shape.seq_len * cfg.kv_dim * 2
    elif shape.kind == "decode":
        cache_bytes = 2 * b_loc * shape.seq_len * \
            max(cfg.kv_dim // tp, cfg.head_dim) * 2 * L_n

    total = (reps * (passes * param_bytes + act_bytes) + opt_bytes
             + cache_bytes)
    return {"bytes_per_device": float(total),
            "parts": {"params": passes * param_bytes * reps,
                      "acts": act_bytes * reps, "opt": opt_bytes,
                      "cache": cache_bytes}}


def attn_layer_count(cfg: ModelConfig) -> int:
    """Attention layers per step (0 for pure SSM)."""
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return sum(1 for p in (cfg.layer_pattern or ()) if p != "m")
    if cfg.family == "encdec":
        # enc self + dec self + dec cross
        return cfg.encdec.n_encoder_layers + 2 * cfg.n_layers
    return cfg.n_layers


def _lower_cost(build_cell, arch_cfg: ModelConfig, shape: ShapeSpec,
                mesh, opt: str, accum: int) -> Cost:
    """Lower+compile one probe and read its (per-device) cost."""
    cell = build_cell(arch_cfg, shape, mesh, opt, accum)
    with mesh:
        jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
        compiled = jf.lower(*cell.args).compile()
    ca = compiled.cost_analysis() or {}
    cstats = coll.collective_bytes(compiled.as_text())
    return Cost(float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                cstats["bytes_per_device"], cstats["by_op_bytes"])


def probe_costs(arch: str, shape_name: str, mesh, build_cell,
                accum: int, opt: str = "base", fast: bool = True,
                verbose: bool = False) -> Dict:
    """Run the probe set and extrapolate the full-cell per-device cost.

    ``build_cell(cfg_override, shape, mesh, opt, accum)`` must honour the
    probe config and the forced accum.

    ``fast``: skip the accum-separation probes (P3/P4) and use
        total ~= A * (P1 + Sum_k extra_k * B_k)
    which over-counts only the once-per-step part (Adam update + grad
    finalisation) by (A-1)x — sub-1% for every assigned cell, since the
    optimizer touches the (sharded) parameter tree once while each
    microbatch moves the full activation set.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    base_over, stacks = stacks_for(cfg)

    is_train = shape.kind == "train"
    A = accum if is_train else 1
    # probes run ONE microbatch: shrink the global batch by the accum
    mb_batch = max(shape.global_batch // A, 1)
    pshape = dataclasses.replace(shape, global_batch=mb_batch)
    p2shape = dataclasses.replace(shape, global_batch=2 * mb_batch)

    prev_unroll = L.SCAN_UNROLL
    L.SCAN_UNROLL = True
    try:
        base_cfg = cfg.replace(**base_over)
        P1 = _lower_cost(build_cell, base_cfg, pshape, mesh, opt, 1)
        P2 = {s.name: _lower_cost(build_cell, cfg.replace(**s.bump),
                                  pshape, mesh, opt, 1) for s in stacks}
        if is_train and A > 1 and not fast:
            P3 = _lower_cost(build_cell, base_cfg, p2shape, mesh, opt, 2)
            P4 = {s.name: _lower_cost(build_cell, cfg.replace(**s.bump),
                                      p2shape, mesh, opt, 2)
                  for s in stacks}
        else:
            P3, P4 = None, None
    finally:
        L.SCAN_UNROLL = prev_unroll

    if P3 is not None:
        F = (P3 - P1).clamped()
        O = (P1 - F).clamped()
        total = O + A * F
        for s in stacks:
            f_k = ((P4[s.name] - P2[s.name]) - F).clamped()
            o_k = ((P2[s.name] - P1) - f_k).clamped()
            extra = s.n_layers - s.base
            total = total + extra * o_k + (A * extra) * f_k
        method = "probe-extrapolate exact (unrolled scans, accum split)"
    else:
        per_mb = P1
        for s in stacks:
            B_k = (P2[s.name] - P1).clamped()
            per_mb = per_mb + (s.n_layers - s.base) * B_k
        total = A * per_mb
        method = ("probe-extrapolate fast (unrolled scans; optimizer "
                  "counted A times, <1% error)")

    out = {
        "flops_per_device": total.flops,
        "bytes_per_device": total.bytes,
        "collective_bytes_per_device": total.coll,
        "collective_by_op": total.coll_by_op,
        "probes": {
            "P1": dataclasses.asdict(P1),
            **{f"P2_{k}": dataclasses.asdict(v) for k, v in P2.items()},
        },
        "accum": A,
        "stacks": {s.name: s.n_layers for s in stacks},
        "method": method,
    }
    if P3 is not None:
        out["probes"]["P3"] = dataclasses.asdict(P3)
        out["probes"].update({f"P4_{k}": dataclasses.asdict(v)
                              for k, v in P4.items()})
    return out
