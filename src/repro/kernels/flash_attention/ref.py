"""Pure-jnp oracle for the flash attention kernel (no tiling)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = False,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, T, H, Dh); k/v: (B, S, KV, Dh) with H = KV*G.
    Dense fp32 softmax attention.  Returns (B, T, H, Dh) in q.dtype."""
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = Dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, T, KV, G, Dh).astype(jnp.float32)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, T, H, Dh).astype(q.dtype)
