"""jit'd entry point for the flash attention kernel.

Handles the (B, T, H, Dh) <-> (B, H, T, Dh) layout swap, pads T/S up to
the block size (padded keys are masked in-kernel via the static
``kv_valid`` length), and picks interpret mode automatically off-TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "scale", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = False, scale: Optional[float] = None,
                    bq: int = K.DEFAULT_BQ, bk: int = K.DEFAULT_BK,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Drop-in for models.attention.sdpa (training/prefill path).

    q: (B, T, H, Dh); k/v: (B, S, KV, Dh).  Returns (B, T, H, Dh).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, T, H, Dh = q.shape
    S = k.shape[1]
    scale = Dh ** -0.5 if scale is None else scale

    bq_ = min(bq, _round8(T))
    bk_ = min(bk, _round8(S))
    pad_t = (-T) % bq_
    pad_s = (-S) % bk_

    qt = jnp.moveaxis(q, 2, 1)                       # (B, H, T, Dh)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_t:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))

    out = K.flash_attention_kernel(qt, kt, vt, causal=causal, scale=scale,
                                   bq=bq_, bk=bk_, kv_valid=S,
                                   interpret=interpret)
    out = out[:, :, :T, :]
    return jnp.moveaxis(out, 1, 2)


def _round8(n: int) -> int:
    """Smallest multiple of 8 >= n (sublane granularity)."""
    return max(8, ((n + 7) // 8) * 8)
