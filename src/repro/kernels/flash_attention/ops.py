"""jit'd entry point for the flash attention kernel.

Handles the (B, T, H, Dh) <-> (B, H, T, Dh) layout swap, pads T/S up to
the block size (padded keys are masked in-kernel via the static
``kv_valid`` length), and picks interpret mode automatically off-TPU.

The entry point carries a ``jax.custom_vjp``: the forward runs the
Pallas kernel, the backward is the analytic softmax-attention gradient
recomputed densely in plain jnp.  The dense recompute materialises the
(T, S) score matrix per (kv head, group), so it targets training-scale
sequences (the serving path never differentiates); it is exact and
keeps the Pallas lane usable under ``jax.grad``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.flash_attention import kernel as K

NEG_INF = K.NEG_INF


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round8(n: int) -> int:
    """Smallest multiple of 8 >= n (sublane granularity)."""
    return max(8, ((n + 7) // 8) * 8)


def _forward(q, k, v, causal, scale, bq, bk, interpret):
    B, T, H, Dh = q.shape
    S = k.shape[1]

    bq_ = min(bq, _round8(T))
    bk_ = min(bk, _round8(S))
    pad_t = (-T) % bq_
    pad_s = (-S) % bk_

    qt = jnp.moveaxis(q, 2, 1)                       # (B, H, T, Dh)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_t:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))

    out = K.flash_attention_kernel(qt, kt, vt, causal=causal, scale=scale,
                                   bq=bq_, bk=bk_, kv_valid=S,
                                   interpret=interpret)
    out = out[:, :, :T, :]
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attention(q, k, v, causal, scale, bq, bk, interpret):
    return _forward(q, k, v, causal, scale, bq, bk, interpret)


def _vjp_fwd(q, k, v, causal, scale, bq, bk, interpret):
    return _forward(q, k, v, causal, scale, bq, bk, interpret), (q, k, v)


def _vjp_bwd(causal, scale, bq, bk, interpret, res, g):
    """Dense analytic backward (recomputes p; O(T*S) scores)."""
    q, k, v = res
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    f32 = jnp.float32
    qg = q.reshape(B, T, KV, G, Dh).astype(f32)
    kk = k.astype(f32)
    vv = v.astype(f32)
    gg = g.reshape(B, T, KV, G, Dh).astype(f32)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, kk) * scale
    if causal:
        mask = jnp.arange(T)[:, None] >= jnp.arange(S)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bkgts,btkgd->bskd", p, gg)
    dp = jnp.einsum("btkgd,bskd->bkgts", gg, vv)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bkgts,bskd->btkgd", ds, kk) * scale
    dk = jnp.einsum("bkgts,btkgd->bskd", ds, qg) * scale
    dq = dq.reshape(B, T, H, Dh).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention.defvjp(_vjp_fwd, _vjp_bwd)

_entry = jax.jit(_flash_attention, static_argnums=(3, 4, 5, 6, 7))


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = False, scale: Optional[float] = None,
                    bq: Optional[int] = None, bk: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Drop-in for models.attention.sdpa (training/prefill path).

    q: (B, T, H, Dh); k/v: (B, S, KV, Dh).  Returns (B, T, H, Dh).
    ``bq``/``bk`` default to the autotuned block sizes for this shape
    bucket (kernel defaults when untuned).  Differentiable.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    scale = float(Dh ** -0.5 if scale is None else scale)

    if bq is None or bk is None:
        tuned = autotune.block(
            "flash_attention",
            autotune.flash_bucket(B, T, S, H, KV, Dh, causal, q.dtype),
            {"bq": K.DEFAULT_BQ, "bk": K.DEFAULT_BK})
        bq = tuned["bq"] if bq is None else bq
        bk = tuned["bk"] if bk is None else bk

    return _entry(q, k, v, bool(causal), scale, int(bq), int(bk),
                  bool(interpret))
