"""Flash attention Pallas TPU kernel (online-softmax tiling, GQA-aware).

Tiling: grid = (batch, q_heads, n_q_blocks, n_kv_blocks); the LAST grid
axis iterates sequentially on TPU, so the kv axis is the accumulation
loop.  Per program the VMEM working set is

    q    (BQ, Dh)      one query block of one head
    k,v  (BK, Dh)      one kv block of the matching kv head (GQA: the
                       index_map folds h -> h // group into the kv head
                       axis, so grouped queries re-read the same kv block
                       from HBM — on TPU this is served by VMEM locality
                       across consecutive grid steps)
    acc  (BQ, Dh) f32  output accumulator   (scratch, persists over kv)
    m, l (BQ, 128) f32 running max / sum    (scratch)

Block shapes default to BQ = BK = 128 — MXU-aligned (the two matmuls are
(BQ x Dh) @ (Dh x BK) and (BQ x BK) @ (BK x Dh); with Dh in {64, 128}
every contraction dim is a multiple of the 128x128 MXU tile or exactly
half of it, which Mosaic handles natively).

Causal masking: programs whose kv block lies entirely above the causal
diagonal still run (Pallas TPU grids are dense) but skip the matmuls via
``pl.when`` — only the (rare) diagonal blocks pay for the iota mask.

fp32 softmax throughout; inputs may be bf16/f32 (cast on load).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  n_kv_blocks: int, kv_valid: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: kv block strictly above the q block's last row -> all masked
    q_start = qi * bq
    k_start = ki * bk

    def _body():
        q = q_ref[...].astype(jnp.float32)            # (BQ, Dh)
        k = k_ref[...].astype(jnp.float32)            # (BK, Dh)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal or kv_valid % bk:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = (q_pos >= k_pos) if causal else (k_pos < kv_valid)
            if causal and kv_valid % bk:
                mask = mask & (k_pos < kv_valid)
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                          # (BQ,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)               # rescale of old acc
        p = jnp.exp(s - m_cur[:, None])               # (BQ, BK)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_cur
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:   # skip kv blocks entirely above the causal diagonal
        pl.when(k_start <= q_start + bq - 1)(_body)
    else:
        _body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        # fully-masked rows (causal padding) have l == 0 -> emit zeros
        inv = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[...] = (acc_ref[...] * inv[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool, scale: float,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           kv_valid: int = 0,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, T, Dh); k/v: (B, KV, S, Dh); H = KV * G.  T % bq == 0,
    S % bk == 0 (ops.py pads).  ``kv_valid``: number of real (unpadded)
    keys; 0 means all S.  Returns (B, H, T, Dh) in q.dtype."""
    B, H, T, Dh = q.shape
    KV, S = k.shape[1], k.shape[2]
    group = H // KV
    n_q = T // bq
    n_k = S // bk
    kv_valid = kv_valid or S

    grid = (B, H, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
        n_kv_blocks=n_k, kv_valid=kv_valid)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, Dh),
                         lambda b, h, q_, k_: (b, h, q_, 0)),
            pl.BlockSpec((None, None, bk, Dh),
                         lambda b, h, q_, k_: (b, h // group, k_, 0)),
            pl.BlockSpec((None, None, bk, Dh),
                         lambda b, h, q_, k_: (b, h // group, k_, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, Dh),
                               lambda b, h, q_, k_: (b, h, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
