"""ViTDet window attention Pallas TPU kernel.

The mixed-resolution sequence (core.mixed_res) is *window-blocked*: the
token stream is a concatenation of independent w*w-token windows, and
window attention is dense attention inside each window — the paper's
§III hot path (all but one block per ViTDet subset are window blocks).

Tiling: windows are tiny (w^2 = 64 tokens for w = 8) so a single window
underfills the 128x128 MXU.  The kernel therefore processes ``WB``
windows per program as a batched dot:

    grid = (n_windows / WB, heads)
    q,k,v block (WB, W2p, Dh)   VMEM, W2p = w^2 padded to sublane(8)
    scores      (WB, W2p, W2p)  fp32, formed and consumed in VMEM

With WB = 8, Dh = 64: working set = 3*8*64*64*2B (qkv bf16) + 8*64*64*4B
(scores f32) ~= 1.3 MiB — comfortably inside the ~16 MiB VMEM budget,
and the batched (8x64x64)@(8x64x64) dot keeps the MXU pipeline full.

No causal mask (ViT windows are bidirectional); padded token rows are
masked by a static ``w2_valid`` length (windows like 9x9 = 81 pad to 88).
GQA is supported through the kv index_map (h -> h // group) although
ViTDet itself uses MHA (H == KV).

Length-bucketed padded sequences (core.partition.PlanLayout) add a
second, *runtime* mask next to the static ``w2_valid``: a per-window
valid flag (``win_flags``, (BW, 1) i32).  Window attention is
window-local, so a pad window never influences a valid one — the flag
only zeroes the pad windows' own outputs, keeping padded lanes
deterministic on every backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_WB = 8
NEG_INF = -2.0 ** 30


def _window_attend(q_ref, k_ref, v_ref, *, scale: float, w2_valid: int):
    q = q_ref[...].astype(jnp.float32)               # (WB, W2p, Dh)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    WB, W2p, _ = q.shape

    # batched dot: contract Dh, batch over the window axis
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale  # (WB, W2p, W2p)
    if w2_valid < W2p:
        k_pos = jax.lax.broadcasted_iota(jnp.int32, (WB, W2p, W2p), 2)
        s = jnp.where(k_pos < w2_valid, s, NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # (WB, W2p, Dh)


def _window_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float,
                   w2_valid: int):
    o = _window_attend(q_ref, k_ref, v_ref, scale=scale, w2_valid=w2_valid)
    o_ref[...] = o.astype(o_ref.dtype)


def _window_kernel_flagged(q_ref, k_ref, v_ref, f_ref, o_ref, *,
                           scale: float, w2_valid: int):
    """Variant with a per-window runtime valid flag: pad windows (flag 0)
    emit zeros instead of attention over their replicated content."""
    o = _window_attend(q_ref, k_ref, v_ref, scale=scale, w2_valid=w2_valid)
    flags = f_ref[...]                               # (WB, 1) int32
    o = jnp.where(flags[:, :, None] > 0, o, 0.0)
    o_ref[...] = o.astype(o_ref.dtype)


def window_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            *, scale: float, w2_valid: int,
                            wb: int = DEFAULT_WB,
                            interpret: bool = True,
                            win_flags: jnp.ndarray = None) -> jnp.ndarray:
    """q: (BW, H, W2p, Dh); k/v: (BW, KV, W2p, Dh).  BW = batch*windows,
    BW % wb == 0, W2p % 8 == 0 (ops.py pads).  Returns q-shaped output.

    ``win_flags``: optional (BW, 1) i32 per-window valid flag (1 = real
    window, 0 = length-bucket pad — output zeroed)."""
    BW, H, W2p, Dh = q.shape
    KV = k.shape[1]
    group = H // KV
    in_specs = [
        pl.BlockSpec((wb, None, W2p, Dh), lambda i, h: (i, h, 0, 0)),
        pl.BlockSpec((wb, None, W2p, Dh),
                     lambda i, h: (i, h // group, 0, 0)),
        pl.BlockSpec((wb, None, W2p, Dh),
                     lambda i, h: (i, h // group, 0, 0)),
    ]
    args = (q, k, v)
    if win_flags is None:
        kernel = functools.partial(_window_kernel, scale=scale,
                                   w2_valid=w2_valid)
    else:
        kernel = functools.partial(_window_kernel_flagged, scale=scale,
                                   w2_valid=w2_valid)
        in_specs.append(pl.BlockSpec((wb, 1), lambda i, h: (i, 0)))
        args = (q, k, v, win_flags.astype(jnp.int32))
    return pl.pallas_call(
        kernel,
        grid=(BW // wb, H),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((wb, None, W2p, Dh),
                               lambda i, h: (i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BW, H, W2p, Dh), q.dtype),
        interpret=interpret,
    )(*args)
