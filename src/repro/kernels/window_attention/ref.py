"""Pure-jnp oracle for window attention: dense attention per window."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def window_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         window: int,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, T, H, Dh); k/v: (B, T, KV, Dh); T % window == 0.
    Each contiguous ``window``-token block attends only to itself."""
    B, T, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    W = T // window
    scale = Dh ** -0.5 if scale is None else scale

    qw = q.reshape(B, W, window, KV, G, Dh).astype(jnp.float32)
    kw = k.reshape(B, W, window, KV, Dh).astype(jnp.float32)
    vw = v.reshape(B, W, window, KV, Dh).astype(jnp.float32)
    s = jnp.einsum("bwikgd,bwjkd->bwkgij", qw, kw) * scale
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bwkgij,bwjkd->bwikgd", p, vw)
    return o.reshape(B, T, H, Dh).astype(q.dtype)
