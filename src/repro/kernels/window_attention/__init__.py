from repro.kernels.window_attention.ops import window_attention  # noqa: F401
