"""jit'd entry point for the window attention kernel.

Reshapes the window-blocked (B, T, H, Dh) stream into per-window blocks,
pads w^2 to the sublane granularity and the window count to WB, and
dispatches the Pallas kernel (interpret mode off-TPU).

The entry point carries a ``jax.custom_vjp``: the forward runs the
Pallas kernel, the backward is the analytic softmax-attention gradient
recomputed in plain jnp per window (windows are tiny — w^2 x w^2 scores
— so the O(w^4) recompute is cheap and exact).  This keeps the Pallas
lane differentiable, so ``dispatch.resolve(None)`` no longer forces XLA
for gradient safety.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.window_attention import kernel as K


def _forward(q, k, v, valid_f, window, scale, wb, interpret):
    B, T, H, Dh = q.shape
    KV = k.shape[2]
    W = T // window

    w2p = ((window + 7) // 8) * 8

    def to_blocks(x, heads):
        x = x.reshape(B * W, window, heads, Dh)
        x = jnp.moveaxis(x, 2, 1)                    # (BW, heads, w2, Dh)
        if w2p != window:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, w2p - window), (0, 0)))
        return x

    flags = None
    if valid_f is not None:
        flags = (jnp.arange(W)[None, :] < valid_f[:, None]) \
            .astype(jnp.int32).reshape(B * W, 1)
    out = K.window_attention_kernel(
        to_blocks(q, H), to_blocks(k, KV), to_blocks(v, KV),
        scale=scale, w2_valid=window, wb=wb, interpret=interpret,
        win_flags=flags)
    out = jnp.moveaxis(out[:, :, :window, :], 1, 2)  # (BW, w2, H, Dh)
    return out.reshape(B, T, H, Dh)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _window_attention(q, k, v, valid_f, window, scale, wb, interpret):
    return _forward(q, k, v, valid_f, window, scale, wb, interpret)


def _vjp_fwd(q, k, v, valid_f, window, scale, wb, interpret):
    out = _forward(q, k, v, valid_f, window, scale, wb, interpret)
    return out, (q, k, v, valid_f)


def _vjp_bwd(window, scale, wb, interpret, res, g):
    """Analytic per-window softmax-attention backward (pure jnp).

    dv = p^T g;  dp = g v^T;  ds = p * (dp - sum_s(dp * p));
    dq = ds k * scale;  dk = ds^T q * scale.  Pad windows (beyond
    ``valid_f``) emit constant zeros in the forward, so their output
    cotangent is masked off before the recompute.
    """
    q, k, v, valid_f = res
    B, T, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    W = T // window
    f32 = jnp.float32
    qw = q.reshape(B, W, window, KV, G, Dh).astype(f32)
    kw = k.reshape(B, W, window, KV, Dh).astype(f32)
    vw = v.reshape(B, W, window, KV, Dh).astype(f32)
    gw = g.reshape(B, W, window, KV, G, Dh).astype(f32)
    if valid_f is not None:
        keep = (jnp.arange(W)[None, :] < valid_f[:, None]).astype(f32)
        gw = gw * keep[:, :, None, None, None, None]
    s = jnp.einsum("bwtkgd,bwskd->bwkgts", qw, kw) * scale
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bwkgts,bwtkgd->bwskd", p, gw)
    dp = jnp.einsum("bwtkgd,bwskd->bwkgts", gw, vw)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bwkgts,bwskd->bwtkgd", ds, kw) * scale
    dk = jnp.einsum("bwkgts,bwtkgd->bwskd", ds, qw) * scale
    dq = dq.reshape(B, T, H, Dh).astype(q.dtype)
    dk = dk.reshape(B, T, KV, Dh).astype(k.dtype)
    dv = dv.reshape(B, T, KV, Dh).astype(v.dtype)
    dvalid = None if valid_f is None else jnp.zeros_like(valid_f)
    return dq, dk, dv, dvalid


_window_attention.defvjp(_vjp_fwd, _vjp_bwd)

_entry = jax.jit(_window_attention, static_argnums=(4, 5, 6, 7))


def window_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     window: int, *, scale: Optional[float] = None,
                     win_valid: Optional[jnp.ndarray] = None,
                     wb: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Drop-in for models.attention.window_sdpa.

    q: (B, T, H, Dh); k/v: (B, T, KV, Dh); T % window == 0.
    ``win_valid``: optional (B,) i32 valid-window counts (length-bucketed
    padded sequences) — pad windows' outputs are zeroed in-kernel.
    ``wb=None`` picks the autotuned window-block tiling for this shape
    bucket (kernel default when untuned).  Differentiable.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, H, Dh = q.shape
    W = T // window
    scale = float(Dh ** -0.5 if scale is None else scale)

    if wb is None:
        wb = autotune.block(
            "window_attention",
            autotune.window_bucket(B, T, H, Dh, window, q.dtype),
            {"wb": K.DEFAULT_WB})["wb"]
    wb = min(int(wb), B * W)
    while (B * W) % wb:
        wb //= 2

    valid_f = None
    if win_valid is not None:
        # float32 so the custom-VJP boundary has a float cotangent
        # (int primals would need float0 plumbing)
        valid_f = jnp.asarray(win_valid).astype(jnp.float32)
    return _entry(q, k, v, valid_f, window, scale, wb, bool(interpret))
