"""jit'd entry point for the window attention kernel.

Reshapes the window-blocked (B, T, H, Dh) stream into per-window blocks,
pads w^2 to the sublane granularity and the window count to WB, and
dispatches the Pallas kernel (interpret mode off-TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.window_attention import kernel as K


@functools.partial(jax.jit, static_argnames=("window", "scale", "wb",
                                             "interpret"))
def window_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     window: int, *, scale: Optional[float] = None,
                     win_valid: Optional[jnp.ndarray] = None,
                     wb: int = K.DEFAULT_WB,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Drop-in for models.attention.window_sdpa.

    q: (B, T, H, Dh); k/v: (B, T, KV, Dh); T % window == 0.
    ``win_valid``: optional (B,) i32 valid-window counts (length-bucketed
    padded sequences) — pad windows' outputs are zeroed in-kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, H, Dh = q.shape
    KV = k.shape[2]
    W = T // window
    scale = Dh ** -0.5 if scale is None else scale

    w2p = ((window + 7) // 8) * 8
    wb = min(wb, B * W)
    while (B * W) % wb:
        wb //= 2

    def to_blocks(x, heads):
        x = x.reshape(B * W, window, heads, Dh)
        x = jnp.moveaxis(x, 2, 1)                    # (BW, heads, w2, Dh)
        if w2p != window:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, w2p - window), (0, 0)))
        return x

    flags = None
    if win_valid is not None:
        flags = (jnp.arange(W)[None, :] < win_valid[:, None]) \
            .astype(jnp.int32).reshape(B * W, 1)
    out = K.window_attention_kernel(
        to_blocks(q, H), to_blocks(k, KV), to_blocks(v, KV),
        scale=scale, w2_valid=window, wb=wb, interpret=interpret,
        win_flags=flags)
    out = jnp.moveaxis(out[:, :, :window, :], 1, 2)  # (BW, w2, H, Dh)
    return out.reshape(B, T, H, Dh)
