"""Pure-jnp oracles for the fused serving kernels (parity targets).

Both ops are data movement (+ one add), so kernel vs ref parity is
exact bitwise equality, not a tolerance.
"""
from __future__ import annotations

import jax.numpy as jnp


def fused_pack_pos_ref(bank: jnp.ndarray, pos_bank: jnp.ndarray,
                       win_src: jnp.ndarray, nw: jnp.ndarray) -> jnp.ndarray:
    """bank: (B, nbank, w2, C); pos_bank: (nbank, w2, C);
    win_src: (B, nw_pad); nw: (B,).  Returns (B, nw_pad, w2, C)."""
    B, _, w2, C = bank.shape
    nw_pad = win_src.shape[1]
    packed = jnp.take_along_axis(bank, win_src[:, :, None, None], axis=1)
    pos = pos_bank[win_src]                          # (B, nw_pad, w2, C)
    valid = jnp.arange(nw_pad)[None, :] < nw[:, None]
    return jnp.where(valid[:, :, None, None], packed + pos,
                     jnp.zeros((), bank.dtype))


def fused_restore_ref(src: jnp.ndarray, maps: jnp.ndarray,
                      out_src: jnp.ndarray,
                      out_map: jnp.ndarray) -> jnp.ndarray:
    """src: (B, nsrc, w2, D); maps: (d^2+1, w2) i32 token gather maps;
    out_src/out_map: (B, nout).  Returns (B, nout, w2, D)."""
    blk = jnp.take_along_axis(src, out_src[:, :, None, None], axis=1)
    sel = maps[out_map]                              # (B, nout, w2)
    return jnp.take_along_axis(blk, sel[..., None], axis=2)
