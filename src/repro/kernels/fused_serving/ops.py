"""Entry points for the fused serving prologue/epilogue kernels.

Normalises the PlanLayout arrays to per-sample rank, builds the static
upsample permutation tables, appends the reuse-tile bank, and dispatches
the Pallas kernels (interpret mode off-TPU).

Token-map convention (``upsample_token_maps``): the restoration's
nearest-neighbour upsample sends LOW-window token ``t`` of sub-window
``k = di*d + dj`` to low token ``((di*w + wi)//d)*w + (dj*w + wj)//d``
with ``t = wi*w + wj`` — map 0 is the identity (FULL windows and reuse
tiles copy through unchanged).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_serving import kernel as K


@functools.lru_cache(maxsize=None)
def upsample_token_maps(window: int, downsample: int) -> np.ndarray:
    """(d^2 + 1, w^2) i32: maps[0] identity; maps[k+1][t] = the low-window
    token that nearest-neighbour upsampling replicates into token ``t``
    of full-region sub-window ``k`` (mixed_res._upsample_low_windows)."""
    w, d = window, downsample
    w2, dd = w * w, d * d
    maps = np.zeros((dd + 1, w2), np.int32)
    maps[0] = np.arange(w2)
    t = np.arange(w2)
    wi, wj = t // w, t % w
    for di in range(d):
        for dj in range(d):
            maps[di * d + dj + 1] = ((di * w + wi) // d) * w \
                + (dj * w + wj) // d
    return maps


@functools.lru_cache(maxsize=None)
def _perm_table(window: int, downsample: int) -> np.ndarray:
    """One-hot f32 permutation matrices of :func:`upsample_token_maps`:
    (d^2 + 1, w^2, w^2) with perm[m][t, maps[m][t]] = 1."""
    maps = upsample_token_maps(window, downsample)
    n, w2 = maps.shape
    perm = np.zeros((n, w2, w2), np.float32)
    perm[np.arange(n)[:, None], np.arange(w2)[None, :], maps] = 1.0
    return perm


def _per_sample(ids, B: int) -> jnp.ndarray:
    ids = jnp.asarray(ids, jnp.int32)
    if ids.ndim == 1:
        ids = jnp.broadcast_to(ids[None], (B,) + ids.shape)
    return ids


def fused_pack_pos(bank: jnp.ndarray, pos_bank: jnp.ndarray,
                   win_src: jnp.ndarray, nw: jnp.ndarray, *,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused pack + positional add + pad-window zeroing.

    bank: (B, nR*d^2 + nR, w^2, C) window bank (mixed_res.window_bank);
    pos_bank: (nR*d^2 + nR, w^2, C) positional window bank; win_src:
    (nw_pad,) or (B, nw_pad); nw: scalar, (1,) or (B,).  Returns packed
    tokens (B, nw_pad * w^2, C) — bit-identical to
    ``pack_padded(...) + pack_positions_padded(...)`` on valid windows,
    zeros on pad windows.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, w2, C = bank.shape
    src = _per_sample(win_src, B)
    nw = jnp.asarray(nw, jnp.int32).reshape(-1)
    nw = jnp.broadcast_to(nw, (B,))
    out = K.pack_pos_kernel(bank, pos_bank, src, nw, interpret=interpret)
    return out.reshape(B, src.shape[1] * w2, C)


def fused_restore(windows: jnp.ndarray, out_src: jnp.ndarray,
                  out_map: jnp.ndarray, window: int, downsample: int,
                  reuse_tiles: Optional[jnp.ndarray] = None, *,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused destination-major restoration gather.

    windows: (B, nw_pad, w^2, D) packed post-block activations; out_src /
    out_map: (nout,) or (B, nout) PlanLayout inverse maps (nout =
    nR*d^2; reuse sources are offset by nw_pad into the tile bank);
    reuse_tiles: optional (B, nR, d^2, w^2, D).  Returns the full-res
    window-blocked sequence (B, nout * w^2, D) — bit-identical to
    ``mixed_res.restore_padded`` (pure data movement; the one-hot matmul
    selects exactly one finite row).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, w2, D = windows.shape
    src_idx = _per_sample(out_src, B)
    map_idx = _per_sample(out_map, B)
    nout = src_idx.shape[1]
    if reuse_tiles is None:
        tiles = jnp.zeros((B, nout, w2, D), windows.dtype)
    else:
        tiles = reuse_tiles.astype(windows.dtype).reshape(B, -1, w2, D)
    src = jnp.concatenate([windows, tiles], axis=1)
    perm = jnp.asarray(_perm_table(window, downsample))
    out = K.restore_gather_kernel(src, perm, src_idx, map_idx,
                                  interpret=interpret)
    return out.reshape(B, nout * w2, D)
