from repro.kernels.fused_serving.ops import (  # noqa: F401
    fused_pack_pos, fused_restore, upsample_token_maps)
