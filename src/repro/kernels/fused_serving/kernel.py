"""Fused serving prologue/epilogue Pallas TPU kernels.

The padded serving forward used to be a chain of separate dispatches —
window-bank gather (pack), positional-embedding add, pad-window zeroing,
then after the blocks a sentinel-row scatter + low-window upsample +
reuse-tile splice (restore) — each materialising the full packed
sequence in HBM.  These two kernels collapse each seam into one pass:

  pack_pos_kernel       out[b, i] = bank[b, win_src[b, i]]
                                    + pos_bank[win_src[b, i]]   if i < nw[b]
                        else 0
                        One window per program; ``win_src``/``nw`` arrive
                        via scalar prefetch so the bank BlockSpec gathers
                        the source window directly from HBM — the packed
                        sequence is written exactly once.

  restore_gather_kernel out[b, o] = perm[out_map[b, o]]
                                    @ src[b, out_src[b, o]]
                        The destination-major inverse of the restoration
                        scatter: every output window (full-res grid slot)
                        gathers its source window — a packed FULL window
                        (perm 0 = identity), the region's LOW window
                        through one of d^2 upsample permutations, or a
                        spliced reuse tile.  ``perm`` is a small
                        (d^2+1, w^2, w^2) one-hot table; the gather is an
                        MXU matmul (one-hot f32 rows select exactly one
                        finite activation row, so the product is
                        bit-identical to the scatter formulation).

Both kernels are pure data movement + one add/matmul, so their Pallas
and jnp reference paths agree bitwise (tests/test_fused_serving.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_pos_body(src_ref, nw_ref, bank_ref, pos_ref, o_ref):
    b = pl.program_id(0)
    i = pl.program_id(1)
    x = bank_ref[...] + pos_ref[...]
    o_ref[...] = jnp.where(i < nw_ref[b], x, jnp.zeros_like(x))


def pack_pos_kernel(bank: jnp.ndarray, pos_bank: jnp.ndarray,
                    win_src: jnp.ndarray, nw: jnp.ndarray, *,
                    interpret: bool = True) -> jnp.ndarray:
    """bank: (B, nbank, w2, C); pos_bank: (nbank, w2, C);
    win_src: (B, nw_pad) i32; nw: (B,) i32.  Returns (B, nw_pad, w2, C)
    packed windows with positions added and pad windows zeroed."""
    B, nbank, w2, C = bank.shape
    nw_pad = win_src.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nw_pad),
        in_specs=[
            pl.BlockSpec((None, None, w2, C),
                         lambda b, i, src, nw_: (b, src[b, i], 0, 0)),
            pl.BlockSpec((None, w2, C),
                         lambda b, i, src, nw_: (src[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, w2, C),
                               lambda b, i, src, nw_: (b, i, 0, 0)),
    )
    return pl.pallas_call(
        _pack_pos_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nw_pad, w2, C), bank.dtype),
        interpret=interpret,
    )(win_src, nw, bank, pos_bank)


def _restore_body(src_idx_ref, map_idx_ref, src_ref, perm_ref, o_ref):
    blk = src_ref[...].astype(jnp.float32)            # (w2, D)
    o_ref[...] = jax.lax.dot_general(
        perm_ref[...], blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def restore_gather_kernel(src: jnp.ndarray, perm: jnp.ndarray,
                          out_src: jnp.ndarray, out_map: jnp.ndarray, *,
                          interpret: bool = True) -> jnp.ndarray:
    """src: (B, nsrc, w2, D) source bank [packed windows | reuse tiles];
    perm: (d^2+1, w2, w2) f32 one-hot token permutations; out_src /
    out_map: (B, nout) i32.  Returns (B, nout, w2, D) restored windows
    in full-res grid slot order."""
    B, nsrc, w2, D = src.shape
    nout = out_src.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nout),
        in_specs=[
            pl.BlockSpec((None, None, w2, D),
                         lambda b, o, si, mi: (b, si[b, o], 0, 0)),
            pl.BlockSpec((None, w2, w2),
                         lambda b, o, si, mi: (mi[b, o], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, w2, D),
                               lambda b, o, si, mi: (b, o, 0, 0)),
    )
    return pl.pallas_call(
        _restore_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nout, w2, D), src.dtype),
        interpret=interpret,
    )(out_src, out_map, src, perm)
