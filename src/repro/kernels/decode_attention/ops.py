"""jit'd entry point for the decode attention kernel.

Folds the H = KV x G query heads into per-kv-head groups (the kernel's
matmul rows), pads G to sublane granularity and S to the cache block,
and dispatches with interpret mode off-TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.decode_attention import kernel as K


@functools.partial(jax.jit, static_argnames=("scale", "bs", "interpret"))
def _decode_jit(q, k, v, kv_len, *, scale, bs, interpret):
    B, _, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV

    Gp = max(8, ((G + 7) // 8) * 8)
    bs_ = min(bs, max(8, S))
    pad_s = (-S) % bs_

    qg = q.reshape(B, KV, G, Dh)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    kt = jnp.moveaxis(k, 2, 1)                        # (B, KV, S, Dh)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    # padded cache rows sit beyond kv_len so the in-kernel mask drops them

    out = K.decode_attention_kernel(qg, kt, vt, kv_len.astype(jnp.int32),
                                    scale=scale, bs=bs_,
                                    interpret=interpret)
    return out[:, :, :G, :].reshape(B, 1, H, Dh)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray, *, scale: Optional[float] = None,
                     bs: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Drop-in for the decode path of models.attention (sdpa with kv_len).

    q: (B, 1, H, Dh) single-token queries; k/v: (B, S, KV, Dh) cache;
    kv_len: (B,) int32 valid lengths.  Returns (B, 1, H, Dh).
    ``bs=None`` picks the autotuned cache-block size for this shape
    bucket (kernel default when untuned).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    scale = float(Dh ** -0.5 if scale is None else scale)
    if bs is None:
        bs = autotune.block(
            "decode_attention",
            autotune.decode_bucket(B, S, H, KV, Dh, q.dtype),
            {"bs": K.DEFAULT_BS})["bs"]
    return _decode_jit(q, k, v, kv_len, scale=scale, bs=int(bs),
                       interpret=bool(interpret))
