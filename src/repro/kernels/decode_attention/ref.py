"""Pure-jnp oracle for decode attention (dense masked softmax)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         kv_len: jnp.ndarray,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, 1, H, Dh); k/v: (B, S, KV, Dh); kv_len: (B,) valid lengths.
    Returns (B, 1, H, Dh)."""
    B, _, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = Dh ** -0.5 if scale is None else scale
    qg = q.reshape(B, KV, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None, :] < kv_len[:, None]            # (B, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, Dh).astype(q.dtype)
