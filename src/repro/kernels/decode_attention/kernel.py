"""One-token GQA decode attention Pallas TPU kernel (flash-decode style).

Serving decode reads a (B, S, KV, Dh) cache with one fresh query token;
the op is memory-bound (arithmetic intensity ~ 1 FLOP/byte), so the
kernel's job is to stream the cache through VMEM exactly once at full
HBM bandwidth while the VPU does the online softmax.

Tiling: grid = (B, KV, n_s_blocks); the cache axis iterates sequentially
and accumulates in VMEM scratch.  The G = H/KV grouped queries of one kv
head form the row axis of the matmuls (padded to sublane granularity),
so GQA grouping is what provides MXU rows — the bigger the group, the
better the utilisation (the roofline §Perf notes rely on this).

    q     (Gp, Dh)        one kv head's query group
    k,v   (BS, Dh)        one cache block
    acc   (Gp, Dh) f32    output accumulator (scratch)
    m, l  (Gp, 128) f32   running max / sum  (scratch)

Per-sequence valid lengths arrive via scalar prefetch (kv_len, int32
(B,)): blocks entirely beyond kv_len[b] are skipped with ``pl.when`` —
for a half-full ring buffer this halves the HBM traffic, which is the
whole cost of decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 512
NEG_INF = -2.0 ** 30


def _decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, bs: int,
                   n_s_blocks: int):
    b = pl.program_id(0)
    si = pl.program_id(2)
    kv_len = kv_len_ref[b]

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s_start = si * bs

    @pl.when(s_start < kv_len)       # skip blocks beyond the valid cache
    def _body():
        q = q_ref[...].astype(jnp.float32)           # (Gp, Dh)
        k = k_ref[...].astype(jnp.float32)           # (BS, Dh)
        v = v_ref[...].astype(jnp.float32)
        Gp = q.shape[0]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = s_start + jax.lax.broadcasted_iota(jnp.int32, (Gp, bs), 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_cur
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(si == n_s_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        inv = jnp.where(l > 0.0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[...] = (acc_ref[...] * inv[:, None]).astype(o_ref.dtype)


def decode_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            kv_len: jnp.ndarray, *, scale: float,
                            bs: int = DEFAULT_BS,
                            interpret: bool = True) -> jnp.ndarray:
    """q: (B, KV, Gp, Dh); k/v: (B, KV, S, Dh); kv_len: (B,) int32.
    S % bs == 0 (ops.py pads).  Returns (B, KV, Gp, Dh) in q.dtype."""
    B, KV, Gp, Dh = q.shape
    S = k.shape[2]
    n_s = S // bs
    kernel = functools.partial(_decode_kernel, scale=scale, bs=bs,
                               n_s_blocks=n_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec((None, None, Gp, Dh),
                         lambda b, h, s, kv_len: (b, h, 0, 0)),
            pl.BlockSpec((None, None, bs, Dh),
                         lambda b, h, s, kv_len: (b, h, s, 0)),
            pl.BlockSpec((None, None, bs, Dh),
                         lambda b, h, s, kv_len: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, Gp, Dh),
                               lambda b, h, s, kv_len: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Gp, Dh), jnp.float32),
            pltpu.VMEM((Gp, 128), jnp.float32),
            pltpu.VMEM((Gp, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, Gp, Dh), q.dtype),
        interpret=interpret,
    )(kv_len, q, k, v)
