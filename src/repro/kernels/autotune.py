"""Block-size autotuner for the Pallas serving kernels.

The kernels' tile sizes (flash ``bq``/``bk``, window-attention ``wb``,
decode ``bs``) are fixed defaults chosen for one TPU generation; the
right values differ per device kind and per shape regime.  This module
sweeps a small candidate grid at ``warmup()`` time, times each candidate
on the device, and caches the winner on disk keyed
``(device kind, kernel, shape bucket)`` so later processes skip the
sweep entirely.

Knobs:

  ``REPRO_AUTOTUNE=0``       disable — every lookup returns the fixed
                             default (the escape hatch for CI or when a
                             stale cache misbehaves).
  ``REPRO_AUTOTUNE_CACHE``   override the cache directory
                             (default ``~/.cache/repro/autotune``).

Shape buckets round every dynamic dimension up to a power of two so the
cache stays bounded; a lookup miss always falls back to the kernel's
fixed default, never to a sweep in the hot path — sweeps only run from
the explicit ``tune_*`` entry points called by warmup.

Off-TPU the kernels run in interpret mode, where candidate timings are
meaningless; sweeps are skipped there unless ``force=True`` (unit tests
exercise the machinery that way).
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ENV_VAR = "REPRO_AUTOTUNE"
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

# candidate grids per kernel; first entry is never assumed — the fixed
# ops.py default is always a candidate so tuning can only tie or win.
FLASH_CANDIDATES = ({"bq": 128, "bk": 128}, {"bq": 128, "bk": 256},
                    {"bq": 256, "bk": 256}, {"bq": 256, "bk": 512},
                    {"bq": 512, "bk": 512})
WINDOW_CANDIDATES = ({"wb": 4}, {"wb": 8}, {"wb": 16}, {"wb": 32})
DECODE_CANDIDATES = ({"bs": 256}, {"bs": 512}, {"bs": 1024})
MATMUL_CANDIDATES = ({"bm": 128, "bn": 128, "bk": 128},
                     {"bm": 256, "bn": 128, "bk": 128},
                     {"bm": 128, "bn": 256, "bk": 256},
                     {"bm": 256, "bn": 256, "bk": 256},
                     {"bm": 512, "bn": 256, "bk": 256})

_LOCK = threading.Lock()
_TABLE: Dict[str, Dict[str, Dict]] = {}     # kernel -> bucket_key -> entry
_LOADED_FOR: Optional[str] = None           # device kind the table is for

_ENABLED: bool = os.environ.get(ENV_VAR, "1") != "0"


def refresh_from_env() -> bool:
    """Re-read ``REPRO_AUTOTUNE`` (tests that monkeypatch the env).

    ``enabled()`` sits on the kernel-dispatch hot path (every ops.py
    block-size resolution calls ``lookup``), so the env var is read once
    at import and cached — same convention as kernels.dispatch."""
    global _ENABLED
    _ENABLED = os.environ.get(ENV_VAR, "1") != "0"
    return _ENABLED


def enabled() -> bool:
    return _ENABLED


def device_kind() -> str:
    kind = jax.devices()[0].device_kind
    return re.sub(r"[^A-Za-z0-9._-]+", "_", kind)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cache_dir() -> Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune"


def cache_path(kind: Optional[str] = None) -> Path:
    return cache_dir() / f"{kind or device_kind()}.json"


def bucket_key(**dims) -> str:
    """Canonical bucket string: dims sorted by name, dynamic sizes
    rounded up to the next power of two."""
    parts = []
    for name in sorted(dims):
        val = dims[name]
        if isinstance(val, (int,)) and not isinstance(val, bool):
            val = _pow2(val)
        parts.append(f"{name}={val}")
    return ",".join(parts)


def _pow2(n: int) -> int:
    p = 1
    while p < max(1, n):
        p *= 2
    return p


# ---------------------------------------------------------------------------
# cache table


def _load(kind: str) -> None:
    global _LOADED_FOR
    if _LOADED_FOR == kind:
        return
    _TABLE.clear()
    path = cache_path(kind)
    try:
        _TABLE.update(json.loads(path.read_text()))
    except (OSError, ValueError):
        pass
    _LOADED_FOR = kind


def _save(kind: str) -> None:
    path = cache_path(kind)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(_TABLE, indent=1, sort_keys=True))
        tmp.replace(path)
    except OSError:
        pass                            # cache is best-effort


def clear_memory_cache() -> None:
    """Drop the in-process table (tests; the disk file is untouched)."""
    global _LOADED_FOR
    with _LOCK:
        _TABLE.clear()
        _LOADED_FOR = None


def lookup(kernel: str, bucket: str) -> Optional[Dict]:
    """Tuned params for (kernel, bucket) or None.  Never sweeps."""
    if not enabled():
        return None
    with _LOCK:
        _load(device_kind())
        entry = _TABLE.get(kernel, {}).get(bucket)
    return dict(entry["params"]) if entry else None


def block(kernel: str, bucket: str, default: Dict) -> Dict:
    """Resolved block params: tuned winner if cached, else ``default``."""
    tuned = lookup(kernel, bucket)
    out = dict(default)
    if tuned:
        out.update({k: v for k, v in tuned.items() if k in out})
    return out


def record(kernel: str, bucket: str, params: Dict, us: float) -> None:
    with _LOCK:
        kind = device_kind()
        _load(kind)
        _TABLE.setdefault(kernel, {})[bucket] = {
            "params": dict(params), "us": float(us)}
        _save(kind)


# ---------------------------------------------------------------------------
# sweeping


def _time_us(fn: Callable[[], jnp.ndarray], reps: int = 3) -> float:
    fn().block_until_ready()            # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def tune(kernel: str, bucket: str, candidates: Sequence[Dict],
         bench: Callable[[Dict], Optional[Callable[[], jnp.ndarray]]], *,
         force: bool = False, reps: int = 3) -> Optional[Dict]:
    """Sweep ``candidates`` for (kernel, bucket); cache and return the
    winner.  ``bench(params)`` returns a nullary callable running the
    kernel with those params, or None when the candidate is invalid for
    the shape.  Returns the cached/tuned params, or None when tuning is
    disabled or skipped (off-TPU without force)."""
    if not enabled():
        return None
    cached = lookup(kernel, bucket)
    if cached is not None:
        return cached
    if not (on_tpu() or force):
        return None
    results: List[Tuple[float, Dict]] = []
    for params in candidates:
        fn = bench(dict(params))
        if fn is None:
            continue
        try:
            results.append((_time_us(fn, reps=reps), dict(params)))
        except Exception:               # candidate failed to lower/run
            continue
    if not results:
        return None
    us, params = min(results, key=lambda r: r[0])
    record(kernel, bucket, params, us)
    return params


# ---------------------------------------------------------------------------
# kernel-specific entry points (called from warmup paths)


def window_bucket(B: int, T: int, H: int, Dh: int, window: int,
                  dtype) -> str:
    return bucket_key(bw=B * (T // window), h=H, dh=Dh, w=window,
                      dt=jnp.dtype(dtype).name)


def flash_bucket(B: int, T: int, S: int, H: int, KV: int, Dh: int,
                 causal: bool, dtype) -> str:
    return bucket_key(b=B, t=T, s=S, h=H, kv=KV, dh=Dh, causal=causal,
                      dt=jnp.dtype(dtype).name)


def decode_bucket(B: int, S: int, H: int, KV: int, Dh: int, dtype) -> str:
    return bucket_key(b=B, s=S, h=H, kv=KV, dh=Dh,
                      dt=jnp.dtype(dtype).name)


def matmul_bucket(M: int, N: int, K: int, act_dtype, weight_dtype) -> str:
    """GEMM bucket keyed on BOTH operand dtypes: the int8 lane and a
    half-precision lane have different MXU tile economics, so an int8
    sweep's winner must never answer an fp32/fp16 lookup (or vice
    versa) — the dtype-separation contract tests/test_autotune pins."""
    return bucket_key(m=M, n=N, k=K, adt=jnp.dtype(act_dtype).name,
                      wdt=jnp.dtype(weight_dtype).name)


def tune_window(B: int, T: int, H: int, Dh: int, window: int, *,
                KV: Optional[int] = None, dtype=jnp.float32,
                force: bool = False) -> Optional[Dict]:
    from repro.kernels.window_attention import ops as _win
    KV = H if KV is None else KV
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, T, H, Dh), dtype)
    k = jax.random.normal(rng, (B, T, KV, Dh), dtype)
    v = jax.random.normal(rng, (B, T, KV, Dh), dtype)

    def bench(params):
        wb = params["wb"]
        if wb > B * (T // window):
            return None
        return lambda: _win.window_attention(q, k, v, window, wb=wb)

    return tune("window_attention",
                window_bucket(B, T, H, Dh, window, dtype),
                WINDOW_CANDIDATES, bench, force=force)


def tune_flash(B: int, T: int, S: int, H: int, Dh: int, *,
               KV: Optional[int] = None, causal: bool = False,
               dtype=jnp.float32, force: bool = False) -> Optional[Dict]:
    from repro.kernels.flash_attention import ops as _flash
    KV = H if KV is None else KV
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, T, H, Dh), dtype)
    k = jax.random.normal(rng, (B, S, KV, Dh), dtype)
    v = jax.random.normal(rng, (B, S, KV, Dh), dtype)

    def bench(params):
        return lambda: _flash.flash_attention(
            q, k, v, causal=causal, bq=params["bq"], bk=params["bk"])

    return tune("flash_attention",
                flash_bucket(B, T, S, H, KV, Dh, causal, dtype),
                FLASH_CANDIDATES, bench, force=force)


def tune_decode(B: int, S: int, H: int, Dh: int, *,
                KV: Optional[int] = None, dtype=jnp.float32,
                force: bool = False) -> Optional[Dict]:
    from repro.kernels.decode_attention import ops as _dec
    KV = H if KV is None else KV
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, 1, H, Dh), dtype)
    k = jax.random.normal(rng, (B, S, KV, Dh), dtype)
    v = jax.random.normal(rng, (B, S, KV, Dh), dtype)
    kv_len = jnp.full((B,), S, jnp.int32)

    def bench(params):
        return lambda: _dec.decode_attention(q, k, v, kv_len,
                                             bs=params["bs"])

    return tune("decode_attention", decode_bucket(B, S, H, KV, Dh, dtype),
                DECODE_CANDIDATES, bench, force=force)


def tune_matmul(M: int, N: int, K: int, *, out_dtype=jnp.float32,
                force: bool = False) -> Optional[Dict]:
    """Sweep the int8 GEMM block sizes for an (M, N, K) shape bucket."""
    from repro.kernels.int8_matmul import ops as _mm
    rng = np.random.default_rng(0)
    xq = jnp.asarray(rng.integers(-127, 128, (M, K), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (K, N), dtype=np.int8))
    sx = jnp.ones((M,), jnp.float32)
    sw = jnp.ones((N,), jnp.float32)

    def bench(params):
        return lambda: _mm.int8_matmul(xq, wq, sx, sw,
                                       out_dtype=out_dtype,
                                       bm=params["bm"], bn=params["bn"],
                                       bk=params["bk"])

    return tune("int8_matmul",
                matmul_bucket(M, N, K, jnp.int8, jnp.int8),
                MATMUL_CANDIDATES, bench, force=force)
