"""Kernel backend dispatch layer — routes model hot paths to the Pallas
kernels or the pure-jnp (XLA) reference implementations.

Every dispatch site takes a ``backend`` argument:

  ``"xla"``     pure-jnp path (the original model code) — always available.
  ``"pallas"``  the validated Pallas kernels under ``repro.kernels``;
                off-TPU they run in *interpret* mode (the ops.py
                convention), which is numerically exact but slow — meant
                for parity testing, not performance.
  ``"auto"``    ``"pallas"`` on TPU, ``"xla"`` everywhere else.  Interpret
                mode is a correctness tool, so auto never selects it for
                the hot path.
  ``None``      the process default (``set_backend``), else ``"auto"``.
                The kernels define custom VJPs (window/flash attention
                analytic backward, pooling closed forms), so the bare
                default no longer has to force XLA for gradient safety:
                training code that never mentions a backend rides the
                Pallas lane on TPU and XLA elsewhere.

Backend resolution is a hot-path operation (every attention call in a
traced forward hits it), so the ``REPRO_BACKEND`` environment variable
is read ONCE at import and cached; it still overrides everything —
useful for A/B runs of the benchmark harness without touching call
sites.  Tests that monkeypatch the env var must call
``refresh_from_env()`` afterwards.  Precedence, strongest first:

  env var (cached) > per-call ``backend`` arg > ``set_backend()`` > auto

Only the *shapes the kernels support* are routed to Pallas; anything
else (query offsets, multi-token ``kv_len`` masks) stays on the XLA
path — the dispatcher is a router, not a second implementation.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import ops as _decode
from repro.kernels.flash_attention import ops as _flash
from repro.kernels.fused_serving import ops as _fused
from repro.kernels.int8_matmul import ops as _int8
from repro.kernels.int8_matmul import ref as _int8_ref
from repro.kernels.mixed_res_pool import ops as _pool
from repro.kernels.window_attention import ops as _win

BACKENDS = ("auto", "pallas", "xla")
ENV_VAR = "REPRO_BACKEND"

QUANT_MODES = ("native", "dequant")
QUANT_ENV_VAR = "REPRO_QUANT"

_ENV_BACKEND: Optional[str] = None      # cached env override ('' -> None)
_PROCESS_BACKEND: Optional[str] = None  # set_backend() default
_ENV_QUANT: Optional[str] = None        # cached REPRO_QUANT override
_PROCESS_QUANT: Optional[str] = None    # set_quant_mode() default


def _check(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got "
                         f"{backend!r}")
    return backend


def _check_quant(mode: str) -> str:
    if mode not in QUANT_MODES:
        raise ValueError(f"quant mode must be one of {QUANT_MODES}, got "
                         f"{mode!r}")
    return mode


def refresh_from_env() -> Optional[str]:
    """Re-read the cached env overrides — ``REPRO_BACKEND`` and
    ``REPRO_QUANT`` together (tests that monkeypatch either env).  Both
    are resolved on hot paths (every attention / quantized-matmul call
    in a traced forward), so neither is consulted per call."""
    global _ENV_BACKEND, _ENV_QUANT
    env = os.environ.get(ENV_VAR)
    _ENV_BACKEND = _check(env) if env else None
    qenv = os.environ.get(QUANT_ENV_VAR)
    _ENV_QUANT = _check_quant(qenv) if qenv else None
    return _ENV_BACKEND


def set_backend(backend: Optional[str]) -> None:
    """Set the process-wide default used when a call site passes
    ``backend=None``.  ``None`` restores the built-in ``"auto"``."""
    global _PROCESS_BACKEND
    _PROCESS_BACKEND = _check(backend) if backend is not None else None


def get_backend() -> Optional[str]:
    """The current process default (None when unset)."""
    return _PROCESS_BACKEND


@contextlib.contextmanager
def backend_scope(backend: Optional[str]):
    """Temporarily set the process default (trace-time scoping: wrap a
    jit trace to pin every ``backend=None`` site inside it).  A ``None``
    scope is a no-op — the current default stays in force."""
    if backend is None:
        yield
        return
    prev = _PROCESS_BACKEND
    set_backend(backend)
    try:
        yield
    finally:
        set_backend(prev)


def resolve(backend: Optional[str] = None) -> str:
    """Resolve a backend request to a concrete {"pallas", "xla"} choice."""
    if _ENV_BACKEND is not None:
        backend = _ENV_BACKEND
    elif backend is None:
        backend = _PROCESS_BACKEND if _PROCESS_BACKEND is not None else "auto"
    _check(backend)
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def use_pallas(backend: Optional[str] = None) -> bool:
    return resolve(backend) == "pallas"


# ---------------------------------------------------------------------------
# quantized-execution mode — mirrors the backend machinery exactly.
#
#   "native"   QuantTensor matmuls run the int8 x int8 -> int32 lane
#              (Pallas kernel on the pallas backend, the
#              ``preferred_element_type=int32`` dot_general elsewhere)
#              with dynamic per-row activation quantization.
#   "dequant"  weights are dequantized to float and the plain GEMM runs
#              — the numerically-transparent oracle the parity tests
#              compare the native lane against.
#
# Precedence, strongest first (same contract as backends):
#
#   env REPRO_QUANT (cached) > per-call ``mode`` arg > set_quant_mode()
#   > "native"


def set_quant_mode(mode: Optional[str]) -> None:
    """Process-wide default quant mode for ``mode=None`` call sites.
    ``None`` restores the built-in ``"native"``."""
    global _PROCESS_QUANT
    _PROCESS_QUANT = _check_quant(mode) if mode is not None else None


def get_quant_mode() -> Optional[str]:
    """The current process default (None when unset)."""
    return _PROCESS_QUANT


@contextlib.contextmanager
def quant_scope(mode: Optional[str]):
    """Temporarily set the process quant mode (trace-time scoping, the
    ``backend_scope`` twin).  A ``None`` scope is a no-op."""
    if mode is None:
        yield
        return
    prev = _PROCESS_QUANT
    set_quant_mode(mode)
    try:
        yield
    finally:
        set_quant_mode(prev)


def resolve_quant(mode: Optional[str] = None) -> str:
    """Resolve a quant-mode request to {"native", "dequant"}."""
    if _ENV_QUANT is not None:
        return _ENV_QUANT
    if mode is None:
        return _PROCESS_QUANT if _PROCESS_QUANT is not None else "native"
    return _check_quant(mode)


refresh_from_env()


# ---------------------------------------------------------------------------
# thin wrappers over the kernel entry points (ops.py handles padding,
# layout, autotuned block sizes and interpret-mode selection; nothing to
# add here but a stable import point that models/ can use without
# reaching into each kernel).


def window_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     window: int,
                     win_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pallas non-overlapping window attention (ViTDet window blocks).

    q: (B, T, H, Dh); k/v: (B, T, KV, Dh); T % window == 0.
    ``win_valid``: optional (B,) valid-window counts — pad windows of a
    length-bucketed sequence emit zeros.  Differentiable (custom VJP).
    """
    return _win.window_attention(q, k, v, window, win_valid=win_valid)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = False) -> jnp.ndarray:
    """Pallas flash attention (ViTDet global blocks / LM prefill).

    q: (B, T, H, Dh); k/v: (B, S, KV, Dh).  Differentiable (custom VJP).
    """
    return _flash.flash_attention(q, k, v, causal=causal)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray) -> jnp.ndarray:
    """Pallas one-token GQA decode against the KV cache.

    q: (B, 1, H, Dh); k/v: (B, S, KV, Dh); kv_len: (B,) valid lengths.
    """
    return _decode.decode_attention(q, k, v, kv_len)


def avg_pool(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """Pallas average pool — drop-in for mixed_res.downsample_grid."""
    return _pool.avg_pool_2d(x, d)


def nn_upsample(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """Pallas nearest-neighbour upsample (restoration)."""
    return _pool.nn_upsample_2d(x, d)


def fused_pack_pos(bank: jnp.ndarray, pos_bank: jnp.ndarray,
                   win_src: jnp.ndarray, nw: jnp.ndarray) -> jnp.ndarray:
    """Fused serving prologue: window-bank gather + positional-embedding
    add + pad-window zeroing in one kernel (no HBM round-trip between
    pack and pos-embed).  Returns packed tokens (B, nw_pad * w2, C)."""
    return _fused.fused_pack_pos(bank, pos_bank, win_src, nw)


def fused_restore(windows: jnp.ndarray, out_src: jnp.ndarray,
                  out_map: jnp.ndarray, window: int, downsample: int,
                  reuse_tiles: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Fused serving epilogue: destination-major restoration gather
    (window un-pack + low-res upsample + reuse-tile splice) in one
    kernel.  ``windows``: packed activations (B, nw_pad, w2, D)."""
    return _fused.fused_restore(windows, out_src, out_map, window,
                                downsample, reuse_tiles=reuse_tiles)


def int8_matmul(xq: jnp.ndarray, wq: jnp.ndarray, sx: jnp.ndarray,
                sw: jnp.ndarray, *, out_dtype=jnp.float32,
                backend: Optional[str] = None) -> jnp.ndarray:
    """Quantized GEMM for the int8 weight lane (repro.quant.qtensor).

    xq: (M, K) int8 row-quantized activations; wq: (K, N) int8
    per-output-channel weights; sx: (M,) / sw: (N,) f32 scales.  The
    pallas backend runs the blocked int8 x int8 -> int32 kernel
    (kernels/int8_matmul, autotuned per-dtype block sizes); the XLA
    path is the ``dot_general(..., preferred_element_type=int32)``
    reference — bit-exact against the kernel.
    """
    if use_pallas(backend):
        return _int8.int8_matmul(xq, wq, sx, sw, out_dtype=out_dtype)
    return _int8_ref.int8_matmul_ref(xq, wq, sx, sw, out_dtype=out_dtype)
