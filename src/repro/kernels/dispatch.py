"""Kernel backend dispatch layer — routes model hot paths to the Pallas
kernels or the pure-jnp (XLA) reference implementations.

Every dispatch site takes a ``backend`` argument:

  ``"xla"``     pure-jnp path (the original model code) — always available.
  ``"pallas"``  the validated Pallas kernels under ``repro.kernels``;
                off-TPU they run in *interpret* mode (the ops.py
                convention), which is numerically exact but slow — meant
                for parity testing, not performance.
  ``"auto"``    ``"pallas"`` on TPU, ``"xla"`` everywhere else.  Interpret
                mode is a correctness tool, so auto never selects it for
                the hot path.
  ``None``      ``"xla"``.  The kernels define no custom VJP, so the
                bare default must stay differentiable: training code
                that never mentions a backend keeps its gradient path.
                Inference entry points (``ServerModel``) opt into
                ``"auto"`` explicitly.

The resolved choice can be forced globally with the ``REPRO_BACKEND``
environment variable (useful for A/B runs of the benchmark harness
without touching call sites).

Only the *shapes the kernels support* are routed to Pallas; anything
else (per-batch ``kv_len`` masks, query offsets) stays on the XLA path —
the dispatcher is a router, not a second implementation.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as _flash
from repro.kernels.mixed_res_pool import ops as _pool
from repro.kernels.window_attention import ops as _win

BACKENDS = ("auto", "pallas", "xla")
ENV_VAR = "REPRO_BACKEND"


def resolve(backend: Optional[str] = None) -> str:
    """Resolve a backend request to a concrete {"pallas", "xla"} choice."""
    env = os.environ.get(ENV_VAR)
    if env:
        backend = env
    if backend is None:
        backend = "xla"      # grad-safe default; see module docstring
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got "
                         f"{backend!r}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def use_pallas(backend: Optional[str] = None) -> bool:
    return resolve(backend) == "pallas"


# ---------------------------------------------------------------------------
# thin wrappers over the kernel entry points (ops.py handles padding,
# layout and interpret-mode selection; nothing to add here but a stable
# import point that models/ can use without reaching into each kernel).


def window_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     window: int,
                     win_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pallas non-overlapping window attention (ViTDet window blocks).

    q: (B, T, H, Dh); k/v: (B, T, KV, Dh); T % window == 0.
    ``win_valid``: optional (B,) valid-window counts — pad windows of a
    length-bucketed sequence emit zeros.
    """
    return _win.window_attention(q, k, v, window, win_valid=win_valid)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = False) -> jnp.ndarray:
    """Pallas flash attention (ViTDet global blocks / LM prefill).

    q: (B, T, H, Dh); k/v: (B, S, KV, Dh).
    """
    return _flash.flash_attention(q, k, v, causal=causal)


def avg_pool(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """Pallas average pool — drop-in for mixed_res.downsample_grid."""
    return _pool.avg_pool_2d(x, d)


def nn_upsample(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """Pallas nearest-neighbour upsample (restoration)."""
    return _pool.nn_upsample_2d(x, d)
