from repro.kernels.mixed_res_pool.ops import avg_pool_2d, nn_upsample_2d  # noqa: F401
