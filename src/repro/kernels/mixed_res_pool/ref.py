"""Pure-jnp oracles for the mixed-res pooling kernels."""
from __future__ import annotations

import jax.numpy as jnp


def avg_pool_2d_ref(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """(B, H, W, C) -> (B, H/d, W/d, C) mean over d x d tiles (fp32)."""
    B, H, W, C = x.shape
    x = x.reshape(B, H // d, d, W // d, d, C)
    return jnp.mean(x.astype(jnp.float32), axis=(2, 4)).astype(x.dtype)


def nn_upsample_2d_ref(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """(B, H, W, C) -> (B, H*d, W*d, C) nearest-neighbour broadcast."""
    return jnp.repeat(jnp.repeat(x, d, axis=1), d, axis=2)
