"""jit'd entry points for the mixed-res pooling kernels.

Pads channels to the 128-lane tile and picks a row block that divides
the grid; interpret mode off-TPU.

Both ops carry closed-form ``jax.custom_vjp``s (average pool's adjoint
is nearest-upsample / d^2 and vice versa), so the Pallas pooling lane
is differentiable without an XLA fallback.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.mixed_res_pool import kernel as K


def _plan(n: int, want: int) -> int:
    rb = min(want, n)
    while n % rb:
        rb -= 1
    return rb


def _pad_c(x: jnp.ndarray, bc: int):
    C = x.shape[-1]
    pad = (-C) % bc
    if pad:
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))
    return x, C


@functools.partial(jax.jit, static_argnames=("d", "rb", "bc", "interpret"))
def _avg_pool_fwd(x, *, d, rb, bc, interpret):
    B, H, W, C0 = x.shape
    bc_ = min(bc, ((C0 + 7) // 8) * 8)
    x, C0 = _pad_c(x, bc_)
    rb_ = _plan(H // d, rb)
    out = K.avg_pool_kernel(x, d, rb=rb_, bc=bc_, interpret=interpret)
    return out[..., :C0]


@functools.partial(jax.jit, static_argnames=("d", "rb", "bc", "interpret"))
def _nn_upsample_fwd(x, *, d, rb, bc, interpret):
    B, H, W, C0 = x.shape
    bc_ = min(bc, ((C0 + 7) // 8) * 8)
    x, C0 = _pad_c(x, bc_)
    rb_ = _plan(H, rb)
    out = K.nn_upsample_kernel(x, d, rb=rb_, bc=bc_, interpret=interpret)
    return out[..., :C0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _avg_pool(x, d, rb, bc, interpret):
    return _avg_pool_fwd(x, d=d, rb=rb, bc=bc, interpret=interpret)


def _avg_pool_vfwd(x, d, rb, bc, interpret):
    return _avg_pool(x, d, rb, bc, interpret), None


def _avg_pool_vbwd(d, rb, bc, interpret, _, g):
    # adjoint of mean pooling: broadcast each pooled cotangent back over
    # its d x d block, scaled by 1/d^2
    dx = jnp.repeat(jnp.repeat(g, d, axis=1), d, axis=2) / (d * d)
    return (dx.astype(g.dtype),)


_avg_pool.defvjp(_avg_pool_vfwd, _avg_pool_vbwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _nn_upsample(x, d, rb, bc, interpret):
    return _nn_upsample_fwd(x, d=d, rb=rb, bc=bc, interpret=interpret)


def _nn_upsample_vfwd(x, d, rb, bc, interpret):
    return _nn_upsample(x, d, rb, bc, interpret), None


def _nn_upsample_vbwd(d, rb, bc, interpret, _, g):
    # adjoint of nearest-neighbour replication: sum each d x d block
    B, H, W, C = g.shape
    dx = g.reshape(B, H // d, d, W // d, d, C).sum(axis=(2, 4))
    return (dx.astype(g.dtype),)


_nn_upsample.defvjp(_nn_upsample_vfwd, _nn_upsample_vbwd)


def avg_pool_2d(x: jnp.ndarray, d: int, *, rb: int = K.DEFAULT_RB,
                bc: int = K.DEFAULT_BC,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Drop-in for core.mixed_res.downsample_grid.  x: (B, H, W, C)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if d == 1:
        return x
    return _avg_pool(x, int(d), int(rb), int(bc), bool(interpret))


def nn_upsample_2d(x: jnp.ndarray, d: int, *, rb: int = K.DEFAULT_RB,
                   bc: int = K.DEFAULT_BC,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Nearest-neighbour upsample (restoration §III-B).  x: (B, H, W, C)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if d == 1:
        return x
    return _nn_upsample(x, int(d), int(rb), int(bc), bool(interpret))
