"""jit'd entry points for the mixed-res pooling kernels.

Pads channels to the 128-lane tile and picks a row block that divides
the grid; interpret mode off-TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.mixed_res_pool import kernel as K


def _plan(n: int, want: int) -> int:
    rb = min(want, n)
    while n % rb:
        rb -= 1
    return rb


def _pad_c(x: jnp.ndarray, bc: int):
    C = x.shape[-1]
    pad = (-C) % bc
    if pad:
        x = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))
    return x, C


@functools.partial(jax.jit, static_argnames=("d", "rb", "bc", "interpret"))
def avg_pool_2d(x: jnp.ndarray, d: int, *, rb: int = K.DEFAULT_RB,
                bc: int = K.DEFAULT_BC,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Drop-in for core.mixed_res.downsample_grid.  x: (B, H, W, C)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if d == 1:
        return x
    B, H, W, C0 = x.shape
    bc_ = min(bc, ((C0 + 7) // 8) * 8)
    x, C0 = _pad_c(x, bc_)
    rb_ = _plan(H // d, rb)
    out = K.avg_pool_kernel(x, d, rb=rb_, bc=bc_, interpret=interpret)
    return out[..., :C0]


@functools.partial(jax.jit, static_argnames=("d", "rb", "bc", "interpret"))
def nn_upsample_2d(x: jnp.ndarray, d: int, *, rb: int = K.DEFAULT_RB,
                   bc: int = K.DEFAULT_BC,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Nearest-neighbour upsample (restoration §III-B).  x: (B, H, W, C)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if d == 1:
        return x
    B, H, W, C0 = x.shape
    bc_ = min(bc, ((C0 + 7) // 8) * 8)
    x, C0 = _pad_c(x, bc_)
    rb_ = _plan(H, rb)
    out = K.nn_upsample_kernel(x, d, rb=rb_, bc=bc_, interpret=interpret)
    return out[..., :C0]
