"""Mixed-resolution pooling / restoration Pallas TPU kernels.

Two small data-movement kernels backing the paper's §III hot spots:

  avg_pool      d x d average pooling of a (B, H, W, C) patch/pixel grid —
                the device-side downsampling step of §III-A (pack_mixed /
                downsample_grid).
  nn_upsample   d x d nearest-neighbour broadcast — the restoration-point
                upsample of §III-B (restore_full).

Both are bandwidth-bound reshapes+reductions; the kernels exist to keep
the op on-chip when fused into the packing pipeline (HBM -> VMEM once),
with channel tiles of 128 lanes and row blocks sized to the VMEM budget.

    avg_pool grid = (B, Hout / RB, C / BC)
      in  block (RB*d, W,   BC)      out block (RB, W/d, BC)
    upsample grid = (B, Hin / RB, C / BC)
      in  block (RB,   W,   BC)      out block (RB*d, W*d, BC)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 128
DEFAULT_RB = 8


def _avg_pool_kernel(x_ref, o_ref, *, d: int):
    x = x_ref[...].astype(jnp.float32)                # (RB*d, W, BC)
    RBd, W, BC = x.shape
    x = x.reshape(RBd // d, d, W // d, d, BC)
    o_ref[...] = jnp.mean(x, axis=(1, 3)).astype(o_ref.dtype)


def _nn_upsample_kernel(x_ref, o_ref, *, d: int):
    x = x_ref[...]                                    # (RB, W, BC)
    RB, W, BC = x.shape
    x = jnp.broadcast_to(x[:, None, :, None, :], (RB, d, W, d, BC))
    o_ref[...] = x.reshape(RB * d, W * d, BC)


def avg_pool_kernel(x: jnp.ndarray, d: int, *, rb: int = DEFAULT_RB,
                    bc: int = DEFAULT_BC, interpret: bool = True):
    """x: (B, H, W, C) with H % (rb*d) == 0, W % d == 0, C % bc == 0."""
    B, H, W, C = x.shape
    Ho, Wo = H // d, W // d
    return pl.pallas_call(
        functools.partial(_avg_pool_kernel, d=d),
        grid=(B, Ho // rb, C // bc),
        in_specs=[pl.BlockSpec((None, rb * d, W, bc),
                               lambda b, i, c: (b, i, 0, c))],
        out_specs=pl.BlockSpec((None, rb, Wo, bc),
                               lambda b, i, c: (b, i, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, C), x.dtype),
        interpret=interpret,
    )(x)


def nn_upsample_kernel(x: jnp.ndarray, d: int, *, rb: int = DEFAULT_RB,
                       bc: int = DEFAULT_BC, interpret: bool = True):
    """x: (B, H, W, C) with H % rb == 0, C % bc == 0 -> (B, H*d, W*d, C)."""
    B, H, W, C = x.shape
    return pl.pallas_call(
        functools.partial(_nn_upsample_kernel, d=d),
        grid=(B, H // rb, C // bc),
        in_specs=[pl.BlockSpec((None, rb, W, bc),
                               lambda b, i, c: (b, i, 0, c))],
        out_specs=pl.BlockSpec((None, rb * d, W * d, bc),
                               lambda b, i, c: (b, i, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H * d, W * d, C), x.dtype),
        interpret=interpret,
    )(x)
