"""Pure-jnp oracle for the SSD scan: the naive sequential recurrence.

Deliberately NOT the chunked formulation (models.mamba2.ssd_chunked is
itself chunked) — this is the O(T) step-by-step state recurrence, the
definitionally-correct semantics both chunked versions must match:

    S_t = exp(dt_t * A) * S_{t-1} + B_t (dt_t x_t)^T
    y_t = C_t . S_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            Bm: jnp.ndarray, Cm: jnp.ndarray,
            init_state: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, T, H, P); dt: (b, T, H); A: (H,); Bm/Cm: (b, T, G, N).
    Returns (y (b, T, H, P) f32, final_state (b, H, N, P) f32)."""
    b, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    f32 = jnp.float32

    xb = (x.astype(f32) * dt[..., None].astype(f32))          # (b,T,H,P)
    dA = jnp.exp(dt.astype(f32) * A.astype(f32)[None, None, :])
    Bh = jnp.repeat(Bm.astype(f32), hpg, axis=2)              # (b,T,H,N)
    Ch = jnp.repeat(Cm.astype(f32), hpg, axis=2)

    s0 = (jnp.zeros((b, H, N, P), f32) if init_state is None
          else init_state.astype(f32))

    def step(s, inp):
        xb_t, dA_t, B_t, C_t = inp                            # (b,H,*) each
        s = s * dA_t[..., None, None] + \
            jnp.einsum("bhn,bhp->bhnp", B_t, xb_t)
        y = jnp.einsum("bhn,bhnp->bhp", C_t, s)
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xb, dA, Bh, Ch))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin
