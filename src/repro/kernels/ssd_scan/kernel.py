"""Mamba-2 SSD (state-space duality) Pallas TPU kernel.

The SSD decomposition (arXiv:2405.21060) splits the sequence into chunks:
inside a chunk the recurrence is a *quadratic* masked-decay form (three
MXU matmuls), across chunks it is a *linear* state recurrence.  The
kernel maps this directly onto the Pallas grid:

    grid = (batch, heads / BH, n_chunks)   —  chunk axis sequential
    state scratch (BH, N, P) f32          —  carried across chunks

Per program (one chunk of BH heads), VMEM working set with the default
Q = 128, BH = 8, N = 128, P = 64:

    xbar (Q, BH, P)   dt-scaled inputs          256 KiB (f32)
    dA   (Q, BH)      per-step log decays       —
    B, C (Q, N)       group-shared projections  128 KiB
    decay (Q, Q, BH)  masked pairwise decays    512 KiB
    state (BH, N, P)  carried SSM state         256 KiB

~1.2 MiB total, far inside the ~16 MiB VMEM budget; Q, N, P are all
MXU-aligned (128 / 128 / 64).  The three matmuls per chunk-head are
(QxQ)@(QxP) [intra-chunk], (QxN)@(NxP) [state out], (NxQ)@(QxP) [state
update] — each batched over the BH head axis.

The wrapper pre-scales x by dt and pre-multiplies dt by A, so the kernel
sees only ``xbar`` and ``dA`` (no SMEM scalars needed).  Head blocks are
chosen to divide the B/C group size, so each program reads exactly one
group's B/C block (no head-indexed gather).

Zero-padded tail rows are state-neutral by construction: dt = 0 gives
dA = 0 (decay 1) and xbar = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BH = 8


def _ssd_kernel(xbar_ref, dA_ref, b_ref, c_ref, s0_ref, y_ref, sfin_ref,
                state_ref, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[...].astype(jnp.float32)

    xbar = xbar_ref[...].astype(jnp.float32)          # (Q, BH, P)
    dA = dA_ref[...].astype(jnp.float32)              # (Q, BH)
    Bm = b_ref[...].astype(jnp.float32)               # (Q, N)
    Cm = c_ref[...].astype(jnp.float32)               # (Q, N)
    Q, BH, P = xbar.shape

    cum = jnp.cumsum(dA, axis=0)                      # (Q, BH) log decay

    # ---- intra-chunk quadratic form -----------------------------------
    scores = jax.lax.dot_general(                     # (Q, Q): C_i . B_j
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    ldec = cum[:, None, :] - cum[None, :, :]          # (Q, Q, BH)
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = (i_pos >= j_pos)[:, :, None]
    M = jnp.where(tril, scores[:, :, None] * jnp.exp(ldec), 0.0)

    Mb = jnp.moveaxis(M, 2, 0)                        # (BH, Q, Q)
    xb = jnp.moveaxis(xbar, 1, 0)                     # (BH, Q, P)
    y = jax.lax.dot_general(                          # (BH, Q, P)
        Mb, xb, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)

    # ---- contribution of the carried inter-chunk state ----------------
    state = state_ref[...]                            # (BH, N, P) f32
    c_scaled = Cm[None, :, :] * jnp.moveaxis(
        jnp.exp(cum), 1, 0)[:, :, None]               # (BH, Q, N)
    y = y + jax.lax.dot_general(
        c_scaled, state, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)

    y_ref[...] = jnp.moveaxis(y, 0, 1).astype(y_ref.dtype)   # (Q, BH, P)

    # ---- state update --------------------------------------------------
    dec_end = jnp.exp(cum[-1, :][None, :] - cum + dA * 0.0)  # (Q, BH)
    # exp(cum_Q - cum_j): decay from step j to the chunk end
    b_scaled = Bm[None, :, :] * jnp.moveaxis(
        dec_end, 1, 0)[:, :, None]                    # (BH, Q, N)
    s_inc = jax.lax.dot_general(                      # (BH, N, P)
        jnp.moveaxis(b_scaled, 1, 2), xb,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    chunk_dec = jnp.exp(cum[-1, :])                   # (BH,)
    state_ref[...] = state * chunk_dec[:, None, None] + s_inc

    @pl.when(ci == nc - 1)
    def _emit_final():
        sfin_ref[...] = state_ref[...].astype(sfin_ref.dtype)


def ssd_scan_kernel(xbar: jnp.ndarray, dA: jnp.ndarray, Bm: jnp.ndarray,
                    Cm: jnp.ndarray, s0: jnp.ndarray, *, chunk: int,
                    bh: int = DEFAULT_BH, interpret: bool = True):
    """xbar: (b, T, H, P); dA: (b, T, H); Bm/Cm: (b, T, G, N);
    s0: (b, H, N, P) initial state.  T % chunk == 0; bh divides H and the
    group size H/G.  Returns (Y (b,T,H,P) f32, final_state (b,H,N,P) f32).
    """
    b, T, H, P = xbar.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    nc = T // chunk
    nh = H // bh
    assert hpg % bh == 0 or bh % hpg == 0 or G == 1, (H, G, bh)

    kernel = functools.partial(_ssd_kernel, nc=nc)
    grid = (b, nh, nc)

    def grp(hi):
        # head-block hi covers heads [hi*bh, (hi+1)*bh) — one group since
        # bh divides hpg (asserted by ops.py)
        return (hi * bh) // hpg

    y_shape = jax.ShapeDtypeStruct((b, T, H, P), jnp.float32)
    s_shape = jax.ShapeDtypeStruct((b, H, N, P), jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, bh, P),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((None, chunk, bh),
                         lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((None, chunk, None, N),
                         lambda bi, hi, ci: (bi, ci, grp(hi), 0)),
            pl.BlockSpec((None, chunk, None, N),
                         lambda bi, hi, ci: (bi, ci, grp(hi), 0)),
            pl.BlockSpec((None, bh, N, P),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, bh, P),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((None, bh, N, P),
                         lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[y_shape, s_shape],
        scratch_shapes=[pltpu.VMEM((bh, N, P), jnp.float32)],
        interpret=interpret,
    )(xbar, dA, Bm, Cm, s0)
