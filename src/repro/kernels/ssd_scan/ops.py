"""jit'd entry point for the SSD scan kernel.

Pre-scales inputs (xbar = dt*x, dA = dt*A — zero-padding is then
state-neutral), pads T to the chunk length, picks a head block that
divides the B/C group size, and dispatches the Pallas kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as K


def _pick_bh(H: int, hpg: int, want: int) -> int:
    bh = min(want, hpg, H)
    while hpg % bh or H % bh:
        bh -= 1
    return max(bh, 1)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "bh", "return_final_state",
                                    "interpret"))
def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, Bm: jnp.ndarray,
        Cm: jnp.ndarray, chunk: int, *,
        init_state: Optional[jnp.ndarray] = None,
        return_final_state: bool = False,
        bh: int = K.DEFAULT_BH, interpret: Optional[bool] = None):
    """Drop-in for models.mamba2.ssd_chunked (use_kernel=True path).

    x: (b, T, H, P); dt: (b, T, H) post-softplus; A: (H,) negative rates;
    Bm/Cm: (b, T, G, N).  Returns Y (b, T, H, P) f32 — and the final
    state (b, H, N, P) when ``return_final_state``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    bh_ = _pick_bh(H, hpg, bh)

    chunk = min(chunk, T)
    pad = (-T) % chunk
    f32 = jnp.float32
    xbar = x.astype(f32) * dt[..., None].astype(f32)
    dA = dt.astype(f32) * A.astype(f32)[None, None, :]
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    s0 = (jnp.zeros((b, H, N, P), f32) if init_state is None
          else init_state.astype(f32))
    y, s_fin = K.ssd_scan_kernel(xbar, dA, Bm.astype(f32), Cm.astype(f32),
                                 s0, chunk=chunk, bh=bh_,
                                 interpret=interpret)
    y = y[:, :T]
    if return_final_state:
        return y, s_fin
    return y
