from repro.kernels.ssd_scan.ops import ssd  # noqa: F401
