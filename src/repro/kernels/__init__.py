"""Pallas TPU kernels for the compute hot-spots of the system.

Each kernel is a package ``<name>/{kernel.py, ops.py, ref.py}``:

  window_attention   ViTDet window attention over the window-blocked
                     mixed-resolution sequence — the paper's §III hot path.
  flash_attention    global attention (ViTDet global blocks, LM prefill):
                     online-softmax tiling, GQA-aware.
  decode_attention   one-token GQA decode against a long KV cache
                     (flash-decode style blocked reduction over the cache).
  ssd_scan           Mamba-2 SSD: intra-chunk quadratic form + carried
                     inter-chunk state, sequential grid over chunks.
  mixed_res_pool     d x d average-pool patch downsampling (mixed-res
                     packing hot spot, §III-A).
  int8_matmul        int8 x int8 -> int32 blocked GEMM with per-channel
                     dequant epilogue — the quantized weight lane
                     (repro.quant) for QKV/MLP/head projections.

TPU is the TARGET (pl.pallas_call + BlockSpec VMEM tiling, MXU-aligned
block shapes); on this CPU container every kernel is validated with
``interpret=True`` against its ``ref.py`` pure-jnp oracle
(tests/test_kernels.py sweeps shapes and dtypes).

The jnp model code paths remain the default for dry-run lowering (XLA
cost analysis reads the jnp HLO); ``ops.py`` wrappers are the swap-in
entry points on real TPU hardware (e.g. ``mamba2_forward(...,
use_kernel=True)``).

``dispatch.py`` is the model-facing router: hot paths take a
``backend`` argument ("auto" | "pallas" | "xla") and "auto" selects the
Pallas kernels on TPU while keeping the jnp paths elsewhere (interpret
mode stays a parity-testing tool).  See README.md.
"""
