"""jit'd entry point for the int8 GEMM kernel.

Pads (M, K, N) up to the resolved block sizes — zero padding is exact
for integer accumulation — dispatches the Pallas kernel (interpret mode
off-TPU) and slices the result back to the caller's shape.  Block sizes
come from the per-dtype autotune cache when not given explicitly
(``autotune.matmul_bucket`` keys carry both operand dtypes, so int8
winners never leak into a hypothetical fp lane and vice versa).

Inference-only: the quantized weight lane is a serving artifact, so no
custom VJP — training always runs the float weights.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.int8_matmul import kernel as K


def _pow2ceil(n: int) -> int:
    p = 1
    while p < max(1, n):
        p *= 2
    return p


def _impl(xq, wq, sx, sw, out_dtype, bm, bn, bk, interpret):
    M, Kd = xq.shape
    N = wq.shape[1]
    Mp = -(-M // bm) * bm
    Kp = -(-Kd // bk) * bk
    Np = -(-N // bn) * bn
    if (Mp, Kp) != (M, Kd):
        xq = jnp.pad(xq, ((0, Mp - M), (0, Kp - Kd)))
    if (Kp, Np) != (Kd, N):
        wq = jnp.pad(wq, ((0, Kp - Kd), (0, Np - N)))
    sx2 = jnp.pad(sx, (0, Mp - M)).reshape(Mp, 1).astype(jnp.float32)
    sw2 = jnp.pad(sw, (0, Np - N)).reshape(1, Np).astype(jnp.float32)
    out = K.int8_matmul_kernel(xq, wq, sx2, sw2,
                               out_dtype=jnp.dtype(out_dtype),
                               bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


_entry = jax.jit(_impl, static_argnums=(4, 5, 6, 7, 8))


def int8_matmul(xq: jnp.ndarray, wq: jnp.ndarray,
                sx: jnp.ndarray, sw: jnp.ndarray, *,
                out_dtype=jnp.float32,
                bm: Optional[int] = None, bn: Optional[int] = None,
                bk: Optional[int] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Quantized GEMM: ``(xq * sx[:, None]) @ (wq * sw[None, :])`` with
    the contraction done in int8 x int8 -> int32.

    xq: (M, K) int8 row-quantized activations; wq: (K, N) int8
    per-output-channel weights; sx: (M,) f32; sw: (N,) f32.  Block sizes
    default to the autotuned winner for this (shape, dtypes) bucket.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M, Kd = xq.shape
    N = wq.shape[1]
    if bm is None or bn is None or bk is None:
        blocks = autotune.block(
            "int8_matmul",
            autotune.matmul_bucket(M, N, Kd, xq.dtype, wq.dtype),
            {"bm": K.DEFAULT_BM, "bn": K.DEFAULT_BN, "bk": K.DEFAULT_BK})
        bm = bm if bm is not None else blocks["bm"]
        bn = bn if bn is not None else blocks["bn"]
        bk = bk if bk is not None else blocks["bk"]
    # clamp blocks to the padded problem so tiny shapes don't inflate
    # to a full default tile (int8 MXU tiles want >= (32, 128) but the
    # kernel is shape-correct at any multiple-of-8 block)
    bm = min(int(bm), max(32, _pow2ceil(M)))
    bn = min(int(bn), max(128, _pow2ceil(N)))
    bk = min(int(bk), max(128, _pow2ceil(Kd)))
    return _entry(xq, wq, sx, sw, jnp.dtype(out_dtype).name,
                  bm, bn, bk, bool(interpret))
