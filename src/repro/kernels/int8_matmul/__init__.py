from repro.kernels.int8_matmul.ops import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref

__all__ = ["int8_matmul", "int8_matmul_ref"]
