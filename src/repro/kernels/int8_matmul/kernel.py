"""int8 weight-quantized GEMM Pallas TPU kernel.

The quantized serving lane (repro.quant) stores every linear weight as
per-output-channel symmetric int8 (``q * scale`` recovers the float
weight) and quantizes activations per row on the fly, so the MXU runs a
native int8 x int8 -> int32 matmul and the float scales are applied once
in the epilogue:

    out[m, n] = (sum_k xq[m, k] * wq[k, n]) * sx[m] * sw[n]

Tiling: classic blocked GEMM with the K loop as the innermost grid
dimension and an int32 VMEM accumulator that lives across the K steps —
zeroed at k == 0, scaled/cast to the output dtype at k == nk-1.  int8
operands want (32, 128)-aligned tiles on the MXU; ops.py pads every
dimension up to the resolved block sizes, and zero-padding is exact
(padded K contributes 0 to the accumulator, padded M/N rows are sliced
off by the caller).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def _matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                   nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        out = acc * sx_ref[...] * sw_ref[...]        # (bm,1) x (1,bn)
        o_ref[...] = out.astype(o_ref.dtype)


def int8_matmul_kernel(xq: jnp.ndarray, wq: jnp.ndarray,
                       sx: jnp.ndarray, sw: jnp.ndarray, *,
                       out_dtype=jnp.float32,
                       bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                       bk: int = DEFAULT_BK,
                       interpret: bool = True) -> jnp.ndarray:
    """xq: (M, K) int8; wq: (K, N) int8; sx: (M, 1) f32 per-row
    activation scales; sw: (1, N) f32 per-output-channel weight scales.
    M % bm == K % bk == N % bn == 0 (ops.py pads).  Returns (M, N) in
    ``out_dtype``."""
    M, K = xq.shape
    N = wq.shape[1]
    nk = K // bk
    kernel = functools.partial(_matmul_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, wq, sx, sw)
