"""Pure-jnp reference for the int8 GEMM — the XLA fallback lane.

Same contract as ops.int8_matmul: int8 x int8 accumulated in int32
(``preferred_element_type`` keeps XLA from silently widening through
float), per-row/per-channel scales applied in float32, cast to the
requested output dtype.  Bit-exact against the Pallas kernel (integer
accumulation has no rounding; the float epilogue is the same three
operations in the same order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(xq: jnp.ndarray, wq: jnp.ndarray,
                    sx: jnp.ndarray, sw: jnp.ndarray,
                    out_dtype=jnp.float32) -> jnp.ndarray:
    """xq: (M, K) int8; wq: (K, N) int8; sx: (M,) f32; sw: (N,) f32."""
    acc = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * sx[:, None] * sw[None, :]
    return out.astype(out_dtype)
