"""ViTDet-style dense-prediction backbone with dynamic mixed-resolution
inference (the paper's case-study model, §III).

Structure (ViTDet, Li et al. 2022): ``n_layers`` pre-norm ViT blocks split
into N subsets of M blocks; within a subset the first M-1 blocks use
non-overlapping window attention, the last uses global attention.

Mixed-resolution inference: the image is packed into a window-blocked
mixed sequence (core.mixed_res).  At restoration point ``beta``:
  beta = 0          restore immediately after patch embedding (paper's
                    "Subset 0" special case — upsampled input);
  beta = k (1..N)   restore inside subset k (1-indexed), between its last
                    window block and its global block.
The output is always a full-resolution (B, Hp, Wp, D) feature map, so the
dense head is untouched — the paper's key compatibility property.

Simplification vs. the released ViTDet (recorded in DESIGN.md): no
relative-position bias inside attention (absolute learned pos-emb only).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import det_head as dh
from repro.core import mixed_res as mr
from repro.core.partition import (Partition, length_bucket as
                                  pt_length_bucket, make_partition)
from repro.kernels import dispatch
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.quant import qtensor as qt


def vit_partition(cfg: ModelConfig) -> Partition:
    v = cfg.vit
    grid_h = v.img_size[0] // v.patch_size
    grid_w = v.img_size[1] // v.patch_size
    d = cfg.mixed_res.downsample if cfg.mixed_res else 2
    return make_partition(grid_h, grid_w, v.window_size, d)


def blocks_per_subset(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.vit.n_subsets == 0
    return cfg.n_layers // cfg.vit.n_subsets


# ---------------------------------------------------------------------------
# params


def init_vitdet_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    v = cfg.vit
    part = vit_partition(cfg)
    ks = jax.random.split(key, cfg.n_layers + 3)
    patch_dim = v.patch_size * v.patch_size * 3

    def block(k):
        kk = jax.random.split(k, 2)
        return {
            "ln1": L.init_norm(cfg, dtype),
            "attn": attn.init_attention(cfg, kk[0], dtype),
            "ln2": L.init_norm(cfg, dtype),
            "ffn": L.init_mlp(cfg, kk[1], dtype),
        }

    return {
        "patch_embed": {
            "w": L.dense_init(ks[0], (patch_dim, cfg.d_model), dtype),
            "b": jnp.zeros((cfg.d_model,), dtype),
        },
        "pos_emb": L.embed_init(ks[1], (part.grid_h, part.grid_w,
                                        cfg.d_model), dtype),
        "blocks": [block(ks[2 + i]) for i in range(cfg.n_layers)],
        "final_norm": L.init_norm(cfg, dtype),
        "head": dh.init_det_head(cfg, ks[-1], dtype),
    }


# ---------------------------------------------------------------------------
# patchify (conv-free: reshape + matmul, MXU-friendly)


def patchify(image: jnp.ndarray, patch: int) -> jnp.ndarray:
    """(B, H, W, 3) -> (B, H/p, W/p, p*p*3) raw patch grid."""
    B, H, W, C = image.shape
    x = image.reshape(B, H // patch, patch, W // patch, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, H // patch, W // patch, patch * patch * C)


def embed_patches(cfg: ModelConfig, params, image: jnp.ndarray,
                  downsample: int = 1,
                  backend: Optional[str] = None) -> jnp.ndarray:
    """Patchify (optionally pixel-downsampled) image and project to D."""
    if downsample > 1:
        image = mr.downsample_grid(image, downsample, backend=backend)
    p = params["patch_embed"]
    patches = patchify(image, cfg.vit.patch_size)
    return qt.matmul(patches, p["w"]) + p["b"]


# ---------------------------------------------------------------------------
# packed positional-embedding cache
#
# pack_positions is pure data movement on the (trainable but
# inference-frozen) pos_emb grid; re-packing it inside every eager
# forward_features call is wasted work.  The cache key is
# (pos_emb identity, partition, layout fingerprint) and is bypassed
# whenever any input is a tracer (jit/grad see the uncached computation,
# so training and compiled paths are unaffected).  The fingerprint is
# ``ids_key`` when the caller precomputed one (PlanLayout.key — built
# ONCE at plan-layout time, so cache hits are O(1) with no host sync);
# legacy callers without a key fall back to hashing the id bytes per
# call (one d2h per array — the cost the serving hot path now avoids).


_POS_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_POS_CACHE_MAX = 64


def _concrete(*xs) -> bool:
    return not any(isinstance(x, jax.core.Tracer) for x in xs)


def _ids_fingerprint(*ids: jnp.ndarray) -> bytes:
    return b"|".join(np.ascontiguousarray(np.asarray(a)).tobytes()
                     for a in ids)


def packed_positions(pos: jnp.ndarray, part: Partition,
                     full_ids: Optional[jnp.ndarray],
                     low_ids: Optional[jnp.ndarray], *,
                     win_src: Optional[jnp.ndarray] = None,
                     ids_key: Optional[bytes] = None) -> jnp.ndarray:
    """Cached pack of the positional grid for the requested layout.

    full_ids/low_ids None and win_src None -> full-resolution
    window-blocked layout; (B, n) per-sample ids produce a
    (B, n_tokens, D) batch.  ``win_src`` selects the length-bucketed
    padded layout (mixed_res.pack_positions_padded) instead of the
    exact-shape one.  ``ids_key``: precomputed layout fingerprint for
    O(1) cache hits.
    """
    if win_src is not None:
        if not _concrete(pos, win_src):
            return mr.pack_positions_padded(pos, part, win_src)
        key = (id(pos), part, "padded", tuple(win_src.shape),
               ids_key if ids_key is not None
               else _ids_fingerprint(win_src))
    elif low_ids is not None:
        if not _concrete(pos, full_ids, low_ids):
            return mr.pack_positions(pos, part, full_ids, low_ids)
        key = (id(pos), part, tuple(low_ids.shape),
               ids_key if ids_key is not None
               else _ids_fingerprint(full_ids, low_ids))
    else:
        if not _concrete(pos):
            return mr.grid_to_full_seq(pos[None], part)[0]
        key = (id(pos), part, "full")

    hit = _POS_CACHE.get(key)
    # the cached entry pins ``pos`` so id() cannot be recycled; the
    # identity check guards against a stale module-level cache anyway.
    if hit is not None and hit[0] is pos:
        _POS_CACHE.move_to_end(key)
        return hit[1]
    if win_src is not None:
        packed = mr.pack_positions_padded(pos, part, win_src)
    elif low_ids is not None:
        packed = mr.pack_positions(pos, part, full_ids, low_ids)
    else:
        packed = mr.grid_to_full_seq(pos[None], part)[0]
    while len(_POS_CACHE) >= _POS_CACHE_MAX:
        _POS_CACHE.popitem(last=False)    # LRU: evict the oldest only
    _POS_CACHE[key] = (pos, packed)
    return packed


def pos_window_bank(pos: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """Cached (nR*d^2 + nR, w^2, D) window bank of the positional grid —
    the fused pack/pos prologue gathers positions from this bank in the
    same pass as the activations, so the layout-dependent gather no
    longer needs a per-layout cache entry (one bank covers every plan).
    """
    if not _concrete(pos):
        return mr.window_bank(pos[None], part)[0]
    key = (id(pos), part, "bank")
    hit = _POS_CACHE.get(key)
    if hit is not None and hit[0] is pos:
        _POS_CACHE.move_to_end(key)
        return hit[1]
    bank = mr.window_bank(pos[None], part)[0]
    while len(_POS_CACHE) >= _POS_CACHE_MAX:
        _POS_CACHE.popitem(last=False)
    _POS_CACHE[key] = (pos, bank)
    return bank


# ---------------------------------------------------------------------------
# blocks


def _vit_block(cfg: ModelConfig, p, x, *, window: int,
               kv_len: Optional[jnp.ndarray] = None,
               win_valid: Optional[jnp.ndarray] = None,
               backend: Optional[str] = None) -> jnp.ndarray:
    """x: (B, T, D) window-blocked.  window=0 -> global attention.

    kv_len/win_valid: (B,) traced validity of a length-bucketed padded
    sequence — pad tokens are masked out of global attention keys, pad
    windows' window-attention outputs are zeroed.
    """
    B, T, D = x.shape
    h = L.apply_norm(cfg, p["ln1"], x)
    positions = jnp.zeros((B, T), jnp.int32)      # no RoPE in ViT
    a = attn.attention_forward(cfg, p["attn"], h, positions,
                               causal=False, window=window, rope=False,
                               kv_len=kv_len, win_valid=win_valid,
                               backend=backend)
    x = x + a
    h = L.apply_norm(cfg, p["ln2"], x)
    return x + L.apply_mlp(cfg, p["ffn"], h)


def forward_features(cfg: ModelConfig, params, image: jnp.ndarray,
                     full_ids: Optional[jnp.ndarray] = None,
                     low_ids: Optional[jnp.ndarray] = None,
                     beta: int = 0,
                     backend: Optional[str] = None,
                     reuse_ids: Optional[jnp.ndarray] = None,
                     reuse_tiles: Optional[jnp.ndarray] = None,
                     capture_beta: int = 0,
                     layout: Optional[Dict] = None,
                     ids_key: Optional[bytes] = None):
    """Backbone forward.  Returns the (B, Hp, Wp, D) full-res feature map
    (or ``(feats, tiles)`` when ``capture_beta > 0``, see below).

    full_ids/low_ids: static-length region id arrays (see core.partition),
    either (n,) shared across the batch or (B, n) per-sample (batched
    multi-client serving, serve/edge.py); None or empty low_ids -> plain
    full-resolution inference.
    beta: restoration point, 0..n_subsets (static).
    backend: kernel backend ("auto" | "pallas" | "xla", kernels.dispatch)
    for the window/global attention and pool/upsample hot paths.

    layout: the length-bucketed padded alternative to full_ids/low_ids
    (serving hot path): a dict of PlanLayout arrays — ``win_src`` /
    ``win_dst`` (·, nw_pad), ``low_src`` / ``low_ids`` / ``reuse_ids``
    (·, n_regions), ``nw`` valid window counts — each (n,) shared or
    (B, n) per-sample.  Shapes depend only on the LENGTH BUCKET; which
    regions are LOW/REUSE and how many windows are real is runtime i32
    data, so one executable serves every (n_low, n_reuse) mix.  Pad
    windows are masked out of pre-restoration global attention
    (``kv_len``), zeroed by window attention's per-window valid flag,
    and routed to the sentinel row at restoration — the valid prefix is
    bit-identical to the exact-shape forward (tests/test_padded_plans).
    Requires ``beta >= 1``; reuse regions splice from ``reuse_tiles``
    shaped (B, n_regions, d^2, w^2, D) (pad rows land on the sentinel).
    ids_key: optional precomputed layout fingerprint for the eager
    positional-embedding cache (PlanLayout.key).

    Temporal reuse (partition.RegionPlan):
    reuse_ids/reuse_tiles: regions ABSENT from the transmitted sequence,
    restored from cached per-region feature tiles
    ((B, n_reuse, d^2, w^2, D), captured by a previous forward at the
    SAME restoration point) — requires ``beta >= 1``.  None or empty
    reuse_ids leaves every code path bit-identical to the no-reuse call.
    capture_beta: when > 0, ALSO return the per-region feature tiles
    (B, n_regions, d^2, w^2, D) of the token state entering the global
    block of subset ``capture_beta`` — the tile the feature cache stores
    for the NEXT frame's reuse.  Must be >= beta when a restoration is
    pending (tiles are only defined on the full-length sequence); for a
    mixed forward ``capture_beta == beta`` captures the restored tensor
    itself, so reused regions' tiles round-trip unchanged (staleness is
    bounded by the policy's K, not by the cache).
    """
    part = vit_partition(cfg)
    v = cfg.vit
    M = blocks_per_subset(cfg)
    N = v.n_subsets
    w2 = part.window * part.window
    padded = layout is not None
    n_reuse = 0 if reuse_ids is None else reuse_ids.shape[-1]
    has_low = low_ids is not None and low_ids.shape[-1] > 0
    mixed = (padded and beta > 0) or ((has_low or n_reuse > 0)
                                      and beta > 0)
    assert 0 <= beta <= N
    assert 0 <= capture_beta <= N
    if padded:
        assert full_ids is None and low_ids is None and reuse_ids is None
        if beta == 0:
            # restore-at-input (paper's "Subset 0"): upsampled full-
            # length sequence from block 0 — REUSE tiles cannot splice
            # here (they are restoration-point features)
            assert reuse_tiles is None
    if n_reuse > 0:
        assert beta >= 1, "REUSE regions need a restoration point >= 1"
        assert reuse_tiles is not None
    if capture_beta and mixed:
        assert capture_beta >= beta, \
            "cannot capture tiles before the restoration point"

    x_full = embed_patches(cfg, params, image, backend=backend)  # B,Hp,Wp,D
    pos = qt.asarray(params["pos_emb"])
    kv_len = win_valid = None
    # fused serving lane (kernels.fused_serving): pack + pos-embed +
    # pad zeroing fold into one prologue kernel and the restoration
    # scatter into one destination-major gather epilogue, so the packed
    # activations never round-trip HBM between the stages.  Engages on
    # the Pallas backend when the layout carries the inverse maps
    # (PlanLayout.out_src/out_map); legacy layout dicts fall back to the
    # unfused path unchanged.
    fused = (padded and beta >= 1 and "out_src" in layout
             and dispatch.use_pallas(backend))
    if padded:
        # the collapsed executable serves every plan mix, so the pooled
        # grid is always packed (a reuse-only sample simply never
        # gathers from the low half of the window bank)
        x_low = embed_patches(cfg, params, image, part.downsample, backend)
        if fused:
            # pad windows come out zero rather than window-0 replicas:
            # window attention zeroes them anyway, global attention
            # masks them via kv_len, restoration never reads them — the
            # valid lanes are bit-identical to the unfused pack
            bank = mr.window_bank(x_full, part, x_low, backend=backend)
            tokens = dispatch.fused_pack_pos(bank,
                                             pos_window_bank(pos, part),
                                             layout["win_src"],
                                             layout["nw"])
        else:
            tokens = mr.pack_padded(x_full, part, layout["win_src"],
                                    x_low_grid=x_low, backend=backend)
        if beta == 0:                     # restore at input: full length
            tokens = mr.restore_padded(tokens, part, layout["win_dst"],
                                       layout["low_src"],
                                       layout["low_ids"],
                                       backend=backend)
            tokens = tokens + packed_positions(pos, part, None, None)
        else:
            if not fused:
                tokens = tokens + packed_positions(
                    pos, part, None, None, win_src=layout["win_src"],
                    ids_key=ids_key)
            win_valid = jnp.asarray(layout["nw"], jnp.int32)
            kv_len = win_valid * w2
            if win_valid.ndim == 0:
                win_valid = win_valid[None]
                kv_len = kv_len[None]
    elif mixed:
        # reuse-only plans (n_low = 0) never read the pooled grid — skip
        # the downsampled patch-embedding pass entirely
        x_low = (embed_patches(cfg, params, image, part.downsample,
                               backend) if has_low else None)
        tokens, _ = mr.pack_mixed(x_full, part, full_ids, low_ids,
                                  x_low_grid=x_low, backend=backend)
        tokens = tokens + packed_positions(pos, part, full_ids, low_ids,
                                           ids_key=ids_key)
    else:
        if has_low:                                           # beta == 0
            x_low = embed_patches(cfg, params, image, part.downsample,
                                  backend)
            packed, _ = mr.pack_mixed(x_full, part, full_ids, low_ids,
                                      x_low_grid=x_low, backend=backend)
            tokens = mr.restore_full(packed, part, full_ids, low_ids,
                                     backend=backend)
        else:
            tokens = mr.grid_to_full_seq(x_full, part)
        tokens = tokens + packed_positions(pos, part, None, None)

    tiles = None
    restored = not mixed
    for s in range(N):
        for m in range(M):
            idx = s * M + m
            params_blk = params["blocks"][idx]
            is_global = m == M - 1
            if is_global and not restored and beta == s + 1:
                if fused:
                    B, D = tokens.shape[0], tokens.shape[-1]
                    tokens = dispatch.fused_restore(
                        tokens.reshape(B, -1, w2, D), layout["out_src"],
                        layout["out_map"], part.window, part.downsample,
                        reuse_tiles=reuse_tiles)
                elif padded:
                    tokens = mr.restore_padded(
                        tokens, part, layout["win_dst"],
                        layout["low_src"], layout["low_ids"],
                        backend=backend,
                        reuse_ids=(layout["reuse_ids"]
                                   if reuse_tiles is not None else None),
                        reuse_tiles=reuse_tiles)
                else:
                    tokens = mr.restore_full(
                        tokens, part, full_ids, low_ids, backend=backend,
                        reuse_ids=(reuse_ids if n_reuse else None),
                        reuse_tiles=(reuse_tiles if n_reuse else None))
                restored = True
            if is_global and capture_beta == s + 1:
                B = tokens.shape[0]
                tiles = tokens.reshape(B, part.n_regions,
                                       part.windows_per_full_region,
                                       w2, tokens.shape[-1])
            tokens = _vit_block(cfg, params_blk, tokens,
                                window=0 if is_global else w2,
                                kv_len=None if restored else kv_len,
                                win_valid=None if restored else win_valid,
                                backend=backend)
    # beta <= N always restores: beta == N hits the LAST global block.

    tokens = L.apply_norm(cfg, params["final_norm"], tokens)
    feats = mr.full_seq_to_grid(tokens, part)
    if capture_beta:
        return feats, tiles
    return feats


def forward_det(cfg: ModelConfig, params, image,
                full_ids=None, low_ids=None, beta: int = 0,
                backend: Optional[str] = None,
                reuse_ids=None, reuse_tiles=None, capture_beta: int = 0,
                layout: Optional[Dict] = None,
                ids_key: Optional[bytes] = None):
    """Full model: backbone + dense head.  Returns det_head outputs (or
    ``(outputs, tiles)`` when ``capture_beta > 0`` — the per-region
    restoration-point feature tiles that refresh the client's
    FeatureCache for temporal reuse).  ``layout`` selects the
    length-bucketed padded forward (see forward_features)."""
    feats = forward_features(cfg, params, image, full_ids, low_ids, beta,
                             backend=backend, reuse_ids=reuse_ids,
                             reuse_tiles=reuse_tiles,
                             capture_beta=capture_beta, layout=layout,
                             ids_key=ids_key)
    if capture_beta:
        feats, tiles = feats
        return dh.det_head_forward(cfg, params["head"], feats), tiles
    return dh.det_head_forward(cfg, params["head"], feats)


# ---------------------------------------------------------------------------
# FLOP accounting (used by the latency model and Fig. 5 benchmark)


def backbone_flops_windows(cfg: ModelConfig, n_windows: int,
                           beta: int) -> float:
    """Analytic attention+MLP FLOPs with the PRE-restoration sequence
    pinned to ``n_windows`` windows (``n_windows * w^2`` tokens) — the
    cost of a length-bucketed padded forward, where pad windows are
    masked but still computed.  ``beta == 0`` or a full-length
    ``n_windows`` degenerates to the plain full-resolution cost.
    """
    part = vit_partition(cfg)
    D, F = cfg.d_model, cfg.d_ff
    M = blocks_per_subset(cfg)
    N = cfg.vit.n_subsets
    w2 = part.window * part.window

    n_mixed = n_windows * w2
    n_full = part.grid_h * part.grid_w
    nw_full = part.n_regions * part.windows_per_full_region

    def block_flops(n_tok, n_win):
        proj = 4 * 2 * n_tok * D * D                     # qkvo projections
        if n_win:                                        # window attention
            att = 2 * 2 * n_win * w2 * w2 * D
        else:                                            # global attention
            att = 2 * 2 * n_tok * n_tok * D
        mlp = 2 * 2 * n_tok * D * F
        return proj + att + mlp

    total = 0.0
    restored = beta <= 0
    for s in range(N):
        for m in range(M):
            is_global = m == M - 1
            if is_global and not restored and beta == s + 1:
                restored = True
            if restored:
                total += block_flops(n_full, 0 if is_global else nw_full)
            else:
                total += block_flops(n_mixed, 0 if is_global else n_windows)
    return total


def backbone_flops(cfg: ModelConfig, n_low: int, beta: int,
                   n_reuse: int = 0,
                   length_edges: Optional[Sequence[int]] = None) -> float:
    """Analytic attention+MLP FLOPs of the backbone for a given config.

    Mirrors forward_features' block schedule; used to parameterise the
    inference-delay linear models LM^inf_beta(N_d, N_r) (paper §IV-D,
    extended with the temporal-reuse term: reused regions contribute NO
    tokens before the restoration point).

    ``length_edges``: cost the PADDED length bucket the serving hot path
    actually runs (partition.length_bucket_set) instead of the exact
    mixed length — what LM^inf must model once executables are keyed on
    length buckets rather than (n_low, n_reuse).
    """
    part = vit_partition(cfg)
    mixed = (n_low > 0 or n_reuse > 0) and beta > 0
    if not mixed:
        return backbone_flops_windows(
            cfg, part.n_regions * part.windows_per_full_region, 0)
    nw = part.n_windows(n_low, n_reuse)
    if length_edges is not None:
        # the degenerate all-reuse point (0 transmitted windows) is not
        # servable (policies keep >= 1 transmitted region) but delay-
        # model fits probe it — cost it at the smallest bucket
        nw = pt_length_bucket(max(nw, 1), length_edges)
    return backbone_flops_windows(cfg, nw, beta)
