"""Dense prediction head: simple ViTDet-style feature pyramid + an
anchor-free (FCOS-lite) detection head.

The pyramid is built from the backbone's single-scale stride-16 map
(ViTDet's key observation): stride 8 by 2x nearest upsample, stride 16
identity, stride 32 by 2x average pool — each followed by a 1x1 lateral
projection and a 3x3 conv.  The shared head predicts per-location class
logits, ltrb box offsets (in stride units), and centerness.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.quant import qtensor as qt

STRIDES = (8, 16, 32)


def _conv_init(key, k, cin, cout, dtype):
    fan_in = k * k * cin
    w = jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout),
                                    jnp.float32) / jnp.sqrt(fan_in)
    return w.astype(dtype)


def conv2d(x, w, b=None):
    """NHWC conv, SAME padding.  int8 QuantTensor weights dequantize at
    entry (convs are a small share of backbone FLOPs; the int8 win there
    is the 4x smaller resident weights, not an int8 conv kernel)."""
    if isinstance(w, qt.QuantTensor):
        w = w.dequant()
        x = x.astype(w.dtype)
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out if b is None else out + b


def init_det_head(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    v = cfg.vit
    D, C = cfg.d_model, v.out_channels
    ks = jax.random.split(key, 12)
    p = {"lateral": [], "smooth": []}
    for i in range(len(STRIDES)):
        p["lateral"].append({"w": _conv_init(ks[2 * i], 1, D, C, dtype),
                             "b": jnp.zeros((C,), dtype)})
        p["smooth"].append({"w": _conv_init(ks[2 * i + 1], 3, C, C, dtype),
                            "b": jnp.zeros((C,), dtype)})
    p["tower"] = {"w": _conv_init(ks[6], 3, C, C, dtype),
                  "b": jnp.zeros((C,), dtype)}
    p["cls"] = {"w": _conv_init(ks[7], 3, C, v.n_classes, dtype),
                "b": jnp.full((v.n_classes,), -4.0, dtype)}   # focal prior
    p["box"] = {"w": _conv_init(ks[8], 3, C, 4, dtype),
                "b": jnp.zeros((4,), dtype)}
    p["ctr"] = {"w": _conv_init(ks[9], 3, C, 1, dtype),
                "b": jnp.zeros((1,), dtype)}
    return p


def _resize2x_up(x):
    B, H, W, C = x.shape
    x = jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)
    return x


def _pool2x(x):
    B, H, W, C = x.shape
    return jnp.mean(x.reshape(B, H // 2, 2, W // 2, 2, C), axis=(2, 4))


def det_head_forward(cfg: ModelConfig, p, feats: jnp.ndarray
                     ) -> List[Dict[str, jnp.ndarray]]:
    """feats: (B, Hp, Wp, D) stride-16 map -> per-level head outputs."""
    levels = [_resize2x_up(feats), feats, _pool2x(feats)]
    outs = []
    for i, x in enumerate(levels):
        x = conv2d(x, p["lateral"][i]["w"], p["lateral"][i]["b"])
        x = jax.nn.relu(conv2d(x, p["smooth"][i]["w"], p["smooth"][i]["b"]))
        t = jax.nn.relu(conv2d(x, p["tower"]["w"], p["tower"]["b"]))
        outs.append({
            "cls": conv2d(t, p["cls"]["w"], p["cls"]["b"]),
            "box": jax.nn.softplus(conv2d(t, p["box"]["w"], p["box"]["b"])),
            "ctr": conv2d(t, p["ctr"]["w"], p["ctr"]["b"]),
            "stride": STRIDES[i],
        })
    return outs


# ---------------------------------------------------------------------------
# loss (FCOS-lite): focal BCE on class, L1 on ltrb at positives, BCE ctr


def _focal_bce(logits, targets, alpha=0.25, gamma=2.0):
    # stable form: log-sigmoid everywhere and pt = exp(-ce) — the naive
    # log(p + eps) variant is value-stable but its fused XLA backward
    # produces NaN for saturated logits (0 * inf in the chain rule)
    x = logits.astype(jnp.float32)
    log_p = jax.nn.log_sigmoid(x)
    log_1mp = jax.nn.log_sigmoid(-x)
    ce = -(targets * log_p + (1 - targets) * log_1mp)
    pt = jnp.exp(-ce)
    w = (targets * alpha + (1 - targets) * (1 - alpha)) * (1 - pt) ** gamma
    return w * ce


def det_loss(cfg: ModelConfig, outputs, targets) -> Tuple[jnp.ndarray, Dict]:
    """targets: per-level dicts {"cls": (B,H,W,nc), "box": (B,H,W,4),
    "pos": (B,H,W,1)} produced by data.synthetic_video.render_targets."""
    total_cls = total_box = total_ctr = 0.0
    n_pos = 0.0
    for out, tgt in zip(outputs, targets):
        total_cls += jnp.sum(_focal_bce(out["cls"], tgt["cls"]))
        pos = tgt["pos"].astype(jnp.float32)
        n_pos += jnp.sum(pos)
        total_box += jnp.sum(jnp.abs(out["box"] - tgt["box"]) * pos)
        ctr_t = tgt.get("ctr", pos)
        total_ctr += jnp.sum(
            _focal_bce(out["ctr"], ctr_t, alpha=0.5, gamma=0.0) * pos)
    # clamp the normaliser at 1: frames whose objects all fall outside the
    # stride bands have zero positives, and dividing by ~0 explodes the
    # focal term (one such frame poisons training with NaN grads)
    n_pos = jnp.maximum(n_pos, 1.0)
    loss = (total_cls + total_box + total_ctr) / n_pos
    return loss, {"cls": total_cls / n_pos, "box": total_box / n_pos,
                  "n_pos": n_pos}


# ---------------------------------------------------------------------------
# decode: head outputs -> (boxes, scores, classes) with static top-k


def decode_detections(cfg: ModelConfig, outputs, top_k: int = 64,
                      score_thresh: float = 0.3):
    """Returns boxes (B,K,4) xyxy in pixels, scores (B,K), classes (B,K).
    Slots below ``score_thresh`` have score 0 (static shapes; no NMS —
    synthetic scenes are sparse and locations are near-unique)."""
    all_scores, all_boxes, all_cls = [], [], []
    for out in outputs:
        B, H, W, nc = out["cls"].shape
        stride = out["stride"]
        prob = jax.nn.sigmoid(out["cls"].astype(jnp.float32)) * \
            jax.nn.sigmoid(out["ctr"].astype(jnp.float32))
        ys, xs = jnp.meshgrid(jnp.arange(H), jnp.arange(W), indexing="ij")
        cx = (xs.astype(jnp.float32) + 0.5) * stride
        cy = (ys.astype(jnp.float32) + 0.5) * stride
        ltrb = out["box"].astype(jnp.float32) * stride
        boxes = jnp.stack([cx[None] - ltrb[..., 0], cy[None] - ltrb[..., 1],
                           cx[None] + ltrb[..., 2], cy[None] + ltrb[..., 3]],
                          axis=-1)                      # (B,H,W,4)
        score = jnp.max(prob, axis=-1)                  # (B,H,W)
        cls = jnp.argmax(prob, axis=-1)
        all_scores.append(score.reshape(B, H * W))
        all_boxes.append(boxes.reshape(B, H * W, 4))
        all_cls.append(cls.reshape(B, H * W))
    scores = jnp.concatenate(all_scores, axis=1)
    boxes = jnp.concatenate(all_boxes, axis=1)
    classes = jnp.concatenate(all_cls, axis=1)
    top_s, top_i = jax.lax.top_k(scores, top_k)
    top_b = jnp.take_along_axis(boxes, top_i[..., None], axis=1)
    top_c = jnp.take_along_axis(classes, top_i, axis=1)
    top_s = jnp.where(top_s >= score_thresh, top_s, 0.0)
    return top_b, top_s, top_c
