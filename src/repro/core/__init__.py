"""Paper C1: dynamic mixed-resolution inference for dense-prediction ViTs
(partition, mixed_res, vit_backbone, det_head) and its 1-D sequence
adaptation for decoder LMs (seq_mixed_res)."""
from repro.core.partition import (Partition, bucket_n_low, bucket_set,
                                  make_partition, mask_to_region_ids,
                                  region_ids_to_mask)

__all__ = [
    "Partition", "make_partition", "bucket_n_low", "bucket_set",
    "mask_to_region_ids", "region_ids_to_mask",
]
