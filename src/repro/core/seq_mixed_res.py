"""1-D sequence adaptation of the paper's mixed-resolution technique
(DESIGN.md §4): **mixed-granularity prefill** for decoder LMs.

Transposition of §III to sequences:
  decision region  -> span of r = w*d consecutive tokens
  low-res region   -> the span's d-token groups mean-pooled (r -> w tokens)
  window attention -> (causal attention over the shorter mixed sequence)
  restoration (RP) -> broadcast pooled hidden states back to all covered
                      positions between backbone subsets; KV-cache entries
                      of pre-RP layers are restored the same way, so decode
                      continues with a full-resolution cache.

Shapes are static given the bucketed ``n_low`` (how many spans are pooled);
WHICH spans is runtime data carried by three gather-index arrays built
host-side by :func:`build_seq_pack`.

The mixed sequence preserves temporal order, so index-causality inside the
standard causal attention == position-causality, and pre-trained weights
apply unchanged — the 1-D analogue of the paper's "no retraining" claim.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.transformer import LOCAL, ParallelCtx


@dataclass(frozen=True)
class SeqPartition:
    seq_len: int
    window: int            # w: tokens per pooled span after pooling
    downsample: int        # d: pooling factor

    @property
    def span(self) -> int:                    # r = w * d tokens per span
        return self.window * self.downsample

    @property
    def n_spans(self) -> int:
        return self.seq_len // self.span

    def validate(self):
        if self.seq_len % self.span:
            raise ValueError(f"seq_len {self.seq_len} % span {self.span}")

    def n_tokens(self, n_low: int) -> int:
        return self.seq_len - n_low * (self.span - self.window)


def seq_partition(cfg: ModelConfig, seq_len: int) -> SeqPartition:
    m = cfg.mixed_res
    p = SeqPartition(seq_len, m.window, m.downsample)
    p.validate()
    return p


def layers_before_rp(cfg: ModelConfig, beta: int, n_layers: int) -> int:
    """Number of leading backbone layers run at mixed granularity."""
    n_sub = cfg.mixed_res.n_subsets
    assert 0 <= beta <= n_sub
    return (beta * n_layers) // n_sub


# ---------------------------------------------------------------------------
# host-side pack-plan construction (numpy; outputs are DATA for the jitted fn)


def build_seq_pack(span_mask: np.ndarray, n_low: int, part: SeqPartition
                   ) -> Dict[str, np.ndarray]:
    """Build gather plans for a given span downsampling mask.

    span_mask: (n_spans,) binary, 1 = pool this span.  ``n_low`` is the
    static bucket; extra selections are dropped (first n_low kept), missing
    ones are filled from the EARLIEST unselected spans (old context is the
    least fresh — the 1-D analogue of preferring background regions).

    Returns int32 arrays:
      mix_idx     (T_mix,) index into concat([tokens (T), pooled (T/d)])
      pos_mix     (T_mix,) RoPE position of each mixed slot
      restore_idx (T,)     mixed slot covering each full position
      low_spans   (n_low,) the spans actually pooled
    """
    part.validate()
    mask = np.asarray(span_mask).reshape(-1).astype(bool).copy()
    assert mask.shape[0] == part.n_spans
    sel = np.nonzero(mask)[0]
    if len(sel) > n_low:
        mask[sel[n_low:]] = False
    elif len(sel) < n_low:
        unsel = np.nonzero(~mask)[0]
        mask[unsel[:n_low - len(sel)]] = True
    low_spans = np.nonzero(mask)[0].astype(np.int32)

    r, w, d = part.span, part.window, part.downsample
    T = part.seq_len
    mix_idx, pos_mix, restore_idx = [], [], np.zeros((T,), np.int32)
    for s in range(part.n_spans):
        t0 = s * r
        if mask[s]:
            g0 = t0 // d
            for g in range(w):
                slot = len(mix_idx)
                mix_idx.append(T + g0 + g)               # pooled source
                pos_mix.append(t0 + g * d + (d - 1) // 2)
                restore_idx[t0 + g * d: t0 + (g + 1) * d] = slot
        else:
            for t in range(t0, t0 + r):
                slot = len(mix_idx)
                mix_idx.append(t)
                pos_mix.append(t)
                restore_idx[t] = slot
    assert len(mix_idx) == part.n_tokens(n_low)
    return {
        "mix_idx": np.asarray(mix_idx, np.int32),
        "pos_mix": np.asarray(pos_mix, np.int32),
        "restore_idx": restore_idx,
        "low_spans": low_spans,
    }


# ---------------------------------------------------------------------------
# jitted packing / restoration primitives


def pool_groups(x: jnp.ndarray, d: int) -> jnp.ndarray:
    """Mean-pool groups of d along time: (B, T, D) -> (B, T/d, D)."""
    B, T, D = x.shape
    return jnp.mean(x.reshape(B, T // d, d, D).astype(jnp.float32),
                    axis=2).astype(x.dtype)


def pack_sequence(x: jnp.ndarray, mix_idx: jnp.ndarray, d: int) -> jnp.ndarray:
    """(B, T, D) -> (B, T_mix, D) mixed-granularity sequence."""
    z = jnp.concatenate([x, pool_groups(x, d)], axis=1)
    return jnp.take(z, mix_idx, axis=1)


def restore_sequence(x_mix: jnp.ndarray, restore_idx: jnp.ndarray
                     ) -> jnp.ndarray:
    """Broadcast-restore: (B, T_mix, D) -> (B, T, D)."""
    return jnp.take(x_mix, restore_idx, axis=1)


def _restore_cache_time(leaf: jnp.ndarray, restore_idx: jnp.ndarray,
                        time_axis: int) -> jnp.ndarray:
    """Rewrite cache leaf so [0, T) holds restored entries.

    leaf holds mixed-granularity entries at [0, T_mix) along time_axis.
    """
    T = restore_idx.shape[0]
    gathered = jnp.take(leaf, restore_idx, axis=time_axis)     # len T
    return jax.lax.dynamic_update_slice_in_dim(leaf, gathered, 0, time_axis)


def restore_kv_caches(caches, restore_idx, n_restore_layers: Dict[str, int]):
    """Restore the time axis of pre-RP layers' cache entries.

    caches: {"<kind>_blocks": stacked cache pytree (L, B, S, ...)} — the
    time axis is 2 for GQA k/v (L,B,S,KV,Dh) and MLA (L,B,S,rank).
    n_restore_layers: per stack name, how many leading layers are pre-RP.
    """
    out = {}
    for name, tree in caches.items():
        k = n_restore_layers.get(name, 0)
        if k <= 0:
            out[name] = tree
            continue

        def fix(leaf):
            head = _restore_cache_time(leaf[:k], restore_idx, time_axis=2)
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, head.astype(leaf.dtype), 0, 0)

        out[name] = jax.tree_util.tree_map(fix, tree)
    return out


# ---------------------------------------------------------------------------
# generic mixed-granularity forward / prefill for scanned-decoder families
# (dense / moe / vlm / mla all go through transformer.run_blocks)


def _split_layers(cfg: ModelConfig, params, beta: int) -> int:
    return layers_before_rp(cfg, beta, cfg.n_layers)


def mixed_forward_hidden(cfg: ModelConfig, params, tokens, pack, beta: int,
                         ctx: ParallelCtx = LOCAL, image_embeds=None):
    """Training/eval forward with mixed-granularity lower layers.

    pack: dict of device arrays from build_seq_pack.  beta=0 -> identical
    to the plain forward (restore-at-input degenerates to full res).
    """
    x = tfm.embed_inputs(cfg, params, tokens, image_embeds)
    B, T, _ = x.shape
    Lb = _split_layers(cfg, params, beta)
    d = cfg.mixed_res.downsample
    aux = jnp.zeros((), jnp.float32)
    if Lb > 0:
        xm = pack_sequence(x, pack["mix_idx"], d)
        pos = jnp.broadcast_to(pack["pos_mix"][None], (B, xm.shape[1]))
        xm = ctx.hidden(xm)
        xm, _, a1 = tfm.run_blocks(cfg, params, xm, pos, 0, Lb, ctx)
        aux = aux + a1
        x = restore_sequence(xm, pack["restore_idx"])
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = ctx.hidden(x)
    x, _, a2 = tfm.run_blocks(cfg, params, x, positions, Lb, cfg.n_layers, ctx)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, aux + a2


def mixed_prefill(cfg: ModelConfig, params, tokens, pack, beta: int, caches,
                  ctx: ParallelCtx = LOCAL, image_embeds=None):
    """Serving prefill with mixed-granularity lower layers.

    Pre-RP layers attend over the pooled sequence (the latency win) and
    write pooled K/V; those cache entries are then broadcast-restored so
    the returned caches are FULL-resolution for every layer — decode
    proceeds exactly as after a normal prefill.
    """
    x = tfm.embed_inputs(cfg, params, tokens, image_embeds)
    B, T, _ = x.shape
    Lb = _split_layers(cfg, params, beta)
    d = cfg.mixed_res.downsample
    aux = jnp.zeros((), jnp.float32)

    if Lb > 0:
        xm = pack_sequence(x, pack["mix_idx"], d)
        pos = jnp.broadcast_to(pack["pos_mix"][None], (B, xm.shape[1]))
        xm = ctx.hidden(xm)
        xm, caches, a1 = tfm.run_blocks(cfg, params, xm, pos, 0, Lb, ctx,
                                        caches=caches)
        aux = aux + a1
        x = restore_sequence(xm, pack["restore_idx"])
        # how many leading layers of each homogeneous stack are pre-RP
        n_restore = {}
        off = 0
        for kind, stack, n in tfm._block_stacks(cfg, params):
            n_restore[f"{kind}_blocks"] = max(min(Lb - off, n), 0)
            off += n
        caches = restore_kv_caches(caches, pack["restore_idx"], n_restore)

    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = ctx.hidden(x)
    x, caches, a2 = tfm.run_blocks(cfg, params, x, positions, Lb,
                                   cfg.n_layers, ctx, caches=caches)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, caches, aux + a2


# ---------------------------------------------------------------------------
# SSM family (linear-time backbone: pooling gives LINEAR savings only —
# recorded as technique_gain="linear" in DESIGN.md §Arch-applicability)


def mixed_forward_ssm(cfg: ModelConfig, params, tokens, pack, beta: int,
                      ctx: ParallelCtx = LOCAL):
    from repro.models import mamba2 as m2

    x = L.embed_tokens(params["embed"], tokens)
    B, T, _ = x.shape
    Lb = _split_layers(cfg, params, beta)
    d = cfg.mixed_res.downsample

    def body(x, p):
        h = L.apply_norm(cfg, p["ln"], x)
        x = x + m2.mamba2_forward(cfg, p["mamba"], h)
        x = ctx.hidden(x)
        return x, None

    if Lb > 0:
        xm = pack_sequence(x, pack["mix_idx"], d)
        sliced = jax.tree_util.tree_map(lambda a: a[:Lb],
                                        params["mamba_blocks"])
        xm, _ = jax.lax.scan(body, xm, sliced)
        x = restore_sequence(xm, pack["restore_idx"])
    rest = jax.tree_util.tree_map(lambda a: a[Lb:], params["mamba_blocks"])
    x, _ = jax.lax.scan(body, x, rest)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# whisper: encoder frame pooling (non-causal 1-D regions) — the decoder is
# untouched; mixed-res applies to the 1500-frame encoder (DESIGN.md §4).


def encode_mixed(cfg: ModelConfig, params, frames, pack, beta: int,
                 ctx: ParallelCtx = LOCAL):
    from repro.models import attention as attn

    n_enc = cfg.encdec.n_encoder_layers
    Lb = layers_before_rp(cfg, beta, n_enc)
    d = cfg.mixed_res.downsample
    B, T, D = frames.shape
    pos_emb = L.sinusoidal_positions(T, D).astype(frames.dtype)
    x = frames + pos_emb[None]

    def body(x, p):
        B_, T_, _ = x.shape
        positions = jnp.zeros((B_, T_), jnp.int32)
        h = L.apply_norm(cfg, p["ln1"], x)
        x = x + attn.attention_forward(cfg, p["attn"], h, positions,
                                       causal=False, rope=False)
        x = x + L.apply_mlp(cfg, p["ffn"], L.apply_norm(cfg, p["ln2"], x))
        x = ctx.hidden(x)
        return x, None

    if Lb > 0:
        xm = pack_sequence(x, pack["mix_idx"], d)
        head = jax.tree_util.tree_map(lambda a: a[:Lb], params["enc_blocks"])
        xm, _ = jax.lax.scan(body, xm, head)
        x = restore_sequence(xm, pack["restore_idx"])
    tail = jax.tree_util.tree_map(lambda a: a[Lb:], params["enc_blocks"])
    x, _ = jax.lax.scan(body, x, tail)
    return L.apply_norm(cfg, params["enc_norm"], x)


# ---------------------------------------------------------------------------
# analytic FLOPs of the mixed prefill (latency model input, paper §IV-D)


def prefill_flops(cfg: ModelConfig, seq_len: int, n_low: int,
                  beta: int) -> float:
    """Attention+MLP FLOPs for a mixed-granularity prefill (per batch el)."""
    part = seq_partition(cfg, seq_len)
    Lb = layers_before_rp(cfg, beta, cfg.n_layers)
    Tm = part.n_tokens(n_low)
    D, F = cfg.d_model, cfg.d_ff

    def layer_flops(T):
        proj = 2 * T * D * (cfg.q_dim + 2 * cfg.kv_dim) + \
            2 * T * cfg.q_dim * D
        att = 2 * 2 * T * T * cfg.q_dim / 2          # causal: half the pairs
        if cfg.moe is not None:
            f_eff = cfg.moe.top_k * cfg.moe.d_ff_expert + \
                cfg.moe.n_shared_experts * cfg.moe.d_ff_expert
            mlp = 3 * 2 * T * D * f_eff
        else:
            mlp = 3 * 2 * T * D * F
        return proj + att + mlp

    return Lb * layer_flops(Tm) + (cfg.n_layers - Lb) * layer_flops(seq_len)
