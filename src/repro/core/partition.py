"""Inference-compatible region partitioning (paper §III-A).

The frame is tiled into *decision regions* of ``r x r`` image patches with
``r = w * d`` (w = window size of the backbone's window attention, d = the
downsampling factor).  This guarantees:

  * a FULL-resolution region contributes exactly ``d**2`` attention windows
    of ``w x w`` patch tokens;
  * a DOWNSAMPLED region (pixels shrunk by d, then patchified) contributes
    exactly ONE ``w x w`` window.

so any mixed-resolution token sequence tiles perfectly into windows — the
key structural invariant that lets a *pre-trained* windowed ViT process it
with no architecture change (paper Fig. 3).

Token layout convention used throughout this repo (TPU-native, DESIGN.md):
sequences are **window-blocked** — a sequence of whole windows, each
flattened row-major to ``w*w`` tokens.  Window attention is then a pure
reshape (no gather); gathers appear only at pack (input) and restoration.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Partition:
    """Static geometry of the decision-region grid."""
    grid_h: int          # patch-grid height  (e.g. 64 for 1024px / 16)
    grid_w: int          # patch-grid width
    window: int          # w: attention window size in patches
    downsample: int      # d

    @property
    def region(self) -> int:                       # r = w * d, in patches
        return self.window * self.downsample

    @property
    def regions_h(self) -> int:
        return self.grid_h // self.region

    @property
    def regions_w(self) -> int:
        return self.grid_w // self.region

    @property
    def n_regions(self) -> int:
        return self.regions_h * self.regions_w

    @property
    def tokens_full_region(self) -> int:           # r*r patches
        return self.region * self.region

    @property
    def tokens_low_region(self) -> int:            # one w*w window
        return self.window * self.window

    @property
    def windows_per_full_region(self) -> int:
        return self.downsample * self.downsample

    def validate(self) -> None:
        if self.grid_h % self.region or self.grid_w % self.region:
            raise ValueError(
                f"patch grid {self.grid_h}x{self.grid_w} not divisible by "
                f"decision region r={self.region} (= w{self.window} * "
                f"d{self.downsample})")

    # ------------------------------------------------------------------
    def n_tokens(self, n_low: int) -> int:
        """Total mixed-resolution token count for ``n_low`` low regions."""
        n_full = self.n_regions - n_low
        return (n_full * self.tokens_full_region
                + n_low * self.tokens_low_region)

    def n_windows(self, n_low: int) -> int:
        n_full = self.n_regions - n_low
        return n_full * self.windows_per_full_region + n_low


def make_partition(grid_h: int, grid_w: int, window: int,
                   downsample: int) -> Partition:
    p = Partition(grid_h, grid_w, window, downsample)
    p.validate()
    return p


# ---------------------------------------------------------------------------
# token bucketing (DESIGN.md: XLA cannot retrace per frame — N_d is rounded
# to a small static bucket set so the server compiles a handful of shapes)


def bucket_n_low(n_low: int, n_regions: int, n_buckets: int = 4) -> int:
    """Round ``n_low`` DOWN to the nearest bucket edge.

    Rounding down downsamples *fewer* regions than requested — the safe
    direction for accuracy (some regions selected for downsampling stay
    full-res).  Buckets: 0, R/n, 2R/n, ..., R (R = n_regions).
    """
    if n_low <= 0:
        return 0
    step = max(n_regions // n_buckets, 1)
    return min((n_low // step) * step, n_regions)


def bucket_set(n_regions: int, n_buckets: int = 4) -> Tuple[int, ...]:
    step = max(n_regions // n_buckets, 1)
    edges = list(range(0, n_regions + 1, step))
    if edges[-1] != n_regions:
        edges.append(n_regions)
    return tuple(edges)


# ---------------------------------------------------------------------------
# mask <-> region-id packing helpers (host-side, numpy: these produce the
# *data* gather indices; shapes depend only on the static bucket)


def mask_to_region_ids(mask: np.ndarray, n_low: int) -> Tuple[np.ndarray,
                                                              np.ndarray]:
    """Split region ids into (full_ids, low_ids) with static sizes.

    ``mask``: (n_regions,) binary; 1 = downsample.  ``n_low`` is the static
    bucket: if the mask selects more, the extras (highest ids) stay full;
    if fewer, low_ids is padded by *repeating* its last entry — repeated
    regions are packed twice but restored once (harmless duplicates cost
    only their window of compute).
    """
    mask = np.asarray(mask).reshape(-1).astype(bool)
    n_regions = mask.shape[0]
    low = np.nonzero(mask)[0]
    if len(low) >= n_low:
        kept_low = low[:n_low]
    else:
        pad = np.full((n_low - len(low),), low[-1] if len(low) else 0,
                      dtype=np.int64)
        kept_low = np.concatenate([low, pad]) if len(low) else pad
    low_set = set(kept_low[:min(len(low), n_low)].tolist())
    full = np.array([i for i in range(n_regions) if i not in low_set],
                    dtype=np.int64)
    assert len(full) == n_regions - min(len(low), n_low)
    # static size: n_regions - n_low full slots; if mask had fewer lows,
    # trim extras from the tail (they are covered by the padded low dups).
    full = full[:n_regions - n_low]
    return full.astype(np.int32), kept_low.astype(np.int32)


def region_ids_to_mask(low_ids: np.ndarray, n_regions: int) -> np.ndarray:
    m = np.zeros((n_regions,), np.int32)
    m[np.asarray(low_ids, np.int64)] = 1
    return m
