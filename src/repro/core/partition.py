"""Inference-compatible region partitioning (paper §III-A).

The frame is tiled into *decision regions* of ``r x r`` image patches with
``r = w * d`` (w = window size of the backbone's window attention, d = the
downsampling factor).  This guarantees:

  * a FULL-resolution region contributes exactly ``d**2`` attention windows
    of ``w x w`` patch tokens;
  * a DOWNSAMPLED region (pixels shrunk by d, then patchified) contributes
    exactly ONE ``w x w`` window.

so any mixed-resolution token sequence tiles perfectly into windows — the
key structural invariant that lets a *pre-trained* windowed ViT process it
with no architecture change (paper Fig. 3).

Token layout convention used throughout this repo (TPU-native, DESIGN.md):
sequences are **window-blocked** — a sequence of whole windows, each
flattened row-major to ``w*w`` tokens.  Window attention is then a pure
reshape (no gather); gathers appear only at pack (input) and restoration.

Temporal region reuse (:class:`RegionPlan`): every decision region is in
one of three states per offloaded frame —

  * ``FULL``  — transmit native pixels, pack ``d**2`` windows;
  * ``LOW``   — transmit downsampled pixels, pack one window;
  * ``REUSE`` — transmit NOTHING; the edge splices the region's cached
    backbone-feature tile (from this client's previous offload) back in
    at the restoration point.

The transmitted token sequence therefore excludes REUSE regions
entirely; ``(n_low, n_reuse)`` are bucketed together so the server still
compiles a bounded set of forward shapes.  Because a REUSE region ships
zero payload bytes, its bucket must match the plan EXACTLY (rounding
down would leave the server reading pixels that were never sent) — plans
are built bucket-exact in ``n_reuse`` by the policy
(offload.optimizer.build_reuse_plan).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

# RegionPlan states
FULL, LOW, REUSE = 0, 1, 2


@dataclass(frozen=True)
class Partition:
    """Static geometry of the decision-region grid."""
    grid_h: int          # patch-grid height  (e.g. 64 for 1024px / 16)
    grid_w: int          # patch-grid width
    window: int          # w: attention window size in patches
    downsample: int      # d

    @property
    def region(self) -> int:                       # r = w * d, in patches
        return self.window * self.downsample

    @property
    def regions_h(self) -> int:
        return self.grid_h // self.region

    @property
    def regions_w(self) -> int:
        return self.grid_w // self.region

    @property
    def n_regions(self) -> int:
        return self.regions_h * self.regions_w

    @property
    def tokens_full_region(self) -> int:           # r*r patches
        return self.region * self.region

    @property
    def tokens_low_region(self) -> int:            # one w*w window
        return self.window * self.window

    @property
    def windows_per_full_region(self) -> int:
        return self.downsample * self.downsample

    def validate(self) -> None:
        if self.grid_h % self.region or self.grid_w % self.region:
            raise ValueError(
                f"patch grid {self.grid_h}x{self.grid_w} not divisible by "
                f"decision region r={self.region} (= w{self.window} * "
                f"d{self.downsample})")

    # ------------------------------------------------------------------
    def n_tokens(self, n_low: int, n_reuse: int = 0) -> int:
        """Transmitted token count for ``n_low`` low + ``n_reuse`` reused
        regions (reused regions contribute NO tokens)."""
        n_full = self.n_regions - n_low - n_reuse
        return (n_full * self.tokens_full_region
                + n_low * self.tokens_low_region)

    def n_windows(self, n_low: int, n_reuse: int = 0) -> int:
        n_full = self.n_regions - n_low - n_reuse
        return n_full * self.windows_per_full_region + n_low


def make_partition(grid_h: int, grid_w: int, window: int,
                   downsample: int) -> Partition:
    p = Partition(grid_h, grid_w, window, downsample)
    p.validate()
    return p


# ---------------------------------------------------------------------------
# token bucketing (DESIGN.md: XLA cannot retrace per frame — N_d is rounded
# to a small static bucket set so the server compiles a handful of shapes)


def bucket_n_low(n_low: int, n_regions: int, n_buckets: int = 4) -> int:
    """Round ``n_low`` DOWN to the nearest bucket edge.

    Rounding down downsamples *fewer* regions than requested — the safe
    direction for accuracy (some regions selected for downsampling stay
    full-res).  Buckets: 0, R/n, 2R/n, ..., R (R = n_regions).
    """
    if n_low <= 0:
        return 0
    step = max(n_regions // n_buckets, 1)
    return min((n_low // step) * step, n_regions)


def bucket_set(n_regions: int, n_buckets: int = 4) -> Tuple[int, ...]:
    step = max(n_regions // n_buckets, 1)
    edges = list(range(0, n_regions + 1, step))
    if edges[-1] != n_regions:
        edges.append(n_regions)
    return tuple(edges)


# ---------------------------------------------------------------------------
# token-length buckets (the collapsed executable grid): instead of one
# executable per (n_low bucket, n_reuse bucket), the serving hot path
# pads the window-blocked sequence UP to one of a few LENGTH buckets and
# carries (which regions, how many are valid) as runtime i32 data.  A
# "length" is a WINDOW count — the sequence is a concatenation of
# whole w*w-token windows, so n_tokens = n_windows * w^2 exactly.

N_LENGTH_BUCKETS = 3


def length_bucket_set(part: Partition,
                      n_edges: int = N_LENGTH_BUCKETS) -> Tuple[int, ...]:
    """Window-count bucket edges over the reachable sequence lengths.

    Edges are multiples of ``d^2`` (a whole full-res region) so a bucket
    always fits an integral mix of regions; the top edge is the full-
    resolution window count, so every transmittable plan has a bucket.
    """
    dd = part.windows_per_full_region
    nw_max = part.n_regions * dd
    step = -(-nw_max // max(n_edges, 1))          # ceil
    step = -(-step // dd) * dd                    # round up to d^2 multiple
    edges = list(range(step, nw_max, step))
    edges.append(nw_max)
    return tuple(edges)


def length_bucket(n_windows: int, edges: Sequence[int]) -> int:
    """Round a window count UP to the nearest length-bucket edge.

    Padding up is the only safe direction: pad windows are inert (masked
    out of global attention, routed to the sentinel row at restoration),
    while rounding down would drop transmitted windows.
    """
    assert n_windows >= 1, f"empty sequence: n_windows={n_windows}"
    for edge in sorted(edges):
        if n_windows <= edge:
            return edge
    raise ValueError(f"sequence of {n_windows} windows exceeds largest "
                     f"length bucket {max(edges)}")


# batch-size buckets (serving hot path): waves are padded UP to the next
# edge so the compiled-executable grid is bounded in B as well — without
# this, every distinct wave size B is a fresh XLA trace at serve time.
BATCH_BUCKETS = (1, 2, 4, 8)


def batch_bucket(b: int, buckets: Sequence[int] = BATCH_BUCKETS) -> int:
    """Round a wave size UP to the nearest batch bucket.

    Padding up is the only safe direction: padded samples replicate a
    real sample and are dropped from the decoded detections, so the wave
    result is unchanged (pinned bit-exactly by tests — within one
    executable, XLA results are invariant to pad content and row order).
    """
    assert b >= 1, f"empty wave: B={b}"
    for edge in sorted(buckets):
        if b <= edge:
            return edge
    raise ValueError(f"wave size {b} exceeds largest batch bucket "
                     f"{max(buckets)}")


# ---------------------------------------------------------------------------
# RegionPlan: per-region FULL / LOW / REUSE states


@dataclass(frozen=True)
class RegionPlan:
    """Per-region transmit/compute plan for one offloaded frame.

    ``states``: (n_regions,) int8 array of FULL / LOW / REUSE.  FULL and
    LOW regions are transmitted (native / downsampled); REUSE regions
    ship zero payload bytes and are restored from the client's cached
    backbone-feature tiles (serve.request.FeatureCache).
    """
    states: np.ndarray

    @classmethod
    def from_mask(cls, mask: np.ndarray) -> "RegionPlan":
        """Binary downsample mask (the legacy region model) -> plan."""
        m = np.asarray(mask).reshape(-1)
        return cls(np.where(m != 0, LOW, FULL).astype(np.int8))

    @property
    def n_regions(self) -> int:
        return int(self.states.shape[0])

    @property
    def n_low(self) -> int:
        return int((self.states == LOW).sum())

    @property
    def n_reuse(self) -> int:
        return int((self.states == REUSE).sum())

    @property
    def n_transmit(self) -> int:
        return self.n_regions - self.n_reuse

    def low_mask(self) -> np.ndarray:
        return (self.states == LOW).astype(np.int32)

    def reuse_mask(self) -> np.ndarray:
        return (self.states == REUSE).astype(np.int32)


# ---------------------------------------------------------------------------
# mask/plan <-> region-id packing helpers (host-side, numpy: these produce
# the *data* gather indices; shapes depend only on the static buckets)


def _static_select(ids: np.ndarray, n: int) -> Tuple[np.ndarray, set]:
    """First ``n`` ids with static size; pads by repeating the last entry
    (or 0 when empty).  Returns (kept ids, the set actually selected)."""
    if len(ids) >= n:
        kept = ids[:n]
        return kept, set(kept.tolist())
    pad = np.full((n - len(ids),), ids[-1] if len(ids) else 0,
                  dtype=np.int64)
    kept = np.concatenate([ids, pad]) if len(ids) else pad
    return kept, set(ids.tolist())


def plan_to_region_ids(states: np.ndarray, n_low: int, n_reuse: int = 0
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split plan states into (full_ids, low_ids, reuse_ids), static
    sizes ``(n_regions - n_low - n_reuse, n_low, n_reuse)``.

    ``n_low`` / ``n_reuse`` are the static buckets: extra LOW/REUSE
    selections beyond them revert to FULL (the accuracy-safe direction
    for LOW; for REUSE the caller must pass a bucket-exact plan — see the
    module docstring).  When the plan selects FEWER than the bucket, ids
    are padded by repeating the last entry — duplicates are packed twice
    but restored once through the sentinel-row scatter
    (mixed_res.restore_full).
    """
    states = np.asarray(states).reshape(-1)
    n_regions = states.shape[0]
    low = np.nonzero(states == LOW)[0]
    reuse = np.nonzero(states == REUSE)[0]
    kept_low, low_set = _static_select(low, n_low)
    kept_reuse, reuse_set = _static_select(reuse, n_reuse)
    drop = low_set | reuse_set
    full = np.array([i for i in range(n_regions) if i not in drop],
                    dtype=np.int64)
    # static size: if the plan had fewer lows/reuses than the buckets,
    # trim extras from the tail (they are covered by the padded dups).
    full = full[:n_regions - n_low - n_reuse]
    return (full.astype(np.int32), kept_low.astype(np.int32),
            kept_reuse.astype(np.int32))


def mask_to_region_ids(mask: np.ndarray, n_low: int) -> Tuple[np.ndarray,
                                                              np.ndarray]:
    """Split region ids into (full_ids, low_ids) with static sizes.

    ``mask``: (n_regions,) binary; 1 = downsample.  The two-state special
    case of :func:`plan_to_region_ids` (kept as the API for the binary
    full/low paths)."""
    mask = np.asarray(mask).reshape(-1)
    full, low, _ = plan_to_region_ids(
        np.where(mask != 0, LOW, FULL).astype(np.int8), n_low, 0)
    return full, low


def region_ids_to_mask(low_ids: np.ndarray, n_regions: int) -> np.ndarray:
    m = np.zeros((n_regions,), np.int32)
    m[np.asarray(low_ids, np.int64)] = 1
    return m


# ---------------------------------------------------------------------------
# per-sample id stacking for batched waves (host-side; the (B, n) rank of
# mixed_res).  Every sample keeps its OWN layout; only the bucket counts
# are shared across the wave.


def stack_region_ids(masks: Sequence[np.ndarray], n_low: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-sample (B, nF) / (B, nL) region ids for a same-bucket wave."""
    ids = [mask_to_region_ids(m, n_low) for m in masks]
    return (np.stack([f for f, _ in ids]).astype(np.int32),
            np.stack([l for _, l in ids]).astype(np.int32))


def stack_plan_ids(plans: Sequence["RegionPlan"], n_low: int, n_reuse: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-sample (B, nF) / (B, nL) / (B, nR) ids for a same-bucket wave."""
    ids = [plan_to_region_ids(p.states, n_low, n_reuse) for p in plans]
    return (np.stack([f for f, _, _ in ids]).astype(np.int32),
            np.stack([l for _, l, _ in ids]).astype(np.int32),
            np.stack([r for _, _, r in ids]).astype(np.int32))


# ---------------------------------------------------------------------------
# padded plan layouts (host-side): the mask-traced description of ONE
# RegionPlan inside a length bucket.  All shapes depend only on the
# bucket (``nw_pad``) and the partition — (n_low, n_reuse) are runtime
# data, so every plan mix at one length bucket shares one executable.


def plan_n_windows(plan: "RegionPlan", part: Partition) -> int:
    """Transmitted window count of a plan (its pre-padding length)."""
    return part.n_windows(plan.n_low, plan.n_reuse)


@dataclass(frozen=True)
class PlanLayout:
    """Padded window-level layout of one plan at a length bucket.

    Sequence convention matches the legacy exact-shape pack: full-region
    windows first (regions ascending, d^2 windows each, row-major), then
    one window per LOW region (ascending), then pad windows up to
    ``nw_pad``.  Pad windows replicate source window 0 so their content
    stays finite; every consumer routes them to a sentinel.

      win_src    (nw_pad,)     source window in the packed window bank
                               [full windows (nR*d^2) | low windows (nR)]
      win_dst    (nw_pad,)     restoration slot of a FULL window in the
                               full-res window grid; LOW and pad windows
                               carry the sentinel slot nR*d^2
      low_src    (n_regions,)  sequence position of the i-th LOW window
                               (pads read position 0, discarded)
      low_ids    (n_regions,)  destination region of the i-th LOW window
                               (pads carry the sentinel region nR)
      reuse_ids  (n_regions,)  REUSE regions (pads carry the sentinel)
      out_src    (nR*d^2,)     destination-major inverse of the scatter:
                               the SOURCE window of every full-res grid
                               slot — a packed sequence position for
                               FULL/LOW regions, or ``nw_pad + j*d^2 + k``
                               into the appended reuse-tile bank for the
                               j-th REUSE region's sub-window k (the
                               fused restore epilogue's gather indices,
                               kernels.fused_serving)
      out_map    (nR*d^2,)     token permutation per slot: 0 = identity
                               (FULL/REUSE), k+1 = upsample map of
                               sub-window k (LOW regions)
      nw         valid window count (i32 runtime input; tokens beyond
                 nw * w^2 are masked out of pre-restoration global
                 attention and zeroed by the window-attention valid flag)
      key        fingerprint bytes, computed ONCE here so downstream
                 caches (packed_positions) key in O(1)
    """
    nw: int
    n_low: int
    n_reuse: int
    win_src: np.ndarray
    win_dst: np.ndarray
    low_src: np.ndarray
    low_ids: np.ndarray
    reuse_ids: np.ndarray
    out_src: np.ndarray
    out_map: np.ndarray
    key: bytes


def plan_layout(states: np.ndarray, nw_pad: int,
                part: Partition) -> PlanLayout:
    """Build the padded layout of a plan for the ``nw_pad`` bucket."""
    states = np.asarray(states).reshape(-1)
    nR, dd = part.n_regions, part.windows_per_full_region
    assert states.shape[0] == nR
    full = np.nonzero(states == FULL)[0]
    low = np.nonzero(states == LOW)[0]
    reuse = np.nonzero(states == REUSE)[0]
    nw = len(full) * dd + len(low)
    if not 1 <= nw <= nw_pad:
        raise ValueError(f"plan needs {nw} windows; bucket holds {nw_pad}")

    sent_w = nR * dd
    win_src = np.zeros((nw_pad,), np.int32)
    win_dst = np.full((nw_pad,), sent_w, np.int32)
    slots = (full[:, None] * dd + np.arange(dd)[None, :]).reshape(-1)
    win_src[:len(slots)] = slots
    win_dst[:len(slots)] = slots
    low_src = np.zeros((nR,), np.int32)
    low_ids = np.full((nR,), nR, np.int32)
    win_src[len(slots):nw] = sent_w + low
    low_src[:len(low)] = np.arange(len(slots), nw)
    low_ids[:len(low)] = low
    win_src[nw:] = win_src[0]            # pads replicate a real window
    reuse_pad = np.full((nR,), nR, np.int32)
    reuse_pad[:len(reuse)] = reuse

    # destination-major inverse (fused restore epilogue): every grid
    # slot names its source window.  The states partition the regions,
    # so the inverse is total — no sentinel needed.
    out_src = np.zeros((nR * dd,), np.int32)
    out_map = np.zeros((nR * dd,), np.int32)
    out_src[slots] = np.arange(len(slots), dtype=np.int32)
    for j, r in enumerate(low):
        out_src[r * dd:(r + 1) * dd] = len(slots) + j
        out_map[r * dd:(r + 1) * dd] = np.arange(1, dd + 1)
    for j, r in enumerate(reuse):
        out_src[r * dd:(r + 1) * dd] = nw_pad + j * dd + np.arange(dd)

    key = b"".join((np.int64([nw, nw_pad]).tobytes(), win_src.tobytes(),
                    low_src.tobytes(), low_ids.tobytes(),
                    reuse_pad.tobytes()))
    return PlanLayout(nw=nw, n_low=len(low), n_reuse=len(reuse),
                      win_src=win_src, win_dst=win_dst, low_src=low_src,
                      low_ids=low_ids, reuse_ids=reuse_pad,
                      out_src=out_src, out_map=out_map, key=key)


def stack_plan_layouts(layouts: Sequence[PlanLayout]
                       ) -> Tuple[dict, bytes]:
    """Per-sample (B, ·) arrays + (B,) valid counts for a wave, plus the
    wave's combined layout fingerprint."""
    arrays = {
        "win_src": np.stack([l.win_src for l in layouts]),
        "win_dst": np.stack([l.win_dst for l in layouts]),
        "low_src": np.stack([l.low_src for l in layouts]),
        "low_ids": np.stack([l.low_ids for l in layouts]),
        "reuse_ids": np.stack([l.reuse_ids for l in layouts]),
        "nw": np.array([l.nw for l in layouts], np.int32),
        "out_src": np.stack([l.out_src for l in layouts]),
        "out_map": np.stack([l.out_map for l in layouts]),
    }
    return arrays, b"|".join(l.key for l in layouts)
