"""Mixed-resolution tokenization + flexible restoration (paper §III).

2-D (ViT-native) implementation.  All functions keep shapes static given
the bucketed number of low-resolution regions ``n_low`` (static python
int); WHICH regions are low is runtime data (``full_ids`` / ``low_ids``
int32 arrays produced by ``partition.mask_to_region_ids``).

Region ids come in two ranks:
  * (n,)   — one layout shared by every sample in the batch (the original
             single-stream path);
  * (B, n) — a layout PER SAMPLE, so frames from different clients with
             different low-region masks (but the same n_low bucket) can be
             stacked into one batched forward (serve/edge.py).

Layout invariant (window-blocked, see partition.py):
  token sequence = [ full-region windows (n_full * d^2 of them)
                   | low-region windows  (n_low of them) ]
  each window = w*w tokens, row-major within the window.

With ``r = w*d`` every low region is exactly one window and every full
region is exactly d^2 windows, so window attention over the mixed
sequence is ``reshape -> sdpa -> reshape`` with NO gather (TPU-native
adaptation recorded in DESIGN.md).

Temporal region reuse (partition.RegionPlan): REUSE regions are absent
from the transmitted sequence entirely — ``n_full`` above is
``n_regions - n_low - n_reuse``.  At the restoration point
:func:`restore_full` splices the cached per-region feature tiles
(``reuse_tiles``, shaped (B, n_reuse, d^2, w^2, D)) into the reused
regions' slots, alongside the scattered full windows and the upsampled
low windows.  All scatters go through a sentinel-row buffer so padded
duplicate ids have well-defined (first-write-wins) semantics.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.partition import Partition
from repro.kernels import dispatch


# ---------------------------------------------------------------------------
# grid <-> window-blocked reshapes (pure layout, no compute)


def grid_to_region_windows(x: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """(B, Hp, Wp, C) -> (B, nR, d^2, w^2, C) region-major window blocks."""
    B, Hp, Wp, C = x.shape
    r, w, d = part.region, part.window, part.downsample
    nRh, nRw = part.regions_h, part.regions_w
    x = x.reshape(B, nRh, d, w, nRw, d, w, C)
    # region index = (nRh, nRw); window-in-region = (d, d); token = (w, w)
    x = x.transpose(0, 1, 4, 2, 5, 3, 6, 7)        # B,nRh,nRw,d,d,w,w,C
    return x.reshape(B, nRh * nRw, d * d, w * w, C)


def region_windows_to_grid(x: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """Inverse of :func:`grid_to_region_windows`."""
    B = x.shape[0]
    C = x.shape[-1]
    r, w, d = part.region, part.window, part.downsample
    nRh, nRw = part.regions_h, part.regions_w
    x = x.reshape(B, nRh, nRw, d, d, w, w, C)
    x = x.transpose(0, 1, 3, 5, 2, 4, 6, 7)        # B,nRh,d,w,nRw,d,w,C
    return x.reshape(B, part.grid_h, part.grid_w, C)


def low_grid_to_windows(x_low: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """(B, Hp/d, Wp/d, C) low-res grid -> (B, nR, w^2, C) one window/region."""
    B = x_low.shape[0]
    C = x_low.shape[-1]
    w = part.window
    nRh, nRw = part.regions_h, part.regions_w
    x = x_low.reshape(B, nRh, w, nRw, w, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, nRh * nRw, w * w, C)


# ---------------------------------------------------------------------------
# packing


def downsample_grid(x: jnp.ndarray, d: int, *,
                    backend: Optional[str] = None) -> jnp.ndarray:
    """Average-pool a (B, Hp, Wp, C) grid by d (pixel/patch downsampling).

    ``backend`` routes to the Pallas mixed_res_pool kernel
    (kernels.dispatch); default keeps the pure-jnp path.
    """
    if d > 1 and dispatch.use_pallas(backend):
        return dispatch.avg_pool(x, d)
    B, Hp, Wp, C = x.shape
    x = x.reshape(B, Hp // d, d, Wp // d, d, C)
    return jnp.mean(x.astype(jnp.float32), axis=(2, 4)).astype(x.dtype)


def pack_mixed(x_grid: jnp.ndarray, part: Partition,
               full_ids: jnp.ndarray, low_ids: jnp.ndarray,
               x_low_grid: Optional[jnp.ndarray] = None, *,
               backend: Optional[str] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build the mixed-resolution window sequence.

    x_grid: (B, Hp, Wp, C) full-res patch grid (embeddings or raw patch
    pixels).  x_low_grid: optional precomputed (B, Hp/d, Wp/d, C) low-res
    grid (e.g. patchified from device-downsampled pixels); derived by
    average pooling when omitted.

    full_ids/low_ids may be (n,) shared across the batch or (B, n)
    per-sample (batched multi-client packing).

    Returns (tokens (B, n_tokens, C), windows (B, n_windows, w^2, C) view).
    """
    w = part.window
    regions = grid_to_region_windows(x_grid, part)        # B,nR,d^2,w^2,C
    has_low = low_ids.shape[-1] > 0
    if has_low:
        if x_low_grid is None:
            x_low_grid = downsample_grid(x_grid, part.downsample,
                                         backend=backend)
        low_windows = low_grid_to_windows(x_low_grid, part)  # B,nR,w^2,C

    if full_ids.ndim == 2:                                # per-sample ids
        full_part = jnp.take_along_axis(
            regions, full_ids[:, :, None, None, None], axis=1)
    else:
        full_part = regions[:, full_ids]                  # B,nF,d^2,w^2,C
    if has_low:
        if low_ids.ndim == 2:
            low_part = jnp.take_along_axis(
                low_windows, low_ids[:, :, None, None], axis=1)
        else:
            low_part = low_windows[:, low_ids]            # B,nL,w^2,C
    else:           # no low regions: skip the pooled grid entirely
        low_part = jnp.zeros(full_part.shape[:1] + (0, w * w)
                             + full_part.shape[-1:], full_part.dtype)
    B = full_part.shape[0]
    full_part = full_part.reshape(B, -1, w * w, full_part.shape[-1])
    windows = jnp.concatenate([full_part, low_part], axis=1)
    tokens = windows.reshape(B, -1, windows.shape[-1])
    return tokens, windows


def pack_positions(pos_grid: jnp.ndarray, part: Partition,
                   full_ids: jnp.ndarray, low_ids: jnp.ndarray
                   ) -> jnp.ndarray:
    """Positional embeddings for the mixed sequence.

    pos_grid: (Hp, Wp, D).  Low-res tokens receive the mean embedding of
    the d x d patch group they represent (paper: global positional
    embeddings added to both sets of tokens).  Per-sample (B, n) ids
    return a (B, n_tokens, D) batch of packed embeddings.
    """
    if full_ids.ndim == 2:
        B = full_ids.shape[0]
        grid = jnp.broadcast_to(pos_grid[None], (B,) + pos_grid.shape)
        tokens, _ = pack_mixed(grid, part, full_ids, low_ids)
        return tokens
    tokens, _ = pack_mixed(pos_grid[None], part, full_ids, low_ids)
    return tokens[0]


# ---------------------------------------------------------------------------
# padded (length-bucketed) packing — the collapsed-executable layout.
#
# The sequence is described at WINDOW granularity by a PlanLayout
# (core.partition): a (nw_pad,) gather into the window bank
# [all full-res windows | one low window per region], padded with
# replicas of window 0.  All shapes depend only on the length bucket,
# so any (n_low, n_reuse) mix shares one executable; validity is the
# runtime count ``nw`` (traced i32), not the shape.


def window_bank(x_grid: jnp.ndarray, part: Partition,
                x_low_grid: Optional[jnp.ndarray] = None, *,
                backend: Optional[str] = None) -> jnp.ndarray:
    """(B, Hp, Wp, C) -> (B, nR*d^2 + nR, w^2, C) window bank: every
    full-res window of every region, then every region's LOW window."""
    regions = grid_to_region_windows(x_grid, part)        # B,nR,d^2,w^2,C
    B, nR, dd, w2, C = regions.shape
    if x_low_grid is None:
        x_low_grid = downsample_grid(x_grid, part.downsample,
                                     backend=backend)
    low = low_grid_to_windows(x_low_grid, part)           # B,nR,w^2,C
    return jnp.concatenate([regions.reshape(B, nR * dd, w2, C), low],
                           axis=1)


def pack_padded(x_grid: jnp.ndarray, part: Partition,
                win_src: jnp.ndarray,
                x_low_grid: Optional[jnp.ndarray] = None, *,
                backend: Optional[str] = None) -> jnp.ndarray:
    """Build the length-bucketed mixed sequence.

    win_src: (nw_pad,) shared or (B, nw_pad) per-sample window gather
    (PlanLayout.win_src).  Returns tokens (B, nw_pad * w^2, C).  A
    window gathered from the bank carries exactly the bytes the exact-
    shape :func:`pack_mixed` would have packed, so the padded sequence
    is bit-identical to the exact one on its valid prefix.
    """
    bank = window_bank(x_grid, part, x_low_grid, backend=backend)
    if win_src.ndim == 2:
        windows = jnp.take_along_axis(bank, win_src[:, :, None, None],
                                      axis=1)
    else:
        windows = bank[:, win_src]
    B = windows.shape[0]
    return windows.reshape(B, -1, windows.shape[-1])


def pack_positions_padded(pos_grid: jnp.ndarray, part: Partition,
                          win_src: jnp.ndarray) -> jnp.ndarray:
    """Positional embeddings for the padded mixed sequence (low windows
    receive the mean embedding of their d x d patch groups, exactly as
    :func:`pack_positions`)."""
    if win_src.ndim == 2:
        B = win_src.shape[0]
        grid = jnp.broadcast_to(pos_grid[None], (B,) + pos_grid.shape)
        return pack_padded(grid, part, win_src)
    return pack_padded(pos_grid[None], part, win_src)[0]


def restore_padded(tokens: jnp.ndarray, part: Partition,
                   win_dst: jnp.ndarray, low_src: jnp.ndarray,
                   low_ids: jnp.ndarray, *,
                   backend: Optional[str] = None,
                   reuse_ids: Optional[jnp.ndarray] = None,
                   reuse_tiles: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Restore the full-resolution sequence from a padded mixed one.

    tokens: (B, nw_pad * w^2, D).  FULL windows scatter window-level at
    ``win_dst`` (pad and LOW windows carry the sentinel slot, sliced
    off); LOW windows are gathered back out at ``low_src``, upsampled,
    and scattered region-level at ``low_ids``; REUSE tiles splice at
    ``reuse_ids``.  Pad entries of every id array already point at the
    sentinel (PlanLayout builds them host-side), so no traced dup
    masking is needed.  Output: (B, Hp*Wp, D) window-blocked.
    """
    B, _, D = tokens.shape
    w, d = part.window, part.downsample
    w2 = w * w
    nR, dd = part.n_regions, part.windows_per_full_region
    windows = tokens.reshape(B, -1, w2, D)                # B,nw_pad,w2,D
    per_sample = win_dst.ndim == 2
    b = jnp.arange(B)[:, None]

    # FULL windows: window-level scatter into nR*d^2 slots + sentinel
    buf = jnp.zeros((B, nR * dd + 1, w2, D), tokens.dtype)
    if per_sample:
        buf = buf.at[b, win_dst].set(windows)
    else:
        buf = buf.at[:, win_dst].set(windows)
    out = buf[:, :nR * dd].reshape(B, nR, dd, w2, D)

    # LOW windows: gather, upsample, region-level scatter (+ sentinel row)
    if per_sample:
        low_part = jnp.take_along_axis(windows,
                                       low_src[:, :, None, None], axis=1)
    else:
        low_part = windows[:, low_src]
    up = _upsample_low_windows(low_part.reshape(B, -1, w, w, D), part,
                               backend=backend)
    out = jnp.concatenate(
        [out, jnp.zeros((B, 1, dd, w2, D), tokens.dtype)], axis=1)
    if per_sample:
        out = out.at[b, low_ids].set(up)
        if reuse_ids is not None:
            out = out.at[b, reuse_ids].set(reuse_tiles.astype(tokens.dtype))
    else:
        out = out.at[:, low_ids].set(up)
        if reuse_ids is not None:
            out = out.at[:, reuse_ids].set(
                reuse_tiles.astype(tokens.dtype))
    out = out[:, :nR]
    return out.reshape(B, part.grid_h * part.grid_w, D)


# ---------------------------------------------------------------------------
# restoration (paper §III-B)


def _upsample_low_windows(low_part: jnp.ndarray, part: Partition, *,
                          backend: Optional[str] = None) -> jnp.ndarray:
    """Nearest-neighbour upsample LOW windows (B, n, w, w, D) ->
    (B, n, d^2, w^2, D) window-blocked full-region tiles (the shared op
    of :func:`restore_full` and :func:`restore_padded`)."""
    B, nL = low_part.shape[:2]
    D = low_part.shape[-1]
    w, d = part.window, part.downsample
    if dispatch.use_pallas(backend):
        up = dispatch.nn_upsample(low_part.reshape(B * nL, w, w, D), d)
        up = up.reshape(B, nL, w * d, w * d, D)      # B,nL,r,r,D
    else:
        up = jnp.repeat(jnp.repeat(low_part, d, axis=2), d, axis=3)
    up = up.reshape(B, nL, d, w, d, w, D)
    return up.transpose(0, 1, 2, 4, 3, 5, 6).reshape(
        B, nL, d * d, w * w, D)


def _dups_to_sentinel(ids: jnp.ndarray, sentinel: int) -> jnp.ndarray:
    """Map repeated occurrences of an id (any position with an equal id
    at an EARLIER position) to ``sentinel``, making scatters with padded
    duplicate ids deterministic first-write-wins."""
    n = ids.shape[-1]
    if n <= 1:
        return ids
    eq = ids[..., :, None] == ids[..., None, :]        # (..., n, n)
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)
    dup = jnp.any(eq & earlier, axis=-1)
    return jnp.where(dup, sentinel, ids)


def restore_full(tokens: jnp.ndarray, part: Partition,
                 full_ids: jnp.ndarray, low_ids: jnp.ndarray, *,
                 backend: Optional[str] = None,
                 reuse_ids: Optional[jnp.ndarray] = None,
                 reuse_tiles: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Restore the full-resolution window-blocked sequence at an RP.

    tokens: (B, n_tokens, D) mixed sequence (window-blocked layout).
    Low-region windows are upsampled nearest-neighbour: each low token
    broadcasts to the d x d patches it summarised.  REUSE regions (absent
    from ``tokens``) are spliced in from ``reuse_tiles``
    ((B, n_reuse, d^2, w^2, D) cached per-region feature tiles) at
    ``reuse_ids``.  Output: (B, Hp*Wp, D) window-blocked full sequence
    (region-major, d^2 windows per region).  ``backend`` routes the
    upsample through the Pallas mixed_res_pool kernel (kernels.dispatch).
    All id arrays may be (n,) shared or (B, n) per-sample.

    Scatters land in an (n_regions + 1)-row buffer: padded duplicate ids
    are remapped to the sentinel row (sliced off afterwards), so the
    result never depends on XLA's unspecified same-index write order.
    """
    B, _, D = tokens.shape
    w, d = part.window, part.downsample
    nR_reuse = 0 if reuse_ids is None else reuse_ids.shape[-1]
    nF = full_ids.shape[-1]
    n_full_tok = nF * part.tokens_full_region
    full_part = tokens[:, :n_full_tok].reshape(B, nF, d * d, w * w, D)
    low_part = tokens[:, n_full_tok:].reshape(B, -1, w, w, D)
    nL = low_part.shape[1]

    # nearest-neighbour upsample low windows: (w, w) -> (r, r) -> (d^2, w^2)
    up = _upsample_low_windows(low_part, part, backend=backend)

    sentinel = part.n_regions
    out = jnp.zeros((B, part.n_regions + 1, d * d, w * w, D), tokens.dtype)
    low_sc = _dups_to_sentinel(low_ids, sentinel)
    if low_ids.ndim == 2:                   # per-sample scatter
        b = jnp.arange(B)[:, None]
        out = out.at[b, full_ids].set(full_part)
        if nL:
            out = out.at[b, low_sc].set(up)
        if nR_reuse:
            out = out.at[b, _dups_to_sentinel(reuse_ids, sentinel)].set(
                reuse_tiles.astype(tokens.dtype))
    else:
        out = out.at[:, full_ids].set(full_part)
        if nL:
            out = out.at[:, low_sc].set(up)
        if nR_reuse:
            out = out.at[:, _dups_to_sentinel(reuse_ids, sentinel)].set(
                reuse_tiles.astype(tokens.dtype))
    out = out[:, :part.n_regions]
    return out.reshape(B, part.grid_h * part.grid_w, D)


# ---------------------------------------------------------------------------
# device-resident feature-tile index ops (serving hot path)
#
# The FeatureCache behind temporal region reuse holds per-region
# restoration-point tiles.  Keeping them as jax arrays and gathering /
# refreshing with these jitted ops means a reuse-heavy serving loop ships
# ZERO tile bytes over PCIe per offload: the d->h copy at capture and the
# h->d re-upload at reuse both disappear (offload/simulator.ServerModel
# counts the bytes either way — stats.tile_bytes_*).


@jax.jit
def gather_tiles(tiles: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """(n_regions, d^2, w^2, D), (n,) -> (n, d^2, w^2, D), on device."""
    return jnp.take(tiles, ids, axis=0)


@jax.jit
def take_sample_tiles(wave_tiles: jnp.ndarray, i) -> jnp.ndarray:
    """(B, nR, d^2, w^2, D) wave capture -> sample ``i``'s (nR, ...) tiles
    as a standalone device buffer (so the cache does not pin the whole
    wave tensor)."""
    return jnp.take(wave_tiles, i, axis=0)


def _refresh(stale: jnp.ndarray, new: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.dynamic_update_slice(stale, new.astype(stale.dtype),
                                        (0,) * stale.ndim)


# full in-place overwrite: donating the stale buffer lets XLA write the
# refreshed tiles straight into the old allocation instead of growing the
# live set by one (n_regions, d^2, w^2, D) tensor per client per offload.
refresh_tiles = jax.jit(_refresh, donate_argnums=(0,))


def full_seq_to_grid(tokens: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """Window-blocked full sequence (B, Hp*Wp, D) -> (B, Hp, Wp, D)."""
    B, _, D = tokens.shape
    x = tokens.reshape(B, part.n_regions, part.windows_per_full_region,
                       part.window * part.window, D)
    return region_windows_to_grid(x, part)


def grid_to_full_seq(grid: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """(B, Hp, Wp, D) -> window-blocked full sequence (B, Hp*Wp, D)."""
    x = grid_to_region_windows(grid, part)
    B, nR, dd, ww, D = x.shape
    return x.reshape(B, nR * dd * ww, D)
