"""Detection metrics: greedy IoU matching and F1 (the paper's accuracy
measure: F1 between rendered/inferred results and ground truth)."""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def iou(a, b) -> float:
    ax1, ay1, ax2, ay2 = a
    bx1, by1, bx2, by2 = b
    ix1, iy1 = max(ax1, bx1), max(ay1, by1)
    ix2, iy2 = min(ax2, bx2), min(ay2, by2)
    iw, ih = max(ix2 - ix1, 0.0), max(iy2 - iy1, 0.0)
    inter = iw * ih
    ua = (ax2 - ax1) * (ay2 - ay1) + (bx2 - bx1) * (by2 - by1) - inter
    return inter / ua if ua > 0 else 0.0


def match_detections(preds: List[Dict], gts: List[Dict],
                     iou_thresh: float = 0.5,
                     class_aware: bool = True) -> Tuple[int, int, int]:
    """Greedy score-ordered matching.  Returns (tp, fp, fn)."""
    preds = sorted(preds, key=lambda p: -p.get("score", 1.0))
    used = [False] * len(gts)
    tp = 0
    for p in preds:
        best, best_iou = -1, iou_thresh
        for gi, g in enumerate(gts):
            if used[gi]:
                continue
            if class_aware and int(p["cls"]) != int(g["cls"]):
                continue
            i = iou(p["box"], g["box"])
            if i >= best_iou:
                best, best_iou = gi, i
        if best >= 0:
            used[best] = True
            tp += 1
    fp = len(preds) - tp
    fn = len(gts) - tp
    return tp, fp, fn


def f1_score(tp: int, fp: int, fn: int) -> float:
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom > 0 else 1.0


def frame_f1(preds: List[Dict], gts: List[Dict],
             iou_thresh: float = 0.5) -> float:
    return f1_score(*match_detections(preds, gts, iou_thresh))


def detections_from_arrays(boxes, scores, classes,
                           score_thresh: float = 0.3) -> List[Dict]:
    """det_head.decode_detections arrays -> list-of-dicts (one batch el)."""
    out = []
    for b, s, c in zip(np.asarray(boxes), np.asarray(scores),
                       np.asarray(classes)):
        if s > score_thresh:
            out.append({"box": tuple(float(x) for x in b),
                        "score": float(s), "cls": int(c)})
    return out
