"""Deterministic fault injection + graceful degradation for the
device-to-edge offload loop (robustness spine for the fleet work).

Two layers live here:

  * :class:`FaultInjector` — a crc32-seeded (like network traces) fault
    schedule layered onto a :class:`~repro.data.network_traces.NetworkTrace`
    and the simulators: uplink blackouts, handover storms (periodic
    micro-blackouts), RTT spikes / bufferbloat, dropped and duplicated
    offload responses, edge service stalls, and edge crash-restarts.  A
    restart wipes the replica's warmed executables and bumps its cache
    epoch, invalidating every device-resident
    :class:`~repro.serve.request.FeatureCache` (see ServerModel.restart).
    :class:`FaultyTrace` wraps a trace so trace consumers see the
    impaired network without knowing about the injector.

  * :class:`DegradationLadder` — the client-side timeout/retry/backoff
    state machine of the deadline-bounded offload lifecycle.  Every
    offload carries an SLO-derived deadline (:class:`RobustConfig`); on
    timeout / loss / REJECTED the client abandons the offload, lets the
    LK tracker cover the gap, and retries at a DEGRADED config (FULL
    regions promoted to LOW, lower quality) after an exponentially
    backed-off delay.  Ladder levels:

        0  normal — the policy's decision goes out untouched
        1  half of the FULL regions (lowest-motion first) -> LOW,
           quality - 10
        2  every FULL region -> LOW, quality floored at ``min_quality``
        3  shed — offloads pause until the backed-off probe; rendering
           rides the tracker (the shed-to-tracker fallback)

    Successes walk the ladder back down one level per
    ``recover_after`` consecutive completions.  REUSE regions are never
    touched by degradation: they already ship zero bytes.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partition import FULL, LOW, RegionPlan

__all__ = ["FaultSpec", "FaultInjector", "FaultyTrace", "RobustConfig",
           "DegradationLadder", "BLACKOUT_TPUT_BPS"]

# uplink throughput during a blackout: effectively dead, but finite so
# Eq. (2) terms stay computable (the deadline, not an inf, kills the job)
BLACKOUT_TPUT_BPS = 1e3


@dataclass(frozen=True)
class FaultSpec:
    """A concrete fault schedule (all times in seconds, sim clock).

    blackouts:   ((t0, dur), ...)            uplink dead in [t0, t0+dur)
    storms:      ((t0, dur, period, duty), ...)  handover storm: within
                 [t0, t0+dur) the uplink drops for ``duty`` of every
                 ``period`` seconds (periodic micro-blackouts)
    bufferbloat: ((t0, dur, rtt_factor), ...)  RTT inflated by factor,
                 throughput dented to 70 %
    drop_responses: offload sequence numbers whose response is lost
    dup_responses:  offload sequence numbers delivered twice
    edge_stalls: ((t0, dur, extra_s), ...)   service starting within the
                 window takes ``extra_s`` longer (GC pause / preemption)
    edge_restarts: ((t, outage_s), ...)      replica crashes at ``t``,
                 back at ``t + outage_s`` with a new cache epoch and a
                 cold executable cache
    """
    blackouts: Tuple[Tuple[float, float], ...] = ()
    storms: Tuple[Tuple[float, float, float, float], ...] = ()
    bufferbloat: Tuple[Tuple[float, float, float], ...] = ()
    drop_responses: Tuple[int, ...] = ()
    dup_responses: Tuple[int, ...] = ()
    edge_stalls: Tuple[Tuple[float, float, float], ...] = ()
    edge_restarts: Tuple[Tuple[float, float], ...] = ()


class FaultInjector:
    """Evaluates a :class:`FaultSpec` against the sim clock.

    Deterministic: build one via :meth:`from_profile` (crc32-seeded per
    (profile, index), exactly like make_trace) or hand it an explicit
    spec.  Stateless — every query is a pure function of time / sequence
    number, so the single-client loop and the multi-client edge can
    share one injector without ordering hazards.
    """

    def __init__(self, spec: FaultSpec):
        self.spec = spec

    # ------------------------------------------------------------------
    @classmethod
    def from_profile(cls, profile: str, index: int = 0,
                     start_s: float = 1.5,
                     dur_s: float = 1.0) -> "FaultInjector":
        """Canonical single-fault schedules for the bench fault matrix.

        profiles: none | blackout | handover_storm | bufferbloat |
        edge_restart | response_loss.  The fault onset is jittered
        deterministically per (profile, index) — NOT hash(): str hashing
        is salted per process.
        """
        seed = zlib.crc32(f"faults-{profile}-{index}".encode())
        rng = np.random.default_rng(seed)
        t0 = start_s + float(rng.uniform(0.0, 0.3))
        if profile == "none":
            spec = FaultSpec()
        elif profile == "blackout":
            spec = FaultSpec(blackouts=((t0, dur_s),))
        elif profile == "handover_storm":
            # period deliberately NOT a multiple of typical frame ticks
            # (0.1 s): offload submits are frame-quantized, and a
            # commensurate period can phase-lock with the ~0.4 s offload
            # cadence so every submit threads the up-phases and the
            # storm leaves no trace
            spec = FaultSpec(storms=((t0, 2.0 * dur_s, 0.45, 0.5),))
        elif profile == "bufferbloat":
            spec = FaultSpec(bufferbloat=((t0, 2.0 * dur_s,
                                           float(rng.uniform(4.0, 8.0))),))
        elif profile == "edge_restart":
            spec = FaultSpec(edge_restarts=((t0, 0.5 * dur_s),))
        elif profile == "response_loss":
            first = int(rng.integers(2, 5))
            spec = FaultSpec(drop_responses=(first, first + 1),
                             dup_responses=(first + 3,))
        else:
            raise ValueError(f"unknown fault profile {profile!r}")
        return cls(spec)

    # ------------------------------------------------------------------
    # network-plane faults

    def uplink_down(self, t: float) -> bool:
        for (t0, dur) in self.spec.blackouts:
            if t0 <= t < t0 + dur:
                return True
        for (t0, dur, period, duty) in self.spec.storms:
            if t0 <= t < t0 + dur and (t - t0) % period < duty * period:
                return True
        return False

    def net(self, t: float, tput_bps: float,
            rtt_s: float) -> Tuple[float, float]:
        """Impair one (throughput, RTT) sample of the trace."""
        if self.uplink_down(t):
            tput_bps = min(tput_bps, BLACKOUT_TPUT_BPS)
        for (t0, dur, factor) in self.spec.bufferbloat:
            if t0 <= t < t0 + dur:
                rtt_s = rtt_s * factor
                tput_bps = tput_bps * 0.7
        return tput_bps, rtt_s

    # ------------------------------------------------------------------
    # response-plane faults

    def response_dropped(self, seq: int) -> bool:
        return seq in self.spec.drop_responses

    def response_duplicated(self, seq: int) -> bool:
        return seq in self.spec.dup_responses

    # ------------------------------------------------------------------
    # edge-plane faults

    def stall_extra(self, t: float) -> float:
        """Extra service seconds for work STARTING at ``t``."""
        return sum(extra for (t0, dur, extra) in self.spec.edge_stalls
                   if t0 <= t < t0 + dur)

    def restarts_between(self, t0: float,
                         t1: float) -> List[Tuple[float, float]]:
        """Restart events with t0 < t <= t1, in time order."""
        return sorted((r, o) for (r, o) in self.spec.edge_restarts
                      if t0 < r <= t1)

    def edge_down(self, t: float) -> bool:
        """True while a crashed replica has not come back yet — work
        arriving in the outage window is lost, never answered."""
        return any(r <= t < r + outage
                   for (r, outage) in self.spec.edge_restarts)


@dataclass
class FaultyTrace:
    """A NetworkTrace wrapper applying an injector's network-plane
    faults — consumers only ever call ``.at()``, so trace-level layering
    needs nothing else."""
    base: object
    injector: FaultInjector

    @property
    def name(self) -> str:
        return f"{getattr(self.base, 'name', 'trace')}+faults"

    @property
    def kind(self) -> str:
        return getattr(self.base, "kind", "?")

    def at(self, t: float) -> Tuple[float, float]:
        return self.injector.net(t, *self.base.at(t))


# ---------------------------------------------------------------------------
# deadline-bounded offload lifecycle: config + ladder


@dataclass(frozen=True)
class RobustConfig:
    """SLO + retry knobs of the deadline-bounded offload lifecycle."""
    slo_s: float = 1.2            # offload deadline past submit
    backoff_base_s: float = 0.2   # first retry delay after a failure
    backoff_max_s: float = 3.2    # exponential backoff ceiling
    ladder_max: int = 3           # level 3 == shed to tracker
    recover_after: int = 2        # consecutive successes per step down
    min_quality: int = 70
    degrade_beta: int = 2         # restoration point degraded plans use


class DegradationLadder:
    """Client-side failure state machine (see module docstring)."""

    def __init__(self, rc: Optional[RobustConfig] = None):
        self.rc = rc or RobustConfig()
        self.level = 0
        self.max_level_seen = 0
        self.retry_at = 0.0           # no offload before this sim time
        self.backoff = self.rc.backoff_base_s
        self._ok_streak = 0

    @property
    def shedding(self) -> bool:
        return self.level >= self.rc.ladder_max

    def on_failure(self, now: float) -> None:
        """Timeout, lost response, or edge REJECTED: climb one level and
        back off exponentially before the retry probe."""
        self.level = min(self.level + 1, self.rc.ladder_max)
        self.max_level_seen = max(self.max_level_seen, self.level)
        self._ok_streak = 0
        self.retry_at = now + self.backoff
        self.backoff = min(self.backoff * 2.0, self.rc.backoff_max_s)

    def on_success(self) -> None:
        """A completed, rendered offload: reset backoff; after
        ``recover_after`` in a row, step one level back down."""
        self.backoff = self.rc.backoff_base_s
        self.retry_at = 0.0
        self._ok_streak += 1
        if self.level > 0 and self._ok_streak >= self.rc.recover_after:
            self.level -= 1
            self._ok_streak = 0

    # ------------------------------------------------------------------
    def degrade(self, decision: Dict, m: Optional[np.ndarray]) -> Dict:
        """Rewrite a policy decision for the current ladder level:
        promote FULL regions to LOW (lowest motion ``m`` first — legal
        at runtime since (n_low, n_reuse) are executable DATA, not
        shape), drop quality, and force a restoration point onto mixed
        plans.  REUSE regions are untouched.  Level 0 is the identity.
        """
        lvl = min(self.level, 2)
        if lvl == 0:
            return decision
        d = dict(decision)
        plan: Optional[RegionPlan] = d.get("plan")
        if plan is not None:
            states = np.asarray(plan.states).copy()
        else:
            states = np.where(np.asarray(d["mask"]).reshape(-1) != 0,
                              LOW, FULL).astype(np.int8)
        full_ids = np.nonzero(states == FULL)[0]
        k = len(full_ids) if lvl >= 2 else (len(full_ids) + 1) // 2
        demoted = np.zeros((0,), np.int64)
        if k:
            mm = (np.asarray(m, np.float64)[full_ids] if m is not None
                  else np.zeros(len(full_ids)))
            order = full_ids[np.argsort(mm, kind="stable")]
            demoted = order[:k].astype(np.int64)
            states[demoted] = LOW
        new_plan = RegionPlan(states.astype(np.int8))
        d["plan"] = new_plan
        d["mask"] = new_plan.low_mask()
        d["quality"] = int(max(self.rc.min_quality,
                               int(d["quality"]) - 10 * lvl))
        if (new_plan.n_low > 0 or new_plan.n_reuse > 0) \
                and int(d.get("beta", 0)) < 1:
            d["beta"] = self.rc.degrade_beta
        d["degraded"] = lvl
        # regions transmitted LOW as a stopgap: their captured tiles are
        # low-fidelity and must NOT become durable splice sources — the
        # client expires them from its FeatureCache on completion, so
        # reuse of these regions resumes only after a genuine FULL
        # re-transmission (else one degraded offload poisons the next K)
        d["demoted"] = demoted
        return d


def fresh_rstats() -> Dict[str, int]:
    """Robustness counters a Simulation tracks (see Simulation.rstats)."""
    return {"timeouts": 0, "lost_responses": 0, "late_discards": 0,
            "dup_discards": 0, "stale_discards": 0, "rejected": 0,
            "stale_epoch_nacks": 0, "edge_restarts": 0,
            "degraded_offloads": 0, "tracker_frames": 0,
            "max_ladder_level": 0}
