"""Local Tracker: pyramidal Lucas–Kanade optical flow box propagation
(the paper selects an optical-flow method [25] for its accuracy/speed
balance).  Pure numpy.

``LKTracker`` holds the last frame and a set of boxes; ``step(frame)``
estimates per-box translation from LK flow at Shi–Tomasi-ish corner
points inside each box and shifts the boxes.  ``retention`` reports the
fraction of initial objects still tracked (kappa in Algorithm 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.offload.motion import to_gray


def _pyr_down(img: np.ndarray) -> np.ndarray:
    H, W = img.shape
    H2, W2 = H // 2 * 2, W // 2 * 2
    x = img[:H2, :W2]
    return 0.25 * (x[0::2, 0::2] + x[1::2, 0::2] + x[0::2, 1::2]
                   + x[1::2, 1::2])


def _gradients(img: np.ndarray):
    gy, gx = np.gradient(img)
    return gx, gy


def _lk_point(prev, cur, gx, gy, x, y, win: int = 7, iters: int = 3
              ) -> Optional[Tuple[float, float]]:
    """One-point LK with iterative refinement.  Returns (dx, dy) or None."""
    H, W = prev.shape
    r = win // 2
    xi, yi = int(round(x)), int(round(y))
    if not (r <= xi < W - r and r <= yi < H - r):
        return None
    Ix = gx[yi - r:yi + r + 1, xi - r:xi + r + 1].ravel()
    Iy = gy[yi - r:yi + r + 1, xi - r:xi + r + 1].ravel()
    A = np.stack([Ix, Iy], axis=1)
    G = A.T @ A
    if np.linalg.det(G) < 1e-7:
        return None
    Ginv = np.linalg.inv(G)
    tpl = prev[yi - r:yi + r + 1, xi - r:xi + r + 1].ravel()
    dx = dy = 0.0
    for _ in range(iters):
        cx, cy = xi + dx, yi + dy
        x0, y0 = int(np.floor(cx)), int(np.floor(cy))
        if not (r <= x0 < W - r - 1 and r <= y0 < H - r - 1):
            return None
        ax, ay = cx - x0, cy - y0
        w = cur[y0 - r:y0 + r + 2, x0 - r:x0 + r + 2]
        interp = ((1 - ax) * (1 - ay) * w[:-1, :-1]
                  + ax * (1 - ay) * w[:-1, 1:]
                  + (1 - ax) * ay * w[1:, :-1] + ax * ay * w[1:, 1:])
        err = (interp.ravel() - tpl)
        b = -np.array([err @ Ix, err @ Iy])
        d = Ginv @ b
        dx += d[0]
        dy += d[1]
        if abs(d[0]) + abs(d[1]) < 0.03:
            break
    return dx, dy


@dataclass
class Track:
    box: Tuple[float, float, float, float]
    cls: int
    score: float
    tid: int
    alive: bool = True
    conf: float = 1.0     # decays while the box coasts without flow


class LKTracker:
    """Multi-object optical-flow tracker with catch-up tracking.

    A frame where a box yields too few surviving flow points (blackout-
    length gaps with large motion, texture-free crops) no longer kills
    the track outright: the box HOLDS position and its confidence decays
    by ``hold_decay``; only when confidence drops below ``conf_floor``
    does the track die.  ``retention`` sums confidences, so kappa in
    Algorithm 1 degrades smoothly (and stays finite) instead of
    cliff-dropping to 0 on one bad frame.
    """

    def __init__(self, levels: int = 3, grid: int = 4,
                 hold_decay: float = 0.7, conf_floor: float = 0.2):
        self.levels = levels
        self.grid = grid
        self.hold_decay = hold_decay
        self.conf_floor = conf_floor
        self.prev_gray: Optional[np.ndarray] = None
        self.tracks: List[Track] = []
        self._n_init = 0
        self._next_id = 0

    # ------------------------------------------------------------------
    def reinit(self, frame: np.ndarray, detections: List[Dict]) -> None:
        """Synchronise with a fresh remote inference result."""
        self.prev_gray = to_gray(frame)
        self.tracks = []
        for d in detections:
            self.tracks.append(Track(box=tuple(map(float, d["box"])),
                                     cls=int(d["cls"]),
                                     score=float(d.get("score", 1.0)),
                                     tid=self._next_id))
            self._next_id += 1
        self._n_init = len(self.tracks)

    @property
    def retention(self) -> float:
        """kappa: confidence-weighted fraction of objects still tracked
        since reinit (1.0 while every box keeps finding flow; a coasting
        box contributes its decayed confidence)."""
        if self._n_init == 0:
            return 1.0
        return sum(t.conf for t in self.tracks if t.alive) / self._n_init

    def boxes(self) -> List[Dict]:
        return [{"box": t.box, "cls": t.cls, "score": t.score,
                 "tid": t.tid} for t in self.tracks if t.alive]

    # ------------------------------------------------------------------
    def step(self, frame: np.ndarray) -> List[Dict]:
        """Propagate boxes to ``frame``; returns current box list."""
        gray = to_gray(frame)
        if self.prev_gray is None:
            self.prev_gray = gray
            return self.boxes()

        # build pyramids
        prev_pyr, cur_pyr = [self.prev_gray], [gray]
        for _ in range(self.levels - 1):
            prev_pyr.append(_pyr_down(prev_pyr[-1]))
            cur_pyr.append(_pyr_down(cur_pyr[-1]))
        grads = [_gradients(p) for p in prev_pyr]

        H, W = gray.shape
        for t in self.tracks:
            if not t.alive:
                continue
            x1, y1, x2, y2 = t.box
            # sample a grid of points inside the box
            xs = np.linspace(x1 + 2, x2 - 2, self.grid)
            ys = np.linspace(y1 + 2, y2 - 2, self.grid)
            flows = []
            for py in ys:
                for px in xs:
                    dx_total = dy_total = 0.0
                    ok = True
                    # coarse-to-fine; a small object can be featureless
                    # at a coarse level (its texture averages away), so a
                    # failed coarse estimate contributes zero update and
                    # the chain continues — only the finest level is
                    # allowed to reject the point
                    for lv in range(self.levels - 1, -1, -1):
                        s = 2 ** lv
                        gx, gy = grads[lv]
                        res = _lk_point(prev_pyr[lv], cur_pyr[lv], gx, gy,
                                        (px + dx_total) / s,
                                        (py + dy_total) / s)
                        if res is None:
                            if lv == 0:
                                ok = False
                            continue
                        dx_total += res[0] * s
                        dy_total += res[1] * s
                    if ok and abs(dx_total) < W * 0.2 and \
                            abs(dy_total) < H * 0.2:
                        flows.append((dx_total, dy_total))
            if len(flows) < max(2, self.grid):
                # hold position, decay confidence; die only at the floor
                t.conf *= self.hold_decay
                if t.conf < self.conf_floor:
                    t.alive = False
                continue
            f = np.median(np.asarray(flows), axis=0)
            nx1, ny1, nx2, ny2 = x1 + f[0], y1 + f[1], x2 + f[0], y2 + f[1]
            if nx2 <= 4 or ny2 <= 4 or nx1 >= W - 4 or ny1 >= H - 4:
                t.alive = False
                continue
            t.box = (nx1, ny1, nx2, ny2)
            t.conf = 1.0

        self.prev_gray = gray
        return self.boxes()

    def catch_up(self, frames: List[np.ndarray]) -> None:
        """Catch-up tracking: replay intermediate frames captured while a
        remote result was in flight (paper §V)."""
        for f in frames:
            self.step(f)
