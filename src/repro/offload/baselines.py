"""Offloading policies: the paper's baselines (§VI-A) + ViTMAlis itself
(+ its ablated variants, §VI-D)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.partition import bucket_n_low
from repro.offload import motion as mo
from repro.offload.optimizer import (OffloadOptimizer, SystemState,
                                     candidate_configs)
from repro.offload.simulator import Policy, Simulation

FULL_QUALITY = 95        # baselines' default JPEG quality (paper §VI-A)


def _zeros(sim: Simulation) -> np.ndarray:
    return np.zeros((sim.part.n_regions,), np.int32)


class Back2Back(Policy):
    """Offload the newest frame immediately on completion; full res; no
    tracker — stale cache results are rendered as-is."""
    name = "Back2Back"
    use_tracker = False

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        return {"mask": _zeros(sim), "quality": FULL_QUALITY, "beta": 0}


class TrackB2B(Back2Back):
    """Accuracy-centric: Back2Back + local tracker (canonical paradigm)."""
    name = "TrackB2B"
    use_tracker = True


class TrackRoI(Policy):
    """Content-aware RoI masking: non-DOR regions are blanked before
    encoding (big bandwidth cut, same inference cost, context lost)."""
    name = "TrackRoI"
    use_tracker = True

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        rho = sim.rho()
        phi = mo.classify_regions(sim.m, rho)
        mask = _zeros(sim)
        # non-DOR regions are blanked before encoding; the flat blanks
        # genuinely compress to almost nothing in the DCT+zlib codec
        blank = (phi != 2).astype(np.int32)
        return {"mask": mask, "quality": FULL_QUALITY, "beta": 0,
                "blank": blank}


class TrackUD(Policy):
    """Latency-adaptive: uniform downsample x2 when the last E2E latency
    exceeded 15 frame intervals (adaptive-streaming heuristic)."""
    name = "TrackUD"
    use_tracker = True
    threshold_frames = 15

    def __init__(self, fps: int = 10, n_subsets: int = 4):
        self.fps = fps
        self.n_subsets = n_subsets
        self.last_e2e: Optional[float] = None

    def observe_completion(self, e2e_latency: float) -> None:
        self.last_e2e = e2e_latency

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        slow = (self.last_e2e is not None and
                self.last_e2e > self.threshold_frames / self.fps)
        if slow:
            mask = np.ones((sim.part.n_regions,), np.int32)
            return {"mask": mask, "quality": FULL_QUALITY,
                    "beta": self.n_subsets}
        return {"mask": _zeros(sim), "quality": FULL_QUALITY, "beta": 0}


# ---------------------------------------------------------------------------
# ViTMAlis


class ViTMAlis(Policy):
    """The full system: Algorithm 1 over the (tau_d, lambda, beta) space."""
    name = "ViTMAlis"
    use_tracker = True

    def __init__(self, optimizer: OffloadOptimizer):
        self.opt = optimizer

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        import time as _t
        self.opt.delays.net = sim.net_est
        rho = sim.rho()
        t0 = _t.perf_counter()
        choice = self.opt.select(sim.m, sim.m_f, rho, sim.state)
        wall = _t.perf_counter() - t0
        c = choice["config"]
        mask = choice["mask"].copy()
        # enforce the static bucket: trim/pad handled by region ids later
        n_d = choice["N_d"]
        if int(mask.sum()) != n_d:
            ones = np.nonzero(mask)[0]
            mask[:] = 0
            mask[ones[:n_d]] = 1
        return {"mask": mask, "quality": c.quality,
                "beta": c.beta if n_d > 0 else 0,
                "opt_wall": wall}


class ViTMAlisNoRegType(ViTMAlis):
    """Ablation w/o RegType: downsample ALL non-DOR regions (no SBR/CMR
    distinction) — the tau_d knob collapses to {0, all-non-DOR}."""
    name = "w/o RegType"

    def __init__(self, optimizer: OffloadOptimizer):
        super().__init__(optimizer)
        self.opt.configs = [c for c in self.opt.configs
                            if c.tau_d in (0, 2)]


class ViTMAlisNoDynaRes(ViTMAlis):
    """Ablation w/o DynaRes: restoration deferred to the last subset."""
    name = "w/o DynaRes"

    def __init__(self, optimizer: OffloadOptimizer, n_subsets: int = 4):
        super().__init__(optimizer)
        self.opt.configs = [c for c in self.opt.configs
                            if c.beta in (0, n_subsets)]
