"""Offloading policies: the paper's baselines (§VI-A) + ViTMAlis itself
(+ its ablated variants, §VI-D)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.partition import RegionPlan, bucket_n_low
from repro.offload import motion as mo
from repro.offload.optimizer import (OffloadOptimizer, SystemState,
                                     build_reuse_plan, candidate_configs)
from repro.offload.simulator import Policy, Simulation

FULL_QUALITY = 95        # baselines' default JPEG quality (paper §VI-A)


def _zeros(sim: Simulation) -> np.ndarray:
    return np.zeros((sim.part.n_regions,), np.int32)


class Back2Back(Policy):
    """Offload the newest frame immediately on completion; full res; no
    tracker — stale cache results are rendered as-is."""
    name = "Back2Back"
    use_tracker = False

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        return {"mask": _zeros(sim), "quality": FULL_QUALITY, "beta": 0}


class TrackB2B(Back2Back):
    """Accuracy-centric: Back2Back + local tracker (canonical paradigm)."""
    name = "TrackB2B"
    use_tracker = True


class TrackRoI(Policy):
    """Content-aware RoI masking: non-DOR regions are blanked before
    encoding (big bandwidth cut, same inference cost, context lost)."""
    name = "TrackRoI"
    use_tracker = True

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        rho = sim.rho()
        phi = mo.classify_regions(sim.m, rho)
        mask = _zeros(sim)
        # non-DOR regions are blanked before encoding; the flat blanks
        # genuinely compress to almost nothing in the DCT+zlib codec
        blank = (phi != 2).astype(np.int32)
        return {"mask": mask, "quality": FULL_QUALITY, "beta": 0,
                "blank": blank}


class TrackUD(Policy):
    """Latency-adaptive: uniform downsample x2 when the last E2E latency
    exceeded 15 frame intervals (adaptive-streaming heuristic)."""
    name = "TrackUD"
    use_tracker = True
    threshold_frames = 15

    def __init__(self, fps: int = 10, n_subsets: int = 4):
        self.fps = fps
        self.n_subsets = n_subsets
        self.last_e2e: Optional[float] = None

    def observe_completion(self, e2e_latency: float) -> None:
        self.last_e2e = e2e_latency

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        slow = (self.last_e2e is not None and
                self.last_e2e > self.threshold_frames / self.fps)
        if slow:
            mask = np.ones((sim.part.n_regions,), np.int32)
            return {"mask": mask, "quality": FULL_QUALITY,
                    "beta": self.n_subsets}
        return {"mask": _zeros(sim), "quality": FULL_QUALITY, "beta": 0}


# ---------------------------------------------------------------------------
# ViTMAlis


class ViTMAlis(Policy):
    """The full system: Algorithm 1 over the (tau_d, lambda, beta) space."""
    name = "ViTMAlis"
    use_tracker = True

    def __init__(self, optimizer: OffloadOptimizer):
        self.opt = optimizer

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        import time as _t
        # bootstrap: before the first detections arrive, the tracker is
        # empty and rho is identically zero — outside the estimators'
        # profile distribution, so Algorithm 1's A-hats are meaningless.
        # Seed the pipeline with one full-resolution offload (what the
        # prototype's first frame does implicitly).
        if sim.cache_frame < 0 and not sim.tracker.boxes():
            return {"mask": _zeros(sim), "quality": FULL_QUALITY,
                    "beta": 0, "opt_wall": 0.0}
        self.opt.delays.net = sim.net_est
        rho = sim.rho()
        t0 = _t.perf_counter()
        choice = self.opt.select(sim.m, sim.m_f, rho, sim.state)
        wall = _t.perf_counter() - t0
        c = choice["config"]
        mask = choice["mask"].copy()
        # enforce the static bucket: trim/pad handled by region ids later
        n_d = choice["N_d"]
        if int(mask.sum()) != n_d:
            ones = np.nonzero(mask)[0]
            mask[:] = 0
            mask[ones[:n_d]] = 1
        return {"mask": mask, "quality": c.quality,
                "beta": c.beta if n_d > 0 else 0,
                "opt_wall": wall}


class ViTMAlisReuse(ViTMAlis):
    """ViTMAlis + temporal region reuse (three-state RegionPlan).

    On top of Algorithm 1's (tau_d, lambda, beta) choice, regions the
    motion analyzer reports motionless AND whose cached feature tile is
    still fresh (same restoration point, reused < K consecutive
    offloads) transmit NOTHING — the edge splices their cached tiles in
    at the restoration point.  Full-res choices still warm the cache
    (tiles captured at ``capture_beta_default``), and a full-res choice
    with a warm cache is lifted to FULL+REUSE at the cached restoration
    point so static scenes stop paying for pixels that have not changed.
    """
    name = "ViTMAlis+Reuse"

    def __init__(self, optimizer: OffloadOptimizer, reuse_k: int = 4,
                 reuse_delta_m: float = 1e-3, min_transmit: int = 1,
                 capture_beta_default: int = 2):
        super().__init__(optimizer)
        self.reuse_k = reuse_k
        self.reuse_delta_m = reuse_delta_m
        self.min_transmit = min_transmit
        self.capture_beta_default = capture_beta_default

    def decide(self, sim: Simulation, frame_idx: int) -> Dict:
        base = super().decide(sim, frame_idx)
        cache = sim.feature_cache
        mask = base["mask"]
        beta = base["beta"]
        n_d = int(mask.sum())

        if beta < 1 and n_d == 0 and cache is not None and \
                cache.eligible(cache.beta).any():
            # full-res choice, warm cache: reuse at the cached RP
            beta = cache.beta
        eligible = (cache.eligible(beta) if cache is not None
                    else np.zeros((sim.part.n_regions,), bool))
        plan = build_reuse_plan(sim.part, mask, sim.m, eligible,
                                delta_m=self.reuse_delta_m,
                                n_buckets=self.opt.n_buckets,
                                min_transmit=self.min_transmit)
        base["plan"] = plan
        base["beta"] = beta if (plan.n_reuse > 0 or n_d > 0) else 0
        base["capture_beta"] = self.capture_beta_default
        return base


class ViTMAlisNoRegType(ViTMAlis):
    """Ablation w/o RegType: downsample ALL non-DOR regions (no SBR/CMR
    distinction) — the tau_d knob collapses to {0, all-non-DOR}."""
    name = "w/o RegType"

    def __init__(self, optimizer: OffloadOptimizer):
        super().__init__(optimizer)
        self.opt.configs = [c for c in self.opt.configs
                            if c.tau_d in (0, 2)]


class ViTMAlisNoDynaRes(ViTMAlis):
    """Ablation w/o DynaRes: restoration deferred to the last subset."""
    name = "w/o DynaRes"

    def __init__(self, optimizer: OffloadOptimizer, n_subsets: int = 4):
        super().__init__(optimizer)
        self.opt.configs = [c for c in self.opt.configs
                            if c.beta in (0, n_subsets)]
