"""Region Motion Analyzer (paper §IV-C).

Running-average background subtraction (the OpenCV-tutorial model the
paper cites [33]) -> per-region motion values m_j = fraction of the
frame's foreground pixels falling in decision region j, plus the
normalized frame motion m^f (foreground / total pixels).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.partition import Partition


def to_gray(frame: np.ndarray) -> np.ndarray:
    return frame @ np.array([0.299, 0.587, 0.114], np.float32)


@dataclass
class MotionState:
    background: np.ndarray       # running-average gray background
    initialized: bool = False


class RegionMotionAnalyzer:
    def __init__(self, part: Partition, patch_px: int,
                 alpha: float = 0.05, thresh: float = 0.08):
        self.part = part
        self.patch_px = patch_px            # pixels per image patch
        self.alpha = alpha
        self.thresh = thresh
        self.state: Optional[MotionState] = None

    def region_px(self) -> int:
        return self.part.region * self.patch_px

    def update(self, frame: np.ndarray) -> Tuple[np.ndarray, float]:
        """-> (m (n_regions,), m_f).  frame: HxWx3 float in [0,1]."""
        gray = to_gray(frame)
        if self.state is None or not self.state.initialized:
            self.state = MotionState(background=gray.copy(),
                                     initialized=True)
            return np.zeros((self.part.n_regions,), np.float32), 0.0

        fg = np.abs(gray - self.state.background) > self.thresh
        self.state.background = ((1 - self.alpha) * self.state.background
                                 + self.alpha * gray)

        rpx = self.region_px()
        nRh, nRw = self.part.regions_h, self.part.regions_w
        fg_r = fg[:nRh * rpx, :nRw * rpx].reshape(nRh, rpx, nRw, rpx)
        counts = fg_r.sum(axis=(1, 3)).reshape(-1).astype(np.float64)
        total_fg = counts.sum()
        m = (counts / total_fg).astype(np.float32) if total_fg > 0 else \
            np.zeros((self.part.n_regions,), np.float32)
        m_f = float(total_fg / fg.size)
        return m, m_f


def classify_regions(m: np.ndarray, rho: np.ndarray, delta_m: float = 0.001,
                     delta_rho: float = 0.0) -> np.ndarray:
    """SBR/CMR/DOR classification (paper §IV-C).

    Returns int8 array: 0=SBR, 1=CMR, 2=DOR.
      m_j <  delta_m             -> SBR
      m_j >= delta_m, rho_j >  delta_rho -> DOR
      otherwise                  -> CMR
    """
    out = np.ones_like(m, dtype=np.int8)               # CMR default
    out[m < delta_m] = 0                               # SBR
    out[(m >= delta_m) & (rho > delta_rho)] = 2        # DOR
    return out


def downsample_mask(region_types: np.ndarray, tau_d: int) -> np.ndarray:
    """B = f_tau(phi): which regions are downsampled for each tau_d.

    tau_d: 0 = none, 1 = CMRs only, 2 = CMRs + SBRs.  DORs never."""
    if tau_d == 0:
        return np.zeros_like(region_types, dtype=np.int32)
    if tau_d == 1:
        return (region_types == 1).astype(np.int32)
    return (region_types <= 1).astype(np.int32)


def prediction_confidence(m: np.ndarray, states: np.ndarray,
                          m_f: float = 1.0,
                          thresh: float = 0.02) -> float:
    """Confidence that the previous decoded frame predicts the
    IN-FLIGHT (transmitted FULL/LOW) regions of a plan (paper Eq. (2)
    uplink-hiding: the speculative-REUSE admission signal).

    ``m`` is the analyzer's per-region share of the frame's foreground
    (it sums to 1 whenever ANY pixel moved, so it must be rescaled by
    ``m_f`` — foreground / total pixels — to mean absolute motion
    mass).  A transmitted region with high motion mass will diverge
    from any motion-free prediction, so confidence decays with the
    *worst* transmitted region: 1.0 when every in-flight region is
    still, 0.0 once one region holds ``thresh`` of the FRAME's pixels
    as foreground (a whole region is 1/n_regions).  Plans with nothing
    in flight (all REUSE) predict trivially.
    """
    from repro.core.partition import REUSE
    states = np.asarray(states).reshape(-1)
    m = np.asarray(m, np.float64).reshape(-1)
    sel = states != REUSE
    if not sel.any():
        return 1.0
    worst = float(m[sel].max()) * float(m_f)
    return float(np.clip(1.0 - worst / max(thresh, 1e-9), 0.0, 1.0))


def region_density(boxes, part: Partition, patch_px: int) -> np.ndarray:
    """Task relevance rho_j: fraction of objects overlapping region j."""
    rpx = part.region * patch_px
    rho = np.zeros((part.n_regions,), np.float32)
    if not boxes:
        return rho
    for b in boxes:
        x1, y1, x2, y2 = b["box"] if isinstance(b, dict) else b
        rx1, ry1 = int(x1 // rpx), int(y1 // rpx)
        rx2 = min(int(np.ceil(x2 / rpx)), part.regions_w)
        ry2 = min(int(np.ceil(y2 / rpx)), part.regions_h)
        for ry in range(max(ry1, 0), ry2):
            for rx in range(max(rx1, 0), rx2):
                rho[ry * part.regions_w + rx] += 1.0
    return rho / max(len(boxes), 1)
