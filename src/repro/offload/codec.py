"""Mixed-resolution frame codec (device-side encode, server-side decode).

JPEG-like pipeline, reproducible in pure numpy (DESIGN.md hardware
adaptation: libjpeg -> 8x8 DCT + quality-scaled quantization + zlib
entropy stage, so compressed sizes are content- and quality-dependent
exactly as the paper's MLP^size estimator requires).

Encoding a mixed-resolution frame (paper Fig. 3): full-resolution regions
are coded at their native pixels; regions marked for downsampling are
average-pooled by ``d`` first.  The payload is the concatenation of both
streams plus the binary region mask, mirroring the single mixed-res image
the prototype transmits.

Temporal region reuse (core.partition.RegionPlan): regions marked REUSE
(``reuse_mask``) are skipped ENTIRELY — zero payload bytes, no DCT work,
and their canvas area is filled with neutral gray on the decoded frame
(the server restores them from cached backbone features, never from
pixels).  The delay models scale with transmitted regions only.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partition import Partition

# JPEG luminance quantization table
_Q50 = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99]], np.float32)


def _quality_table(quality: int) -> np.ndarray:
    q = max(1, min(int(quality), 100))
    s = 5000 / q if q < 50 else 200 - 2 * q
    tbl = np.floor((_Q50 * s + 50) / 100)
    return np.clip(tbl, 1, 255).astype(np.float32)


def _dct_matrix() -> np.ndarray:
    k = np.arange(8)
    c = np.sqrt(2.0 / 8.0) * np.cos((2 * k[None, :] + 1) * k[:, None]
                                    * np.pi / 16.0)
    c[0] *= 1.0 / np.sqrt(2.0)
    return c.astype(np.float32)


_DCT = _dct_matrix()
_ZIG = np.array(sorted(range(64), key=lambda i: (i // 8 + i % 8,
                                                 (i // 8 + i % 8) % 2 == 0
                                                 and i % 8 or -(i % 8))))


def _blockify(img: np.ndarray) -> np.ndarray:
    """(H, W) -> (H/8 * W/8, 8, 8)."""
    H, W = img.shape
    x = img.reshape(H // 8, 8, W // 8, 8).transpose(0, 2, 1, 3)
    return x.reshape(-1, 8, 8)


def _unblockify(blocks: np.ndarray, H: int, W: int) -> np.ndarray:
    x = blocks.reshape(H // 8, W // 8, 8, 8).transpose(0, 2, 1, 3)
    return x.reshape(H, W)


def _quantize_plane(plane: np.ndarray, quality: int) -> np.ndarray:
    """DCT-quantize one plane -> zigzag-scanned int16 coefficients."""
    tbl = _quality_table(quality)
    blocks = _blockify(plane * 255.0 - 128.0)
    coef = np.einsum("ij,njk,lk->nil", _DCT, blocks, _DCT)
    q = np.round(coef / tbl).astype(np.int16)
    # zigzag scan improves run-length behaviour for zlib
    return q.reshape(-1, 64)[:, _ZIG]


def _plane_payload(zz: np.ndarray) -> bytes:
    return zlib.compress(zz.astype(np.int16).tobytes(), level=6)


def _encode_plane(plane: np.ndarray, quality: int
                  ) -> Tuple[bytes, np.ndarray]:
    """DCT-quantize one plane; returns (compressed bytes, dequantized)."""
    tbl = _quality_table(quality)
    zz = _quantize_plane(plane, quality)
    payload = _plane_payload(zz)
    deq = (zz[:, np.argsort(_ZIG)].reshape(-1, 8, 8) * tbl)
    rec = np.einsum("ji,njk,kl->nil", _DCT, deq, _DCT)
    rec = (rec + 128.0) / 255.0
    return payload, _unblockify(rec, *plane.shape)


@dataclass
class EncodedFrame:
    payload_bytes: int
    mask: np.ndarray                 # (n_regions,) int32
    quality: int
    streams: List[bytes]
    shapes: Dict


class MixedResCodec:
    def __init__(self, part: Partition, patch_px: int, downsample: int):
        self.part = part
        self.patch_px = patch_px
        self.d = downsample

    def region_px(self) -> int:
        return self.part.region * self.patch_px

    # ------------------------------------------------------------------
    def _header_bytes(self, mask: np.ndarray,
                      reuse_mask: Optional[np.ndarray]) -> int:
        """Mask bits + header; the three-state plan costs one extra bit
        row when any region is reused."""
        total = len(mask) // 8 + 1 + 16
        if reuse_mask is not None and np.asarray(reuse_mask).any():
            total += len(mask) // 8 + 1
        return total

    def _region_plane(self, gray: np.ndarray, j: int,
                      low: bool) -> np.ndarray:
        rpx = self.region_px()
        ry, rx = divmod(j, self.part.regions_w)
        region = gray[ry * rpx:(ry + 1) * rpx, rx * rpx:(rx + 1) * rpx]
        if low:
            region = region.reshape(rpx // self.d, self.d,
                                    rpx // self.d, self.d).mean(axis=(1, 3))
        return region

    def encode(self, frame: np.ndarray, mask: np.ndarray, quality: int,
               reuse_mask: Optional[np.ndarray] = None
               ) -> Tuple[EncodedFrame, np.ndarray]:
        """Encode with region mask; also returns the server-side decoded
        mixed frame (full canvas with low regions decoded-upsampled) for
        accuracy evaluation.  Regions set in ``reuse_mask`` transmit
        nothing (empty stream, gray canvas fill)."""
        rpx = self.region_px()
        gray = frame.mean(axis=-1)          # luma-only codec (3x cheaper)
        decoded = frame.copy()
        streams: List[bytes] = []
        total = self._header_bytes(mask, reuse_mask)
        chroma_factor = 1.5                 # subsampled chroma cost model
        reuse = (np.zeros(len(mask), bool) if reuse_mask is None
                 else np.asarray(reuse_mask).astype(bool))

        for j, low in enumerate(np.asarray(mask).astype(bool)):
            ry, rx = divmod(j, self.part.regions_w)
            y0, x0 = ry * rpx, rx * rpx
            if reuse[j]:
                # REUSE: zero payload; the server never reads these pixels
                streams.append(b"")
                decoded[y0:y0 + rpx, x0:x0 + rpx] = 0.5
                continue
            r = self._region_plane(gray, j, low)
            payload, rec = _encode_plane(r, quality)
            streams.append(payload)
            total += int(len(payload) * chroma_factor)
            if low:
                rec = np.repeat(np.repeat(rec, self.d, axis=0), self.d,
                                axis=1)
            # luma-corrected reconstruction: scale rgb by luma ratio
            patch = frame[y0:y0 + rpx, x0:x0 + rpx]
            luma = patch.mean(axis=-1, keepdims=True)
            ratio = np.clip(rec[..., None] / np.maximum(luma, 1e-3),
                            0.25, 4.0)
            decoded[y0:y0 + rpx, x0:x0 + rpx] = np.clip(patch * ratio, 0, 1)

        enc = EncodedFrame(payload_bytes=total, mask=np.asarray(mask),
                           quality=quality, streams=streams,
                           shapes={"rpx": rpx})
        return enc, decoded

    def encode_size_only(self, frame: np.ndarray, mask: np.ndarray,
                         quality: int,
                         reuse_mask: Optional[np.ndarray] = None) -> int:
        """Payload size of :meth:`encode` without the decode work.

        Runs only the forward half of the pipeline (pool + DCT + quantize
        + entropy length) — no dequantize, no inverse DCT, no
        reconstruction — so estimator profiling can sweep configs at a
        fraction of ``encode``'s cost.  Byte-for-byte identical totals.
        """
        gray = frame.mean(axis=-1)
        total = self._header_bytes(mask, reuse_mask)
        chroma_factor = 1.5
        reuse = (np.zeros(len(mask), bool) if reuse_mask is None
                 else np.asarray(reuse_mask).astype(bool))
        for j, low in enumerate(np.asarray(mask).astype(bool)):
            if reuse[j]:
                continue
            payload = _plane_payload(
                _quantize_plane(self._region_plane(gray, j, low), quality))
            total += int(len(payload) * chroma_factor)
        return total


# ---------------------------------------------------------------------------
# codec delay model (the paper profiles T_enc(N_d, lambda) offline on the
# device and uses a mean T_dec; we do the same with a calibrated analytic
# model of our codec on the reference mobile SoC)


@dataclass(frozen=True)
class CodecDelayModel:
    """Delays in seconds.  Calibrated against the paper's Fig. 10 medians
    (total codec delay ~30 ms for full-res 1080p at q95 on the Jetson).

    Costs scale with TRANSMITTED regions only: a LOW region costs
    ``1/d^2`` of a full region, a REUSE region costs nothing (it is
    skipped before the DCT stage)."""
    enc_base: float = 0.0145          # full-res encode at q<=95
    dec_base: float = 0.0150          # full-res decode
    quality_slope: float = 0.004      # extra cost toward q100 (entropy len)
    mixed_overhead: float = 0.004     # mask + dual-stream preprocessing

    def _work_frac(self, part: Partition, n_low: int, n_reuse: int) -> float:
        frac = (1.0 - n_low * (1 - 1 / (part.downsample ** 2))
                / part.n_regions - n_reuse / part.n_regions)
        return max(frac, 0.0)

    def encode_delay(self, part: Partition, n_low: int,
                     quality: int, n_reuse: int = 0) -> float:
        q_extra = self.quality_slope * max(quality - 95, 0) / 5.0
        over = self.mixed_overhead if (n_low > 0 or n_reuse > 0) else 0.0
        return ((self.enc_base + q_extra)
                * self._work_frac(part, n_low, n_reuse) + over)

    def decode_delay(self, part: Partition, n_low: int,
                     n_reuse: int = 0) -> float:
        return self.dec_base * self._work_frac(part, n_low, n_reuse)
