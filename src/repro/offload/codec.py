"""Mixed-resolution frame codec (device-side encode, server-side decode).

JPEG-like pipeline, reproducible in pure numpy (DESIGN.md hardware
adaptation: libjpeg -> 8x8 DCT + quality-scaled quantization + zlib
entropy stage, so compressed sizes are content- and quality-dependent
exactly as the paper's MLP^size estimator requires).

Encoding a mixed-resolution frame (paper Fig. 3): full-resolution regions
are coded at their native pixels; regions marked for downsampling are
average-pooled by ``d`` first.  The payload is the concatenation of both
streams plus the binary region mask, mirroring the single mixed-res image
the prototype transmits.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partition import Partition

# JPEG luminance quantization table
_Q50 = np.array([
    [16, 11, 10, 16, 24, 40, 51, 61],
    [12, 12, 14, 19, 26, 58, 60, 55],
    [14, 13, 16, 24, 40, 57, 69, 56],
    [14, 17, 22, 29, 51, 87, 80, 62],
    [18, 22, 37, 56, 68, 109, 103, 77],
    [24, 35, 55, 64, 81, 104, 113, 92],
    [49, 64, 78, 87, 103, 121, 120, 101],
    [72, 92, 95, 98, 112, 100, 103, 99]], np.float32)


def _quality_table(quality: int) -> np.ndarray:
    q = max(1, min(int(quality), 100))
    s = 5000 / q if q < 50 else 200 - 2 * q
    tbl = np.floor((_Q50 * s + 50) / 100)
    return np.clip(tbl, 1, 255).astype(np.float32)


def _dct_matrix() -> np.ndarray:
    k = np.arange(8)
    c = np.sqrt(2.0 / 8.0) * np.cos((2 * k[None, :] + 1) * k[:, None]
                                    * np.pi / 16.0)
    c[0] *= 1.0 / np.sqrt(2.0)
    return c.astype(np.float32)


_DCT = _dct_matrix()
_ZIG = np.array(sorted(range(64), key=lambda i: (i // 8 + i % 8,
                                                 (i // 8 + i % 8) % 2 == 0
                                                 and i % 8 or -(i % 8))))


def _blockify(img: np.ndarray) -> np.ndarray:
    """(H, W) -> (H/8 * W/8, 8, 8)."""
    H, W = img.shape
    x = img.reshape(H // 8, 8, W // 8, 8).transpose(0, 2, 1, 3)
    return x.reshape(-1, 8, 8)


def _unblockify(blocks: np.ndarray, H: int, W: int) -> np.ndarray:
    x = blocks.reshape(H // 8, W // 8, 8, 8).transpose(0, 2, 1, 3)
    return x.reshape(H, W)


def _encode_plane(plane: np.ndarray, quality: int
                  ) -> Tuple[bytes, np.ndarray]:
    """DCT-quantize one plane; returns (compressed bytes, dequantized)."""
    tbl = _quality_table(quality)
    blocks = _blockify(plane * 255.0 - 128.0)
    coef = np.einsum("ij,njk,lk->nil", _DCT, blocks, _DCT)
    q = np.round(coef / tbl).astype(np.int16)
    # zigzag scan improves run-length behaviour for zlib
    zz = q.reshape(-1, 64)[:, _ZIG]
    payload = zlib.compress(zz.astype(np.int16).tobytes(), level=6)
    deq = (zz[:, np.argsort(_ZIG)].reshape(-1, 8, 8) * tbl)
    rec = np.einsum("ji,njk,kl->nil", _DCT, deq, _DCT)
    rec = (rec + 128.0) / 255.0
    return payload, _unblockify(rec, *plane.shape)


@dataclass
class EncodedFrame:
    payload_bytes: int
    mask: np.ndarray                 # (n_regions,) int32
    quality: int
    streams: List[bytes]
    shapes: Dict


class MixedResCodec:
    def __init__(self, part: Partition, patch_px: int, downsample: int):
        self.part = part
        self.patch_px = patch_px
        self.d = downsample

    def region_px(self) -> int:
        return self.part.region * self.patch_px

    # ------------------------------------------------------------------
    def encode(self, frame: np.ndarray, mask: np.ndarray,
               quality: int) -> Tuple[EncodedFrame, np.ndarray]:
        """Encode with region mask; also returns the server-side decoded
        mixed frame (full canvas with low regions decoded-upsampled) for
        accuracy evaluation."""
        rpx = self.region_px()
        nRw = self.part.regions_w
        gray = frame.mean(axis=-1)          # luma-only codec (3x cheaper)
        decoded = frame.copy()
        streams: List[bytes] = []
        total = len(mask) // 8 + 1 + 16     # mask bits + header
        chroma_factor = 1.5                 # subsampled chroma cost model

        for j, low in enumerate(np.asarray(mask).astype(bool)):
            ry, rx = divmod(j, nRw)
            y0, x0 = ry * rpx, rx * rpx
            region = gray[y0:y0 + rpx, x0:x0 + rpx]
            if low:
                r = region.reshape(rpx // self.d, self.d,
                                   rpx // self.d, self.d).mean(axis=(1, 3))
            else:
                r = region
            payload, rec = _encode_plane(r, quality)
            streams.append(payload)
            total += int(len(payload) * chroma_factor)
            if low:
                rec = np.repeat(np.repeat(rec, self.d, axis=0), self.d,
                                axis=1)
            # luma-corrected reconstruction: scale rgb by luma ratio
            patch = frame[y0:y0 + rpx, x0:x0 + rpx]
            luma = patch.mean(axis=-1, keepdims=True)
            ratio = np.clip(rec[..., None] / np.maximum(luma, 1e-3),
                            0.25, 4.0)
            decoded[y0:y0 + rpx, x0:x0 + rpx] = np.clip(patch * ratio, 0, 1)

        enc = EncodedFrame(payload_bytes=total, mask=np.asarray(mask),
                           quality=quality, streams=streams,
                           shapes={"rpx": rpx})
        return enc, decoded

    def encode_size_only(self, frame: np.ndarray, mask: np.ndarray,
                         quality: int) -> int:
        return self.encode(frame, mask, quality)[0].payload_bytes


# ---------------------------------------------------------------------------
# codec delay model (the paper profiles T_enc(N_d, lambda) offline on the
# device and uses a mean T_dec; we do the same with a calibrated analytic
# model of our codec on the reference mobile SoC)


@dataclass(frozen=True)
class CodecDelayModel:
    """Delays in seconds.  Calibrated against the paper's Fig. 10 medians
    (total codec delay ~30 ms for full-res 1080p at q95 on the Jetson)."""
    enc_base: float = 0.0145          # full-res encode at q<=95
    dec_base: float = 0.0150          # full-res decode
    quality_slope: float = 0.004      # extra cost toward q100 (entropy len)
    mixed_overhead: float = 0.004     # mask + dual-stream preprocessing

    def encode_delay(self, part: Partition, n_low: int,
                     quality: int) -> float:
        full_frac = 1.0 - n_low * (1 - 1 / (part.downsample ** 2)) \
            / part.n_regions
        q_extra = self.quality_slope * max(quality - 95, 0) / 5.0
        over = self.mixed_overhead if n_low > 0 else 0.0
        return (self.enc_base + q_extra) * full_frac + over

    def decode_delay(self, part: Partition, n_low: int) -> float:
        full_frac = 1.0 - n_low * (1 - 1 / (part.downsample ** 2)) \
            / part.n_regions
        return self.dec_base * full_frac
