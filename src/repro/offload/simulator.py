"""Event-driven end-to-end MVA offloading simulator (paper §VI).

Replays a synthetic video at a fixed FPS against a network trace.  The
device side (motion analysis, tracking, estimation, Algorithm 1) runs for
real; the server side runs the actual mixed-resolution ViTDet model
(trained on the synthetic domain) on the codec-decoded frame; delays
follow Eq. (2) with the inference term calibrated to the paper's measured
ViTDet-L numbers (281 ms @ full-res 1080p on the RTX 5090 — DESIGN.md).

Rendering accuracy is the F1 between what the user SEES (cache or tracker
output) and the ground truth of the CURRENT frame, where ground truth =
the full-resolution model output (exactly the paper's metric).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixed_res as mr
from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import LOW, REUSE, Partition, RegionPlan
from repro.kernels import autotune, dispatch
from repro.models import registry
from repro.quant import qtensor as qt
from repro.models.config import ModelConfig
from repro.offload import detection as det
from repro.offload import motion as mo
from repro.offload.codec import CodecDelayModel, MixedResCodec
from repro.offload.estimator import ThroughputEstimator
from repro.offload.faults import (DegradationLadder, FaultInjector,
                                  RobustConfig, fresh_rstats)
from repro.offload.optimizer import OffloadConfig, SystemState
from repro.offload.tracker import LKTracker
from repro.serve.request import (FeatureCache, ServingStats,
                                 StaleCacheEpoch)
from repro.serve.scheduler import SoloScheduler

# payload scale: our 512x512 luma codec vs the paper's 1080p YUV frames
SIZE_SCALE = (1920 * 1080) / (512 * 512)


# ---------------------------------------------------------------------------
# server model wrapper — the length-bucketed serving hot path.
#
# EVERY inference (solo N=1, batched multi-client wave, padded or
# coalesced) runs through ONE code path, infer_wave: mask-traced padded
# plan layouts (core.partition.PlanLayout), the wave padded UP to a
# batch bucket, against an AOT-compiled executable keyed on the
# collapsed grid
#     (length bucket, beta, capture point, B bucket).
# (n_low, n_reuse) are runtime i32 DATA, not shape — any plan mix at one
# length bucket shares one executable.  warmup() compiles the grid off
# the critical path at replica start; after it, a steady-state compile
# is telemetry (stats.steady_compiles) that tests and bench_serving
# treat as a failure.

# argument order of a mixed executable's layout arrays
_LAYOUT_ARGS = ("win_src", "win_dst", "low_src", "low_ids", "reuse_ids",
                "nw", "out_src", "out_map")


@dataclass
class StagedWave:
    """A wave's decoded frames, already padded to their B bucket and
    shipped to the device (:meth:`ServerModel.stage_frames`) — the h2d
    of wave N+1 overlaps wave N's compute under JAX async dispatch."""
    B: int                   # real rows; imgs carries Bp >= B
    imgs: jnp.ndarray


@dataclass
class PendingWave:
    """An in-flight wave result: the forward has been DISPATCHED but
    the blocking host-side detection decode has not run.  The scheduler
    defers :meth:`wait` until the next wave is on the device, so host
    decode hides under device compute."""
    boxes: jnp.ndarray
    scores: jnp.ndarray
    classes: jnp.ndarray
    B: int
    score_thresh: float

    def wait(self) -> List[List[Dict]]:
        return [det.detections_from_arrays(
                    self.boxes[i], self.scores[i], self.classes[i],
                    self.score_thresh)
                for i in range(self.B)]


class ServerModel:
    """Server-side detector with an AOT-compiled length-bucketed
    executable grid and device-resident feature caches.

    The transmitted window count of a plan is rounded UP to a
    ``length_edges`` bucket (partition.length_bucket_set); WHICH regions
    are LOW/REUSE and how many windows are real travel as runtime i32
    inputs (PlanLayout), so executables are keyed on
    ``(length bucket, beta, capture, B bucket)`` only — ~len(edges)+1
    executables per (beta, B) instead of one per (n_low, n_reuse)
    bucket pair, and waves may mix arbitrary (n_low, n_reuse) plans at
    one length bucket.  Mixed executables always capture restoration-
    point tiles (capture == beta; callers without a session drop them)
    and full-res executables capture at the deployment's canonical
    ``full_capture`` point, so sessionful and stateless traffic share
    one grid.  Wave sizes are padded UP to ``b_buckets`` edges with
    copies of sample 0; padded rows are dropped from the decoded
    detections and never touch a FeatureCache, so within one executable
    the padding is bit-invisible (pinned by tests).

    ``device_cache=True`` keeps captured restoration-point tiles as
    device arrays end to end: reuse gathers and cache refreshes are
    jitted index ops (core.mixed_res) and ship ZERO tile bytes between
    host and device per offload (stats.tile_bytes_*); ``False`` is the
    legacy host-resident mode the bench compares against.

    ``backend`` selects the kernel backend for the backbone hot path
    (kernels.dispatch: "auto" | "pallas" | "xla").  ``jit=False`` runs
    the forward eagerly (op-by-op) — only useful to benchmark what the
    bucketed cache buys (benchmarks/bench_backbone.py quotes both).
    """

    def __init__(self, cfg: ModelConfig, params, top_k: int = 32,
                 score_thresh: float = 0.4,
                 backend: Optional[str] = "auto", jit: bool = True,
                 n_buckets: int = 4,
                 b_buckets: Tuple[int, ...] = pt.BATCH_BUCKETS,
                 device_cache: bool = True,
                 n_length_buckets: int = pt.N_LENGTH_BUCKETS,
                 donate_frames: bool = True,
                 quant=None, calib_frames=None):
        # ``quant``: optional quant.ptq.QuantSpec — compress the float
        # tree (int8 / half-cast / head-pruned) BEFORE any executable is
        # built, so the whole grid compiles against the compressed
        # params and the grid keys never change.  ``calib_frames`` feeds
        # head scoring when the spec prunes.  Pre-compressed trees (the
        # calibration gate builds candidates itself) pass quant=None.
        self.quant_report = None
        if quant is not None:
            from repro.quant import ptq
            cfg, params, self.quant_report = ptq.compress(
                cfg, params, quant, calib_frames=calib_frames)
        self.cfg = cfg
        self.params = params
        # activation dtype of the serving grid.  Detected from the tree
        # rather than a spec so pre-compressed params work: cast_tree
        # always casts the patch-embed bias (QuantTensor scales stay f32
        # and are useless as a probe), and activations take this dtype
        # at the very first matmul.
        self.act_dtype = jnp.dtype(params["patch_embed"]["b"].dtype)
        self.part = vb.vit_partition(cfg)
        self.top_k = top_k
        self.score_thresh = score_thresh
        self.backend = backend
        self.jit = jit
        # donate the staged frame buffer to the executable so XLA can
        # reuse it as scratch — each wave stages a fresh array, so the
        # buffer is never read again.  CPU XLA ignores donation (and
        # warns), so the gate keeps the host path quiet.
        self._donate = donate_frames and jax.default_backend() != "cpu"
        self.n_buckets = n_buckets
        self.b_buckets = tuple(sorted(b_buckets))
        self.device_cache = device_cache
        self.length_edges = pt.length_bucket_set(self.part,
                                                 n_length_buckets)
        # canonical capture point of the FULL-RES executable: sessions
        # bootstrap with full-res offloads that capture tiles at their
        # beta; stateless callers share that executable and drop the
        # tiles, so capture never fragments the grid (set by warmup)
        self.full_capture = 0
        self._fns: Dict[Tuple[int, int, int, int], Callable] = {}
        self._zero_tiles: Dict[int, jnp.ndarray] = {}
        self.stats = ServingStats()
        # cache generation: bumped by restart(); FeatureCache tiles
        # captured under an older epoch can never be spliced again
        self.epoch = 0

    def restart(self, preserve_executables: bool = False) -> int:
        """Crash-restart this replica.

        Bumps the cache epoch — every FeatureCache tile captured before
        this moment belongs to a dead replica and any REUSE plan still
        carrying it is refused (StaleCacheEpoch) — and, unless
        ``preserve_executables`` (a bench shortcut when the outage is
        modelled in sim time only), wipes the warmed executable grid so
        the new process must re-warm (compiles count toward warmup
        again, not steady-state stalls).  Returns the new epoch.
        """
        self.epoch += 1
        self.stats.restarts += 1
        if not preserve_executables:
            self._fns.clear()
            self._zero_tiles.clear()
            self.stats.warmed = False
        return self.epoch

    def bucket(self, n_low: int) -> int:
        """Legacy policy-side n_low bucket (plan EMISSION still rounds
        down; the executable grid no longer keys on it)."""
        return pt.bucket_n_low(n_low, self.part.n_regions, self.n_buckets)

    def batch_bucket(self, b: int) -> int:
        return pt.batch_bucket(b, self.b_buckets)

    def length_bucket(self, n_windows: int) -> int:
        return pt.length_bucket(n_windows, self.length_edges)

    def plan_length_bucket(self, plan: RegionPlan) -> int:
        """The length bucket a plan's transmitted windows land in
        (0 = the dedicated full-resolution executable)."""
        if plan.n_low == 0 and plan.n_reuse == 0:
            return 0
        return self.length_bucket(pt.plan_n_windows(plan, self.part))

    def _decode(self, outs):
        from repro.core import det_head as dh
        return dh.decode_detections(self.cfg, outs, self.top_k,
                                    self.score_thresh)

    # ------------------------------------------------------------------
    # executable grid

    def _build_fn(self, lb: int, beta: int, capture: int) -> Callable:
        cfg, backend = self.cfg, self.backend

        def finish(outs):
            if capture:
                outs, tiles = outs
                return self._decode(outs), tiles
            return self._decode(outs)

        if lb == 0:
            def fn(params, img):
                return finish(vb.forward_det(cfg, params, img,
                                             backend=backend,
                                             capture_beta=capture))
        else:
            def fn(params, img, win_src, win_dst, low_src, low_ids,
                   reuse_ids, nw, out_src, out_map, reuse_tiles,
                   ids_key=None):
                layout = {"win_src": win_src, "win_dst": win_dst,
                          "low_src": low_src, "low_ids": low_ids,
                          "reuse_ids": reuse_ids, "nw": nw,
                          "out_src": out_src, "out_map": out_map}
                # beta == 0 restores at input — reuse tiles are
                # restoration-point features and cannot splice there
                # (infer_wave bars reuse plans from beta=0 waves)
                return finish(vb.forward_det(
                    cfg, params, img, beta=beta, backend=backend,
                    layout=layout,
                    reuse_tiles=reuse_tiles if beta >= 1 else None,
                    capture_beta=capture, ids_key=ids_key))
        return fn

    def _arg_structs(self, lb: int, batch: int) -> List:
        """ShapeDtypeStructs of one executable's data arguments — shapes
        depend only on (length bucket, B bucket)."""
        part = self.part
        H, W = self.cfg.vit.img_size
        sds = [jax.ShapeDtypeStruct((batch, H, W, 3), jnp.float32)]
        if lb > 0:
            nR = part.n_regions
            sds.append(jax.ShapeDtypeStruct((batch, lb), jnp.int32))
            sds.append(jax.ShapeDtypeStruct((batch, lb), jnp.int32))
            for _ in ("low_src", "low_ids", "reuse_ids"):
                sds.append(jax.ShapeDtypeStruct((batch, nR), jnp.int32))
            sds.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
            for _ in ("out_src", "out_map"):
                sds.append(jax.ShapeDtypeStruct(
                    (batch, nR * part.windows_per_full_region), jnp.int32))
            sds.append(jax.ShapeDtypeStruct(
                (batch, nR, part.windows_per_full_region,
                 part.tokens_low_region, self.cfg.d_model),
                self.act_dtype))
        return sds

    def _get_fn(self, lb: int, beta: int, capture: int = 0,
                batch: int = 1) -> Callable:
        key = (lb, beta, capture, batch)
        if key not in self._fns:
            fn = self._build_fn(lb, beta, capture)
            if self.jit:
                # AOT: lower + compile against the key's exact shapes.
                # The executable can never silently retrace, so each
                # cache miss is exactly one XLA compile — the telemetry
                # below is the whole compile surface.
                fn = jax.jit(
                    fn,
                    donate_argnums=(1,) if self._donate else ()).lower(
                    self.params, *self._arg_structs(lb, batch)).compile()
                self.stats.note_compile(key)
            self._fns[key] = fn
        return self._fns[key]

    def _exec_key(self, n_low: int, n_reuse: int, beta: int,
                  cap: int) -> Tuple[int, int, int]:
        """Collapse a legacy (n_low, n_reuse, beta, capture) plan shape
        onto the (length bucket, beta, capture) executable it runs on.
        Full-res entries canonicalise through :meth:`_full_cap`, so a
        deployment that really configures several distinct full-res
        capture points warms each of them (no-capture requests fold
        into ``full_capture``)."""
        if n_low == 0 and n_reuse == 0:
            return (0, 0, self._full_cap(cap))
        lb = self.length_bucket(self.part.n_windows(n_low, n_reuse))
        return (lb, beta, beta)

    def warmup(self, plan_space, batch_buckets: Optional[Tuple[int, ...]]
               = None) -> int:
        """AOT-compile the executable grid off the critical path.

        ``plan_space``: iterable of (n_low, n_reuse, beta, capture
        point) tuples — the plan shapes the deployment's config space
        can emit (see :meth:`default_plan_space`).  The space is
        COLLAPSED onto the (length bucket, beta, capture, B bucket)
        grid: every (n_low, n_reuse) pair maps to its padded length
        bucket, mixed captures canonicalise to beta, and full-res
        captures to the deployment-wide ``full_capture`` point.  Each
        surviving key is compiled for every batch bucket.  Returns the
        number of executables compiled; afterwards
        ``stats.steady_compiles`` counts every further compile (a
        steady-state stall).
        """
        t0 = time.perf_counter()
        before = self.stats.compiles
        if dispatch.use_pallas(self.backend):
            # sweep Pallas block sizes for the grid's attention shapes
            # before any executable is traced, so the tuned winners are
            # baked into the compiled graphs (no-op off-TPU or with
            # REPRO_AUTOTUNE=0; later processes hit the disk cache)
            self._autotune_kernels(batch_buckets or self.b_buckets)
        space = dict.fromkeys(tuple(p) for p in plan_space)
        self.full_capture = max(
            [self.full_capture] + [cap for (n_low, n_reuse, _, cap)
                                   in space
                                   if n_low == 0 and n_reuse == 0])
        keys = dict.fromkeys(self._exec_key(n_low, n_reuse, beta, cap)
                             for (n_low, n_reuse, beta, cap) in space)
        for (lb, beta, cap) in keys:
            for b in (batch_buckets or self.b_buckets):
                self._get_fn(lb, beta, cap, b)
        if self.device_cache:
            self._warm_tile_ops(space, batch_buckets or self.b_buckets)
        return self.stats.finish_warmup(t0, before, time.perf_counter())

    def _autotune_kernels(self, batch_buckets) -> None:
        """Autotune window/flash block sizes for every (B bucket, length
        bucket) attention shape the executable grid can run."""
        part, cfg = self.part, self.cfg
        w2 = part.window * part.window
        T_full = part.grid_h * part.grid_w
        dt = self.act_dtype          # per-dtype buckets: an fp16 grid
        for b in batch_buckets:      # must never reuse fp32 winners
            for lb in self.length_edges:
                autotune.tune_window(b, lb * w2, cfg.n_heads,
                                     cfg.head_dim, w2, dtype=dt)
            autotune.tune_window(b, T_full, cfg.n_heads, cfg.head_dim,
                                 w2, dtype=dt)
            autotune.tune_flash(b, T_full, T_full, cfg.n_heads,
                                cfg.head_dim, dtype=dt)
        if any(isinstance(l, qt.QuantTensor)
               for l in jax.tree_util.tree_leaves(
                   self.params,
                   is_leaf=lambda x: isinstance(x, qt.QuantTensor))):
            # int8 lane: sweep the GEMM blocks for the grid's matmul
            # shapes (fused QKV / w_o / MLP at every sequence length)
            qkv_n = cfg.q_dim + 2 * cfg.kv_dim
            shapes = {(cfg.d_model, qkv_n), (cfg.q_dim, cfg.d_model),
                      (cfg.d_model, cfg.d_ff), (cfg.d_ff, cfg.d_model)}
            for b in batch_buckets:
                for T in {T_full} | {lb * w2 for lb in self.length_edges}:
                    for (K, N) in shapes:
                        autotune.tune_matmul(b * T, N, K,
                                             out_dtype=self.act_dtype)

    def _warm_tile_ops(self, space, batch_buckets) -> None:
        """Compile the device-resident cache's jitted index ops
        (mixed_res.gather_tiles / take_sample_tiles / refresh_tiles) for
        every tile shape the plan space can produce — they sit on the
        serving critical path too, and an unwarmed jit there would be a
        steady-state stall invisible to ``stats`` (jax.jit caches are
        global per function + shape, so one warm call covers serving)."""
        part = self.part
        tile = (part.n_regions, part.windows_per_full_region,
                part.tokens_low_region, self.cfg.d_model)
        dummy = jnp.zeros(tile, self.act_dtype)
        if any(n_reuse for (_, n_reuse, _, _) in space):
            # reuse gathers are (n_regions,)-padded — one shape for all
            mr.gather_tiles(dummy, jnp.zeros((part.n_regions,),
                                             jnp.int32))
        if any(cap for (_, _, _, cap) in space) or \
                any(n_low or n_reuse for (n_low, n_reuse, _, _) in space):
            # mixed executables always capture, so take/refresh are hot
            mr.refresh_tiles(jnp.zeros(tile, self.act_dtype), dummy)
            for b in batch_buckets:
                mr.take_sample_tiles(
                    jnp.zeros((b,) + tile, self.act_dtype), np.int32(0))

    def default_plan_space(self, betas: Sequence[int],
                           reuse_edges: Sequence[int] = (0,),
                           captures: Sequence[int] = (0,),
                           full_res: bool = True) -> List[Tuple[int, int,
                                                                int, int]]:
        """The plan grid a config space induces: every n_low bucket edge
        x n_reuse edge x beta x capture point.  Mixed plans capture at
        their own beta when the session captures at all (``captures``
        lists the extra full-res capture points).  :meth:`warmup`
        collapses this onto the (length bucket, beta, capture, B)
        executable grid."""
        edges = pt.bucket_set(self.part.n_regions, self.n_buckets)
        space: List[Tuple[int, int, int, int]] = []
        if full_res:
            for cap in captures:
                space.append((0, 0, 0, cap))
        for beta in betas:
            if beta < 1:
                continue
            for n_low in edges:
                for n_reuse in reuse_edges:
                    if n_low + n_reuse > self.part.n_regions:
                        continue
                    if n_low == 0 and n_reuse == 0:
                        continue
                    caps = {0}
                    if any(c > 0 for c in captures) or n_reuse > 0:
                        caps.add(beta)        # sessions capture at beta
                    for cap in sorted(caps):
                        if n_reuse > 0 and cap == 0:
                            continue          # reuse implies a session
                        space.append((n_low, n_reuse, beta, cap))
        return list(dict.fromkeys(space))

    # ------------------------------------------------------------------
    # the one serving entry point

    def _full_cap(self, want: int) -> int:
        """Canonical capture point of the full-res executable: requests
        for no capture (or the deployment's point) share ``full_capture``
        and simply drop the tiles."""
        if want == 0 or want == self.full_capture:
            return self.full_capture
        return want

    def stage_frames(self, frames: np.ndarray) -> StagedWave:
        """Asynchronously stage a wave's decoded frames on the device.

        Pads up to the B bucket on the host, then ``jax.device_put``
        enqueues the h2d copy WITHOUT blocking — called while the
        previous wave still computes, the transfer overlaps it.  The
        result feeds :meth:`infer_wave` in place of the host array.
        """
        frames = np.asarray(frames)
        B = frames.shape[0]
        npad = self.batch_bucket(B) - B
        if npad:
            frames = np.concatenate(
                [frames, np.repeat(frames[:1], npad, axis=0)])
        return StagedWave(B=B, imgs=jax.device_put(frames))

    def infer_wave(self, frames, plans: Sequence[RegionPlan],
                   beta: int = 0,
                   caches: Optional[Sequence[Optional[FeatureCache]]]
                   = None,
                   frame_ids: Optional[Sequence[int]] = None,
                   capture_beta: int = 0,
                   lb_override: Optional[int] = None,
                   defer: bool = False):
        """Serve one wave (B >= 1 frames) through the collapsed grid.

        frames: (B, H, W, 3); plans: per-sample RegionPlans — ANY
        (n_low, n_reuse) mix is servable in one executable; the wave
        runs at the length bucket of its LONGEST plan (or
        ``lb_override``, which may only pad further — the coalescing
        direction, zero resolution changes, zero accuracy question).
        caches/frame_ids: the per-client FeatureCaches of sessionful
        (reuse/capture) jobs — entries may be None for stateless jobs
        co-batched into a sessionful wave; each sample splices from and
        refreshes its OWN cache, never another's.

        The wave is padded up to the next batch bucket with copies of
        sample 0; padded rows are dropped from the decoded detections
        and never touch a cache (within one executable the result is
        bit-invariant to pad content — pinned by tests).

        ``frames`` may be a :class:`StagedWave` (pre-padded device
        array from :meth:`stage_frames`) and ``defer=True`` returns a
        :class:`PendingWave` instead of decoded detections — together
        the continuous scheduler's async-overlap path.
        """
        staged: Optional[StagedWave] = None
        if isinstance(frames, StagedWave):
            staged = frames
            B = staged.B
        else:
            frames = np.asarray(frames)
            B = frames.shape[0]
        assert len(plans) == B and B >= 1
        if caches is not None:
            assert len(caches) == B
        for i, p in enumerate(plans):
            assert p.n_reuse == 0 or (caches is not None
                                      and caches[i] is not None
                                      and beta >= 1), \
                "REUSE regions need feature caches and a restoration point"
        if caches is not None:
            # epoch guard: no splice ever reads tiles from a dead
            # replica — a REUSE plan whose cache predates the last
            # restart is refused outright (the client must invalidate
            # and bootstrap FULL)
            for i, p in enumerate(plans):
                c = caches[i]
                if p.n_reuse > 0 and c is not None \
                        and getattr(c, "epoch", 0) != self.epoch:
                    self.stats.stale_epoch_rejects += 1
                    raise StaleCacheEpoch(
                        f"sample {i}: REUSE plan carries cache epoch "
                        f"{getattr(c, 'epoch', 0)} but the replica is at "
                        f"epoch {self.epoch}")
            self.stats.reuse_splices += sum(
                1 for i, p in enumerate(plans)
                if p.n_reuse > 0 and caches[i] is not None)
        full_res = all(p.n_low == 0 and p.n_reuse == 0 for p in plans)

        Bp = self.batch_bucket(B)
        npad = Bp - B

        def pad_rows(a: np.ndarray) -> np.ndarray:
            if npad == 0:
                return a
            return np.concatenate([a, np.repeat(a[:1], npad, axis=0)])

        if staged is not None:
            assert staged.imgs.shape[0] == Bp, \
                f"staged wave padded to {staged.imgs.shape[0]} rows " \
                f"but the B bucket is {Bp}"
            imgs = staged.imgs
        else:
            imgs = jnp.asarray(pad_rows(frames))
        layouts: Optional[List[pt.PlanLayout]] = None
        if full_res and lb_override is None:
            store_cap = capture_beta if caches is not None else 0
            exec_cap = self._full_cap(store_cap)
            fn = self._get_fn(0, 0, exec_cap, Bp)
            out = fn(self.params, imgs)
        else:
            # beta == 0 with a mixed plan is the paper's restore-at-
            # input case (full-length compute, upsampled input) — it has
            # no restoration point, so reuse plans are barred (per-plan
            # assert above) and tiles are never captured
            beta_eff = beta if not full_res else max(beta, 1)
            nws = [pt.plan_n_windows(p, self.part) for p in plans]
            lb = (self.length_bucket(max(nws)) if lb_override is None
                  else lb_override)
            assert lb >= max(nws) and lb in self.length_edges, \
                f"lb_override {lb} cannot hold {max(nws)} windows " \
                f"(edges {self.length_edges})"
            layouts = [pt.plan_layout(p.states, lb, self.part)
                       for p in plans]
            arrays, wave_key = pt.stack_plan_layouts(layouts)
            tiles_in = self._wave_tiles(layouts, caches, npad)
            # mixed execs always capture at their restoration point;
            # beta_eff == 0 has none, so it never captures
            exec_cap = beta_eff
            store_cap = beta_eff if caches is not None else 0
            fn = self._get_fn(lb, beta_eff, exec_cap, Bp)
            args = [jnp.asarray(pad_rows(arrays[k]))
                    for k in _LAYOUT_ARGS]
            kw = {} if self.jit else {"ids_key": wave_key}
            out = fn(self.params, imgs, *args, tiles_in, **kw)

        if exec_cap:
            (boxes, scores, classes), tiles_out = out
            if store_cap and caches is not None:
                self._refresh_caches(caches, tiles_out, layouts,
                                     store_cap,
                                     frame_ids if frame_ids is not None
                                     else [-1] * B)
        else:
            boxes, scores, classes = out
        self.stats.offloads += B
        pending = PendingWave(boxes, scores, classes, B,
                              self.score_thresh)
        return pending if defer else pending.wait()

    def _zeros_tiles(self, Bp: int) -> jnp.ndarray:
        """Cached all-zero reuse-tiles input for reuse-free waves (a
        device-side fill — no h2d traffic, allocated once per B)."""
        z = self._zero_tiles.get(Bp)
        if z is None:
            part = self.part
            z = jnp.zeros((Bp, part.n_regions,
                           part.windows_per_full_region,
                           part.tokens_low_region, self.cfg.d_model),
                          self.act_dtype)
            self._zero_tiles[Bp] = z
        return z

    def _wave_tiles(self, layouts: List[pt.PlanLayout], caches,
                    npad: int) -> jnp.ndarray:
        """(Bp, n_regions, d^2, w^2, D) stacked per-sample reuse tiles.

        Rows are (n_regions,)-padded: entries past a sample's n_reuse
        gather clipped garbage that the restoration scatter routes to
        the sentinel.  Device-resident caches stack on device — zero
        h2d tile bytes; host caches are uploaded (and accounted) here.
        """
        B = len(layouts)
        if caches is None or all(l.n_reuse == 0 for l in layouts):
            return self._zeros_tiles(B + npad)
        part = self.part
        tile = (part.n_regions, part.windows_per_full_region,
                part.tokens_low_region, self.cfg.d_model)
        gathered, host_bytes = [], 0
        for l, c in zip(layouts, caches):
            if l.n_reuse == 0 or c is None or c.tiles is None:
                gathered.append(None)
                continue
            ids = np.where(l.reuse_ids < part.n_regions, l.reuse_ids, 0)
            g = c.gather(ids)
            if isinstance(g, np.ndarray):
                # only the real rows are payload; the clipped pad rows
                # are an artifact of the padded gather
                host_bytes += g[:l.n_reuse].nbytes
            gathered.append(g)
        if host_bytes == 0:
            rows = [g if g is not None
                    else jnp.zeros(tile, self.act_dtype)
                    for g in gathered]
            rows += [rows[0]] * npad
            return jnp.stack(rows)
        self.stats.tile_bytes_h2d += host_bytes
        rows = [np.asarray(g) if g is not None
                else np.zeros(tile, np.dtype(self.act_dtype))
                for g in gathered]
        rows += [rows[0]] * npad
        return jnp.asarray(np.stack(rows))

    def _refresh_caches(self, caches, tiles_out, layouts, cap: int,
                        frame_ids) -> None:
        """Refresh each real sessionful sample's cache with its captured
        tiles.  Padded rows and cache-less samples are never written."""
        B = len(caches)
        reuse_rows = [l.reuse_ids[:l.n_reuse] if l is not None
                      else np.zeros((0,), np.int32)
                      for l in (layouts or [None] * B)]
        if self.device_cache:
            for i, c in enumerate(caches[:B]):
                if c is None:
                    continue
                c.update(mr.take_sample_tiles(tiles_out, np.int32(i)),
                         reuse_rows[i], cap, frame_ids[i],
                         epoch=self.epoch)
        else:
            tiles_np = np.asarray(tiles_out)
            live = [i for i, c in enumerate(caches[:B]) if c is not None]
            self.stats.tile_bytes_d2h += sum(tiles_np[i].nbytes
                                             for i in live)
            for i in live:
                caches[i].update(tiles_np[i], reuse_rows[i], cap,
                                 frame_ids[i], epoch=self.epoch)

    # ------------------------------------------------------------------
    # speculative REUSE execution (the spliced forward starts before the
    # payload lands; serve/scheduler.py owns admission and resolution)

    def infer_speculative(self, pred_canvas: np.ndarray, plan: RegionPlan,
                          beta: int, cache: FeatureCache,
                          frame_idx: int) -> Tuple[List[Dict],
                                                   FeatureCache]:
        """Launch a plan's spliced forward on a PREDICTED canvas.

        The canvas substitutes the in-flight LOW/FULL regions' pixels
        with the session's prediction source (:func:`predict_canvas`);
        REUSE regions splice from the cache exactly as the real forward
        would.  Same plan, same length bucket, B=1 — the warmed
        ``(lb, beta, beta, 1)`` executable, so speculation adds ZERO
        grid keys.  Capture goes into a :meth:`FeatureCache.
        speculative_clone`, never the live session: a discarded
        speculation leaves the real cache byte-identical, and the epoch
        guard applies to the clone exactly as to a real splice.
        Returns ``(dets, clone)``; the scheduler patches or discards on
        payload arrival and commits the clone only on success.
        """
        clone = cache.speculative_clone()
        dets = self.infer_wave(pred_canvas[None], [plan], beta,
                               caches=[clone], frame_ids=[frame_idx])
        return dets[0], clone

    # ------------------------------------------------------------------
    # N=1 conveniences (thin wrappers over infer_wave)

    def infer(self, frame: np.ndarray, mask: Optional[np.ndarray] = None,
              beta: int = 0) -> List[Dict]:
        plan = (RegionPlan.from_mask(mask) if mask is not None
                else RegionPlan(np.zeros((self.part.n_regions,), np.int8)))
        return self.infer_wave(frame[None], [plan], beta)[0]

    def infer_plan(self, frame: np.ndarray, plan: RegionPlan,
                   beta: int = 0, cache: Optional[FeatureCache] = None,
                   frame_idx: int = -1,
                   capture_beta: int = 0) -> List[Dict]:
        """Stateful three-state inference for one client frame.

        Splices the cached feature tiles of the plan's REUSE regions in
        at the restoration point, and (when ``cache`` is given) refreshes
        the cache with this forward's restoration-point tiles — captured
        at ``beta`` for mixed forwards, at ``capture_beta`` for full-res
        ones — so the NEXT offload can reuse them.
        """
        return self.infer_wave(
            frame[None], [plan], beta,
            caches=None if cache is None else [cache],
            frame_ids=[frame_idx], capture_beta=capture_beta)[0]


# ---------------------------------------------------------------------------
# speculative-prediction helpers (host-side numpy; the scheduler drives
# them around ServerModel.infer_speculative)


def predict_canvas(part: Partition, region_px: int,
                   pred_frame: np.ndarray,
                   plan: RegionPlan) -> np.ndarray:
    """The speculative forward's input: the session's prediction source
    standing in for the in-flight LOW/FULL regions, REUSE regions filled
    0.5 gray exactly as the codec fills them in a real decoded canvas
    (their pixels never reach the splice — bit-faithful anyway)."""
    canvas = np.asarray(pred_frame, np.float32).copy()
    nRw = part.regions_w
    for j in np.nonzero(np.asarray(plan.states) == REUSE)[0]:
        ry, rx = divmod(int(j), nRw)
        canvas[ry * region_px:(ry + 1) * region_px,
               rx * region_px:(rx + 1) * region_px] = 0.5
    return canvas


def region_divergence(part: Partition, region_px: int,
                      decoded: np.ndarray, predicted: np.ndarray,
                      plan: RegionPlan) -> np.ndarray:
    """(n_regions,) mean |decoded - predicted| per TRANSMITTED region
    (REUSE rows stay 0 — nothing was predicted there).  The patch pass
    recomputes only regions whose real decoded content diverged from
    the speculative prediction beyond the tolerance."""
    div = np.zeros((part.n_regions,), np.float32)
    states = np.asarray(plan.states).reshape(-1)
    nRw = part.regions_w
    for j in np.nonzero(states != REUSE)[0]:
        ry, rx = divmod(int(j), nRw)
        sl = (slice(ry * region_px, (ry + 1) * region_px),
              slice(rx * region_px, (rx + 1) * region_px))
        div[j] = float(np.abs(np.asarray(decoded, np.float32)[sl]
                              - predicted[sl]).mean())
    return div


def build_patch_plan(plan: RegionPlan,
                     diverged: np.ndarray) -> RegionPlan:
    """The cheap patch pass's plan: transmitted regions that CONVERGED
    (prediction within tolerance) flip to REUSE — splicing the
    speculative forward's captured tiles — and only diverged regions
    stay LOW/FULL.  Window count can only shrink, so the patch runs at
    an equal-or-smaller length bucket of the existing grid (zero new
    executable keys).  Callers handle the all-converged case (no patch
    compute at all) before building a plan, so at least one transmitted
    window always remains."""
    states = np.asarray(plan.states).copy()
    diverged = np.asarray(diverged, bool).reshape(-1)
    assert diverged.any(), "all-converged speculations need no patch"
    states[(states != REUSE) & ~diverged] = REUSE
    return RegionPlan(states.astype(np.int8))


# ---------------------------------------------------------------------------
# policies


class Policy:
    """Decides the offload configuration for each frame to be offloaded.

    Returns dict(mask (n_regions,), quality, beta, use_tracker: bool).
    Temporal-reuse policies additionally return a three-state ``plan``
    (partition.RegionPlan, bucket-exact in n_reuse) and may return
    ``capture_beta`` (the restoration point full-res offloads capture
    feature tiles at); they must set ``reuse_k`` (the staleness bound K)
    so the Simulation provisions a per-client FeatureCache.
    """
    name = "policy"
    use_tracker = True
    reuse_k = 0                 # K > 0 enables the per-client FeatureCache

    def decide(self, sim: "Simulation", frame_idx: int) -> Dict:
        raise NotImplementedError

    def observe_completion(self, e2e_latency: float) -> None:
        pass


@dataclass
class SimResult:
    policy: str
    video: str
    trace: str
    rendering_f1: List[float] = field(default_factory=list)
    inference_f1: List[float] = field(default_factory=list)
    e2e_latency: List[float] = field(default_factory=list)
    offload_interval: List[int] = field(default_factory=list)
    delay_parts: List[Dict] = field(default_factory=list)
    overhead: Dict[str, List[float]] = field(default_factory=dict)
    sizes: List[float] = field(default_factory=list)

    def summary(self) -> Dict:
        def med(x):
            return float(np.median(x)) if len(x) else float("nan")
        return {
            "policy": self.policy, "video": self.video, "trace": self.trace,
            "median_rendering_f1": med(self.rendering_f1),
            "mean_rendering_f1": (float(np.mean(self.rendering_f1))
                                  if self.rendering_f1 else float("nan")),
            "mean_inference_f1": (float(np.mean(self.inference_f1))
                                  if self.inference_f1 else float("nan")),
            "median_e2e_latency": med(self.e2e_latency),
            "median_interval": med(self.offload_interval),
            "median_net_delay": med([d["net"] for d in self.delay_parts]),
            "median_inf_delay": med([d["inf"] for d in self.delay_parts]),
            "median_codec_delay": med([d["enc"] + d["dec"]
                                       for d in self.delay_parts]),
            "median_queue_delay": med([d.get("queue", 0.0)
                                       for d in self.delay_parts]),
        }


class Simulation:
    """One (video, trace, policy) run."""

    def __init__(self, frames: np.ndarray, gt_dets: List[List[Dict]],
                 trace, policy: Policy, server: ServerModel,
                 part: Partition, patch_px: int, fps: int = 10,
                 delay_model: Optional[CodecDelayModel] = None,
                 inf_delay=None,
                 faults: Optional[FaultInjector] = None,
                 robust: Optional[RobustConfig] = None):
        self.frames = frames
        self.gt_dets = gt_dets            # full-res model outputs per frame
        self.trace = trace
        self.policy = policy
        self.server = server
        self.part = part
        self.fps = fps
        self.dt = 1.0 / fps
        self.codec = MixedResCodec(part, patch_px, part.downsample)
        self.delay_model = delay_model or CodecDelayModel()
        self.inf_delay = inf_delay        # InferenceDelayModel
        self.analyzer = mo.RegionMotionAnalyzer(part, patch_px)
        self.tracker = LKTracker()
        self.net_est = ThroughputEstimator()
        self.state = SystemState()
        # temporal-reuse session state: one FeatureCache per client
        # stream, provisioned only for reuse-capable policies (reuse_k =
        # the staleness bound K)
        self.feature_cache: Optional[FeatureCache] = (
            FeatureCache(part.n_regions, max_age=policy.reuse_k)
            if policy.reuse_k > 0 else None)

        # failure model: fault schedule + the deadline/retry/backoff
        # state machine (both optional — None keeps the legacy
        # fault-free, deadline-free lifecycle byte-identical)
        self.faults = faults
        self.robust = robust
        self.ladder = (DegradationLadder(robust) if robust is not None
                       else None)
        self.rstats = fresh_rstats()
        self.offload_seq = 0
        # the N=1 scheduling plane: immediate dedicated execution with
        # the shared stale-epoch NACK + crash-restart semantics
        # (serve/scheduler.py — the multi-client engine swaps in a
        # WaveScheduler over the same per-frame step methods)
        self.scheduler = SoloScheduler(self)

        # runtime state
        self.cache_dets: List[Dict] = []
        self.cache_frame = -1
        self.tracker_frame = -1           # frame the tracker state is at
        self.inflight: Optional[Dict] = None
        self.last_offload_frame = -10 ** 9
        self.m = np.zeros((part.n_regions,), np.float32)
        self.m_f = 0.0

    # ------------------------------------------------------------------
    # per-frame steps.  Single-client ``run`` below and the multi-client
    # engine (serve/edge.py) drive the SAME methods; the engine replaces
    # the synchronous server call in _start_offload with batched waves.

    def rho(self) -> np.ndarray:
        return mo.region_density(self.tracker.boxes(), self.part,
                                 self.analyzer.patch_px)

    def _motion_tick(self, frame_idx: int, res: SimResult) -> None:
        t0 = time.perf_counter()
        self.m, self.m_f = self.analyzer.update(self.frames[frame_idx])
        res.overhead.setdefault("motion_wall", []).append(
            time.perf_counter() - t0)

    def _should_offload(self, frame_idx: int) -> bool:
        """Back-to-back: a new offload starts as soon as none is in
        flight (frame 0 is skipped — the motion model needs a delta).
        After a failure, the ladder's exponential backoff additionally
        holds the retry until ``retry_at`` — while it holds (and at shed
        level), rendering rides the LK tracker."""
        if self.inflight is not None or frame_idx <= 0:
            return False
        if self.ladder is not None \
                and frame_idx * self.dt < self.ladder.retry_at:
            return False
        return True

    def _note_offload_gap(self, frame_idx: int, res: SimResult) -> None:
        if self.last_offload_frame >= 0:
            # the first offload has no predecessor: recording its warm-up
            # gap as an inter-offload interval would bias the median
            res.offload_interval.append(frame_idx - self.last_offload_frame)
        self.state.eta = frame_idx - max(self.last_offload_frame, 0)
        self.state.kappa = self.tracker.retention

    def _inf_delay_s(self, beta: int, n_d: int, n_r: int) -> float:
        """Inference-delay estimate; tolerates legacy 2-arg models."""
        if self.inf_delay is None:
            return 0.05
        try:
            return self.inf_delay(beta, n_d, n_r)
        except TypeError:
            return self.inf_delay(beta, n_d)

    def _prepare_offload(self, frame_idx: int, now: float,
                         res: SimResult) -> Dict:
        """Device side of an offload: policy decision, codec encode, and
        the device-computable Eq. (2) delay terms.  Marks the client busy
        (``inflight``) but does NOT run server inference — the caller
        finishes the job via :meth:`_finish_offload` (immediately for the
        single-client path, at wave time for the batched edge)."""
        decision = self.policy.decide(self, frame_idx)
        if self.ladder is not None:
            # retries after failures go out degraded: FULL regions
            # promoted to LOW (lowest motion first), quality dropped
            decision = self.ladder.degrade(decision, self.m)
        quality = decision["quality"]
        beta = decision["beta"]
        plan: Optional[RegionPlan] = decision.get("plan")
        if plan is None:
            plan = RegionPlan.from_mask(decision["mask"])
        mask = plan.low_mask()
        n_r = plan.n_reuse
        reuse_mask = plan.reuse_mask() if n_r > 0 else None

        frame = self.frames[frame_idx]
        if decision.get("blank") is not None:       # RoI masking baselines
            frame = frame.copy()
            rpx = self.part.region * self.analyzer.patch_px
            nRw = self.part.regions_w
            for j in np.nonzero(decision["blank"])[0]:
                ry, rx = divmod(int(j), nRw)
                frame[ry * rpx:(ry + 1) * rpx, rx * rpx:(rx + 1) * rpx] = 0.5
        t0 = time.perf_counter()
        enc, decoded = self.codec.encode(frame, mask, quality,
                                         reuse_mask=reuse_mask)
        res.overhead.setdefault("codec_wall", []).append(
            time.perf_counter() - t0)
        size = enc.payload_bytes * SIZE_SCALE
        n_d = int(mask.sum())
        beta_eff = beta if (n_d > 0 or n_r > 0) else 0

        tput, rtt = self.trace.at(now)
        if self.faults is not None:
            tput, rtt = self.faults.net(now, tput, rtt)
        job = {
            "frame": frame_idx, "submit": now, "decoded": decoded,
            "mask": mask, "n_d": n_d, "beta": beta_eff,
            "plan": plan, "n_r": n_r,
            "capture_beta": decision.get("capture_beta", 0),
            "tput": tput, "rtt": rtt, "size": size,
            "t_enc": self.delay_model.encode_delay(self.part, n_d, quality,
                                                   n_reuse=n_r),
            "t_up": size * 8.0 / tput,
            "t_dec": self.delay_model.decode_delay(self.part, n_d,
                                                   n_reuse=n_r),
            "t_inf": self._inf_delay_s(beta_eff, n_d, n_r),
            "done_at": float("inf"), "dets": None,
            "seq": self.offload_seq,
            # plan-header metadata (ships ahead of the payload; the
            # continuous scheduler's speculative-REUSE admission reads
            # it before the LOW/FULL windows land): the REUSE +
            # predicted-still-LOW fraction of the plan, and the motion
            # analyzer's confidence that the previous decoded frame
            # predicts the in-flight regions
            "spec_frac": (plan.n_reuse
                          + int(((plan.states == LOW)
                                 & (self.m * self.m_f < 1e-3)).sum()))
            / self.part.n_regions,
            "spec_conf": mo.prediction_confidence(self.m, plan.states,
                                                  m_f=self.m_f),
            # SLO-derived deadline: past it the client abandons the
            # offload and the LK tracker covers the gap
            "deadline": (now + self.robust.slo_s
                         if self.robust is not None else float("inf")),
        }
        self.offload_seq += 1
        if decision.get("degraded"):
            job["degraded"] = decision["degraded"]
            job["demoted"] = decision.get("demoted")
            self.rstats["degraded_offloads"] += 1
        self.inflight = job
        self.last_offload_frame = frame_idx
        return job

    def _finish_offload(self, job: Dict, dets: List[Dict],
                        queue_delay: float = 0.0,
                        t_dec: Optional[float] = None,
                        t_inf: Optional[float] = None) -> None:
        """Server side of an offload: attach detections and finalise the
        Eq. (2) end-to-end latency.  ``queue_delay`` (and wave-amortised
        ``t_dec``/``t_inf`` overrides) come from the edge scheduler.
        The fault schedule hooks in here: edge stalls stretch the
        service time, and a dropped response (or one arriving at a
        crashed replica) marks the job LOST — its result never comes
        back, only the client-side deadline reaps it."""
        t_dec = job["t_dec"] if t_dec is None else t_dec
        t_inf = job["t_inf"] if t_inf is None else t_inf
        arrival = job["submit"] + job["t_enc"] + job["t_up"]
        if self.faults is not None:
            t_inf = t_inf + self.faults.stall_extra(arrival + queue_delay)
        e2e = (job["t_enc"] + job["t_up"] + queue_delay + t_dec + t_inf
               + job["rtt"])
        job["dets"] = dets
        job["inf_f1"] = det.frame_f1(dets, self.gt_dets[job["frame"]])
        job["e2e"] = e2e
        job["done_at"] = job["submit"] + e2e
        job["parts"] = {"enc": job["t_enc"], "net": job["t_up"] + job["rtt"],
                        "dec": t_dec, "inf": t_inf, "queue": queue_delay}
        if self.faults is not None:
            if self.faults.response_dropped(job["seq"]) \
                    or self.faults.edge_down(arrival):
                job["lost"] = True
                job["done_at"] = float("inf")
            elif self.faults.response_duplicated(job["seq"]):
                job["dup"] = True

    def _start_offload(self, frame_idx: int, now: float, res: SimResult):
        """Single-client path: prepare, then hand to the scheduling
        plane (immediate dedicated inference for N=1)."""
        job = self._prepare_offload(frame_idx, now, res)
        self.scheduler.submit(job, now)

    def _complete_offload(self, res: SimResult, now_frame: int) -> Dict:
        fl = self.inflight
        self.inflight = None
        if fl.get("stale_epoch"):
            # the edge refused the splice (tiles from a dead replica):
            # drop the dead cache and bootstrap FULL next offload — no
            # backoff, the edge is healthy, just a new generation
            self.rstats["stale_epoch_nacks"] += 1
            if self.feature_cache is not None:
                self.feature_cache.invalidate()
            return fl
        if fl.get("rejected"):
            # edge admission shed: REJECTED response — track locally,
            # retry degraded after backoff
            self.rstats["rejected"] += 1
            if self.ladder is not None:
                self.ladder.on_failure(fl["done_at"])
                self.rstats["max_ladder_level"] = max(
                    self.rstats["max_ladder_level"], self.ladder.level)
            return fl
        if fl["frame"] <= self.cache_frame:
            # stale response: older than the rendered head — discarded,
            # never rendered
            self.rstats["stale_discards"] += 1
            return fl
        if fl.get("dup"):
            # the duplicate copy arrives later, behind the (advanced)
            # rendered head, and dies on the staleness guard above
            self.rstats["dup_discards"] += 1
        res.e2e_latency.append(fl["e2e"])
        res.inference_f1.append(fl["inf_f1"])
        res.delay_parts.append(fl["parts"])
        res.sizes.append(fl["size"])
        self.net_est.observe(fl["tput"], fl["rtt"], t=fl["done_at"])
        self.policy.observe_completion(fl["e2e"])
        if self.ladder is not None:
            self.ladder.on_success()

        if self.feature_cache is not None \
                and fl.get("demoted") is not None and len(fl["demoted"]):
            # ladder-demoted regions went out LOW: their freshly captured
            # tiles are low-fidelity stopgaps, so expire them from the
            # reuse-eligible set rather than letting one degraded offload
            # poison the next K splices
            self.feature_cache.expire(fl["demoted"])
        self.cache_dets = fl["dets"]
        self.cache_frame = fl["frame"]
        if self.policy.use_tracker:
            # reinit at the offloaded frame, catch up to the present
            self.tracker.reinit(self.frames[fl["frame"]], fl["dets"])
            for fi in range(fl["frame"] + 1, now_frame):
                self.tracker.step(self.frames[fi])
            self.tracker_frame = max(now_frame - 1, fl["frame"])
        return fl

    def _poll_inflight(self, now: float, now_frame: int,
                       res: SimResult) -> Optional[Dict]:
        """Deadline-bounded completion check: deliver a response due by
        ``now`` unless its deadline passed first — a LOST job (response
        never coming) or a LATE one (arriving past the deadline, behind
        the rendered head) is abandoned and the tracker covers the gap.
        Returns the job on delivery, else None."""
        job = self.inflight
        if job is None:
            return None
        deadline = job.get("deadline", float("inf"))
        if np.isfinite(job["done_at"]) \
                and job["done_at"] <= min(now, deadline):
            return self._complete_offload(res, now_frame)
        if now >= deadline:
            self._abandon_offload(job, min(now, job["deadline"]))
        return None

    def _abandon_offload(self, job: Dict, now: float) -> None:
        """Client-side timeout: give up on the offload, climb the
        degradation ladder, and back off before retrying."""
        self.inflight = None
        job["abandoned"] = True
        if job.get("lost"):
            self.rstats["lost_responses"] += 1
        else:
            self.rstats["timeouts"] += 1
            if np.isfinite(job["done_at"]):
                # the response does arrive eventually — after the
                # deadline — and is discarded, never rendered
                self.rstats["late_discards"] += 1
        if self.ladder is not None:
            self.ladder.on_failure(now)
            self.rstats["max_ladder_level"] = max(
                self.rstats["max_ladder_level"], self.ladder.level)

    def _edge_fault_tick(self, prev: float, now: float) -> None:
        """Single-client path owns its replica: crash-restarts apply
        through the shared scheduling plane (the multi-client engine
        drives the shared replica's restarts through the same
        ``edge_restart_tick`` helper)."""
        self.scheduler.fault_tick(prev, now)

    def _render_tick(self, frame_idx: int, res: SimResult) -> None:
        # rendering for this frame: exact cache hit, else tracker
        if frame_idx == self.cache_frame or not self.policy.use_tracker:
            rendered = self.cache_dets
        else:
            t0 = time.perf_counter()
            if self.tracker_frame < frame_idx:
                self.tracker.step(self.frames[frame_idx])
                self.tracker_frame = frame_idx
            rendered = self.tracker.boxes()
            self.rstats["tracker_frames"] += 1
            res.overhead.setdefault("tracker_wall", []).append(
                time.perf_counter() - t0)
        res.rendering_f1.append(det.frame_f1(rendered,
                                             self.gt_dets[frame_idx]))

    # ------------------------------------------------------------------
    def run(self, video_name: str = "video") -> SimResult:
        res = SimResult(policy=self.policy.name, video=video_name,
                        trace=getattr(self.trace, "name", "trace"))
        n = len(self.frames)
        prev = -1.0
        for fi in range(n):
            now = fi * self.dt

            self._edge_fault_tick(prev, now)
            self._motion_tick(fi, res)
            # completions due by now (deadline-bounded)
            self._poll_inflight(now, fi, res)
            # schedule next offload (back-to-back upon completion,
            # backed off after failures)
            if self._should_offload(fi):
                self._note_offload_gap(fi, res)
                self._start_offload(fi, now, res)
            self._render_tick(fi, res)
            prev = now
        # flush the final in-flight offload: its latency / delay parts /
        # inference F1 belong in the result even though the clip ended
        # (unless its deadline already reaped it)
        self._poll_inflight(float("inf"), n, res)
        return res
