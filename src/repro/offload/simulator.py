"""Event-driven end-to-end MVA offloading simulator (paper §VI).

Replays a synthetic video at a fixed FPS against a network trace.  The
device side (motion analysis, tracking, estimation, Algorithm 1) runs for
real; the server side runs the actual mixed-resolution ViTDet model
(trained on the synthetic domain) on the codec-decoded frame; delays
follow Eq. (2) with the inference term calibrated to the paper's measured
ViTDet-L numbers (281 ms @ full-res 1080p on the RTX 5090 — DESIGN.md).

Rendering accuracy is the F1 between what the user SEES (cache or tracker
output) and the ground truth of the CURRENT frame, where ground truth =
the full-resolution model output (exactly the paper's metric).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition as pt
from repro.core import vit_backbone as vb
from repro.core.partition import Partition, RegionPlan
from repro.models import registry
from repro.models.config import ModelConfig
from repro.offload import detection as det
from repro.offload import motion as mo
from repro.offload.codec import CodecDelayModel, MixedResCodec
from repro.offload.estimator import ThroughputEstimator
from repro.offload.optimizer import OffloadConfig, SystemState
from repro.offload.tracker import LKTracker
from repro.serve.request import FeatureCache

# payload scale: our 512x512 luma codec vs the paper's 1080p YUV frames
SIZE_SCALE = (1920 * 1080) / (512 * 512)


# ---------------------------------------------------------------------------
# server model wrapper — jitted bucketed inference cache: one compiled
# forward_det per (n_low bucket, beta), mirroring ServeEngine._get_prefill.
# Shapes are static within a bucket so per-frame calls never retrace.


class ServerModel:
    """Server-side detector with a per-(n_low bucket, n_reuse bucket,
    beta, capture point) compiled-fn cache.

    ``n_low`` is rounded DOWN to a bucket edge (partition.bucket_n_low)
    before it keys the cache, so a policy emitting varied masks compiles
    at most a bounded set of forwards instead of one per distinct region
    count; extra selected regions beyond the bucket stay full-res (the
    accuracy-safe direction).  ``n_reuse`` is NOT re-bucketed here —
    reuse plans must arrive bucket-exact (a reused region ships zero
    payload bytes, so codec and server must agree on the transmitted
    set; offload.optimizer.build_reuse_plan enforces it).

    ``backend`` selects the kernel backend for the backbone hot path
    (kernels.dispatch: "auto" | "pallas" | "xla").  ``jit=False`` runs
    the forward eagerly (op-by-op) — only useful to benchmark what the
    bucketed cache buys (benchmarks/bench_backbone.py quotes both).
    """

    def __init__(self, cfg: ModelConfig, params, top_k: int = 32,
                 score_thresh: float = 0.4,
                 backend: Optional[str] = "auto", jit: bool = True,
                 n_buckets: int = 4):
        self.cfg = cfg
        self.params = params
        self.part = vb.vit_partition(cfg)
        self.top_k = top_k
        self.score_thresh = score_thresh
        self.backend = backend
        self.jit = jit
        self.n_buckets = n_buckets
        self._fns: Dict[Tuple[int, int, int, int], Callable] = {}

    def bucket(self, n_low: int) -> int:
        return pt.bucket_n_low(n_low, self.part.n_regions, self.n_buckets)

    def _decode(self, outs):
        from repro.core import det_head as dh
        return dh.decode_detections(self.cfg, outs, self.top_k,
                                    self.score_thresh)

    def _get_fn(self, n_low: int, beta: int, n_reuse: int = 0,
                capture: int = 0) -> Callable:
        key = (n_low, n_reuse, beta, capture)
        if key not in self._fns:
            cfg, backend = self.cfg, self.backend

            def finish(outs):
                if capture:
                    outs, tiles = outs
                    return self._decode(outs), tiles
                return self._decode(outs)

            if n_low == 0 and n_reuse == 0:
                def fn(params, img):
                    return finish(vb.forward_det(cfg, params, img,
                                                 backend=backend,
                                                 capture_beta=capture))
            elif n_reuse == 0:
                def fn(params, img, full_ids, low_ids):
                    return finish(vb.forward_det(cfg, params, img, full_ids,
                                                 low_ids, beta,
                                                 backend=backend,
                                                 capture_beta=capture))
            else:
                def fn(params, img, full_ids, low_ids, reuse_ids,
                       reuse_tiles):
                    return finish(vb.forward_det(cfg, params, img, full_ids,
                                                 low_ids, beta,
                                                 backend=backend,
                                                 reuse_ids=reuse_ids,
                                                 reuse_tiles=reuse_tiles,
                                                 capture_beta=capture))
            self._fns[key] = jax.jit(fn) if self.jit else fn
        return self._fns[key]

    def infer(self, frame: np.ndarray, mask: Optional[np.ndarray] = None,
              beta: int = 0) -> List[Dict]:
        img = jnp.asarray(frame)[None]
        n_low = 0 if mask is None else self.bucket(int(mask.sum()))
        if n_low == 0:
            fn = self._get_fn(0, 0)
            boxes, scores, classes = fn(self.params, img)
        else:
            full_ids, low_ids = pt.mask_to_region_ids(mask, n_low)
            fn = self._get_fn(n_low, beta)
            boxes, scores, classes = fn(self.params, img,
                                        jnp.asarray(full_ids),
                                        jnp.asarray(low_ids))
        return det.detections_from_arrays(boxes[0], scores[0], classes[0],
                                          self.score_thresh)

    # ------------------------------------------------------------------
    def plan_buckets(self, plan: RegionPlan) -> Tuple[int, int]:
        """(bucketed n_low, bucket-exact n_reuse) for a plan."""
        n_reuse = plan.n_reuse
        assert pt.bucket_n_low(n_reuse, self.part.n_regions,
                               self.n_buckets) == n_reuse, \
            f"reuse plan not bucket-exact: n_reuse={n_reuse}"
        return self.bucket(plan.n_low), n_reuse

    def infer_plan(self, frame: np.ndarray, plan: RegionPlan,
                   beta: int = 0, cache: Optional[FeatureCache] = None,
                   frame_idx: int = -1,
                   capture_beta: int = 0) -> List[Dict]:
        """Stateful three-state inference for one client frame.

        Splices the cached feature tiles of the plan's REUSE regions in
        at the restoration point, and (when ``cache`` is given) refreshes
        the cache with this forward's restoration-point tiles — captured
        at ``beta`` for mixed forwards, at ``capture_beta`` for full-res
        ones — so the NEXT offload can reuse them.
        """
        img = jnp.asarray(frame)[None]
        n_low, n_reuse = self.plan_buckets(plan)
        assert n_reuse == 0 or (cache is not None and beta >= 1), \
            "REUSE regions need a feature cache and a restoration point"
        cap = 0
        if cache is not None:
            cap = beta if beta >= 1 else capture_beta
        if n_low == 0 and n_reuse == 0:
            fn = self._get_fn(0, 0, 0, cap)
            out = fn(self.params, img)
            reuse_ids = np.zeros((0,), np.int32)
        else:
            full_ids, low_ids, reuse_ids = pt.plan_to_region_ids(
                plan.states, n_low, n_reuse)
            fn = self._get_fn(n_low, beta, n_reuse, cap)
            if n_reuse == 0:
                out = fn(self.params, img, jnp.asarray(full_ids),
                         jnp.asarray(low_ids))
            else:
                tiles_in = jnp.asarray(cache.gather(reuse_ids))[None]
                out = fn(self.params, img, jnp.asarray(full_ids),
                         jnp.asarray(low_ids), jnp.asarray(reuse_ids),
                         tiles_in)
        if cap:
            (boxes, scores, classes), tiles = out
            cache.update(np.asarray(tiles[0]), reuse_ids, cap, frame_idx)
        else:
            boxes, scores, classes = out
        return det.detections_from_arrays(boxes[0], scores[0], classes[0],
                                          self.score_thresh)


# ---------------------------------------------------------------------------
# policies


class Policy:
    """Decides the offload configuration for each frame to be offloaded.

    Returns dict(mask (n_regions,), quality, beta, use_tracker: bool).
    Temporal-reuse policies additionally return a three-state ``plan``
    (partition.RegionPlan, bucket-exact in n_reuse) and may return
    ``capture_beta`` (the restoration point full-res offloads capture
    feature tiles at); they must set ``reuse_k`` (the staleness bound K)
    so the Simulation provisions a per-client FeatureCache.
    """
    name = "policy"
    use_tracker = True
    reuse_k = 0                 # K > 0 enables the per-client FeatureCache

    def decide(self, sim: "Simulation", frame_idx: int) -> Dict:
        raise NotImplementedError

    def observe_completion(self, e2e_latency: float) -> None:
        pass


@dataclass
class SimResult:
    policy: str
    video: str
    trace: str
    rendering_f1: List[float] = field(default_factory=list)
    inference_f1: List[float] = field(default_factory=list)
    e2e_latency: List[float] = field(default_factory=list)
    offload_interval: List[int] = field(default_factory=list)
    delay_parts: List[Dict] = field(default_factory=list)
    overhead: Dict[str, List[float]] = field(default_factory=dict)
    sizes: List[float] = field(default_factory=list)

    def summary(self) -> Dict:
        def med(x):
            return float(np.median(x)) if len(x) else float("nan")
        return {
            "policy": self.policy, "video": self.video, "trace": self.trace,
            "median_rendering_f1": med(self.rendering_f1),
            "mean_rendering_f1": (float(np.mean(self.rendering_f1))
                                  if self.rendering_f1 else float("nan")),
            "mean_inference_f1": (float(np.mean(self.inference_f1))
                                  if self.inference_f1 else float("nan")),
            "median_e2e_latency": med(self.e2e_latency),
            "median_interval": med(self.offload_interval),
            "median_net_delay": med([d["net"] for d in self.delay_parts]),
            "median_inf_delay": med([d["inf"] for d in self.delay_parts]),
            "median_codec_delay": med([d["enc"] + d["dec"]
                                       for d in self.delay_parts]),
            "median_queue_delay": med([d.get("queue", 0.0)
                                       for d in self.delay_parts]),
        }


class Simulation:
    """One (video, trace, policy) run."""

    def __init__(self, frames: np.ndarray, gt_dets: List[List[Dict]],
                 trace, policy: Policy, server: ServerModel,
                 part: Partition, patch_px: int, fps: int = 10,
                 delay_model: Optional[CodecDelayModel] = None,
                 inf_delay=None):
        self.frames = frames
        self.gt_dets = gt_dets            # full-res model outputs per frame
        self.trace = trace
        self.policy = policy
        self.server = server
        self.part = part
        self.fps = fps
        self.dt = 1.0 / fps
        self.codec = MixedResCodec(part, patch_px, part.downsample)
        self.delay_model = delay_model or CodecDelayModel()
        self.inf_delay = inf_delay        # InferenceDelayModel
        self.analyzer = mo.RegionMotionAnalyzer(part, patch_px)
        self.tracker = LKTracker()
        self.net_est = ThroughputEstimator()
        self.state = SystemState()
        # temporal-reuse session state: one FeatureCache per client
        # stream, provisioned only for reuse-capable policies (reuse_k =
        # the staleness bound K)
        self.feature_cache: Optional[FeatureCache] = (
            FeatureCache(part.n_regions, max_age=policy.reuse_k)
            if policy.reuse_k > 0 else None)

        # runtime state
        self.cache_dets: List[Dict] = []
        self.cache_frame = -1
        self.tracker_frame = -1           # frame the tracker state is at
        self.inflight: Optional[Dict] = None
        self.last_offload_frame = -10 ** 9
        self.m = np.zeros((part.n_regions,), np.float32)
        self.m_f = 0.0

    # ------------------------------------------------------------------
    # per-frame steps.  Single-client ``run`` below and the multi-client
    # engine (serve/edge.py) drive the SAME methods; the engine replaces
    # the synchronous server call in _start_offload with batched waves.

    def rho(self) -> np.ndarray:
        return mo.region_density(self.tracker.boxes(), self.part,
                                 self.analyzer.patch_px)

    def _motion_tick(self, frame_idx: int, res: SimResult) -> None:
        t0 = time.perf_counter()
        self.m, self.m_f = self.analyzer.update(self.frames[frame_idx])
        res.overhead.setdefault("motion_wall", []).append(
            time.perf_counter() - t0)

    def _should_offload(self, frame_idx: int) -> bool:
        """Back-to-back: a new offload starts as soon as none is in
        flight (frame 0 is skipped — the motion model needs a delta)."""
        return self.inflight is None and frame_idx > 0

    def _note_offload_gap(self, frame_idx: int, res: SimResult) -> None:
        if self.last_offload_frame >= 0:
            # the first offload has no predecessor: recording its warm-up
            # gap as an inter-offload interval would bias the median
            res.offload_interval.append(frame_idx - self.last_offload_frame)
        self.state.eta = frame_idx - max(self.last_offload_frame, 0)
        self.state.kappa = self.tracker.retention

    def _inf_delay_s(self, beta: int, n_d: int, n_r: int) -> float:
        """Inference-delay estimate; tolerates legacy 2-arg models."""
        if self.inf_delay is None:
            return 0.05
        try:
            return self.inf_delay(beta, n_d, n_r)
        except TypeError:
            return self.inf_delay(beta, n_d)

    def _prepare_offload(self, frame_idx: int, now: float,
                         res: SimResult) -> Dict:
        """Device side of an offload: policy decision, codec encode, and
        the device-computable Eq. (2) delay terms.  Marks the client busy
        (``inflight``) but does NOT run server inference — the caller
        finishes the job via :meth:`_finish_offload` (immediately for the
        single-client path, at wave time for the batched edge)."""
        decision = self.policy.decide(self, frame_idx)
        quality = decision["quality"]
        beta = decision["beta"]
        plan: Optional[RegionPlan] = decision.get("plan")
        if plan is None:
            plan = RegionPlan.from_mask(decision["mask"])
        mask = plan.low_mask()
        n_r = plan.n_reuse
        reuse_mask = plan.reuse_mask() if n_r > 0 else None

        frame = self.frames[frame_idx]
        if decision.get("blank") is not None:       # RoI masking baselines
            frame = frame.copy()
            rpx = self.part.region * self.analyzer.patch_px
            nRw = self.part.regions_w
            for j in np.nonzero(decision["blank"])[0]:
                ry, rx = divmod(int(j), nRw)
                frame[ry * rpx:(ry + 1) * rpx, rx * rpx:(rx + 1) * rpx] = 0.5
        t0 = time.perf_counter()
        enc, decoded = self.codec.encode(frame, mask, quality,
                                         reuse_mask=reuse_mask)
        res.overhead.setdefault("codec_wall", []).append(
            time.perf_counter() - t0)
        size = enc.payload_bytes * SIZE_SCALE
        n_d = int(mask.sum())
        beta_eff = beta if (n_d > 0 or n_r > 0) else 0

        tput, rtt = self.trace.at(now)
        job = {
            "frame": frame_idx, "submit": now, "decoded": decoded,
            "mask": mask, "n_d": n_d, "beta": beta_eff,
            "plan": plan, "n_r": n_r,
            "capture_beta": decision.get("capture_beta", 0),
            "tput": tput, "rtt": rtt, "size": size,
            "t_enc": self.delay_model.encode_delay(self.part, n_d, quality,
                                                   n_reuse=n_r),
            "t_up": size * 8.0 / tput,
            "t_dec": self.delay_model.decode_delay(self.part, n_d,
                                                   n_reuse=n_r),
            "t_inf": self._inf_delay_s(beta_eff, n_d, n_r),
            "done_at": float("inf"), "dets": None,
        }
        self.inflight = job
        self.last_offload_frame = frame_idx
        return job

    def _finish_offload(self, job: Dict, dets: List[Dict],
                        queue_delay: float = 0.0,
                        t_dec: Optional[float] = None,
                        t_inf: Optional[float] = None) -> None:
        """Server side of an offload: attach detections and finalise the
        Eq. (2) end-to-end latency.  ``queue_delay`` (and wave-amortised
        ``t_dec``/``t_inf`` overrides) come from the edge scheduler."""
        t_dec = job["t_dec"] if t_dec is None else t_dec
        t_inf = job["t_inf"] if t_inf is None else t_inf
        e2e = (job["t_enc"] + job["t_up"] + queue_delay + t_dec + t_inf
               + job["rtt"])
        job["dets"] = dets
        job["inf_f1"] = det.frame_f1(dets, self.gt_dets[job["frame"]])
        job["e2e"] = e2e
        job["done_at"] = job["submit"] + e2e
        job["parts"] = {"enc": job["t_enc"], "net": job["t_up"] + job["rtt"],
                        "dec": t_dec, "inf": t_inf, "queue": queue_delay}

    def _start_offload(self, frame_idx: int, now: float, res: SimResult):
        """Single-client path: prepare + immediate (dedicated) server
        inference on the decoded mixed frame."""
        job = self._prepare_offload(frame_idx, now, res)
        if self.feature_cache is not None:
            dets = self.server.infer_plan(job["decoded"], job["plan"],
                                          job["beta"],
                                          cache=self.feature_cache,
                                          frame_idx=job["frame"],
                                          capture_beta=job["capture_beta"])
        else:
            dets = self.server.infer(job["decoded"],
                                     job["mask"] if job["n_d"] > 0 else None,
                                     job["beta"])
        self._finish_offload(job, dets)

    def _complete_offload(self, res: SimResult, now_frame: int) -> Dict:
        fl = self.inflight
        self.inflight = None
        res.e2e_latency.append(fl["e2e"])
        res.inference_f1.append(fl["inf_f1"])
        res.delay_parts.append(fl["parts"])
        res.sizes.append(fl["size"])
        self.net_est.observe(fl["tput"], fl["rtt"])
        self.policy.observe_completion(fl["e2e"])

        self.cache_dets = fl["dets"]
        self.cache_frame = fl["frame"]
        if self.policy.use_tracker:
            # reinit at the offloaded frame, catch up to the present
            self.tracker.reinit(self.frames[fl["frame"]], fl["dets"])
            for fi in range(fl["frame"] + 1, now_frame):
                self.tracker.step(self.frames[fi])
            self.tracker_frame = max(now_frame - 1, fl["frame"])
        return fl

    def _render_tick(self, frame_idx: int, res: SimResult) -> None:
        # rendering for this frame: exact cache hit, else tracker
        if frame_idx == self.cache_frame or not self.policy.use_tracker:
            rendered = self.cache_dets
        else:
            t0 = time.perf_counter()
            if self.tracker_frame < frame_idx:
                self.tracker.step(self.frames[frame_idx])
                self.tracker_frame = frame_idx
            rendered = self.tracker.boxes()
            res.overhead.setdefault("tracker_wall", []).append(
                time.perf_counter() - t0)
        res.rendering_f1.append(det.frame_f1(rendered,
                                             self.gt_dets[frame_idx]))

    # ------------------------------------------------------------------
    def run(self, video_name: str = "video") -> SimResult:
        res = SimResult(policy=self.policy.name, video=video_name,
                        trace=getattr(self.trace, "name", "trace"))
        n = len(self.frames)
        for fi in range(n):
            now = fi * self.dt

            self._motion_tick(fi, res)
            # completions due by now
            if self.inflight and self.inflight["done_at"] <= now:
                self._complete_offload(res, fi)
            # schedule next offload (back-to-back upon completion)
            if self._should_offload(fi):
                self._note_offload_gap(fi, res)
                self._start_offload(fi, now, res)
            self._render_tick(fi, res)
        # flush the final in-flight offload: its latency / delay parts /
        # inference F1 belong in the result even though the clip ended
        if self.inflight is not None:
            self._complete_offload(res, n)
        return res
