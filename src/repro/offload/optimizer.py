"""Offload Configuration Selection (paper Algorithm 1) + temporal reuse
planning.

For each frame to offload: classify regions, estimate (T-hat, A-hat) for
every candidate configuration c = (tau_d, lambda, beta), take the Pareto
frontier, and select by system state (min-latency when stale, knee point
otherwise).

:func:`build_reuse_plan` then lifts the chosen binary mask into a
three-state :class:`~repro.core.partition.RegionPlan`: regions the
RegionMotionAnalyzer reports motionless AND whose cached feature tile is
still fresh (FeatureCache.eligible — same restoration point, reused
fewer than K consecutive offloads) are promoted to REUSE and transmit
nothing.  The emitted plan is bucket-EXACT in ``n_reuse`` so the codec
and the server agree on the transmitted region set.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partition import (FULL, LOW, REUSE, Partition, RegionPlan,
                                  bucket_n_low, bucket_set)
from repro.offload import motion as mo
from repro.offload.estimator import (InferenceDelayModel, ThroughputEstimator,
                                     feature_vector)


@dataclass(frozen=True)
class OffloadConfig:
    tau_d: int          # 0: none, 1: CMRs, 2: CMRs+SBRs
    quality: int        # lambda: JPEG quality 70..100 step 5
    beta: int           # restoration point 0..N

    def astuple(self):
        return (self.tau_d, self.quality, self.beta)


def candidate_configs(qualities: Sequence[int] = tuple(range(70, 101, 5)),
                      betas: Sequence[int] = (0, 1, 2, 3, 4)
                      ) -> List[OffloadConfig]:
    """The paper's config space: 7 qualities x (tau_d, beta) combos.
    tau_d = 0 fixes beta = 0 (no downsampled regions to restore)."""
    out = [OffloadConfig(0, q, 0) for q in qualities]
    for tau in (1, 2):
        for q in qualities:
            for b in betas:
                if b == 0:
                    continue          # beta=0 with downsampling = upsample
                out.append(OffloadConfig(tau, q, b))
    return out


@dataclass
class SystemState:
    eta: int = 0                 # frames since last offload
    kappa: float = 1.0           # tracking retention ratio
    delta_eta: int = 30
    delta_kappa: float = 0.7


@dataclass
class DelayModels:
    enc: "object"                # CodecDelayModel
    inf: InferenceDelayModel
    net: ThroughputEstimator


class OffloadOptimizer:
    def __init__(self, part: Partition, size_est, acc_est,
                 delays: DelayModels, configs=None,
                 delta_m: float = 0.001, delta_rho: float = 0.0,
                 n_buckets: int = 4, a_floor: float = 0.25):
        self.part = part
        self.size_est = size_est
        self.acc_est = acc_est
        self.delays = delays
        self.configs = configs or candidate_configs()
        self.delta_m = delta_m
        self.delta_rho = delta_rho
        self.n_buckets = n_buckets
        # accuracy floor (fraction of the frontier's best A-hat): configs
        # predicted to collapse accuracy are never selected while a
        # viable alternative exists.  Guards the degenerate two-point
        # frontier a fully-static scene produces (everything classifies
        # SBR -> the config space is "downsample nothing" vs "downsample
        # everything", and the <=2-point knee fallback would pick the
        # min-latency point even at A-hat ~ 0).
        self.a_floor = a_floor

    # ------------------------------------------------------------------
    def evaluate(self, m: np.ndarray, m_f: float, rho: np.ndarray
                 ) -> List[Dict]:
        """Lines 1-11: estimate (T, A) for every candidate config.

        Both MLPs run ONE batched predict over the whole config space —
        per-config dispatch costs ~50x more on the device CPU (this is
        how the prototype hits the paper's ~9 ms estimator budget)."""
        phi = mo.classify_regions(m, rho, self.delta_m, self.delta_rho)
        mu_rho = float(rho.mean())
        sigma_rho = float(rho.std())
        feats, metas = [], []
        for c in self.configs:
            mask = mo.downsample_mask(phi, c.tau_d)
            n_d_raw = int(mask.sum())
            n_d = bucket_n_low(n_d_raw, self.part.n_regions, self.n_buckets)
            m_d = float((mask * m).sum())
            feats.append(feature_vector(c.tau_d, n_d, m_d, m_f, c.quality,
                                        mu_rho, sigma_rho, c.beta))
            metas.append((c, n_d, mask))
        X = np.stack(feats)
        s_hats = np.maximum(self.size_est.predict(X), 256.0)
        a_hats = np.clip(self.acc_est.predict(X), 0.0, 1.0)
        out = []
        for (c, n_d, mask), s_hat, a_hat in zip(metas, s_hats, a_hats):
            t_hat = (self.delays.enc.encode_delay(self.part, n_d, c.quality)
                     + float(s_hat) * 8.0 / self.delays.net.throughput
                     + self.delays.enc.decode_delay(self.part, n_d)
                     + self.delays.inf(c.beta if n_d > 0 else 0, n_d)
                     + self.delays.net.rtt)
            out.append({"config": c, "T": t_hat, "A": float(a_hat),
                        "N_d": n_d, "mask": mask, "phi": phi})
        return out

    # ------------------------------------------------------------------
    def select(self, m: np.ndarray, m_f: float, rho: np.ndarray,
               state: SystemState) -> Dict:
        """Algorithm 1: returns the chosen candidate record."""
        Z = self.evaluate(m, m_f, rho)
        front = pareto_frontier(Z)
        floor = self.a_floor * max(z["A"] for z in front)
        viable = [z for z in front if z["A"] >= floor]
        if viable:
            front = viable
        if len(front) == 1:
            return front[0]
        if state.kappa < state.delta_kappa or state.eta > state.delta_eta:
            return min(front, key=lambda z: z["T"])
        return knee_point(front)


# ---------------------------------------------------------------------------
# temporal reuse planning


def build_reuse_plan(part: Partition, mask: np.ndarray, m: np.ndarray,
                     eligible: np.ndarray, delta_m: float = 1e-3,
                     n_buckets: int = 4, min_transmit: int = 1
                     ) -> RegionPlan:
    """Lift a binary downsample mask into a three-state RegionPlan.

    ``m``: per-region motion from the RegionMotionAnalyzer; ``eligible``:
    FeatureCache.eligible(beta) — regions whose cached tile matches the
    chosen restoration point and is within the staleness bound K.  A
    region becomes REUSE when it is both motionless (``m < delta_m``) and
    eligible; the stillest regions win the bucket slots.

    The returned plan is bucket-exact: exactly ``bucket_n_low(...)``
    regions are REUSE (REUSE ships zero bytes, so codec and server must
    agree on the set — rounding down at the server would read pixels
    that were never transmitted).  At least ``min_transmit`` regions stay
    transmitted so the packed sequence is never empty.
    """
    mask = np.asarray(mask).reshape(-1)
    states = np.where(mask != 0, LOW, FULL).astype(np.int8)
    cand = np.asarray(eligible, bool) & (np.asarray(m) < delta_m)
    cand_ids = np.nonzero(cand)[0]
    limit = min(len(cand_ids), part.n_regions - min_transmit)
    n_reuse = bucket_n_low(limit, part.n_regions, n_buckets)
    n_reuse = min(n_reuse, limit)
    if n_reuse > 0:
        order = cand_ids[np.argsort(np.asarray(m)[cand_ids],
                                    kind="stable")]
        states[order[:n_reuse]] = REUSE
    return RegionPlan(states)


def pareto_frontier(Z: List[Dict]) -> List[Dict]:
    """Minimize T, maximize A."""
    zs = sorted(Z, key=lambda z: (z["T"], -z["A"]))
    front = []
    best_a = -np.inf
    for z in zs:
        if z["A"] > best_a + 1e-12:
            front.append(z)
            best_a = z["A"]
    return front


def knee_point(front: List[Dict]) -> Dict:
    """Max distance to the chord between the frontier's extreme points
    (the trade-off-utility knee the paper cites [37]).

    A frontier of <= 2 points has no interior knee.  Fall back to the
    MAX-ACCURACY point: select() only reaches the knee when the system
    is healthy (stale states already take the min-latency branch), and
    on a degenerate two-point frontier — the static-scene case where
    the config space collapses to "downsample nothing" vs "downsample
    everything" — the min-latency fallback silently trades the entire
    accuracy gap for latency the healthy state doesn't need.  The
    a_floor guard only catches this when the estimator's A-hat for the
    aggressive point is honest; this fallback stays safe when it is
    not.
    """
    if len(front) <= 2:
        return front[-1]
    pts = np.array([[z["T"], z["A"]] for z in front])
    # normalise both objectives to [0, 1]
    lo, hi = pts.min(0), pts.max(0)
    span = np.maximum(hi - lo, 1e-12)
    n = (pts - lo) / span
    a, b = n[0], n[-1]
    ab = b - a
    ab /= np.linalg.norm(ab) + 1e-12
    d = (n - a) - np.outer((n - a) @ ab, ab)
    idx = int(np.argmax(np.linalg.norm(d, axis=1)))
    return front[idx]
