"""Content-aware Performance Estimator (paper §IV-D, Table II).

Three estimator families for compressed size S(c) and inference accuracy
A(c):
  * MLPEstimator      — the paper's choice: 3-layer MLP (128, 64, 1)
                        trained with our JAX Adam on offline profiling data
  * LinearEstimator   — least-squares baseline on the same features
  * OfflineMean       — static mean of the profiling data

plus the delay models of Eq. (2): profiled T_enc(N_d, lambda), mean
T_dec, per-beta linear inference-delay models LM^inf_beta(N_d), and the
short-window throughput/RTT estimator.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam

# feature vector: (tau_d, N_d, m_d, m_f, lambda, mu_rho, sigma_rho, beta)
N_FEATURES = 8


def feature_vector(tau_d: int, n_d: int, m_d: float, m_f: float,
                   quality: int, mu_rho: float, sigma_rho: float,
                   beta: int) -> np.ndarray:
    return np.array([tau_d, n_d, m_d, m_f, quality / 100.0,
                     mu_rho, sigma_rho, beta], np.float32)


# ---------------------------------------------------------------------------
# MLP (the paper's estimator; Optuna-tuned architecture 128-64-1)


def _init_mlp(key, sizes=(N_FEATURES, 128, 64, 1)):
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (sizes[i], sizes[i + 1]),
                              jnp.float32) / np.sqrt(sizes[i])
        params.append({"w": w, "b": jnp.zeros((sizes[i + 1],))})
    return params


def _mlp_fwd(params, x):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x[..., 0]


@jax.jit
def _mlp_loss(params, x, y):
    pred = _mlp_fwd(params, x)
    return jnp.mean(jnp.square(pred - y))


_mlp_fwd_jit = jax.jit(_mlp_fwd)


class MLPEstimator:
    """Predicts a scalar target from config+content features."""

    def __init__(self, seed: int = 0):
        self.params = _init_mlp(jax.random.PRNGKey(seed))
        self.x_mean = np.zeros(N_FEATURES, np.float32)
        self.x_std = np.ones(N_FEATURES, np.float32)
        self.y_mean, self.y_std = 0.0, 1.0

    def fit(self, X: np.ndarray, y: np.ndarray, steps: int = 2000,
            lr: float = 3e-3, batch: int = 256, seed: int = 0) -> None:
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        self.x_mean = X.mean(0)
        self.x_std = X.std(0) + 1e-6
        self.y_mean, self.y_std = float(y.mean()), float(y.std() + 1e-6)
        Xn = (X - self.x_mean) / self.x_std
        yn = (y - self.y_mean) / self.y_std

        state = adam.init_adam(self.params)
        rng = np.random.default_rng(seed)
        grad_fn = jax.jit(jax.value_and_grad(_mlp_loss))
        for s in range(steps):
            idx = rng.integers(0, len(Xn), min(batch, len(Xn)))
            loss, g = grad_fn(self.params, jnp.asarray(Xn[idx]),
                              jnp.asarray(yn[idx]))
            self.params, state, _ = adam.adam_update(
                g, state, self.params, lr=lr * (0.1 ** (s / steps)))

    def predict(self, X: np.ndarray) -> np.ndarray:
        Xn = (np.asarray(X, np.float32) - self.x_mean) / self.x_std
        out = _mlp_fwd_jit(self.params, jnp.asarray(Xn))
        return np.asarray(out) * self.y_std + self.y_mean


class LinearEstimator:
    """Closed-form least squares on the same features (Table II row 1)."""

    def __init__(self):
        self.w: Optional[np.ndarray] = None

    def fit(self, X, y, **kw):
        X = np.asarray(X, np.float64)
        A = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        self.w, *_ = np.linalg.lstsq(A, np.asarray(y, np.float64),
                                     rcond=None)

    def predict(self, X):
        X = np.asarray(X, np.float64)
        A = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        return A @ self.w


class OfflineMean:
    """Static profiling mean (Table II row 2)."""

    def __init__(self):
        self.mean = 0.0

    def fit(self, X, y, **kw):
        self.mean = float(np.mean(y))

    def predict(self, X):
        return np.full((len(X),), self.mean)


def regression_metrics(y_true, y_pred) -> Dict[str, float]:
    y_true = np.asarray(y_true, np.float64)
    y_pred = np.asarray(y_pred, np.float64)
    err = y_pred - y_true
    mae = float(np.mean(np.abs(err)))
    rmse = float(np.sqrt(np.mean(err ** 2)))
    # floor the denominator at 5% of the target scale: near-zero targets
    # (empty-frame F1) otherwise make MAPE meaningless
    scale = max(float(np.abs(y_true).mean()), 1e-9)
    denom = np.maximum(np.abs(y_true), 0.05 * scale)
    mape = float(np.mean(np.abs(err) / denom) * 100.0)
    ss_res = float(np.sum(err ** 2))
    ss_tot = float(np.sum((y_true - y_true.mean()) ** 2) + 1e-12)
    return {"MAE": mae, "RMSE": rmse, "MAPE": mape,
            "R2": 1.0 - ss_res / ss_tot}


# ---------------------------------------------------------------------------
# delay models (Eq. 2)


@dataclass
class InferenceDelayModel:
    """LM^inf_beta(N_d, N_r): per-beta linear models
    ``a_beta * N_d + r_beta * N_r + b_beta``.

    Parameterised from the ViTDet FLOP model calibrated to the paper's
    measured full-res delay (fit_from_flops) or from profiling samples.
    ``N_r`` is the number of temporally REUSED regions (zero tokens
    before the restoration point); a model fitted without the reuse term
    (2-tuple coefs) treats it as free — callers that never reuse are
    unaffected."""
    coefs: Dict[int, Tuple[float, ...]] = field(default_factory=dict)

    def __call__(self, beta: int, n_d: int, n_reuse: int = 0) -> float:
        c = self.coefs[int(beta)]
        if len(c) == 2:
            a, b = c
            return a * n_d + b
        a, r, b = c
        return a * n_d + r * n_reuse + b

    @classmethod
    def fit_from_flops(cls, flops_fn: Callable[..., float],
                       n_regions: int, betas: Sequence[int],
                       full_res_delay_s: float) -> "InferenceDelayModel":
        """flops_fn(n_low, beta[, n_reuse]) -> FLOPs; anchored so that
        n_low=0 costs ``full_res_delay_s`` (the paper's 1080p ViTDet-L
        measurement).  A 3-argument flops_fn fits the reuse plane; a
        2-argument one falls back to the legacy N_d-only line."""
        try:
            flops_fn(0, int(betas[0]), 0)
            with_reuse = True
        except TypeError:
            with_reuse = False
        f_full = flops_fn(0, 0, 0) if with_reuse else flops_fn(0, 0)
        scale = full_res_delay_s / f_full
        coefs: Dict[int, Tuple[float, ...]] = {}
        for b in betas:
            if with_reuse and b >= 1:
                feats, ys = [], []
                for n in range(0, n_regions + 1):
                    for r in range(0, n_regions + 1 - n):
                        feats.append((n, r, 1.0))
                        ys.append(flops_fn(n, b, r) * scale)
                sol, *_ = np.linalg.lstsq(np.array(feats, np.float64),
                                          np.array(ys, np.float64),
                                          rcond=None)
                coefs[int(b)] = tuple(float(v) for v in sol)
            else:
                xs = np.arange(0, n_regions + 1)
                ys = np.array([(flops_fn(int(n), b, 0) if with_reuse
                                else flops_fn(int(n), b)) * scale
                               for n in xs])
                a, c = np.polyfit(xs, ys, 1)
                coefs[int(b)] = (float(a), float(c))
        return cls(coefs)


@dataclass
class ThroughputEstimator:
    """Short-window mean of recent observations (paper: last two).

    Hardened for the failure model: ``min_tput_bps`` floors the estimate
    (a blackout-era near-zero sample would otherwise drive Eq. (2)'s
    transmission-delay terms toward infinity and wedge config
    selection), and observations older than ``max_age_s`` relative to
    the newest are expired rather than averaged — after a blackout the
    first fresh sample speaks alone instead of being blended with the
    pre-blackout world.  Callers that pass no ``t`` keep the legacy
    pure-window behaviour (each observation ages the horizon by 1 s).
    """
    window: int = 2
    min_tput_bps: float = 5e4
    max_age_s: float = 30.0
    obs_tput: List[float] = field(default_factory=list)
    obs_rtt: List[float] = field(default_factory=list)
    obs_t: List[float] = field(default_factory=list)

    def observe(self, tput_bps: float, rtt_s: float,
                t: Optional[float] = None) -> None:
        if t is None:
            t = (self.obs_t[-1] + 1.0) if self.obs_t else 0.0
        # expire stale observations BEFORE the window trim so a lone
        # fresh post-gap sample is not averaged with a pre-gap one
        while self.obs_t and t - self.obs_t[0] > self.max_age_s:
            del self.obs_tput[0], self.obs_rtt[0], self.obs_t[0]
        self.obs_tput.append(tput_bps)
        self.obs_rtt.append(rtt_s)
        self.obs_t.append(t)
        # only the last ``window`` observations are ever read — trim so
        # long-running clients don't grow the lists without bound
        if len(self.obs_tput) > self.window:
            del self.obs_tput[:-self.window]
            del self.obs_rtt[:-self.window]
            del self.obs_t[:-self.window]

    @property
    def throughput(self) -> float:
        if not self.obs_tput:
            return 10e6
        return max(self.min_tput_bps,
                   float(np.mean(self.obs_tput[-self.window:])))

    @property
    def rtt(self) -> float:
        if not self.obs_rtt:
            return 0.04
        return float(np.mean(self.obs_rtt[-self.window:]))
