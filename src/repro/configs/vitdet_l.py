"""vitdet-l [vit] — the paper's own model (Li et al., ECCV 2022).

ViT-L backbone: 24 blocks, d_model=1024, 16 heads, d_ff=4096, patch 16.
N=4 subsets of M=6 blocks; last block of each subset uses global
attention, the rest window attention.  The paper fine-tunes with window
9x9; we use 8 (MXU-aligned) as recorded in DESIGN.md.

1024x1024 input -> 64x64 patch grid; window 8 and downsample 2 give
decision regions of r = w*d = 16x16 patches (the paper's 18x18 with
w=9), i.e. a 4x4 decision grid.
"""
from repro.models.config import (MixedResConfig, ModelConfig, ViTConfig,
                                 reduced)

CONFIG = ModelConfig(
    name="vitdet-l",
    family="vit",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=1,                 # unused for the vision task
    norm="layernorm",
    activation="gelu",
    attention_bias=True,
    max_seq_len=4096,             # 64x64 patch tokens
    vit=ViTConfig(img_size=(1024, 1024), patch_size=16, window_size=8,
                  n_subsets=4, out_channels=256, n_classes=80),
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)

# System-simulation variant: trainable on CPU in minutes, same 4x4
# decision-region grid as the full model (256px / 16 = 16x16 patches,
# window 2, d 2 -> region r=4 patches -> 4x4 = 16 regions), so Algorithm 1
# operates on an identical decision space while delays are modelled from
# the FULL ViTDet-L FLOP curve (see offload/simulator.py).
SIM = CONFIG.replace(
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vit=CONFIG.vit.__class__(img_size=(256, 256), patch_size=16,
                             window_size=2, n_subsets=4, out_channels=32,
                             n_classes=8),
    mixed_res=CONFIG.mixed_res.__class__(enabled=True, window=2,
                                         downsample=2, n_subsets=4),
)
