"""mamba2-370m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L d_model=1024, attention-free, vocab=50280, ssm_state=128.
Official family hyperparameters: expand=2 (d_inner=2048), headdim=64
(=> 32 SSD heads), 1 B/C group, conv kernel 4.
"""
from repro.models.config import MixedResConfig, ModelConfig, SSMConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,              # SSD heads (d_inner / head_dim)
    n_kv_heads=32,
    head_dim=64,
    d_ff=0,                  # attention-free: no transformer MLP
    vocab_size=50280,
    tied_embeddings=True,
    max_seq_len=1048576,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
    # paper technique: span pooling is linear-gain only for SSM (DESIGN.md)
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
    subquadratic=True,
)

REDUCED = reduced(CONFIG)
