"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
(arXiv:2405.04434).

60L d_model=5120 128H (kv=128 via MLA) d_ff(expert)=1536 vocab=102400.
First layer uses a dense FFN (d_ff=12288) per the release.
"""
from repro.models.config import (MLAConfig, MixedResConfig, MoEConfig,
                                 ModelConfig, reduced)

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,
    vocab_size=102400,
    rope_theta=10000.0,
    max_seq_len=131072,
    moe=MoEConfig(n_experts=160, top_k=6, n_shared_experts=2,
                  d_ff_expert=1536, first_dense_layers=1, d_ff_dense=12288,
                  capacity_factor=1.25),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)
