"""llava-next-mistral-7b [vlm] — anyres tiling
(hf:llava-hf/llava-v1.6-mistral-7b-hf).

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (anyres: base 576 + 4 tiles x 576 = 2880
tokens at CLIP-L hidden 1024) which the projector maps into d_model.

This is the arch where the paper's technique is NATIVE: the anyres tiles
form the 2-D decision regions for mixed-resolution tokenization.
"""
from repro.models.config import (MixedResConfig, ModelConfig, VLMConfig,
                                 reduced)

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1000000.0,
    max_seq_len=131072,
    vlm=VLMConfig(n_image_tokens=2880, vision_hidden=1024),
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)
