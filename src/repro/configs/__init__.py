"""Architecture configs (one module per assigned arch + the paper's ViTDet).

``get_config(name)`` returns the full published config; ``get_reduced(name)``
returns the CPU smoke-test variant of the same family.  ``SHAPES`` defines
the assigned input-shape set; ``cells()`` enumerates the 40 (arch x shape)
dry-run cells with their per-arch applicability (long_500k only for
sub-quadratic archs, per the assignment).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig, reduced

ARCH_MODULES = {
    "mamba2-370m": "repro.configs.mamba2_370m",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3p8b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "whisper-medium": "repro.configs.whisper_medium",
    "vitdet-l": "repro.configs.vitdet_l",          # the paper's own model
}

ASSIGNED = [a for a in ARCH_MODULES if a != "vitdet-l"]


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCH_MODULES)}")
    return importlib.import_module(ARCH_MODULES[name]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(ARCH_MODULES[name])
    if hasattr(mod, "REDUCED"):
        return mod.REDUCED
    return reduced(get_config(name))


# ---------------------------------------------------------------------------
# assigned input shapes


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_runnable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runnable, reason).  long_500k needs sub-quadratic decode."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 512k-context decode is "
                       "quadratic-KV-bound; skipped per assignment")
    return True, ""


def cells() -> List[Tuple[str, str]]:
    """All 40 assigned (arch, shape) cells (including recorded skips)."""
    return [(a, s) for a in ASSIGNED for s in SHAPES]
