"""dbrx-132b [moe] — 16 experts top-4, fine-grained (hf:databricks/dbrx-base).

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.
"""
from repro.models.config import (MixedResConfig, MoEConfig, ModelConfig,
                                 reduced)

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500000.0,
    max_seq_len=32768,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752,
                  capacity_factor=1.25),
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)
