"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA (arXiv:2412.08905).

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, head_dim=128,
partial rotary factor 0.75 (phi family trait).
"""
from repro.models.config import MixedResConfig, ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    partial_rotary_factor=0.75,
    tied_embeddings=True,
    max_seq_len=131072,
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)
