"""mistral-nemo-12b [dense] — 128k ctx (hf:mistralai/Mistral-Nemo-Base-2407).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
"""
from repro.models.config import MixedResConfig, ModelConfig, reduced

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    max_seq_len=131072,
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)
