"""whisper-medium [audio] — enc-dec, conv frontend stubbed (arXiv:2212.04356).

24L decoder (+24L encoder) d_model=1024 16H d_ff=4096 vocab=51865.
Encoder consumes 1500 precomputed frame embeddings (30 s of audio after
the conv frontend, which is a stub per the assignment).  LayerNorm+GELU.
"""
from repro.models.config import (EncDecConfig, MixedResConfig, ModelConfig,
                                 reduced)

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,                  # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    norm="layernorm",
    activation="gelu",
    attention_bias=True,
    tied_embeddings=True,
    max_seq_len=32768,            # decode_32k cell; real model uses 448
    encdec=EncDecConfig(n_encoder_layers=24, encoder_seq_len=1500),
    mixed_res=MixedResConfig(enabled=True, window=10, downsample=2,
                             n_subsets=4),   # encoder frame pooling
)

REDUCED = reduced(CONFIG)
