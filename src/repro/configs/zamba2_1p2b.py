"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
(arXiv:2411.15242).

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
The shared transformer block (Zamba hallmark: one set of attention+MLP
weights reused periodically) is applied every SHARED_PERIOD mamba layers.
"""
from repro.models.config import (MixedResConfig, ModelConfig, SSMConfig,
                                 reduced)

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    tied_embeddings=True,
    max_seq_len=1048576,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=256),
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
    subquadratic=True,   # SSM decode state is O(1); shared-attn KV is sparse
)

REDUCED = reduced(CONFIG)
