"""deepseek-7b [dense] — llama-arch MHA (arXiv:2401.02954).

30L d_model=4096 32H (kv=32: full MHA) d_ff=11008 vocab=102400.
"""
from repro.models.config import MixedResConfig, ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    max_seq_len=131072,
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)
