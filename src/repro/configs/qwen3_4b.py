"""qwen3-4b [dense] — qk_norm + GQA (hf:Qwen/Qwen3-8B family).

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, head_dim=128.
"""
from repro.models.config import MixedResConfig, ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    tied_embeddings=True,
    max_seq_len=131072,
    mixed_res=MixedResConfig(enabled=True, window=8, downsample=2,
                             n_subsets=4),
)

REDUCED = reduced(CONFIG)
