"""Parameter / activation / cache PartitionSpecs for every arch family.

Layout (DESIGN.md §5):
  * ``model`` axis: Megatron-style TP — attention heads & FFN hidden,
    vocab-parallel embedding/logits, expert-parallel MoE slabs.
  * ``data`` axis: FSDP — the non-TP dim of every large matrix is sharded
    over data; XLA all-gathers per layer inside the scan body (overlapped).
  * ``pod`` axis (multi-pod): pure data parallelism; batch is sharded over
    ("pod", "data"), parameters are REPLICATED across pods so cross-pod
    traffic is gradient all-reduce only (the term gradient compression
    attacks).

Uneven dims (e.g. phi-4's 24 heads on a 16-way model axis) are handled by
GSPMD padding — divisibility is not required under pjit.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

STACK_KEYS = ("dense_blocks", "moe_blocks", "mamba_blocks", "enc_blocks",
              "dec_blocks", "blocks")


def fsdp_axis(mesh: Mesh) -> str:
    return "data"


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the global batch."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    return int(jnp.prod(jnp.array([mesh.shape[a] for a in dp_axes(mesh)])))


# ---------------------------------------------------------------------------
# parameter specs


def _leaf_spec(names: Tuple[str, ...], ndim: int, cfg: ModelConfig) -> P:
    """Spec for an UNSTACKED leaf identified by its path names."""
    nm = names[-1]
    ctx = names[:-1]
    F, D_ = "data", "model"          # fsdp axis / tensor axis shorthands

    if ndim <= 1:
        # vectors: shard hidden-sized ones over model where unambiguous
        if nm in ("conv_b",):
            return P(D_)
        return P()

    if "moe" in ctx or nm == "router" or ndim == 3:
        # MoE expert slabs (E, D, F') / (E, F', D): experts over model
        if nm == "router":
            return P(F, None)
        if nm == "w_down":
            return P(D_, None, F)
        if nm in ("w_gate", "w_up"):
            return P(D_, F, None)

    if "shared" in ctx:              # deepseek shared experts = dense TP FFN
        if nm == "w_down":
            return P(D_, F)
        return P(F, D_)

    table = {
        # embeddings
        "tok": P(D_, F),                      # vocab-parallel
        "dec_pos": P(None, F),
        "pos_emb": P(None, None, None),
        # attention (GQA)
        "w_q": P(F, D_), "w_k": P(F, D_), "w_v": P(F, D_),
        "w_o": P(D_, F),
        # MLA
        "w_dq": P(F, None), "w_uq": P(None, D_),
        "w_dkv": P(F, None), "w_uk": P(None, D_), "w_uv": P(None, D_),
        # MLP
        "w_gate": P(F, D_), "w_up": P(F, D_), "w_down": P(D_, F),
        # mamba
        "w_in": P(F, D_), "w_out": P(D_, F), "conv_w": P(None, D_),
        # heads / projectors
        "w": P(F, D_),                        # lm_head.w (D, V)
        "w1": P(None, D_), "w2": P(D_, F),
        "b": P(),
    }
    if nm in table:
        spec = table[nm]
        return spec if len(spec) == ndim else P(*([None] * ndim))
    return P(*([None] * ndim))


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def fix_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Make ``spec`` valid for ``shape``: pjit in_shardings require every
    sharded dim divisible by its axis size.  Offending axes are moved to
    another (currently unsharded, divisible) dim, else dropped."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    homeless = []
    for i, e in enumerate(entries):
        if e is not None and shape[i] % _axes_size(mesh, e):
            homeless.append(e)
            entries[i] = None
    for e in homeless:
        # prefer TRAILING dims (contiguous minor axes reshard cheaply) and
        # never dim 0 of >=4-d leaves — that is the scanned layer-stack
        # dim, and sharding it forces a full remat copy every iteration
        lo = 1 if len(shape) >= 4 else 0
        for i in reversed(range(lo, len(entries))):
            if entries[i] is None and shape[i] % _axes_size(mesh, e) == 0 \
                    and shape[i] >= _axes_size(mesh, e):
                entries[i] = e
                break
    return P(*entries)


def fix_specs(mesh: Mesh, spec_tree: Any, shape_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s, l: fix_spec(mesh, s, l.shape), spec_tree, shape_tree,
        is_leaf=lambda s: isinstance(s, P))


def param_specs(cfg: ModelConfig, params: Any,
                mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching ``params`` (works on shapes too)."""

    def walk(path, leaf):
        names = tuple(
            k.key if isinstance(k, jax.tree_util.DictKey) else str(k)
            for k in path if not isinstance(k, jax.tree_util.SequenceKey))
        shape = leaf.shape
        stacked = any(n in STACK_KEYS for n in names) and \
            cfg.family != "vit"
        ndim = len(shape) - (1 if stacked else 0)
        spec = _leaf_spec(names, ndim, cfg)
        if stacked:
            spec = P(None, *spec)
        if mesh is not None:
            spec = fix_spec(mesh, spec, shape)
        return spec

    return jax.tree_util.tree_map_with_path(walk, params)


# ---------------------------------------------------------------------------
# batch / cache specs


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: Dict[str, Any],
                shard_batch: bool = True) -> Dict[str, P]:
    dp = dp_axes(mesh)
    bspec = dp if shard_batch else None
    out = {}
    for k, v in batch.items():
        out[k] = P(bspec, *([None] * (v.ndim - 1)))
    return out


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, state: Any,
                       shard_batch: bool = True) -> Any:
    """Specs for KV caches / SSM states.

    Layout: (L, B, S, KV, Dh) GQA; (L, B, S, rank) MLA; mamba states
    (L, B, H, N, P) / conv (L, B, K-1, C).  Batch over dp axes when it is
    large enough (decode_32k), otherwise (long_500k, B=1) the SEQUENCE
    axis of attention caches is sharded over data — context-parallel
    decode — and SSM states shard their head/channel axis over model.
    """
    dp = dp_axes(mesh)
    bspec = dp if shard_batch else None
    seq_spec = None if shard_batch else "data"   # context-parallel fallback

    def walk(path, l):
        names = tuple(
            k.key if isinstance(k, jax.tree_util.DictKey) else ""
            for k in path)
        nd = l.ndim
        if "ssm" in names and nd == 5:   # (L, B, H, N, P) mamba state
            return P(None, bspec, "model", None, None)
        if "conv" in names and nd == 4:  # (L, B, K-1, C)
            return P(None, bspec, None, "model")
        if names and names[-1] in ("k", "v") and nd == 5:
            return P(None, bspec, seq_spec, "model", None)
        if names and names[-1] == "c_kv" and nd == 4:   # MLA latent
            return P(None, bspec, seq_spec, "model")
        if names and names[-1] == "k_rope" and nd == 4:
            return P(None, bspec, seq_spec, None)
        if nd == 3:                      # whisper enc_out (B, S, D)
            return P(bspec, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(walk, state)


# ---------------------------------------------------------------------------


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
